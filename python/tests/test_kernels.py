"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis is not installed in the offline sandbox, so the sweep is a
deterministic seeded grid over shapes/masks/conditioning — the same
falsification surface, replayable from the printed seed.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels.hessian import hessian_accum, M_BLOCK
from compile.kernels.obs_update import obs_update, ROW_BLOCK
from compile.kernels.ref import hessian_accum_ref, obs_update_ref


def spd_hinv(rng, c, cond=10.0):
    """A well-conditioned SPD matrix to stand in for an inverse Hessian."""
    q, _ = np.linalg.qr(rng.normal(size=(c, c)))
    eigs = np.linspace(1.0, cond, c)
    return (q * eigs) @ q.T


def rand_mask(rng, c, frac):
    mask = np.zeros(c, np.float32)
    k = max(1, int(c * frac))
    mask[rng.choice(c, size=k, replace=False)] = 1.0
    return mask


@pytest.mark.parametrize("c", [8, 16, 32, 64])
@pytest.mark.parametrize("frac", [0.1, 0.3, 0.6])
def test_obs_update_matches_ref(c, frac):
    rng = np.random.default_rng(c * 1000 + int(frac * 10))
    w = rng.normal(size=(ROW_BLOCK, c)).astype(np.float32)
    hinv = spd_hinv(rng, c).astype(np.float32)
    mask = rand_mask(rng, c, frac)
    got = np.asarray(obs_update(w, hinv, mask))
    want = np.asarray(obs_update_ref(w, hinv, mask))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_obs_update_zeroes_pruned_columns():
    rng = np.random.default_rng(7)
    c = 32
    w = rng.normal(size=(ROW_BLOCK, c)).astype(np.float32)
    hinv = spd_hinv(rng, c).astype(np.float32)
    mask = rand_mask(rng, c, 0.4)
    got = np.asarray(obs_update(w, hinv, mask))
    assert np.all(got[:, mask > 0] == 0.0)


def test_obs_update_noop_on_empty_mask():
    rng = np.random.default_rng(8)
    c = 16
    w = rng.normal(size=(ROW_BLOCK, c)).astype(np.float32)
    hinv = spd_hinv(rng, c).astype(np.float32)
    got = np.asarray(obs_update(w, hinv, np.zeros(c, np.float32)))
    np.testing.assert_allclose(got, w, rtol=1e-6, atol=1e-6)


def test_obs_update_reduces_reconstruction_error():
    """The whole point of OBSPA vs naive zeroing: ‖WX − ŴX‖ must shrink.

    As in SparseGPT, the sweep matrix is the *upper Cholesky factor* of
    H⁻¹ (H⁻¹ = UᵀU), which carries the conditional inverse Hessians of
    the shrinking column suffix. Calibration features are correlated
    (low-rank + noise) — the regime where compensation actually helps.
    """
    rng = np.random.default_rng(9)
    c, m = 32, 256
    z = rng.normal(size=(8, m))
    a = rng.normal(size=(c, 8))
    x = (a @ z + 0.1 * rng.normal(size=(c, m))).astype(np.float32)
    w = rng.normal(size=(ROW_BLOCK, c)).astype(np.float32)
    h = x @ x.T + 0.01 * np.eye(c, dtype=np.float32)
    hinv = np.linalg.inv(h)
    u = np.linalg.cholesky(hinv).T.astype(np.float32)  # H⁻¹ = UᵀU
    mask = rand_mask(rng, c, 0.3)
    w_obs = np.asarray(obs_update(w, u, mask))
    w_zero = w * (1.0 - mask)[None, :]
    err_obs = np.linalg.norm(w @ x - w_obs @ x)
    err_zero = np.linalg.norm(w @ x - w_zero @ x)
    assert err_obs < err_zero * 0.85, (err_obs, err_zero)


def test_obs_update_rows_independent():
    """Row blocks can be processed independently (padding correctness)."""
    rng = np.random.default_rng(10)
    c = 16
    w = rng.normal(size=(ROW_BLOCK, c)).astype(np.float32)
    hinv = spd_hinv(rng, c).astype(np.float32)
    mask = rand_mask(rng, c, 0.5)
    full = np.asarray(obs_update(w, hinv, mask))
    # zero-pad extra rows: result on original rows unchanged
    w_pad = np.concatenate([w, np.zeros_like(w)], axis=0)
    padded = np.asarray(obs_update(w_pad, hinv, mask))
    np.testing.assert_allclose(padded[:ROW_BLOCK], full, rtol=1e-5, atol=1e-5)
    assert np.all(padded[ROW_BLOCK:][:, mask == 0] == 0.0)


def test_obs_update_column_padding_exact():
    """Identity-padding Hinv + zero-padding W on unused columns is exact —
    the property the Rust runtime's canonical-shape ladder relies on."""
    rng = np.random.default_rng(11)
    c, cpad = 24, 32
    w = rng.normal(size=(ROW_BLOCK, c)).astype(np.float32)
    hinv = spd_hinv(rng, c).astype(np.float32)
    mask = rand_mask(rng, c, 0.3)
    want = np.asarray(obs_update_ref(w, hinv, mask))
    wp = np.zeros((ROW_BLOCK, cpad), np.float32)
    wp[:, :c] = w
    hp = np.eye(cpad, dtype=np.float32)
    hp[:c, :c] = hinv
    mp = np.zeros(cpad, np.float32)
    mp[:c] = mask
    got = np.asarray(obs_update(wp, hp, mp))
    np.testing.assert_allclose(got[:, :c], want, rtol=2e-4, atol=2e-4)
    assert np.all(got[:, c:] == 0.0)


@pytest.mark.parametrize("c", [16, 64, 128])
def test_hessian_accum_matches_ref(c):
    rng = np.random.default_rng(c)
    h = rng.normal(size=(c, c)).astype(np.float32)
    h = (h + h.T) / 2
    x = rng.normal(size=(c, M_BLOCK)).astype(np.float32)
    got = np.asarray(hessian_accum(h, x))
    want = np.asarray(hessian_accum_ref(jnp.asarray(h), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_hessian_accum_symmetry():
    rng = np.random.default_rng(3)
    c = 32
    x = rng.normal(size=(c, M_BLOCK)).astype(np.float32)
    got = np.asarray(hessian_accum(np.zeros((c, c), np.float32), x))
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-5)
    # PSD: all eigenvalues >= 0 (tolerance for fp)
    eigs = np.linalg.eigvalsh(got)
    assert eigs.min() > -1e-3
