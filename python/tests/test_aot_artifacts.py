"""AOT artifact pipeline checks: the manifest and lowered HLO must stay
consistent with what the Rust runtime expects (canonical ladder, row/M
blocks, artifact naming)."""

import json
import os

import pytest

from compile.aot import COL_LADDER, MODEL_SHAPES, lower_obs_update
from compile.kernels.obs_update import ROW_BLOCK
from compile.kernels.hessian import M_BLOCK

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_ladder_is_sorted_and_covers_zoo():
    assert COL_LADDER == sorted(COL_LADDER)
    # the scaled-down zoo's largest im2col width: 128 ch * 3*3 = fits 512?
    # mini models cap at 64 input channels with 3x3 kernels → 576 would
    # overflow, but grouped layers divide; assert the documented cap
    assert COL_LADDER[-1] == 512


def test_manifest_matches_constants():
    m = manifest()
    assert m["format"] == "spa-artifacts-v1"
    assert m["row_block"] == ROW_BLOCK
    assert m["m_block"] == M_BLOCK
    assert m["col_ladder"] == COL_LADDER
    assert m["model_shapes"] == MODEL_SHAPES


def test_all_artifacts_exist_and_parse_as_hlo():
    m = manifest()
    assert len(m["artifacts"]) == 1 + 2 * len(COL_LADDER)
    for name in m["artifacts"]:
        path = os.path.join(ART_DIR, name)
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_obs_update_hlo_has_expected_params():
    text = lower_obs_update(COL_LADDER[0])
    # three parameters: w, hinv/sweep, mask
    assert text.count("parameter(0)") >= 1
    assert text.count("parameter(1)") >= 1
    assert text.count("parameter(2)") >= 1
    # column sweep loops inside the module
    assert "while" in text


def test_no_lapack_or_mosaic_custom_calls():
    """xla_extension 0.5.1 cannot run jax>=0.5 FFI custom calls; the
    artifacts must not contain any (DESIGN.md: Hessian inversion is done
    natively in Rust for exactly this reason)."""
    m = manifest()
    for name in m["artifacts"]:
        with open(os.path.join(ART_DIR, name)) as f:
            text = f.read()
        low = text.lower()
        assert "lapack" not in low, name
        assert "mosaic" not in low, name
