"""L2 model graph: shape checks and oracle parity before AOT lowering."""

import numpy as np

from compile import model
from compile.kernels.ref import model_fwd_ref
from compile.aot import MODEL_SHAPES, lower_model_fwd, lower_obs_update, lower_hessian


def _params(rng):
    s = MODEL_SHAPES
    x = rng.normal(size=(s["batch"], s["cin"], s["hw"], s["hw"])).astype(np.float32)
    w = (rng.normal(size=(s["cout"], s["cin"], 3, 3)) * 0.2).astype(np.float32)
    b = rng.normal(size=(s["cout"],)).astype(np.float32) * 0.1
    wf = (rng.normal(size=(s["classes"], s["cout"])) * 0.2).astype(np.float32)
    bf = np.zeros((s["classes"],), np.float32)
    return x, w, b, wf, bf


def test_model_fwd_shapes_and_ref():
    rng = np.random.default_rng(1)
    x, w, b, wf, bf = _params(rng)
    (out,) = model.model_fwd(x, w, b, wf, bf)
    assert out.shape == (MODEL_SHAPES["batch"], MODEL_SHAPES["classes"])
    want = np.asarray(model_fwd_ref(x, w, b, wf, bf))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)


def test_model_fwd_lowers_to_hlo_text():
    text = lower_model_fwd()
    assert "HloModule" in text
    assert "convolution" in text


def test_obs_update_lowers_without_custom_calls():
    # interpret=True must lower to plain HLO the CPU PJRT client can run —
    # no Mosaic custom-call may appear.
    text = lower_obs_update(32)
    assert "HloModule" in text
    assert "mosaic" not in text.lower()


def test_hessian_lowers_without_custom_calls():
    text = lower_hessian(32)
    assert "HloModule" in text
    assert "mosaic" not in text.lower()
