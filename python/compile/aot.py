"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (canonical-shape ladder, DESIGN.md):
  model_fwd.hlo.txt            — reference CNN forward (parity check)
  obs_update_c{C}.hlo.txt      — OBSPA column update, W [128, C]
  hessian_c{C}.hlo.txt         — Hessian accumulation, X [C, 128]
  manifest.json                — shapes per artifact, read by Rust

Run via `make artifacts`; a stamp check makes it a no-op when inputs
are unchanged. Python never runs after this step.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.obs_update import ROW_BLOCK
from .kernels.hessian import M_BLOCK
from . import model

# Canonical column-count ladder: layers pad their GEMM/im2col width to
# the next rung. Covers every layer in the scaled-down zoo.
COL_LADDER = [32, 64, 128, 256, 512]

# Reference model shapes (must match rust/tests/pjrt_parity.rs).
MODEL_SHAPES = dict(batch=4, cin=3, hw=8, cout=8, classes=10)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model_fwd():
    s = MODEL_SHAPES
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((s["batch"], s["cin"], s["hw"], s["hw"]), f32),
        jax.ShapeDtypeStruct((s["cout"], s["cin"], 3, 3), f32),
        jax.ShapeDtypeStruct((s["cout"],), f32),
        jax.ShapeDtypeStruct((s["classes"], s["cout"]), f32),
        jax.ShapeDtypeStruct((s["classes"],), f32),
    )
    return to_hlo_text(jax.jit(model.model_fwd).lower(*args))


def lower_obs_update(c: int):
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((ROW_BLOCK, c), f32),
        jax.ShapeDtypeStruct((c, c), f32),
        jax.ShapeDtypeStruct((c,), f32),
    )
    return to_hlo_text(jax.jit(model.obs_update_graph).lower(*args))


def lower_hessian(c: int):
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((c, c), f32),
        jax.ShapeDtypeStruct((c, M_BLOCK), f32),
    )
    return to_hlo_text(jax.jit(model.hessian_graph).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "format": "spa-artifacts-v1",
        "row_block": ROW_BLOCK,
        "m_block": M_BLOCK,
        "col_ladder": COL_LADDER,
        "model_shapes": MODEL_SHAPES,
        "artifacts": [],
    }

    def emit(name: str, text: str):
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(name)
        print(f"  wrote {name} ({len(text)} chars)", file=sys.stderr)

    print("lowering model_fwd ...", file=sys.stderr)
    emit("model_fwd.hlo.txt", lower_model_fwd())
    for c in COL_LADDER:
        print(f"lowering obs_update c={c} ...", file=sys.stderr)
        emit(f"obs_update_c{c}.hlo.txt", lower_obs_update(c))
        print(f"lowering hessian c={c} ...", file=sys.stderr)
        emit(f"hessian_c{c}.hlo.txt", lower_hessian(c))

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts", file=sys.stderr)


if __name__ == "__main__":
    main()
