"""Layer-2 JAX model: the reference CNN whose AOT artifact the Rust
runtime executes for the engine-vs-PJRT numeric parity check, plus the
OBSPA compute graphs composed from the Layer-1 Pallas kernels.

Parameters are *arguments* (not constants), so one artifact serves any
weight values the Rust side feeds.
"""

import jax
import jax.numpy as jnp

from .kernels.hessian import hessian_accum
from .kernels.obs_update import obs_update


def model_fwd(x, w, b, wf, bf):
    """conv3x3(pad1) + bias → relu → global mean pool → dense.

    Matches `spa::zoo`-style semantics (NCHW, OIHW) so the Rust engine
    can execute the same graph natively and compare numerics.
    """
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ) + b[None, :, None, None]
    y = jnp.maximum(y, 0.0)
    pooled = y.mean(axis=(2, 3))
    return (pooled @ wf.T + bf,)


def obs_update_graph(w, hinv, mask):
    """The OBSPA reconstruction step (wraps the Pallas kernel)."""
    return (obs_update(w, hinv, mask),)


def hessian_graph(h, x):
    """One calibration-block Hessian accumulation (wraps the kernel)."""
    return (hessian_accum(h, x),)
