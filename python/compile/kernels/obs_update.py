"""Layer-1 Pallas kernel: OBSPA structured column update.

The compute hot-spot of the paper's train-prune contribution (App. A.6):
a SparseGPT-style sweep that zeroes whole pruned columns of a weight
block and redistributes their contribution onto surviving columns via
the inverse Hessian.

TPU mapping (DESIGN.md §Hardware-Adaptation): rows are blocked at
ROW_BLOCK=128 (one MXU lane tile); the sequential column sweep runs
*inside* the kernel as a `fori_loop`, so the W tile stays resident in
VMEM for the entire sweep — one HBM round-trip per tile instead of one
per column. The rank-1 update `err ⊗ hinv_row` is an outer product the
MXU executes directly. VMEM footprint at C=256: 128×256 f32 W tile
(128 KiB) + 256×256 Hinv (256 KiB) ≪ 16 MiB.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom calls, so the kernel lowers to plain HLO (see /opt/xla-example
README); on a real TPU the same BlockSpec schedule compiles natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One MXU lane tile of rows per grid step.
ROW_BLOCK = 128


def _obs_update_kernel(w_ref, hinv_ref, mask_ref, out_ref):
    """Sweep all columns of one [ROW_BLOCK, C] weight tile."""
    c = w_ref.shape[1]
    cols = jax.lax.iota(jnp.int32, c)

    def body(i, w):
        pruned = mask_ref[i]
        hii = hinv_ref[i, i]
        err = pruned * w[:, i] / hii          # [R]
        hrow = hinv_ref[i, :]                 # [C]
        tail = (cols >= i).astype(w.dtype)    # only j >= i updated
        w = w - jnp.outer(err, hrow * tail)   # rank-1 MXU update
        # zero the pruned column exactly
        keep = jnp.where((cols == i) & (pruned > 0), 0.0, 1.0)
        return w * keep[None, :]

    out_ref[...] = jax.lax.fori_loop(0, c, body, w_ref[...])


@functools.partial(jax.jit, static_argnames=())
def obs_update(w, hinv, mask):
    """Structured OBS update of a weight block.

    Args:
      w:    [R, C] float32, R a multiple of ROW_BLOCK (pad with zero rows).
      hinv: [C, C] float32 — as in SparseGPT, the *upper Cholesky factor*
            U of the inverse Hessian (H⁻¹ = UᵀU); its rows carry the
            conditional inverse Hessians of each column suffix. Passing a
            dense symmetric matrix also works (the sweep only reads row
            suffixes) but compensates less accurately.
      mask: [C] float32, 1.0 = prune this column.
    """
    r, c = w.shape
    grid = (r // ROW_BLOCK,)
    return pl.pallas_call(
        _obs_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, c), lambda i: (i, 0)),
            pl.BlockSpec((c, c), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(w, hinv, mask)
