"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the pytest suite checks `obs_update.py` /
`hessian.py` against, and they mirror the Rust-native fallback in
`rust/src/obspa/solver.rs` (a cargo test cross-checks the Rust fallback
against values generated from these formulas).

The structured column update is OBSPA's core reconstruction (paper App.
A.6, Eqs. 13-14): for every pruned column i, in ascending order,

    err        = W[:, i] / Hinv[i, i]
    W[:, i:]  -= err * Hinv[i, i:]
    W[:, i]    = 0
"""

import jax.numpy as jnp


def obs_update_ref(w, hinv, mask):
    """Structured SparseGPT-style update.

    Args:
      w:    [R, C] weight block (rows independent).
      hinv: [C, C] inverse Hessian of the layer inputs.
      mask: [C] float, 1.0 where the column is pruned.

    Returns:
      [R, C] updated weights with pruned columns zeroed and surviving
      columns compensated.
    """
    w = jnp.asarray(w, jnp.float32)
    c = w.shape[1]
    for i in range(c):
        pruned = mask[i]
        err = pruned * w[:, i] / hinv[i, i]
        # only columns j >= i are updated (column-ascending sweep)
        tail = jnp.arange(c) >= i
        w = w - jnp.outer(err, hinv[i, :] * tail)
        # explicitly zero the pruned column (numerical exactness)
        w = w.at[:, i].set(jnp.where(pruned > 0, 0.0, w[:, i]))
    return w


def hessian_accum_ref(h, x):
    """H + X @ X.T for a calibration block X of shape [C, M]."""
    return h + x @ x.T


def model_fwd_ref(x, w, b, wf, bf):
    """Reference CNN forward used for the engine-vs-PJRT parity check.

    conv3x3(pad 1, NCHW) + bias -> relu -> global mean pool -> dense.
    """
    import jax

    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ) + b[None, :, None, None]
    y = jnp.maximum(y, 0.0)
    pooled = y.mean(axis=(2, 3))
    return pooled @ wf.T + bf
