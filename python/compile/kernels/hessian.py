"""Layer-1 Pallas kernel: blocked Hessian accumulation H += X Xᵀ.

OBSPA derives each layer's Hessian from calibration activations
(H = X Xᵀ + λI, paper Eq. 12 discussion). Calibration batches stream
through in M-blocks; this kernel accumulates one block's Gram matrix
into the running Hessian.

TPU mapping: a (C, C) output tile with (C, MB) X panels — a plain matmul
the MXU is built for; C ≤ 512 keeps X panel + H tile well under VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Calibration columns consumed per call.
M_BLOCK = 128


def _hessian_kernel(h_ref, x_ref, out_ref):
    x = x_ref[...]
    out_ref[...] = h_ref[...] + jnp.dot(
        x, x.T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def hessian_accum(h, x):
    """Return H + X @ X.T for X of shape [C, M_BLOCK]."""
    c = h.shape[0]
    return pl.pallas_call(
        _hessian_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((c, c), lambda i: (0, 0)),
            pl.BlockSpec((c, x.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((c, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, c), jnp.float32),
        interpret=True,
    )(h, x)
