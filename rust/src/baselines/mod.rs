//! Comparator baselines the paper evaluates against.
//!
//! * [`dfpc_prune`] — a DFPC-style (Narshana et al. 2023) data-free,
//!   one-shot coupled-channel pruner: saliency is weight magnitude scaled
//!   by the absorbing BatchNorm's |γ|/√(σ²+ε) (the data-flow signal DFPC
//!   derives from its coupling analysis), with **no** weight
//!   reconstruction and no BN recalibration. This is the Tab. 4/9/10/13
//!   comparator.
//! * [`ungrouped_select`] — classic per-layer structured scoring
//!   (`Scope::SourceOnly`): the "L1 / SNAP / structured-CroP/GraSP"
//!   column of Figs. 3 and 9, sharing SPA's coupling machinery but not
//!   its grouped score aggregation.

use crate::criteria;
use crate::ir::{DataId, Graph, OpKind};
use crate::prune::{score_groups_scoped, Agg, GroupScore, Groups, Norm, Scope};
use crate::session::{Session, Target};
use crate::tensor::Tensor;
use std::collections::HashMap;

/// DFPC-style data-free saliency: |W| with out-channel slices scaled by
/// the immediately-following BN's channel gain.
pub fn dfpc_scores(g: &Graph) -> HashMap<DataId, Tensor> {
    let mut scores: HashMap<DataId, Tensor> = HashMap::new();
    for pid in g.param_ids() {
        scores.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
    }
    for op in &g.ops {
        if !matches!(op.kind, OpKind::Conv2d { .. } | OpKind::Gemm) {
            continue;
        }
        // find a BN directly consuming this op's output
        let out = op.outputs[0];
        let bn = g
            .data(out)
            .consumers
            .iter()
            .map(|&c| g.op(c))
            .find(|o| matches!(o.kind, OpKind::BatchNorm { .. }));
        let Some(bn) = bn else { continue };
        let eps = match bn.kind {
            OpKind::BatchNorm { eps } => eps,
            _ => unreachable!(),
        };
        let gamma = g.data(bn.inputs[1]).param().unwrap();
        let var = g.data(bn.inputs[4]).param().unwrap();
        let wid = op.inputs[1];
        let s = scores.get_mut(&wid).unwrap();
        let co = s.shape[0];
        let inner: usize = s.shape[1..].iter().product();
        for c in 0..co {
            let gain = gamma.data[c].abs() / (var.data[c] + eps).sqrt();
            for v in &mut s.data[c * inner..(c + 1) * inner] {
                *v *= gain;
            }
        }
    }
    scores
}

/// Report from a DFPC-style run.
#[derive(Debug, Clone)]
pub struct DfpcReport {
    pub ccs_removed: usize,
    pub seconds: f64,
}

/// One-shot data-free coupled-channel pruning to a FLOPs target.
pub fn dfpc_prune(g: &mut Graph, target_rf: f64, min_keep: usize) -> anyhow::Result<DfpcReport> {
    let t0 = std::time::Instant::now();
    let pruned = Session::on(&*g)
        .criterion(criteria::precomputed("dfpc", dfpc_scores(g)))
        .min_keep(min_keep)
        .target(Target::FlopsRf(target_rf))
        .plan()?
        .apply()?;
    *g = pruned.graph;
    Ok(DfpcReport {
        ccs_removed: pruned.report.ccs_removed,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Ungrouped ("structured") selection: identical pipeline but scores come
/// only from the source layer's own filters.
pub fn ungrouped_select(
    g: &Graph,
    groups: &Groups,
    param_scores: &HashMap<DataId, Tensor>,
    agg: Agg,
    norm: Norm,
) -> Vec<GroupScore> {
    score_groups_scoped(g, groups, param_scores, agg, norm, Scope::SourceOnly)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis;
    use crate::prune::build_groups;
    use crate::zoo::{self, ImageCfg};

    #[test]
    fn dfpc_prunes_to_target() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::resnet18(cfg, 5);
        let before = g.clone();
        let rep = dfpc_prune(&mut g, 1.5, 1).unwrap();
        assert!(rep.ccs_removed > 0);
        let r = analysis::reduction(&before, &g);
        assert!(r.rf >= 1.5, "rf {}", r.rf);
        g.validate().unwrap();
    }

    #[test]
    fn dfpc_scores_respond_to_bn_gamma() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::resnet18(cfg, 6);
        // zero one BN gamma channel: its conv filter's score collapses
        let gamma_id = g.data_by_name("stem.bn.gamma").unwrap().id;
        g.datas[gamma_id].param_mut().unwrap().data[3] = 0.0;
        let scores = dfpc_scores(&g);
        let w = g.data_by_name("stem.conv.w").unwrap();
        let s = &scores[&w.id];
        let inner: usize = w.shape[1..].iter().product();
        let ch3: f32 = s.data[3 * inner..4 * inner].iter().sum();
        assert_eq!(ch3, 0.0, "zero-gamma channel must have zero saliency");
        let ch0: f32 = s.data[..inner].iter().sum();
        assert!(ch0 > 0.0);
    }

    #[test]
    fn ungrouped_differs_from_grouped() {
        use crate::prune::score_groups;
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let g = zoo::resnet18(cfg, 7);
        let groups = build_groups(&g).unwrap();
        let mut l1 = HashMap::new();
        for pid in g.param_ids() {
            l1.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
        }
        let grouped = score_groups(&g, &groups, &l1, Agg::Sum, Norm::Mean);
        let ungrouped = ungrouped_select(&g, &groups, &l1, Agg::Sum, Norm::Mean);
        assert_eq!(grouped.len(), ungrouped.len());
        // rankings should differ somewhere (grouped sees coupled weights)
        let differs = grouped
            .iter()
            .zip(&ungrouped)
            .any(|(a, b)| (a.score - b.score).abs() > 1e-9);
        assert!(differs);
    }
}
