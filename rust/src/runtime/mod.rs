//! PJRT runtime — loads and executes the AOT artifacts produced by
//! `python/compile/aot.py` (`make artifacts`).
//!
//! Python runs exactly once at build time; this module is the only
//! bridge: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`. Executables are compiled lazily and cached per
//! artifact. Kernel entry points pad operands to the canonical-shape
//! ladder the artifacts were lowered at (zero-pad `W`/`X`, identity-pad
//! Cholesky factors), which the L1 test-suite proves exact.
//!
//! The PJRT path is gated behind the `pjrt` cargo feature: artifact
//! execution needs the Python-side AOT step that hermetic CI does not
//! run. Without the feature, [`Runtime::global`] is always `None` and
//! every kernel entry point takes its bit-exact native fallback.

pub mod kernels;

/// Column-ladder the artifacts are lowered at (mirrors aot.py COL_LADDER).
pub const COL_LADDER: [usize; 5] = [32, 64, 128, 256, 512];
/// Row block of the obs_update kernel (mirrors obs_update.ROW_BLOCK).
pub const ROW_BLOCK: usize = 128;
/// Calibration block of the hessian kernel (mirrors hessian.M_BLOCK).
pub const M_BLOCK: usize = 128;

#[cfg(feature = "pjrt")]
mod pjrt_rt {
    use crate::tensor::Tensor;
    use crate::util::parse_json;
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;
    use std::sync::Mutex;

    /// Artifact-backed PJRT executor.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: Mutex<HashMap<String, &'static xla::PjRtLoadedExecutable>>,
    }

    thread_local! {
        // PJRT client handles are Rc-based (not Send/Sync); keep one runtime
        // per thread. Compiled-executable caches are therefore per-thread too.
        static RUNTIME: RefCell<Option<Option<Rc<Runtime>>>> = const { RefCell::new(None) };
    }

    impl Runtime {
        /// Create a runtime reading artifacts from `dir`.
        pub fn new(dir: &Path) -> anyhow::Result<Runtime> {
            anyhow::ensure!(
                dir.join("manifest.json").exists(),
                "no artifact manifest in {} — run `make artifacts`",
                dir.display()
            );
            let manifest = parse_json(&std::fs::read_to_string(dir.join("manifest.json"))?)?;
            anyhow::ensure!(
                manifest.field("format")?.as_str() == Some("spa-artifacts-v1"),
                "unknown artifact manifest format"
            );
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt: {e}"))?;
            Ok(Runtime {
                client,
                dir: dir.to_path_buf(),
                cache: Mutex::new(HashMap::new()),
            })
        }

        /// The per-thread runtime, if artifacts are available. Looks in
        /// `$SPA_ARTIFACTS` then `./artifacts`. Returns `None` when artifacts
        /// were never built (callers fall back to native kernels).
        pub fn global() -> Option<Rc<Runtime>> {
            RUNTIME.with(|r| {
                let mut slot = r.borrow_mut();
                if slot.is_none() {
                    let dir = std::env::var("SPA_ARTIFACTS")
                        .map(PathBuf::from)
                        .unwrap_or_else(|_| PathBuf::from("artifacts"));
                    *slot = Some(Runtime::new(&dir).ok().map(Rc::new));
                }
                slot.as_ref().unwrap().clone()
            })
        }

        /// Compile (or fetch the cached) executable for an artifact.
        fn executable(&self, name: &str) -> anyhow::Result<&'static xla::PjRtLoadedExecutable> {
            let mut cache = self.cache.lock().unwrap();
            if let Some(e) = cache.get(name) {
                return Ok(e);
            }
            let path = self.dir.join(format!("{name}.hlo.txt"));
            anyhow::ensure!(path.exists(), "missing artifact {}", path.display());
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow::anyhow!("hlo parse {name}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
            // Executables live for the process lifetime; leak to get 'static
            // references the cache can hand out without lifetime gymnastics.
            let leaked: &'static xla::PjRtLoadedExecutable = Box::leak(Box::new(exe));
            cache.insert(name.to_string(), leaked);
            Ok(leaked)
        }

        /// Execute an artifact on f32 tensors, returning the tuple elements.
        pub fn execute(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
            let exe = self.executable(name)?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data)
                        .reshape(&dims)
                        .map_err(|e| anyhow::anyhow!("literal: {e}"))
                })
                .collect::<anyhow::Result<_>>()?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch {name}: {e}"))?;
            // aot.py lowers with return_tuple=True → unwrap the tuple
            let elems = result
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("tuple {name}: {e}"))?;
            let mut outs = Vec::new();
            for elem in elems {
                let dims: Vec<usize> = elem
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("shape: {e}"))?
                    .dims()
                    .iter()
                    .map(|&d| d as usize)
                    .collect();
                let data = elem
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
                outs.push(Tensor::new(dims, data));
            }
            Ok(outs)
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_rt {
    use crate::tensor::Tensor;
    use std::path::Path;
    use std::rc::Rc;

    /// Stub executor used when the `pjrt` feature is disabled: artifacts
    /// are never available, so [`Runtime::global`] is always `None` and
    /// kernels use their native fallbacks.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn new(_dir: &Path) -> anyhow::Result<Runtime> {
            anyhow::bail!("PJRT runtime disabled (build with `--features pjrt`)")
        }

        pub fn global() -> Option<Rc<Runtime>> {
            None
        }

        pub fn execute(&self, name: &str, _inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
            anyhow::bail!("PJRT runtime disabled, cannot execute artifact `{name}`")
        }

        pub fn platform(&self) -> String {
            "disabled".to_string()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_rt::Runtime;
#[cfg(not(feature = "pjrt"))]
pub use stub_rt::Runtime;

/// Round a column count up to the canonical ladder.
pub fn ladder_cols(c: usize) -> anyhow::Result<usize> {
    COL_LADDER
        .iter()
        .copied()
        .find(|&l| l >= c)
        .ok_or_else(|| anyhow::anyhow!("column count {c} exceeds ladder max 512"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_rounds_up() {
        assert_eq!(ladder_cols(1).unwrap(), 32);
        assert_eq!(ladder_cols(32).unwrap(), 32);
        assert_eq!(ladder_cols(33).unwrap(), 64);
        assert_eq!(ladder_cols(512).unwrap(), 512);
        assert!(ladder_cols(513).is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn global_runtime_loads_when_artifacts_exist() {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let rt = Runtime::global().expect("artifacts exist but runtime failed");
            assert!(rt.platform().to_lowercase().contains("cpu"));
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_is_absent() {
        assert!(Runtime::global().is_none());
        assert!(Runtime::new(std::path::Path::new("artifacts")).is_err());
    }
}
