//! Kernel entry points: the OBSPA hot path, executed through the PJRT
//! artifacts (Pallas-lowered) with a bit-exact Rust-native fallback.
//!
//! The fallback exists so `cargo test` passes without `make artifacts`
//! and so the PJRT path can be cross-checked against it (see
//! `rust/tests/pjrt_parity.rs`). Padding to the canonical ladder is
//! exact: zero rows are independent, zero columns with identity-padded
//! sweep matrix produce zero error terms (proved in the L1 pytest
//! suite, `test_obs_update_column_padding_exact`).

use super::{ladder_cols, Runtime, M_BLOCK, ROW_BLOCK};
use crate::tensor::Tensor;
use crate::util::par;

/// Which executor ran a kernel (reported by benches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Native,
}

/// Below this many f32 ops the native kernels stay single-threaded.
const PAR_KERNEL_MIN_OPS: usize = 64 * 1024;

/// Structured OBS column sweep (native reference, mirrors
/// `python/compile/kernels/ref.py::obs_update_ref`).
///
/// The column order is sequential (each pruned column's error term feeds
/// later columns), but rows are fully independent: every row reads and
/// writes only its own `out` slice plus the shared read-only sweep
/// matrix. Rows therefore fan out across the `util::par` pool in fixed
/// bands, each band running the identical column sweep — bit-identical
/// to the serial path at any `SPA_THREADS`.
pub fn obs_update_native(w: &Tensor, sweep: &Tensor, mask: &[f32]) -> Tensor {
    let (r, c) = (w.shape[0], w.shape[1]);
    assert_eq!(sweep.shape, vec![c, c]);
    assert_eq!(mask.len(), c);
    let mut out = w.clone();
    if c == 0 {
        return out;
    }
    let threads = par::max_threads();
    if threads > 1 && r * c * c >= PAR_KERNEL_MIN_OPS {
        // Band size affects scheduling only (rows are self-contained),
        // so shrinking below ROW_BLOCK for small r keeps bit-identity.
        let band = ROW_BLOCK.min(r.div_ceil(threads)).max(1);
        par::par_chunks_mut(&mut out.data, band * c, |_, rows| {
            obs_sweep_rows(rows, sweep, mask, c);
        });
    } else {
        obs_sweep_rows(&mut out.data, sweep, mask, c);
    }
    out
}

/// The serial column sweep over one band of rows.
fn obs_sweep_rows(rows: &mut [f32], sweep: &Tensor, mask: &[f32], c: usize) {
    let r = rows.len() / c;
    for i in 0..c {
        if mask[i] <= 0.0 {
            continue;
        }
        let hii = sweep.data[i * c + i];
        for row in 0..r {
            let err = rows[row * c + i] / hii;
            if err == 0.0 {
                continue;
            }
            let base = row * c;
            for j in i..c {
                rows[base + j] -= err * sweep.data[i * c + j];
            }
        }
        for row in 0..r {
            rows[row * c + i] = 0.0;
        }
    }
}

/// Hessian accumulation H + X·Xᵀ (native reference).
///
/// The upper-triangle dot products `acc[i][j] = Σ_k x[i,k]·x[j,k]` are
/// computed into a scratch matrix whose rows fan out across the pool
/// (row `i` owns `acc[i][i..]`); a serial pass then adds each exact
/// `acc` into both mirror positions — the same arithmetic as the fully
/// serial kernel, so results are bit-identical at any `SPA_THREADS`.
pub fn hessian_accum_native(h: &Tensor, x: &Tensor) -> Tensor {
    let c = h.shape[0];
    let m = x.shape[1];
    assert_eq!(x.shape[0], c);
    let mut out = h.clone();
    if c == 0 {
        return out;
    }
    let mut accs = vec![0.0f32; c * c];
    let accum_row = |i: usize, row: &mut [f32]| {
        for j in i..c {
            let mut acc = 0.0f32;
            let (ri, rj) = (&x.data[i * m..(i + 1) * m], &x.data[j * m..(j + 1) * m]);
            for k in 0..m {
                acc += ri[k] * rj[k];
            }
            row[j] = acc;
        }
    };
    if c * c * m / 2 >= PAR_KERNEL_MIN_OPS && par::workers_for(c) > 1 {
        par::par_chunks_mut(&mut accs, c, |i, row| accum_row(i, row));
    } else {
        for (i, row) in accs.chunks_mut(c).enumerate() {
            accum_row(i, row);
        }
    }
    for i in 0..c {
        for j in i..c {
            let acc = accs[i * c + j];
            out.data[i * c + j] += acc;
            if i != j {
                out.data[j * c + i] += acc;
            }
        }
    }
    out
}

/// OBSPA structured update of a full weight matrix `w` [R, C] using the
/// sweep matrix (upper Cholesky factor of H⁻¹) and a column prune mask.
/// Uses the PJRT Pallas artifact when available, padding rows to
/// ROW_BLOCK multiples and columns to the canonical ladder.
pub fn obs_update(w: &Tensor, sweep: &Tensor, mask: &[f32]) -> anyhow::Result<(Tensor, Backend)> {
    let (r, c) = (w.shape[0], w.shape[1]);
    let Some(rt) = Runtime::global() else {
        return Ok((obs_update_native(w, sweep, mask), Backend::Native));
    };
    let cpad = match ladder_cols(c) {
        Ok(c) => c,
        Err(_) => return Ok((obs_update_native(w, sweep, mask), Backend::Native)),
    };
    // sweep: identity-pad to the ladder; mask: zero-pad
    let mut sp = Tensor::zeros(&[cpad, cpad]);
    for i in 0..cpad {
        sp.data[i * cpad + i] = 1.0;
    }
    for i in 0..c {
        sp.data[i * cpad..i * cpad + c].copy_from_slice(&sweep.data[i * c..(i + 1) * c]);
    }
    let mut mp = Tensor::zeros(&[cpad]);
    mp.data[..c].copy_from_slice(mask);
    // The artifact is lowered at exactly [ROW_BLOCK, cpad]; rows are
    // independent, so stream W in zero-padded ROW_BLOCK chunks.
    let name = format!("obs_update_c{cpad}");
    let mut out = Tensor::zeros(&[r, c]);
    let mut row = 0usize;
    while row < r {
        let take = ROW_BLOCK.min(r - row);
        let mut wp = Tensor::zeros(&[ROW_BLOCK, cpad]);
        for i in 0..take {
            wp.data[i * cpad..i * cpad + c]
                .copy_from_slice(&w.data[(row + i) * c..(row + i + 1) * c]);
        }
        let outs = rt.execute(&name, &[&wp, &sp, &mp])?;
        let blk = &outs[0];
        for i in 0..take {
            out.data[(row + i) * c..(row + i + 1) * c]
                .copy_from_slice(&blk.data[i * cpad..i * cpad + c]);
        }
        row += take;
    }
    Ok((out, Backend::Pjrt))
}

/// Accumulate a calibration block into a Hessian: H += X·Xᵀ where X is
/// [C, M]. PJRT path pads C to the ladder and M to M_BLOCK multiples.
pub fn hessian_accum(h: &Tensor, x: &Tensor) -> anyhow::Result<(Tensor, Backend)> {
    let c = h.shape[0];
    let m = x.shape[1];
    let Some(rt) = Runtime::global() else {
        return Ok((hessian_accum_native(h, x), Backend::Native));
    };
    let cpad = match ladder_cols(c) {
        Ok(c) => c,
        Err(_) => return Ok((hessian_accum_native(h, x), Backend::Native)),
    };
    let mut hp = Tensor::zeros(&[cpad, cpad]);
    for i in 0..c {
        hp.data[i * cpad..i * cpad + c].copy_from_slice(&h.data[i * c..(i + 1) * c]);
    }
    // stream X in M_BLOCK chunks (zero-pad the tail — zero columns add 0)
    let blocks = m.div_ceil(M_BLOCK);
    for b in 0..blocks {
        let mut xb = Tensor::zeros(&[cpad, M_BLOCK]);
        let lo = b * M_BLOCK;
        let hi = (lo + M_BLOCK).min(m);
        for i in 0..c {
            xb.data[i * M_BLOCK..i * M_BLOCK + (hi - lo)]
                .copy_from_slice(&x.data[i * m + lo..i * m + hi]);
        }
        let outs = rt.execute(&format!("hessian_c{cpad}"), &[&hp, &xb])?;
        hp = outs.into_iter().next().unwrap();
    }
    let mut out = Tensor::zeros(&[c, c]);
    for i in 0..c {
        out.data[i * c..(i + 1) * c].copy_from_slice(&hp.data[i * cpad..i * cpad + c]);
    }
    Ok((out, Backend::Pjrt))
}

/// Cholesky decomposition of an SPD matrix: returns lower-triangular L
/// with A = L·Lᵀ. Substrate for H⁻¹ and its Cholesky factor — jax's
/// `linalg` lowers to lapack FFI custom-calls the pinned xla_extension
/// cannot execute, so the factorization is native Rust.
pub fn cholesky(a: &Tensor) -> anyhow::Result<Tensor> {
    let n = a.shape[0];
    anyhow::ensure!(a.shape == vec![n, n], "cholesky needs square");
    let mut l = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.data[i * n + j];
            for k in 0..j {
                sum -= l.data[i * n + k] * l.data[j * n + k];
            }
            if i == j {
                anyhow::ensure!(sum > 0.0, "matrix not positive definite at {i} (sum {sum})");
                l.data[i * n + i] = sum.sqrt();
            } else {
                l.data[i * n + j] = sum / l.data[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Inverse of an SPD matrix via Cholesky: A⁻¹ = L⁻ᵀ·L⁻¹.
pub fn spd_inverse(a: &Tensor) -> anyhow::Result<Tensor> {
    let n = a.shape[0];
    let l = cholesky(a)?;
    // invert L (lower triangular) by forward substitution per column
    let mut linv = Tensor::zeros(&[n, n]);
    for col in 0..n {
        linv.data[col * n + col] = 1.0 / l.data[col * n + col];
        for i in col + 1..n {
            let mut sum = 0.0f32;
            for k in col..i {
                sum -= l.data[i * n + k] * linv.data[k * n + col];
            }
            linv.data[i * n + col] = sum / l.data[i * n + i];
        }
    }
    // A⁻¹ = Linvᵀ · Linv
    let mut inv = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            let mut acc = 0.0f32;
            // sum over k >= max(i, j): linv[k,i]*linv[k,j]
            for k in j..n {
                acc += linv.data[k * n + i] * linv.data[k * n + j];
            }
            inv.data[i * n + j] = acc;
            inv.data[j * n + i] = acc;
        }
    }
    Ok(inv)
}

/// The SparseGPT sweep matrix: upper Cholesky factor U of H⁻¹ (H⁻¹ = UᵀU).
pub fn sweep_matrix(h: &Tensor) -> anyhow::Result<Tensor> {
    let inv = spd_inverse(h)?;
    let l = cholesky(&inv)?;
    Ok(l.t2())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{assert_allclose, ops};
    use crate::util::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Tensor {
        let x = Tensor::new(vec![n, n + 4], rng.uniform_vec(n * (n + 4), -1.0, 1.0));
        let mut h = ops::matmul(&x, &x.t2());
        for i in 0..n {
            h.data[i * n + i] += 0.5;
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        let a = spd(&mut rng, 12);
        let l = cholesky(&a).unwrap();
        let back = ops::matmul(&l, &l.t2());
        assert_allclose(&back, &a, 1e-3, 1e-3);
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Rng::new(2);
        let a = spd(&mut rng, 16);
        let inv = spd_inverse(&a).unwrap();
        let eye = ops::matmul(&a, &inv);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (eye.data[i * 16 + j] - want).abs() < 1e-2,
                    "({i},{j}) = {}",
                    eye.data[i * 16 + j]
                );
            }
        }
    }

    #[test]
    fn sweep_matrix_factorizes_inverse() {
        let mut rng = Rng::new(3);
        let a = spd(&mut rng, 10);
        let u = sweep_matrix(&a).unwrap();
        let inv = spd_inverse(&a).unwrap();
        let back = ops::matmul(&u.t2(), &u);
        assert_allclose(&back, &inv, 1e-2, 1e-2);
        // upper triangular
        for i in 0..10 {
            for j in 0..i {
                assert_eq!(u.data[i * 10 + j], 0.0);
            }
        }
    }

    #[test]
    fn native_obs_update_zeroes_and_compensates() {
        let mut rng = Rng::new(4);
        let c = 8;
        let w = Tensor::new(vec![4, c], rng.uniform_vec(4 * c, -1.0, 1.0));
        let h = spd(&mut rng, c);
        let u = sweep_matrix(&h).unwrap();
        let mut mask = vec![0.0f32; c];
        mask[2] = 1.0;
        mask[5] = 1.0;
        let out = obs_update_native(&w, &u, &mask);
        for row in 0..4 {
            assert_eq!(out.data[row * c + 2], 0.0);
            assert_eq!(out.data[row * c + 5], 0.0);
        }
        // unpruned columns before the first pruned column are untouched
        for row in 0..4 {
            assert_eq!(out.data[row * c], w.data[row * c]);
            assert_eq!(out.data[row * c + 1], w.data[row * c + 1]);
        }
        // at least one surviving later column was adjusted
        assert!(out.data[3] != w.data[3] || out.data[4] != w.data[4]);
    }

    #[test]
    fn native_hessian_accum_symmetric() {
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![6, 20], rng.uniform_vec(120, -1.0, 1.0));
        let h = hessian_accum_native(&Tensor::zeros(&[6, 6]), &x);
        for i in 0..6 {
            for j in 0..6 {
                assert!((h.data[i * 6 + j] - h.data[j * 6 + i]).abs() < 1e-5);
            }
        }
        // equals matmul reference
        let want = ops::matmul(&x, &x.t2());
        assert_allclose(&h, &want, 1e-4, 1e-4);
    }

    #[test]
    fn obs_update_reduces_layer_reconstruction_error() {
        // end-to-end: correlated calibration features, prune 25% of
        // columns; OBS compensation must beat naive zeroing
        let mut rng = Rng::new(6);
        let (c, m, r) = (16usize, 128usize, 8usize);
        // low-rank + noise features
        let basis = Tensor::new(vec![c, 4], rng.uniform_vec(c * 4, -1.0, 1.0));
        let coef = Tensor::new(vec![4, m], rng.uniform_vec(4 * m, -1.0, 1.0));
        let mut x = ops::matmul(&basis, &coef);
        for v in &mut x.data {
            *v += rng.normal() * 0.05;
        }
        let w = Tensor::new(vec![r, c], rng.uniform_vec(r * c, -1.0, 1.0));
        let mut h = hessian_accum_native(&Tensor::zeros(&[c, c]), &x);
        let damp = 0.01 * (0..c).map(|i| h.data[i * c + i]).sum::<f32>() / c as f32;
        for i in 0..c {
            h.data[i * c + i] += damp;
        }
        let u = sweep_matrix(&h).unwrap();
        let mut mask = vec![0.0f32; c];
        for i in [1usize, 6, 9, 13] {
            mask[i] = 1.0;
        }
        let w_obs = obs_update_native(&w, &u, &mask);
        let mut w_zero = w.clone();
        for row in 0..r {
            for i in [1usize, 6, 9, 13] {
                w_zero.data[row * c + i] = 0.0;
            }
        }
        let ref_out = ops::matmul(&w, &x);
        let err_obs = ref_out.l2_dist(&ops::matmul(&w_obs, &x));
        let err_zero = ref_out.l2_dist(&ops::matmul(&w_zero, &x));
        assert!(
            err_obs < err_zero * 0.9,
            "obs {err_obs} not better than zero {err_zero}"
        );
    }
}
