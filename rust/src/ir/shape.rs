//! Shape inference over SPA-IR.
//!
//! The computational graph stores static shapes (the paper relies on ONNX
//! shape information to drive mask propagation, §3.1); this module derives
//! activation shapes from input/param shapes per operator semantics, both
//! at build time and after structural pruning (`Graph::refresh_shapes`).

use super::{Graph, OpKind};
use crate::tensor::ops::conv_out_dim;
use std::collections::HashMap;

/// Infer the output shapes of one operator from its input shapes.
pub fn infer_op_output_shapes(
    kind: &OpKind,
    ins: &[Vec<usize>],
) -> anyhow::Result<Vec<Vec<usize>>> {
    let one = |s: Vec<usize>| Ok(vec![s]);
    match kind {
        OpKind::Conv2d { stride, pad, groups } => {
            anyhow::ensure!(ins.len() >= 2, "conv2d needs x,w");
            let (x, w) = (&ins[0], &ins[1]);
            anyhow::ensure!(x.len() == 4 && w.len() == 4, "conv2d ranks");
            anyhow::ensure!(
                x[1] == w[1] * groups,
                "conv2d Ci mismatch: x has {}, w expects {}x{}",
                x[1],
                w[1],
                groups
            );
            anyhow::ensure!(w[0] % groups == 0, "conv2d Co % groups");
            if let Some(b) = ins.get(2) {
                anyhow::ensure!(b == &vec![w[0]], "conv2d bias shape");
            }
            one(vec![
                x[0],
                w[0],
                conv_out_dim(x[2], w[2], *stride, *pad),
                conv_out_dim(x[3], w[3], *stride, *pad),
            ])
        }
        OpKind::Gemm => {
            anyhow::ensure!(ins.len() >= 2, "gemm needs x,w");
            let (x, w) = (&ins[0], &ins[1]);
            anyhow::ensure!(w.len() == 2, "gemm weight rank");
            anyhow::ensure!(
                x.last() == Some(&w[1]),
                "gemm in-dim mismatch: x {:?} vs w {:?}",
                x,
                w
            );
            if let Some(b) = ins.get(2) {
                anyhow::ensure!(b == &vec![w[0]], "gemm bias shape");
            }
            let mut out = x[..x.len() - 1].to_vec();
            out.push(w[0]);
            one(out)
        }
        OpKind::BatchNorm { .. } => {
            anyhow::ensure!(ins.len() == 5, "batchnorm needs x,gamma,beta,mean,var");
            let c = ins[0][1];
            for p in &ins[1..] {
                anyhow::ensure!(p == &vec![c], "batchnorm param shape {:?} vs C {}", p, c);
            }
            one(ins[0].clone())
        }
        OpKind::LayerNorm { .. } => {
            anyhow::ensure!(ins.len() == 3, "layernorm needs x,gamma,beta");
            let d = *ins[0].last().unwrap();
            anyhow::ensure!(ins[1] == vec![d] && ins[2] == vec![d], "layernorm params");
            one(ins[0].clone())
        }
        OpKind::Relu
        | OpKind::Gelu
        | OpKind::Silu
        | OpKind::Sigmoid
        | OpKind::Tanh
        | OpKind::Softmax
        | OpKind::Scale { .. }
        | OpKind::Identity => one(ins[0].clone()),
        OpKind::Add | OpKind::Mul => {
            anyhow::ensure!(ins.len() == 2, "binary op arity");
            let (a, b) = (&ins[0], &ins[1]);
            if a == b {
                return one(a.clone());
            }
            // channel broadcast: b is [C] or [1,C,1,1]-style against a's dim 1,
            // or [.., 1, D]-style row broadcast for transformers
            if broadcast_ok(a, b) {
                return one(a.clone());
            }
            anyhow::bail!("binary op shape mismatch {:?} vs {:?}", a, b)
        }
        OpKind::MaxPool2d { k, stride, pad } | OpKind::AvgPool2d { k, stride, pad } => {
            let x = &ins[0];
            anyhow::ensure!(x.len() == 4, "pool rank");
            one(vec![
                x[0],
                x[1],
                conv_out_dim(x[2], *k, *stride, *pad),
                conv_out_dim(x[3], *k, *stride, *pad),
            ])
        }
        OpKind::GlobalAvgPool => {
            let x = &ins[0];
            anyhow::ensure!(x.len() == 4, "gap rank");
            one(vec![x[0], x[1]])
        }
        OpKind::Flatten => {
            let x = &ins[0];
            anyhow::ensure!(x.len() >= 2, "flatten rank");
            one(vec![x[0], x[1..].iter().product()])
        }
        OpKind::Concat { axis } => {
            anyhow::ensure!(!ins.is_empty(), "concat arity");
            let mut out = ins[0].clone();
            anyhow::ensure!(*axis < out.len(), "concat axis");
            for s in &ins[1..] {
                anyhow::ensure!(s.len() == out.len(), "concat rank mismatch");
                for (d, (&a, &b)) in out.iter().zip(s).enumerate() {
                    if d == *axis {
                        continue;
                    }
                    anyhow::ensure!(a == b, "concat non-axis dim mismatch");
                }
                out[*axis] += s[*axis];
            }
            one(out)
        }
        OpKind::MatMul => {
            let (a, b) = (&ins[0], &ins[1]);
            anyhow::ensure!(a.len() >= 2 && a.len() == b.len(), "matmul ranks");
            anyhow::ensure!(
                a[..a.len() - 2] == b[..b.len() - 2],
                "matmul batch dims {:?} vs {:?}",
                a,
                b
            );
            anyhow::ensure!(
                a[a.len() - 1] == b[b.len() - 2],
                "matmul contraction {:?} vs {:?}",
                a,
                b
            );
            let mut out = a[..a.len() - 1].to_vec();
            out.push(b[b.len() - 1]);
            one(out)
        }
        OpKind::Transpose { perm } => {
            let x = &ins[0];
            anyhow::ensure!(perm.len() == x.len(), "transpose perm rank");
            one(perm.iter().map(|&p| x[p]).collect())
        }
        OpKind::SplitHeads { heads } => {
            let x = &ins[0];
            anyhow::ensure!(x.len() == 3, "splitheads rank (want [N,T,D])");
            anyhow::ensure!(x[2] % heads == 0, "D % heads");
            one(vec![x[0], *heads, x[1], x[2] / heads])
        }
        OpKind::MergeHeads => {
            let x = &ins[0];
            anyhow::ensure!(x.len() == 4, "mergeheads rank (want [N,h,T,d])");
            one(vec![x[0], x[2], x[1] * x[3]])
        }
        OpKind::Embedding => {
            anyhow::ensure!(ins.len() == 2, "embedding arity");
            let (ids, table) = (&ins[0], &ins[1]);
            anyhow::ensure!(table.len() == 2, "embedding table rank");
            let mut out = ids.clone();
            out.push(table[1]);
            one(out)
        }
        OpKind::NchwToTokens => {
            let x = &ins[0];
            anyhow::ensure!(x.len() == 4, "nchwtotokens rank");
            one(vec![x[0], x[2] * x[3], x[1]])
        }
        OpKind::ReduceMean { axis } => {
            let x = &ins[0];
            anyhow::ensure!(*axis < x.len(), "reducemean axis");
            let out: Vec<usize> = x
                .iter()
                .enumerate()
                .filter(|(i, _)| i != axis)
                .map(|(_, &d)| d)
                .collect();
            one(out)
        }
    }
}

/// Channel/row broadcast compatibility for Add/Mul: `b` may be [C] against
/// a 2-D [N,C]; [C] or [1,C,1,1] against 4-D dim 1; [D] or [1,1,D] against
/// 3-D last dim; or per-sample scale [N,C,1,1] against [N,C,H,W].
pub fn broadcast_ok(a: &[usize], b: &[usize]) -> bool {
    if b.len() == 1 {
        return match a.len() {
            2 => b[0] == a[1],
            3 => b[0] == a[2],
            4 => b[0] == a[1],
            _ => false,
        };
    }
    if a.len() == 4 && b.len() == 2 {
        // per-sample channel gate [N,C] against [N,C,H,W] (SE blocks)
        return b[0] == a[0] && b[1] == a[1];
    }
    if a.len() == 4 && b.len() == 4 {
        // spatial broadcast for SE-style gates
        return b[0] == a[0] && b[1] == a[1] && b[2] == 1 && b[3] == 1;
    }
    if a.len() == 3 && b.len() == 3 {
        // position-embedding broadcast over batch
        return b[0] == 1 && b[1] == a[1] && b[2] == a[2];
    }
    false
}

/// Infer shapes for every data node reachable from graph inputs/params.
pub fn infer_shapes(g: &Graph) -> anyhow::Result<HashMap<usize, Vec<usize>>> {
    let mut shapes: HashMap<usize, Vec<usize>> = HashMap::new();
    for d in &g.datas {
        if d.producer.is_none() {
            shapes.insert(d.id, d.shape.clone());
        }
    }
    for op_id in g.topo_order()? {
        let op = &g.ops[op_id];
        let ins: Vec<Vec<usize>> = op
            .inputs
            .iter()
            .map(|&i| {
                shapes
                    .get(&i)
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("unshaped input to `{}`", op.name))
            })
            .collect::<anyhow::Result<_>>()?;
        let outs = infer_op_output_shapes(&op.kind, &ins)
            .map_err(|e| anyhow::anyhow!("op `{}`: {e}", op.name))?;
        for (&out_id, s) in op.outputs.iter().zip(outs) {
            shapes.insert(out_id, s);
        }
    }
    Ok(shapes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape() {
        let out = infer_op_output_shapes(
            &OpKind::Conv2d { stride: 2, pad: 1, groups: 1 },
            &[vec![4, 3, 32, 32], vec![16, 3, 3, 3], vec![16]],
        )
        .unwrap();
        assert_eq!(out, vec![vec![4, 16, 16, 16]]);
    }

    #[test]
    fn conv_rejects_ci_mismatch() {
        assert!(infer_op_output_shapes(
            &OpKind::Conv2d { stride: 1, pad: 0, groups: 1 },
            &[vec![1, 4, 8, 8], vec![8, 3, 3, 3]],
        )
        .is_err());
    }

    #[test]
    fn gemm_3d_input() {
        let out = infer_op_output_shapes(&OpKind::Gemm, &[vec![2, 7, 16], vec![32, 16]]).unwrap();
        assert_eq!(out, vec![vec![2, 7, 32]]);
    }

    #[test]
    fn concat_axis1() {
        let out = infer_op_output_shapes(
            &OpKind::Concat { axis: 1 },
            &[vec![1, 4, 8, 8], vec![1, 6, 8, 8]],
        )
        .unwrap();
        assert_eq!(out, vec![vec![1, 10, 8, 8]]);
    }

    #[test]
    fn split_merge_heads() {
        let s =
            infer_op_output_shapes(&OpKind::SplitHeads { heads: 4 }, &[vec![2, 9, 32]]).unwrap();
        assert_eq!(s, vec![vec![2, 4, 9, 8]]);
        let m = infer_op_output_shapes(&OpKind::MergeHeads, &[vec![2, 4, 9, 8]]).unwrap();
        assert_eq!(m, vec![vec![2, 9, 32]]);
    }

    #[test]
    fn broadcast_rules() {
        assert!(broadcast_ok(&[2, 8, 4, 4], &[8]));
        assert!(broadcast_ok(&[2, 8], &[8]));
        assert!(broadcast_ok(&[2, 8, 4, 4], &[2, 8, 1, 1]));
        assert!(broadcast_ok(&[2, 9, 32], &[1, 9, 32]));
        assert!(!broadcast_ok(&[2, 8, 4, 4], &[4]));
        assert!(!broadcast_ok(&[2, 8, 4, 4], &[2, 8, 4, 1]));
    }

    #[test]
    fn flatten_and_reduce() {
        let f = infer_op_output_shapes(&OpKind::Flatten, &[vec![2, 8, 4, 4]]).unwrap();
        assert_eq!(f, vec![vec![2, 128]]);
        let r = infer_op_output_shapes(&OpKind::ReduceMean { axis: 1 }, &[vec![2, 9, 32]]).unwrap();
        assert_eq!(r, vec![vec![2, 32]]);
    }
}
