//! Fluent builder for SPA-IR graphs.
//!
//! Used by the model zoo and the frontend importers. Parameters are
//! Kaiming-initialized from a deterministic per-builder RNG (seeded by the
//! builder's `seed` so every experiment is reproducible); shape inference
//! runs incrementally so each `DataNode` has a static shape at build time.

use super::shape::infer_op_output_shapes;
use super::{DataId, DataKind, DataNode, Graph, OpId, OpKind, OpNode};
use crate::tensor::Tensor;
use crate::util::Rng;

pub struct GraphBuilder {
    graph: Graph,
    rng: Rng,
}

impl GraphBuilder {
    pub fn new(name: &str, seed: u64) -> Self {
        GraphBuilder {
            graph: Graph {
                name: name.to_string(),
                ..Default::default()
            },
            rng: Rng::new(seed ^ 0x5370417273u64), // "SPArs"
        }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    fn add_data(&mut self, name: String, shape: Vec<usize>, kind: DataKind) -> DataId {
        let id = self.graph.datas.len();
        self.graph.datas.push(DataNode {
            id,
            name,
            shape,
            kind,
            producer: None,
            consumers: Vec::new(),
        });
        id
    }

    /// Add a graph input with the given (batched) shape.
    pub fn input(&mut self, name: &str, shape: Vec<usize>) -> DataId {
        let id = self.add_data(name.to_string(), shape, DataKind::Input);
        self.graph.inputs.push(id);
        id
    }

    /// Add a parameter node with explicit data.
    pub fn param(&mut self, name: &str, t: Tensor) -> DataId {
        let shape = t.shape.clone();
        self.add_data(name.to_string(), shape, DataKind::Param(t))
    }

    /// Add a Kaiming-initialized parameter.
    pub fn param_kaiming(&mut self, name: &str, shape: &[usize], fan_in: usize) -> DataId {
        let t = Tensor::kaiming(shape, fan_in, &mut self.rng);
        self.param(name, t)
    }

    /// Core: add an operator, infer output shapes, create output data nodes.
    pub fn add_op(&mut self, name: &str, kind: OpKind, inputs: Vec<DataId>) -> DataId {
        let op_id: OpId = self.graph.ops.len();
        let in_shapes: Vec<Vec<usize>> = inputs
            .iter()
            .map(|&i| self.graph.datas[i].shape.clone())
            .collect();
        let out_shapes = infer_op_output_shapes(&kind, &in_shapes)
            .unwrap_or_else(|e| panic!("shape inference failed for op `{name}`: {e}"));
        assert_eq!(out_shapes.len(), 1, "builder supports single-output ops");
        let out = self.add_data(
            format!("{name}.out"),
            out_shapes[0].clone(),
            DataKind::Activation,
        );
        self.graph.datas[out].producer = Some(op_id);
        for &i in &inputs {
            self.graph.datas[i].consumers.push(op_id);
        }
        self.graph.ops.push(OpNode {
            id: op_id,
            name: name.to_string(),
            kind,
            inputs,
            outputs: vec![out],
        });
        out
    }

    // ---- layer helpers -------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        &mut self,
        name: &str,
        x: DataId,
        co: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        bias: bool,
    ) -> DataId {
        let ci = self.graph.datas[x].shape[1];
        assert_eq!(ci % groups, 0, "{name}: Ci {ci} % groups {groups} != 0");
        let w = self.param_kaiming(
            &format!("{name}.w"),
            &[co, ci / groups, k, k],
            ci / groups * k * k,
        );
        let mut inputs = vec![x, w];
        if bias {
            let b = self.param(&format!("{name}.b"), Tensor::zeros(&[co]));
            inputs.push(b);
        }
        self.add_op(name, OpKind::Conv2d { stride, pad, groups }, inputs)
    }

    pub fn gemm(&mut self, name: &str, x: DataId, co: usize, bias: bool) -> DataId {
        let k = *self.graph.datas[x].shape.last().unwrap();
        let w = self.param_kaiming(&format!("{name}.w"), &[co, k], k);
        let mut inputs = vec![x, w];
        if bias {
            let b = self.param(&format!("{name}.b"), Tensor::zeros(&[co]));
            inputs.push(b);
        }
        self.add_op(name, OpKind::Gemm, inputs)
    }

    pub fn batchnorm(&mut self, name: &str, x: DataId) -> DataId {
        let c = self.graph.datas[x].shape[1];
        let gamma = self.param(&format!("{name}.gamma"), Tensor::ones(&[c]));
        let beta = self.param(&format!("{name}.beta"), Tensor::zeros(&[c]));
        let mean = self.param(&format!("{name}.mean"), Tensor::zeros(&[c]));
        let var = self.param(&format!("{name}.var"), Tensor::ones(&[c]));
        self.add_op(
            name,
            OpKind::BatchNorm { eps: 1e-5 },
            vec![x, gamma, beta, mean, var],
        )
    }

    pub fn layernorm(&mut self, name: &str, x: DataId) -> DataId {
        let d = *self.graph.datas[x].shape.last().unwrap();
        let gamma = self.param(&format!("{name}.gamma"), Tensor::ones(&[d]));
        let beta = self.param(&format!("{name}.beta"), Tensor::zeros(&[d]));
        self.add_op(name, OpKind::LayerNorm { eps: 1e-5 }, vec![x, gamma, beta])
    }

    pub fn relu(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::Relu, vec![x])
    }

    pub fn gelu(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::Gelu, vec![x])
    }

    pub fn silu(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::Silu, vec![x])
    }

    pub fn sigmoid(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::Sigmoid, vec![x])
    }

    pub fn tanh(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::Tanh, vec![x])
    }

    pub fn add(&mut self, name: &str, a: DataId, b: DataId) -> DataId {
        self.add_op(name, OpKind::Add, vec![a, b])
    }

    pub fn mul(&mut self, name: &str, a: DataId, b: DataId) -> DataId {
        self.add_op(name, OpKind::Mul, vec![a, b])
    }

    pub fn maxpool2d(
        &mut self,
        name: &str,
        x: DataId,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> DataId {
        self.add_op(name, OpKind::MaxPool2d { k, stride, pad }, vec![x])
    }

    pub fn avgpool2d(
        &mut self,
        name: &str,
        x: DataId,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> DataId {
        self.add_op(name, OpKind::AvgPool2d { k, stride, pad }, vec![x])
    }

    pub fn global_avgpool(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::GlobalAvgPool, vec![x])
    }

    pub fn flatten(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::Flatten, vec![x])
    }

    pub fn concat(&mut self, name: &str, xs: &[DataId], axis: usize) -> DataId {
        self.add_op(name, OpKind::Concat { axis }, xs.to_vec())
    }

    pub fn softmax(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::Softmax, vec![x])
    }

    pub fn matmul(&mut self, name: &str, a: DataId, b: DataId) -> DataId {
        self.add_op(name, OpKind::MatMul, vec![a, b])
    }

    pub fn transpose(&mut self, name: &str, x: DataId, perm: Vec<usize>) -> DataId {
        self.add_op(name, OpKind::Transpose { perm }, vec![x])
    }

    pub fn split_heads(&mut self, name: &str, x: DataId, heads: usize) -> DataId {
        self.add_op(name, OpKind::SplitHeads { heads }, vec![x])
    }

    pub fn merge_heads(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::MergeHeads, vec![x])
    }

    pub fn scale(&mut self, name: &str, x: DataId, c: f32) -> DataId {
        self.add_op(name, OpKind::Scale { c }, vec![x])
    }

    pub fn embedding(&mut self, name: &str, ids: DataId, vocab: usize, dim: usize) -> DataId {
        let table = {
            let t = Tensor::kaiming(&[vocab, dim], dim, &mut self.rng);
            self.param(&format!("{name}.table"), t)
        };
        self.add_op(name, OpKind::Embedding, vec![ids, table])
    }

    pub fn reduce_mean(&mut self, name: &str, x: DataId, axis: usize) -> DataId {
        self.add_op(name, OpKind::ReduceMean { axis }, vec![x])
    }

    pub fn identity(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::Identity, vec![x])
    }

    pub fn nchw_to_tokens(&mut self, name: &str, x: DataId) -> DataId {
        self.add_op(name, OpKind::NchwToTokens, vec![x])
    }

    /// Shape of an already-built data node.
    pub fn peek_shape(&self, id: DataId) -> Vec<usize> {
        self.graph.datas[id].shape.clone()
    }

    /// Mark a data node as a graph output.
    pub fn output(&mut self, id: DataId) {
        self.graph.outputs.push(id);
    }

    /// Finalize: validate and return the graph.
    pub fn finish(self) -> anyhow::Result<Graph> {
        let g = self.graph;
        anyhow::ensure!(!g.outputs.is_empty(), "graph `{}` has no outputs", g.name);
        g.validate()?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_block_builds() {
        let mut b = GraphBuilder::new("res", 1);
        let x = b.input("x", vec![1, 8, 4, 4]);
        let c1 = b.conv2d("c1", x, 8, 3, 1, 1, 1, false);
        let n1 = b.batchnorm("bn1", c1);
        let r1 = b.relu("r1", n1);
        let c2 = b.conv2d("c2", r1, 8, 3, 1, 1, 1, false);
        let n2 = b.batchnorm("bn2", c2);
        let s = b.add("skip", n2, x);
        let out = b.relu("r2", s);
        b.output(out);
        let g = b.finish().unwrap();
        assert_eq!(g.data(s).shape, vec![1, 8, 4, 4]);
        // x feeds both c1 and the add
        let xid = g.inputs[0];
        assert_eq!(g.data(xid).consumers.len(), 2);
    }

    #[test]
    fn attention_shapes() {
        let mut b = GraphBuilder::new("attn", 2);
        let x = b.input("x", vec![2, 5, 16]); // [N,T,D]
        let q = b.gemm("q", x, 16, true);
        let k = b.gemm("k", x, 16, true);
        let v = b.gemm("v", x, 16, true);
        let qh = b.split_heads("qh", q, 4); // [2,4,5,4]
        let kh = b.split_heads("kh", k, 4);
        let vh = b.split_heads("vh", v, 4);
        let kt = b.transpose("kt", kh, vec![0, 1, 3, 2]); // [2,4,4,5]
        let scores = b.matmul("qk", qh, kt); // [2,4,5,5]
        let scaled = b.scale("scl", scores, 0.5);
        let attn = b.softmax("sm", scaled);
        let ctx = b.matmul("av", attn, vh); // [2,4,5,4]
        let merged = b.merge_heads("mh", ctx); // [2,5,16]
        let out = b.gemm("o", merged, 16, true);
        b.output(out);
        let g = b.finish().unwrap();
        assert_eq!(g.data(scores).shape, vec![2, 4, 5, 5]);
        assert_eq!(g.data(merged).shape, vec![2, 5, 16]);
    }

    #[test]
    #[should_panic(expected = "shape inference failed")]
    fn bad_shapes_panic() {
        let mut b = GraphBuilder::new("bad", 1);
        let x = b.input("x", vec![1, 3, 4, 4]);
        let y = b.input("y", vec![1, 5, 4, 4]);
        b.add("oops", x, y);
    }
}
