//! SPA-IR (de)serialization — the library's interchange format.
//!
//! Plays the role ONNX files play in the paper: a standardized, framework-
//! independent serialized computational graph. Frontends (crate::frontends)
//! convert framework dialect descriptions *into* this form; the pruner can
//! dump pruned models back out, and the engine can reload them — the paper's
//! "convert back to the original framework" step (Fig. 1).

use super::{DataKind, DataNode, Graph, OpKind, OpNode};
use crate::tensor::Tensor;
use crate::util::json::{Json, JsonObj};

fn op_kind_to_json(kind: &OpKind) -> JsonObj {
    let mut o = JsonObj::new();
    o.insert("op", kind.name());
    match kind {
        OpKind::Conv2d { stride, pad, groups } => {
            o.insert("stride", *stride);
            o.insert("pad", *pad);
            o.insert("groups", *groups);
        }
        OpKind::BatchNorm { eps } | OpKind::LayerNorm { eps } => {
            o.insert("eps", *eps as f64);
        }
        OpKind::MaxPool2d { k, stride, pad } | OpKind::AvgPool2d { k, stride, pad } => {
            o.insert("k", *k);
            o.insert("stride", *stride);
            o.insert("pad", *pad);
        }
        OpKind::Concat { axis } => o.insert("axis", *axis),
        OpKind::Transpose { perm } => o.insert("perm", perm.as_slice()),
        OpKind::SplitHeads { heads } => o.insert("heads", *heads),
        OpKind::Scale { c } => o.insert("c", *c as f64),
        OpKind::ReduceMean { axis } => o.insert("axis", *axis),
        _ => {}
    }
    o
}

fn op_kind_from_json(o: &Json) -> anyhow::Result<OpKind> {
    let name = o
        .field("op")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("op name not a string"))?;
    let usize_f = |k: &str| -> anyhow::Result<usize> {
        o.field(k)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field {k} not a number"))
    };
    let f32_f = |k: &str| -> anyhow::Result<f32> {
        Ok(o.field(k)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field {k} not a number"))? as f32)
    };
    Ok(match name {
        "conv2d" => OpKind::Conv2d {
            stride: usize_f("stride")?,
            pad: usize_f("pad")?,
            groups: usize_f("groups")?,
        },
        "gemm" => OpKind::Gemm,
        "batchnorm" => OpKind::BatchNorm { eps: f32_f("eps")? },
        "layernorm" => OpKind::LayerNorm { eps: f32_f("eps")? },
        "relu" => OpKind::Relu,
        "gelu" => OpKind::Gelu,
        "silu" => OpKind::Silu,
        "sigmoid" => OpKind::Sigmoid,
        "tanh" => OpKind::Tanh,
        "add" => OpKind::Add,
        "mul" => OpKind::Mul,
        "maxpool2d" => OpKind::MaxPool2d {
            k: usize_f("k")?,
            stride: usize_f("stride")?,
            pad: usize_f("pad")?,
        },
        "avgpool2d" => OpKind::AvgPool2d {
            k: usize_f("k")?,
            stride: usize_f("stride")?,
            pad: usize_f("pad")?,
        },
        "globalavgpool" => OpKind::GlobalAvgPool,
        "flatten" => OpKind::Flatten,
        "concat" => OpKind::Concat { axis: usize_f("axis")? },
        "softmax" => OpKind::Softmax,
        "matmul" => OpKind::MatMul,
        "transpose" => OpKind::Transpose {
            perm: o.field("perm")?.usize_vec()?,
        },
        "splitheads" => OpKind::SplitHeads { heads: usize_f("heads")? },
        "mergeheads" => OpKind::MergeHeads,
        "scale" => OpKind::Scale { c: f32_f("c")? },
        "embedding" => OpKind::Embedding,
        "reducemean" => OpKind::ReduceMean { axis: usize_f("axis")? },
        "nchwtotokens" => OpKind::NchwToTokens,
        "identity" => OpKind::Identity,
        other => anyhow::bail!("unknown op kind `{other}`"),
    })
}

/// Serialize a graph to a JSON value. `with_weights` controls whether
/// parameter tensors are embedded (true for model checkpoints, false for
/// structure-only dumps).
pub fn graph_to_json(g: &Graph, with_weights: bool) -> Json {
    let mut root = JsonObj::new();
    root.insert("format", "spa-ir-v1");
    root.insert("name", g.name.as_str());
    let datas: Vec<Json> = g
        .datas
        .iter()
        .map(|d| {
            let mut o = JsonObj::new();
            o.insert("name", d.name.as_str());
            o.insert("shape", d.shape.as_slice());
            match &d.kind {
                DataKind::Input => o.insert("kind", "input"),
                DataKind::Activation => o.insert("kind", "activation"),
                DataKind::Param(t) => {
                    o.insert("kind", "param");
                    if with_weights {
                        o.insert("data", t.data.as_slice());
                    }
                }
            }
            Json::Obj(o)
        })
        .collect();
    root.insert("datas", datas);
    let ops: Vec<Json> = g
        .ops
        .iter()
        .map(|op| {
            let mut o = op_kind_to_json(&op.kind);
            o.insert("name", op.name.as_str());
            o.insert(
                "inputs",
                op.inputs.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
            );
            o.insert(
                "outputs",
                op.outputs.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
            );
            Json::Obj(o)
        })
        .collect();
    root.insert("ops", ops);
    root.insert(
        "inputs",
        g.inputs.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
    );
    root.insert(
        "outputs",
        g.outputs.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
    );
    Json::Obj(root)
}

/// Deserialize a graph from JSON. Missing weights are zero-initialized.
pub fn graph_from_json(j: &Json) -> anyhow::Result<Graph> {
    anyhow::ensure!(
        j.field("format")?.as_str() == Some("spa-ir-v1"),
        "not a spa-ir-v1 document"
    );
    let name = j.field("name")?.as_str().unwrap_or("graph").to_string();
    let mut g = Graph {
        name,
        ..Default::default()
    };
    for (id, dj) in j.field("datas")?.as_arr().unwrap_or(&[]).iter().enumerate() {
        let dname = dj.field("name")?.as_str().unwrap_or("").to_string();
        let shape = dj.field("shape")?.usize_vec()?;
        let kind = match dj.field("kind")?.as_str() {
            Some("input") => DataKind::Input,
            Some("activation") => DataKind::Activation,
            Some("param") => {
                let data = match dj.as_obj().and_then(|o| o.get("data")) {
                    Some(arr) => arr.f32_vec()?,
                    None => vec![0.0; shape.iter().product()],
                };
                DataKind::Param(Tensor::new(shape.clone(), data))
            }
            other => anyhow::bail!("bad data kind {:?}", other),
        };
        g.datas.push(DataNode {
            id,
            name: dname,
            shape,
            kind,
            producer: None,
            consumers: Vec::new(),
        });
    }
    for (id, oj) in j.field("ops")?.as_arr().unwrap_or(&[]).iter().enumerate() {
        let kind = op_kind_from_json(oj)?;
        let name = oj.field("name")?.as_str().unwrap_or("").to_string();
        let inputs = oj.field("inputs")?.usize_vec()?;
        let outputs = oj.field("outputs")?.usize_vec()?;
        for &i in &inputs {
            anyhow::ensure!(i < g.datas.len(), "op `{name}` bad input id");
            g.datas[i].consumers.push(id);
        }
        for &o in &outputs {
            anyhow::ensure!(o < g.datas.len(), "op `{name}` bad output id");
            g.datas[o].producer = Some(id);
        }
        g.ops.push(OpNode {
            id,
            name,
            kind,
            inputs,
            outputs,
        });
    }
    g.inputs = j.field("inputs")?.usize_vec()?;
    g.outputs = j.field("outputs")?.usize_vec()?;
    // Static checks before `validate`: a corrupted checkpoint should be
    // blamed on the offending node and dependency group (the coupling
    // checker's message), not on whichever generic shape-inference error
    // `validate` happens to hit first.
    crate::check::check_graph(&g)
        .map_err(|e| anyhow::anyhow!("checkpoint `{}` fails static checks: {e}", g.name))?;
    g.validate()?;
    Ok(g)
}

/// Write a graph to a file.
pub fn save_graph(g: &Graph, path: &str, with_weights: bool) -> anyhow::Result<()> {
    std::fs::write(path, graph_to_json(g, with_weights).to_string())?;
    Ok(())
}

/// Read a graph from a file.
pub fn load_graph(path: &str) -> anyhow::Result<Graph> {
    let text = std::fs::read_to_string(path)?;
    graph_from_json(&crate::util::parse_json(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn sample() -> Graph {
        let mut b = GraphBuilder::new("serde-test", 3);
        let x = b.input("x", vec![1, 3, 8, 8]);
        let c = b.conv2d("c1", x, 6, 3, 1, 1, 1, true);
        let n = b.batchnorm("bn", c);
        let r = b.relu("r", n);
        let c2 = b.conv2d("c2", r, 6, 3, 1, 1, 3, false); // grouped
        let s = b.add("res", c2, r);
        let g = b.global_avgpool("gap", s);
        let out = b.gemm("fc", g, 4, true);
        b.output(out);
        b.finish().unwrap()
    }

    #[test]
    fn round_trip_with_weights() {
        let g = sample();
        let j = graph_to_json(&g, true);
        let g2 = graph_from_json(&j).unwrap();
        assert_eq!(g.name, g2.name);
        assert_eq!(g.ops.len(), g2.ops.len());
        assert_eq!(g.datas.len(), g2.datas.len());
        for (a, b) in g.datas.iter().zip(&g2.datas) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.name, b.name);
            match (&a.kind, &b.kind) {
                (DataKind::Param(ta), DataKind::Param(tb)) => assert_eq!(ta.data, tb.data),
                (x, y) => assert_eq!(
                    std::mem::discriminant(x),
                    std::mem::discriminant(y)
                ),
            }
        }
        for (a, b) in g.ops.iter().zip(&g2.ops) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.inputs, b.inputs);
        }
    }

    #[test]
    fn round_trip_structure_only() {
        let g = sample();
        let j = graph_to_json(&g, false);
        let g2 = graph_from_json(&j).unwrap();
        g2.validate().unwrap();
        // weights zeroed but shapes preserved
        let p = g2.datas.iter().find(|d| d.is_param()).unwrap();
        assert!(p.param().unwrap().data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn file_round_trip() {
        let g = sample();
        let path = std::env::temp_dir().join("spa_serde_test.json");
        save_graph(&g, path.to_str().unwrap(), true).unwrap();
        let g2 = load_graph(path.to_str().unwrap()).unwrap();
        assert_eq!(g.num_params(), g2.num_params());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupted_checkpoint_fails_with_group_blame() {
        // the acceptance case: a checkpoint whose c2/bn2 branch was
        // shrunk to 7 channels while the residual branch kept 8 must be
        // rejected at load with the coupling op named
        let mut g = crate::check::tests::resnet_like();
        crate::check::tests::corrupt_residual_branch(&mut g);
        let path = std::env::temp_dir().join("spa_serde_corrupt.json");
        save_graph(&g, path.to_str().unwrap(), true).unwrap();
        let err = load_graph(path.to_str().unwrap()).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("fails static checks"), "got: {err}");
        assert!(err.contains("residual group"), "got: {err}");
        assert!(err.contains("add"), "must name the coupling op: {err}");
    }

    #[test]
    fn rejects_wrong_format() {
        let j = crate::util::parse_json(r#"{"format":"onnx","name":"x"}"#).unwrap();
        assert!(graph_from_json(&j).is_err());
    }
}
