//! Graph-rewriting passes over SPA-IR.
//!
//! The paper's deployment story ends at "convert the pruned ONNX model
//! back to the original framework"; a production pruning toolchain also
//! wants inference-time simplification of the pruned graph. These passes
//! do the standard ones:
//!
//! * [`fold_batchnorm`] — fold eval-mode BatchNorm affine transforms into
//!   the preceding conv/gemm weights (exact at inference);
//! * [`eliminate_identity`] — drop Identity ops;
//! * [`prune_dead_nodes`] — drop data nodes (incl. orphaned params) that
//!   no longer feed the outputs;
//! * [`fold_constants`] — evaluate operators fed only by parameters and
//!   materialize their outputs as parameters;
//! * [`optimize`] — the one-call pipeline over all of the above, used by
//!   the compiled-plan executor (`crate::exec`, `OptLevel::Fast`) and the
//!   `spa optimize` CLI command.
//!
//! Passes preserve numerics exactly (see tests) and re-validate.

use super::{DataId, DataKind, Graph, OpId, OpKind};

/// Redirect every consumer of `from` to read `to` instead, and transfer
/// graph-output status.
pub(crate) fn replace_uses(g: &mut Graph, from: DataId, to: DataId) {
    let consumers = std::mem::take(&mut g.datas[from].consumers);
    for &op_id in &consumers {
        for slot in g.ops[op_id].inputs.iter_mut() {
            if *slot == from {
                *slot = to;
            }
        }
        g.datas[to].consumers.push(op_id);
    }
    for out in g.outputs.iter_mut() {
        if *out == from {
            *out = to;
        }
    }
}

/// Remove a unary pass-through op, splicing its input to its consumers.
fn bypass_op(g: &mut Graph, op_id: OpId) {
    let input = g.ops[op_id].inputs[0];
    let output = g.ops[op_id].outputs[0];
    // detach op from its input's consumer list
    g.datas[input].consumers.retain(|&c| c != op_id);
    replace_uses(g, output, input);
    g.datas[output].producer = None;
    // neutralize the op: keep ids stable by replacing with a no-input
    // Identity that produces nothing (swept by rebuild)
    g.ops[op_id].inputs.clear();
    g.ops[op_id].outputs.clear();
}

/// Compact the graph: drop neutralized ops and unreachable data nodes,
/// re-indexing ids. Returns the number of (ops, datas) removed.
pub fn prune_dead_nodes(g: &mut Graph) -> anyhow::Result<(usize, usize)> {
    let (removed_ops, removed_datas, _, _) = sweep_dead_nodes(g);
    g.validate()?;
    Ok((removed_ops, removed_datas))
}

/// The sweep behind [`prune_dead_nodes`], without the final validation:
/// returns the removal counts plus the old→new id maps (`None` = swept)
/// so callers mid-rewrite (`ir::patch`) can track surviving nodes and
/// defer validation until shapes are re-inferred.
pub(crate) fn sweep_dead_nodes(
    g: &mut Graph,
) -> (usize, usize, Vec<Option<DataId>>, Vec<Option<OpId>>) {
    // liveness: walk back from outputs
    let mut live_data = vec![false; g.datas.len()];
    let mut live_op = vec![false; g.ops.len()];
    let mut stack: Vec<DataId> = g.outputs.clone();
    while let Some(d) = stack.pop() {
        if live_data[d] {
            continue;
        }
        live_data[d] = true;
        if let Some(p) = g.datas[d].producer {
            if !live_op[p] {
                live_op[p] = true;
                for &i in &g.ops[p].inputs {
                    stack.push(i);
                }
            }
        }
    }
    // keep graph inputs alive (callers feed them)
    for &i in &g.inputs {
        live_data[i] = true;
    }
    let removed_ops = live_op.iter().filter(|&&l| !l).count();
    let removed_datas = live_data.iter().filter(|&&l| !l).count();
    // remap
    let data_map: Vec<Option<DataId>> = {
        let mut next = 0usize;
        live_data
            .iter()
            .map(|&l| {
                if l {
                    let id = next;
                    next += 1;
                    Some(id)
                } else {
                    None
                }
            })
            .collect()
    };
    let op_map: Vec<Option<OpId>> = {
        let mut next = 0usize;
        live_op
            .iter()
            .map(|&l| {
                if l {
                    let id = next;
                    next += 1;
                    Some(id)
                } else {
                    None
                }
            })
            .collect()
    };
    let mut new_datas = Vec::new();
    for (old_id, d) in g.datas.drain(..).enumerate() {
        if let Some(new_id) = data_map[old_id] {
            let mut d = d;
            d.id = new_id;
            d.producer = d.producer.and_then(|p| op_map[p]);
            d.consumers = d
                .consumers
                .iter()
                .filter_map(|&c| op_map[c])
                .collect();
            new_datas.push(d);
        }
    }
    g.datas = new_datas;
    let mut new_ops = Vec::new();
    for (old_id, op) in g.ops.drain(..).enumerate() {
        if let Some(new_id) = op_map[old_id] {
            let mut op = op;
            op.id = new_id;
            op.inputs = op.inputs.iter().map(|&i| data_map[i].unwrap()).collect();
            op.outputs = op.outputs.iter().map(|&o| data_map[o].unwrap()).collect();
            new_ops.push(op);
        }
    }
    g.ops = new_ops;
    g.inputs = g.inputs.iter().filter_map(|&i| data_map[i]).collect();
    g.outputs = g.outputs.iter().map(|&o| data_map[o].unwrap()).collect();
    (removed_ops, removed_datas, data_map, op_map)
}

/// Drop all Identity ops.
pub fn eliminate_identity(g: &mut Graph) -> anyhow::Result<usize> {
    let ids: Vec<OpId> = g
        .ops
        .iter()
        .filter(|o| matches!(o.kind, OpKind::Identity) && !o.inputs.is_empty())
        .map(|o| o.id)
        .collect();
    for id in &ids {
        bypass_op(g, *id);
    }
    prune_dead_nodes(g)?;
    Ok(ids.len())
}

/// Fold eval-mode BatchNorm into the preceding Conv2d/Gemm:
/// `w' = w·γ/√(σ²+ε)` per output channel, `b' = (b−μ)·γ/√(σ²+ε)+β`.
/// Only BNs whose input is produced by a conv/gemm consumed *solely* by
/// that BN are folded. Returns the number folded.
pub fn fold_batchnorm(g: &mut Graph) -> anyhow::Result<usize> {
    let mut folded = 0usize;
    for bn_id in 0..g.ops.len() {
        if !matches!(g.ops[bn_id].kind, OpKind::BatchNorm { .. }) {
            continue;
        }
        let x = match g.ops[bn_id].inputs.first() {
            Some(&x) => x,
            None => continue, // already neutralized
        };
        let Some(prod) = g.datas[x].producer else {
            continue;
        };
        if g.datas[x].consumers.len() != 1 {
            continue; // conv output used elsewhere (e.g. residual)
        }
        let has_bias = match g.ops[prod].kind {
            OpKind::Conv2d { .. } => g.ops[prod].inputs.len() > 2,
            OpKind::Gemm => g.ops[prod].inputs.len() > 2,
            _ => continue,
        };
        let eps = match g.ops[bn_id].kind {
            OpKind::BatchNorm { eps } => eps,
            _ => unreachable!(),
        };
        // gather BN params
        let (gamma, beta, mean, var) = {
            let ins = &g.ops[bn_id].inputs;
            (
                g.datas[ins[1]].param().unwrap().clone(),
                g.datas[ins[2]].param().unwrap().clone(),
                g.datas[ins[3]].param().unwrap().clone(),
                g.datas[ins[4]].param().unwrap().clone(),
            )
        };
        let co = gamma.numel();
        let scale: Vec<f32> = (0..co)
            .map(|c| gamma.data[c] / (var.data[c] + eps).sqrt())
            .collect();
        // scale weight rows
        let wid = g.ops[prod].inputs[1];
        {
            let w = g.datas[wid].param_mut().unwrap();
            let inner = w.numel() / co;
            for c in 0..co {
                for v in &mut w.data[c * inner..(c + 1) * inner] {
                    *v *= scale[c];
                }
            }
        }
        // fold bias
        if has_bias {
            let bid = g.ops[prod].inputs[2];
            let b = g.datas[bid].param_mut().unwrap();
            for c in 0..co {
                b.data[c] = (b.data[c] - mean.data[c]) * scale[c] + beta.data[c];
            }
        } else {
            // create a bias param absorbed from the BN shift
            let bias: Vec<f32> = (0..co)
                .map(|c| -mean.data[c] * scale[c] + beta.data[c])
                .collect();
            let bid = g.datas.len();
            g.datas.push(super::DataNode {
                id: bid,
                name: format!("{}.folded_bias", g.ops[prod].name),
                shape: vec![co],
                kind: DataKind::Param(crate::tensor::Tensor::new(vec![co], bias)),
                producer: None,
                consumers: vec![prod],
            });
            g.ops[prod].inputs.push(bid);
        }
        // detach BN params + bypass
        for slot in 1..5 {
            let pid = g.ops[bn_id].inputs[slot];
            g.datas[pid].consumers.retain(|&c| c != bn_id);
        }
        g.ops[bn_id].inputs.truncate(1);
        bypass_op(g, bn_id);
        folded += 1;
    }
    prune_dead_nodes(g)?;
    Ok(folded)
}

/// Constant folding: evaluate (in eval-mode semantics) every operator
/// whose inputs are all parameters, and turn its output into a
/// materialized `Param` node. Chains fold transitively in one call —
/// each folded output is itself a parameter for downstream candidates.
/// Returns the number of operators folded.
pub fn fold_constants(g: &mut Graph) -> anyhow::Result<usize> {
    let mut folded = 0usize;
    for op_id in g.topo_order()? {
        let foldable = {
            let op = &g.ops[op_id];
            !op.inputs.is_empty()
                && op.outputs.len() == 1
                && !g.outputs.contains(&op.outputs[0])
                && op.inputs.iter().all(|&i| g.datas[i].is_param())
        };
        if !foldable {
            continue;
        }
        let (kind, inputs, out_id) = {
            let op = &g.ops[op_id];
            (op.kind.clone(), op.inputs.clone(), op.outputs[0])
        };
        let out = {
            let ins: Vec<&crate::tensor::Tensor> = inputs
                .iter()
                .map(|&i| g.datas[i].param().unwrap())
                .collect();
            crate::engine::eval_op_value(&kind, &ins, crate::engine::Mode::Eval)?
        };
        g.datas[out_id].shape = out.shape.clone();
        g.datas[out_id].kind = DataKind::Param(out);
        g.datas[out_id].producer = None;
        for &i in &inputs {
            g.datas[i].consumers.retain(|&c| c != op_id);
        }
        g.ops[op_id].inputs.clear();
        g.ops[op_id].outputs.clear();
        folded += 1;
    }
    if folded > 0 {
        prune_dead_nodes(g)?;
    }
    Ok(folded)
}

/// What [`optimize`] did, pass by pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptReport {
    /// Operators removed by the initial dead-node sweep.
    pub dead_ops: usize,
    /// Data nodes removed by the initial dead-node sweep.
    pub dead_datas: usize,
    /// Identity operators spliced out.
    pub identities_removed: usize,
    /// BatchNorms folded into the preceding conv/gemm.
    pub bn_folded: usize,
    /// Operators constant-folded into parameters.
    pub constants_folded: usize,
}

impl OptReport {
    /// Total graph rewrites applied.
    pub fn total(&self) -> usize {
        self.dead_ops + self.identities_removed + self.bn_folded + self.constants_folded
    }
}

/// The standard inference-time simplification pipeline, in fixed order:
/// dead-node sweep → identity elimination → BatchNorm folding → constant
/// folding. Numerics are preserved up to the float reassociation of
/// [`fold_batchnorm`] (the other passes are exact); the graph re-validates
/// after every pass, and [`crate::check::check_graph`] additionally re-runs
/// after each pass at the default [`crate::check::CheckLevel`] (debug
/// builds).
pub fn optimize(g: &mut Graph) -> anyhow::Result<OptReport> {
    optimize_checked(g, crate::check::CheckLevel::default())
}

/// [`optimize`] with an explicit verification level: when `check` is
/// enabled, the full static graph analysis re-runs after every rewrite
/// pass, so a pass that breaks a shape or prune-coupling invariant is
/// attributed to the pass that introduced it instead of surfacing later
/// as a compile- or kernel-time failure.
pub fn optimize_checked(
    g: &mut Graph,
    check: crate::check::CheckLevel,
) -> anyhow::Result<OptReport> {
    let verify = |g: &Graph, pass: &str| -> anyhow::Result<()> {
        if check.enabled() {
            crate::check::check_graph(g).map_err(|e| {
                anyhow::anyhow!("graph failed static checks after pass `{pass}`: {e}")
            })?;
        }
        Ok(())
    };
    let (dead_ops, dead_datas) = prune_dead_nodes(g)?;
    verify(g, "prune_dead_nodes")?;
    let identities_removed = eliminate_identity(g)?;
    verify(g, "eliminate_identity")?;
    let bn_folded = fold_batchnorm(g)?;
    verify(g, "fold_batchnorm")?;
    let constants_folded = fold_constants(g)?;
    verify(g, "fold_constants")?;
    Ok(OptReport {
        dead_ops,
        dead_datas,
        identities_removed,
        bn_folded,
        constants_folded,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine;
    use crate::ir::GraphBuilder;
    use crate::tensor::{assert_allclose, Tensor};
    use crate::util::Rng;
    use crate::zoo::{self, ImageCfg};

    #[test]
    fn identity_elimination_preserves_numerics() {
        let mut b = GraphBuilder::new("id", 1);
        let x = b.input("x", vec![1, 3, 4, 4]);
        let i1 = b.identity("drop1", x);
        let c = b.conv2d("c", i1, 4, 3, 1, 1, 1, true);
        let i2 = b.identity("drop2", c);
        let g2 = b.global_avgpool("gap", i2);
        let out = b.gemm("fc", g2, 2, false);
        b.output(out);
        let mut g = b.finish().unwrap();
        let mut rng = Rng::new(2);
        let xv = Tensor::new(vec![1, 3, 4, 4], rng.uniform_vec(48, -1.0, 1.0));
        let before = engine::predict(&g, xv.clone()).unwrap();
        let n = eliminate_identity(&mut g).unwrap();
        assert_eq!(n, 2);
        assert!(g.ops.iter().all(|o| !matches!(o.kind, OpKind::Identity)));
        let after = engine::predict(&g, xv).unwrap();
        assert_allclose(&after, &before, 1e-6, 1e-6);
    }

    #[test]
    fn bn_fold_exact_on_vgg() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::vgg16(cfg, 3);
        // randomize BN stats so folding is non-trivial
        let mut rng = Rng::new(4);
        for d in &mut g.datas {
            let name = d.name.clone();
            if let Some(t) = d.param_mut() {
                if name.ends_with(".mean") {
                    t.data = rng.uniform_vec(t.numel(), -0.5, 0.5);
                } else if name.ends_with(".var") {
                    t.data = rng.uniform_vec(t.numel(), 0.5, 2.0);
                } else if name.ends_with(".gamma") {
                    t.data = rng.uniform_vec(t.numel(), 0.5, 1.5);
                }
            }
        }
        let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 192, -1.0, 1.0));
        let before = engine::predict(&g, x.clone()).unwrap();
        let ops_before = g.ops.len();
        let params_before = g.num_params();
        let folded = fold_batchnorm(&mut g).unwrap();
        assert!(folded >= 10, "folded only {folded}");
        assert!(g.ops.len() < ops_before);
        assert!(g.num_params() < params_before, "BN params must vanish");
        let after = engine::predict(&g, x).unwrap();
        assert_allclose(&after, &before, 1e-3, 1e-3);
    }

    #[test]
    fn bn_fold_skips_shared_outputs() {
        // conv output feeding BOTH a BN and a residual add must not fold
        let mut b = GraphBuilder::new("res", 5);
        let x = b.input("x", vec![1, 4, 4, 4]);
        let c = b.conv2d("c", x, 4, 3, 1, 1, 1, false);
        let n = b.batchnorm("bn", c);
        let s = b.add("add", n, c); // c used twice
        b.output(s);
        let mut g = b.finish().unwrap();
        let folded = fold_batchnorm(&mut g).unwrap();
        assert_eq!(folded, 0);
    }

    #[test]
    fn dead_node_sweep_drops_orphans() {
        let mut b = GraphBuilder::new("dead", 6);
        let x = b.input("x", vec![1, 4]);
        let _unused = b.gemm("orphan", x, 8, true); // output never used
        let out = b.gemm("used", x, 2, true);
        b.output(out);
        let mut g = b.finish().unwrap();
        let before = g.num_params();
        let (ops, datas) = prune_dead_nodes(&mut g).unwrap();
        assert_eq!(ops, 1);
        assert!(datas >= 2);
        assert!(g.num_params() < before);
        g.validate().unwrap();
    }

    /// x[2,4] → Gemm(w[3,4], bias = Add(b1[3], b2[3])) → out[2,3]: the
    /// Add is fed only by params and must constant-fold away.
    fn graph_with_const_subexpr() -> Graph {
        use crate::ir::{DataNode, OpNode};
        let mut rng = Rng::new(31);
        let p = |id: usize, name: &str, shape: Vec<usize>, consumers: Vec<OpId>, rng: &mut Rng| {
            let n: usize = shape.iter().product();
            DataNode {
                id,
                name: name.to_string(),
                shape: shape.clone(),
                kind: DataKind::Param(Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0))),
                producer: None,
                consumers,
            }
        };
        let datas = vec![
            DataNode {
                id: 0,
                name: "x".into(),
                shape: vec![2, 4],
                kind: DataKind::Input,
                producer: None,
                consumers: vec![1],
            },
            p(1, "b1", vec![3], vec![0], &mut rng),
            p(2, "b2", vec![3], vec![0], &mut rng),
            DataNode {
                id: 3,
                name: "bsum".into(),
                shape: vec![3],
                kind: DataKind::Activation,
                producer: Some(0),
                consumers: vec![1],
            },
            p(4, "w", vec![3, 4], vec![1], &mut rng),
            DataNode {
                id: 5,
                name: "out".into(),
                shape: vec![2, 3],
                kind: DataKind::Activation,
                producer: Some(1),
                consumers: vec![],
            },
        ];
        let ops = vec![
            OpNode {
                id: 0,
                name: "bias_add".into(),
                kind: OpKind::Add,
                inputs: vec![1, 2],
                outputs: vec![3],
            },
            OpNode {
                id: 1,
                name: "fc".into(),
                kind: OpKind::Gemm,
                inputs: vec![0, 4, 3],
                outputs: vec![5],
            },
        ];
        let g = Graph {
            name: "constfold".into(),
            ops,
            datas,
            inputs: vec![0],
            outputs: vec![5],
        };
        g.validate().unwrap();
        g
    }

    #[test]
    fn constant_folding_materializes_param_subexprs() {
        let mut g = graph_with_const_subexpr();
        let mut rng = Rng::new(32);
        let x = Tensor::new(vec![2, 4], rng.uniform_vec(8, -1.0, 1.0));
        let before = engine::predict(&g, x.clone()).unwrap();
        let params_before = g.num_params();
        let folded = fold_constants(&mut g).unwrap();
        assert_eq!(folded, 1);
        assert_eq!(g.ops.len(), 1, "only the Gemm survives");
        assert!(
            g.num_params() < params_before,
            "b1+b2 collapse into one bsum param"
        );
        g.validate().unwrap();
        // folding an Add of params is exact: bit-identical logits
        let after = engine::predict(&g, x).unwrap();
        assert_eq!(before.shape, after.shape);
        for (a, b) in before.data.iter().zip(&after.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fold_constants_skips_data_dependent_ops() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::resnet18(cfg, 5);
        let ops_before = g.ops.len();
        let folded = fold_constants(&mut g).unwrap();
        assert_eq!(folded, 0, "every resnet op depends on the input");
        assert_eq!(g.ops.len(), ops_before);
    }

    #[test]
    fn optimize_pipeline_runs_all_passes() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::vgg16(cfg, 6);
        let mut rng = Rng::new(7);
        // randomize BN stats so folding actually changes weights
        for d in &mut g.datas {
            let name = d.name.clone();
            if let Some(t) = d.param_mut() {
                if name.ends_with(".mean") {
                    t.data = rng.uniform_vec(t.numel(), -0.5, 0.5);
                } else if name.ends_with(".var") {
                    t.data = rng.uniform_vec(t.numel(), 0.5, 2.0);
                }
            }
        }
        let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 192, -1.0, 1.0));
        let before = engine::predict(&g, x.clone()).unwrap();
        let ops_before = g.ops.len();
        let rep = optimize(&mut g).unwrap();
        assert!(rep.bn_folded >= 10, "report {rep:?}");
        assert!(rep.total() >= rep.bn_folded);
        assert!(g.ops.len() < ops_before);
        g.validate().unwrap();
        let after = engine::predict(&g, x).unwrap();
        assert_allclose(&after, &before, 1e-3, 1e-3);
    }

    #[test]
    fn optimize_checked_strict_matches_plain_optimize() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut a = zoo::resnet18(cfg, 9);
        let mut b = a.clone();
        let ra = optimize(&mut a).unwrap();
        let rb = optimize_checked(&mut b, crate::check::CheckLevel::Strict).unwrap();
        assert_eq!(ra, rb, "verification must not change the rewrites");
        assert_eq!(a.ops.len(), b.ops.len());
        crate::check::check_graph(&b).unwrap();
    }

    #[test]
    fn fold_then_prune_pipeline_composes() {
        // passes + pruning compose: fold BN, then structural pruning works
        use crate::prune::{self, build_groups, score_groups, Agg, Norm};
        use std::collections::HashMap;
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::vgg16(cfg, 7);
        fold_batchnorm(&mut g).unwrap();
        let groups = build_groups(&g).unwrap();
        let mut l1 = HashMap::new();
        for pid in g.param_ids() {
            l1.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
        }
        let ranked = score_groups(&g, &groups, &l1, Agg::Sum, Norm::Mean);
        let sel = prune::select_lowest(&groups, &ranked, 0.4, 1);
        prune::apply_pruning(&mut g, &groups, &sel).unwrap();
        g.validate().unwrap();
    }
}
