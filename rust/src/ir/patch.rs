//! `GraphPatch`: localized rewrites of SPA-IR without whole-graph
//! reconstruction (the tract `ModelPatch` idea, ported to our IR).
//!
//! A patch is built *against* a specific base graph (it records the base
//! node counts and hands out ids for nodes it will append), accumulates a
//! set of localized edits —
//!
//! * node additions ([`GraphPatch::add_data`] / [`GraphPatch::add_op`]),
//! * removals ([`GraphPatch::remove_op`]),
//! * re-wirings ([`GraphPatch::rewire`] / [`GraphPatch::push_input`]),
//! * parameter edits ([`GraphPatch::set_param`]),
//!
//! — and applies them in one shot with [`GraphPatch::apply`]: append,
//! rewire, edit, disconnect, sweep dead nodes, re-infer shapes,
//! validate. Nodes
//! the patch does not touch keep their identity; the returned
//! [`PatchReport`] carries the old→new id maps from the dead-node sweep
//! so downstream consumers (notably `exec::Plan::recompile`) can track
//! untouched nodes across the rewrite and reuse work keyed by their old
//! ids.
//!
//! The classic `ir::passes` rewrites are also re-expressed as patches
//! where practical: [`identity_patch`] and [`batchnorm_fold_patch`]
//! produce the same graphs as `eliminate_identity` / `fold_batchnorm`
//! but through the patch primitive, which is what keeps the primitive
//! honest (tested equivalent in this module).

use super::passes::sweep_dead_nodes;
use super::{DataId, DataKind, DataNode, Graph, OpId, OpKind, OpNode};
use crate::tensor::Tensor;
use std::collections::HashSet;

/// What a [`GraphPatch::apply`] did, plus the id maps needed to track
/// surviving nodes across the embedded dead-node sweep.
#[derive(Debug, Clone)]
pub struct PatchReport {
    /// Ops appended by the patch (post-sweep survivors).
    pub added_ops: usize,
    /// Data nodes appended by the patch (post-sweep survivors).
    pub added_datas: usize,
    /// Ops removed (explicitly or by the dead-node sweep).
    pub removed_ops: usize,
    /// Data nodes removed by the dead-node sweep.
    pub removed_datas: usize,
    /// `rewire` edges applied.
    pub rewired: usize,
    /// Parameter tensors overwritten.
    pub param_edits: usize,
    /// Op count of the base graph the patch was built against.
    pub base_ops: usize,
    /// Data count of the base graph the patch was built against.
    pub base_datas: usize,
    /// Pre-sweep id → post-sweep id for every data node (`None` = swept).
    /// Ids `< base_datas` are base-graph ids, so this doubles as the
    /// base→patched correspondence for untouched nodes.
    pub data_map: Vec<Option<DataId>>,
    /// Pre-sweep id → post-sweep id for every op (`None` = swept).
    pub op_map: Vec<Option<OpId>>,
    /// Ops (post-sweep ids) whose inputs, params, or existence the patch
    /// changed — the "dirty" set an incremental recompile must rebuild.
    pub touched_ops: Vec<OpId>,
    /// Params (pre-sweep ids) whose tensors the patch overwrote.
    pub edited_params: Vec<DataId>,
}

impl PatchReport {
    /// Total localized rewrites the patch performed.
    pub fn total(&self) -> usize {
        self.added_ops + self.removed_ops + self.rewired + self.param_edits
    }
}

/// A localized rewrite of one specific [`Graph`] — see the module docs.
#[derive(Debug, Clone)]
pub struct GraphPatch {
    /// Human-readable context carried into error messages.
    pub label: String,
    base_ops: usize,
    base_datas: usize,
    new_datas: Vec<DataNode>,
    new_ops: Vec<OpNode>,
    rewires: Vec<(DataId, DataId)>,
    push_inputs: Vec<(OpId, DataId)>,
    removes: Vec<OpId>,
    param_edits: Vec<(DataId, Tensor)>,
}

impl GraphPatch {
    /// An empty patch against `base`. The patch may only be applied to a
    /// graph with the same node counts (a cheap staleness guard).
    pub fn new(label: impl Into<String>, base: &Graph) -> GraphPatch {
        GraphPatch {
            label: label.into(),
            base_ops: base.ops.len(),
            base_datas: base.datas.len(),
            new_datas: Vec::new(),
            new_ops: Vec::new(),
            rewires: Vec::new(),
            push_inputs: Vec::new(),
            removes: Vec::new(),
            param_edits: Vec::new(),
        }
    }

    /// True when the patch performs no edits at all.
    pub fn is_empty(&self) -> bool {
        self.new_datas.is_empty()
            && self.new_ops.is_empty()
            && self.rewires.is_empty()
            && self.push_inputs.is_empty()
            && self.removes.is_empty()
            && self.param_edits.is_empty()
    }

    /// Append a data node; the returned id is valid in the patched graph
    /// and may be referenced by later [`GraphPatch::add_op`] /
    /// [`GraphPatch::rewire`] / [`GraphPatch::push_input`] calls.
    pub fn add_data(
        &mut self,
        name: impl Into<String>,
        shape: Vec<usize>,
        kind: DataKind,
    ) -> DataId {
        let id = self.base_datas + self.new_datas.len();
        self.new_datas.push(DataNode {
            id,
            name: name.into(),
            shape,
            kind,
            producer: None,
            consumers: Vec::new(),
        });
        id
    }

    /// Append an op reading `inputs` and producing `outputs` (each output
    /// must be a patch-added data or an existing producer-less data).
    pub fn add_op(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        inputs: Vec<DataId>,
        outputs: Vec<DataId>,
    ) -> OpId {
        let id = self.base_ops + self.new_ops.len();
        self.new_ops.push(OpNode {
            id,
            name: name.into(),
            kind,
            inputs,
            outputs,
        });
        id
    }

    /// Redirect every consumer of `from` (and graph-output status) to
    /// read `to` instead. Applied before patch-added ops are wired in,
    /// so an added op may read `from` and produce `to` (the insert
    /// pattern) without capturing its own rewire.
    pub fn rewire(&mut self, from: DataId, to: DataId) {
        self.rewires.push((from, to));
    }

    /// Append `data` to an existing op's input list (e.g. attaching a
    /// folded bias to a conv that had none).
    pub fn push_input(&mut self, op: OpId, data: DataId) {
        self.push_inputs.push((op, data));
    }

    /// Disconnect and remove op `id`. Its outputs must be left without
    /// consumers by the time the patch applies (rewire them first);
    /// orphaned inputs/outputs are swept with the dead-node pass.
    pub fn remove_op(&mut self, id: OpId) {
        self.removes.push(id);
    }

    /// Overwrite a parameter tensor (shape may change; activation shapes
    /// downstream are re-inferred at apply time).
    pub fn set_param(&mut self, data: DataId, t: Tensor) {
        self.param_edits.push((data, t));
    }

    /// Apply the patch to `g`: append datas → rewire → edit params →
    /// append ops → disconnect removals → sweep dead nodes → re-infer
    /// shapes → validate. Rewires run before the patch's ops are wired
    /// in, so an added op may read a rewired-away data (the insert
    /// pattern). `g` must be the graph (or an identically-shaped clone
    /// of the graph) the patch was built against.
    pub fn apply(self, g: &mut Graph) -> anyhow::Result<PatchReport> {
        let label = self.label.clone();
        self.apply_inner(g)
            .map_err(|e| anyhow::anyhow!("patch `{label}` failed: {e}"))
    }

    /// [`GraphPatch::apply`] plus a full static re-check
    /// ([`crate::check::check_graph`]) of the patched graph when `check`
    /// is enabled — the gate a patch must pass before any traffic routes
    /// to a plan compiled from it.
    pub fn apply_checked(
        self,
        g: &mut Graph,
        check: crate::check::CheckLevel,
    ) -> anyhow::Result<PatchReport> {
        let label = self.label.clone();
        let rep = self.apply(g)?;
        if check.enabled() {
            crate::check::check_graph(g)
                .map_err(|e| anyhow::anyhow!("patched graph `{label}` failed static checks: {e}"))?;
        }
        Ok(rep)
    }

    fn apply_inner(self, g: &mut Graph) -> anyhow::Result<PatchReport> {
        anyhow::ensure!(
            g.ops.len() == self.base_ops && g.datas.len() == self.base_datas,
            "stale patch: built against {} ops / {} datas, applying to {} / {}",
            self.base_ops,
            self.base_datas,
            g.ops.len(),
            g.datas.len()
        );
        let added_datas = self.new_datas.len();
        let added_ops = self.new_ops.len();
        // dirty set in pre-sweep id space; mapped to post-sweep ids below
        let mut touched: HashSet<OpId> = HashSet::new();

        // 1. append data nodes
        g.datas.extend(self.new_datas);

        // 2. re-wirings — before the patch's ops are wired in, so an
        //    added op may read `from` and produce the replacement data
        //    (the insert pattern) without capturing its own rewire
        for &(from, to) in &self.rewires {
            anyhow::ensure!(
                from < g.datas.len() && to < g.datas.len(),
                "rewire references unknown data ({from} -> {to})"
            );
            super::passes::replace_uses(g, from, to);
            touched.extend(g.datas[to].consumers.iter().copied());
        }

        // 3. parameter edits
        for (pid, t) in &self.param_edits {
            anyhow::ensure!(*pid < g.datas.len(), "param edit on unknown data {pid}");
            let d = &mut g.datas[*pid];
            anyhow::ensure!(
                d.is_param(),
                "param edit targets `{}` which is not a parameter",
                d.name
            );
            d.shape = t.shape.clone();
            d.kind = DataKind::Param(t.clone());
            touched.extend(d.consumers.iter().copied());
        }

        // 4. extra input attachments
        for &(op, data) in &self.push_inputs {
            anyhow::ensure!(op < g.ops.len(), "push_input on unknown op {op}");
            anyhow::ensure!(data < g.datas.len(), "push_input of unknown data {data}");
            g.ops[op].inputs.push(data);
            g.datas[data].consumers.push(op);
            touched.insert(op);
        }

        // 5. append ops, wiring producer/consumer symmetry
        for op in self.new_ops {
            let id = op.id;
            anyhow::ensure!(id == g.ops.len(), "patch op ids must be dense");
            for &i in &op.inputs {
                anyhow::ensure!(i < g.datas.len(), "op `{}` reads unknown data {i}", op.name);
                g.datas[i].consumers.push(id);
            }
            for &o in &op.outputs {
                anyhow::ensure!(o < g.datas.len(), "op `{}` writes unknown data {o}", op.name);
                anyhow::ensure!(
                    g.datas[o].producer.is_none(),
                    "op `{}` writes data `{}` which already has a producer",
                    op.name,
                    g.datas[o].name
                );
                g.datas[o].producer = Some(id);
            }
            touched.insert(id);
            g.ops.push(op);
        }

        // 6. removals: disconnect, leaving an id-stable tombstone the
        //    sweep collects
        for &op_id in &self.removes {
            anyhow::ensure!(op_id < g.ops.len(), "remove of unknown op {op_id}");
            let inputs = std::mem::take(&mut g.ops[op_id].inputs);
            let outputs = std::mem::take(&mut g.ops[op_id].outputs);
            for i in inputs {
                g.datas[i].consumers.retain(|&c| c != op_id);
            }
            for o in outputs {
                g.datas[o].producer = None;
                anyhow::ensure!(
                    g.datas[o].consumers.is_empty() && !g.outputs.contains(&o),
                    "removed op `{}` still feeds `{}` — rewire its consumers first",
                    g.ops[op_id].name,
                    g.datas[o].name
                );
            }
            touched.remove(&op_id);
        }

        // 7. sweep + remap, then re-infer shapes on the clean graph
        let (swept_ops, swept_datas, data_map, op_map) = sweep_dead_nodes(g);
        g.refresh_shapes()?;
        g.validate()?;

        let mut touched_ops: Vec<OpId> =
            touched.iter().filter_map(|&o| op_map[o]).collect();
        touched_ops.sort_unstable();
        Ok(PatchReport {
            added_ops: added_ops.saturating_sub(
                (self.base_ops..self.base_ops + added_ops)
                    .filter(|&o| op_map[o].is_none())
                    .count(),
            ),
            added_datas: added_datas.saturating_sub(
                (self.base_datas..self.base_datas + added_datas)
                    .filter(|&d| data_map[d].is_none())
                    .count(),
            ),
            removed_ops: swept_ops,
            removed_datas: swept_datas,
            rewired: self.rewires.len(),
            param_edits: self.param_edits.len(),
            base_ops: self.base_ops,
            base_datas: self.base_datas,
            data_map,
            op_map,
            touched_ops,
            edited_params: self.param_edits.iter().map(|(d, _)| *d).collect(),
        })
    }
}

/// `eliminate_identity` expressed as a patch: rewire each Identity's
/// output to its input and remove the op. Returns `None` when the graph
/// has no identities (nothing to patch).
pub fn identity_patch(g: &Graph) -> Option<GraphPatch> {
    let mut p = GraphPatch::new("eliminate-identity", g);
    for op in &g.ops {
        if matches!(op.kind, OpKind::Identity) && !op.inputs.is_empty() {
            // resolve chains of identities to the root non-identity
            // data, since every rewire is recorded against the
            // unpatched graph
            let mut to = op.inputs[0];
            while let Some(prod) = g.datas[to].producer {
                if matches!(g.ops[prod].kind, OpKind::Identity) && !g.ops[prod].inputs.is_empty() {
                    to = g.ops[prod].inputs[0];
                } else {
                    break;
                }
            }
            p.rewire(op.outputs[0], to);
            p.remove_op(op.id);
        }
    }
    if p.is_empty() {
        None
    } else {
        Some(p)
    }
}

/// `fold_batchnorm` expressed as a patch: per foldable BN, edit the
/// preceding conv/gemm weight (and bias, appending one if absent),
/// rewire the BN's output to the conv's, and remove the BN. Same
/// fold conditions as the pass: the conv/gemm output must feed *only*
/// the BN. Returns `None` when nothing is foldable.
pub fn batchnorm_fold_patch(g: &Graph) -> anyhow::Result<Option<GraphPatch>> {
    let mut p = GraphPatch::new("fold-batchnorm", g);
    // weight/bias edits may stack when two BNs share a producer chain;
    // the fold conditions make producers unique per BN, so one edit each
    for bn in &g.ops {
        let OpKind::BatchNorm { eps } = bn.kind else {
            continue;
        };
        let x = match bn.inputs.first() {
            Some(&x) => x,
            None => continue,
        };
        let Some(prod) = g.datas[x].producer else {
            continue;
        };
        if g.datas[x].consumers.len() != 1 {
            continue;
        }
        let has_bias = match g.ops[prod].kind {
            OpKind::Conv2d { .. } | OpKind::Gemm => g.ops[prod].inputs.len() > 2,
            _ => continue,
        };
        let (gamma, beta, mean, var) = {
            let ins = &bn.inputs;
            (
                g.datas[ins[1]].param().unwrap(),
                g.datas[ins[2]].param().unwrap(),
                g.datas[ins[3]].param().unwrap(),
                g.datas[ins[4]].param().unwrap(),
            )
        };
        let co = gamma.numel();
        let scale: Vec<f32> = (0..co)
            .map(|c| gamma.data[c] / (var.data[c] + eps).sqrt())
            .collect();
        let wid = g.ops[prod].inputs[1];
        let mut w = g.datas[wid].param().unwrap().clone();
        let inner = w.numel() / co;
        for c in 0..co {
            for v in &mut w.data[c * inner..(c + 1) * inner] {
                *v *= scale[c];
            }
        }
        p.set_param(wid, w);
        if has_bias {
            let bid = g.ops[prod].inputs[2];
            let mut b = g.datas[bid].param().unwrap().clone();
            for c in 0..co {
                b.data[c] = (b.data[c] - mean.data[c]) * scale[c] + beta.data[c];
            }
            p.set_param(bid, b);
        } else {
            let bias: Vec<f32> = (0..co)
                .map(|c| -mean.data[c] * scale[c] + beta.data[c])
                .collect();
            let bid = p.add_data(
                format!("{}.folded_bias", g.ops[prod].name),
                vec![co],
                DataKind::Param(Tensor::new(vec![co], bias)),
            );
            p.push_input(prod, bid);
        }
        p.rewire(bn.outputs[0], x);
        p.remove_op(bn.id);
    }
    Ok(if p.is_empty() { None } else { Some(p) })
}

/// Run the patch-expressible optimize passes (identity elimination, then
/// BN folding) as sequential patches, verifying after each when `check`
/// is enabled. Mirrors the front half of `ir::passes::optimize`.
pub fn optimize_as_patches(
    g: &mut Graph,
    check: crate::check::CheckLevel,
) -> anyhow::Result<Vec<PatchReport>> {
    let mut reports = Vec::new();
    if let Some(p) = identity_patch(g) {
        reports.push(p.apply_checked(g, check)?);
    }
    if let Some(p) = batchnorm_fold_patch(g)? {
        reports.push(p.apply_checked(g, check)?);
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine;
    use crate::ir::passes;
    use crate::ir::GraphBuilder;
    use crate::tensor::assert_allclose;
    use crate::util::Rng;
    use crate::zoo::{self, ImageCfg};

    fn conv_graph() -> Graph {
        let mut b = GraphBuilder::new("patchy", 1);
        let x = b.input("x", vec![1, 3, 8, 8]);
        let i = b.identity("drop", x);
        let c = b.conv2d("c1", i, 4, 3, 1, 1, 1, true);
        let n = b.batchnorm("bn1", c);
        let r = b.relu("r1", n);
        let g2 = b.global_avgpool("gap", r);
        let out = b.gemm("fc", g2, 3, true);
        b.output(out);
        b.finish().unwrap()
    }

    #[test]
    fn identity_patch_matches_the_pass() {
        let mut via_patch = conv_graph();
        let mut via_pass = via_patch.clone();
        let mut rng = Rng::new(3);
        let x = Tensor::new(vec![1, 3, 8, 8], rng.uniform_vec(192, -1.0, 1.0));
        let before = engine::predict(&via_patch, x.clone()).unwrap();

        let rep = identity_patch(&via_patch)
            .expect("one identity")
            .apply(&mut via_patch)
            .unwrap();
        passes::eliminate_identity(&mut via_pass).unwrap();

        assert_eq!(rep.removed_ops, 1);
        assert_eq!(via_patch.ops.len(), via_pass.ops.len());
        assert!(via_patch
            .ops
            .iter()
            .all(|o| !matches!(o.kind, OpKind::Identity)));
        let after = engine::predict(&via_patch, x).unwrap();
        for (a, b) in before.data.iter().zip(&after.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "identity patch must be exact");
        }
    }

    #[test]
    fn batchnorm_patch_matches_the_pass() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut via_patch = zoo::vgg16(cfg, 3);
        let mut rng = Rng::new(4);
        for d in &mut via_patch.datas {
            let name = d.name.clone();
            if let Some(t) = d.param_mut() {
                if name.ends_with(".mean") {
                    t.data = rng.uniform_vec(t.numel(), -0.5, 0.5);
                } else if name.ends_with(".var") {
                    t.data = rng.uniform_vec(t.numel(), 0.5, 2.0);
                }
            }
        }
        let mut via_pass = via_patch.clone();
        let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 192, -1.0, 1.0));
        let before = engine::predict(&via_patch, x.clone()).unwrap();

        let rep = batchnorm_fold_patch(&via_patch)
            .unwrap()
            .expect("foldable BNs")
            .apply(&mut via_patch)
            .unwrap();
        let folded = passes::fold_batchnorm(&mut via_pass).unwrap();

        assert!(folded >= 10, "folded only {folded}");
        assert_eq!(rep.removed_ops, folded, "exactly the folded BNs are swept");
        assert_eq!(via_patch.ops.len(), via_pass.ops.len());
        assert_eq!(via_patch.num_params(), via_pass.num_params());
        let after = engine::predict(&via_patch, x).unwrap();
        assert_allclose(&after, &before, 1e-3, 1e-3);
    }

    #[test]
    fn patch_inserts_an_op_without_rebuilding() {
        let mut g = conv_graph();
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![1, 3, 8, 8], rng.uniform_vec(192, -1.0, 1.0));
        let before = engine::predict(&g, x.clone()).unwrap();
        // splice a Scale(2.0) between gap and fc
        let gap_out = g.op_by_name("gap").unwrap().outputs[0];
        let mut p = GraphPatch::new("insert-scale", &g);
        let scaled = p.add_data("gap.scaled", g.data(gap_out).shape.clone(), DataKind::Activation);
        p.rewire(gap_out, scaled);
        p.add_op("scale2", OpKind::Scale { c: 2.0 }, vec![gap_out], vec![scaled]);
        let rep = p.apply(&mut g).unwrap();
        assert_eq!(rep.added_ops, 1);
        assert!(!rep.touched_ops.is_empty());
        let after = engine::predict(&g, x).unwrap();
        // logits scale by 2 exactly
        for (a, b) in after.data.iter().zip(&before.data) {
            assert_eq!(a.to_bits(), (b * 2.0).to_bits());
        }
    }

    #[test]
    fn stale_patch_is_rejected() {
        let g = conv_graph();
        let mut p = GraphPatch::new("stale", &g);
        p.remove_op(0);
        let mut other = conv_graph();
        passes::eliminate_identity(&mut other).unwrap();
        let err = p.apply(&mut other).unwrap_err().to_string();
        assert!(err.contains("stale patch"), "got: {err}");
    }

    #[test]
    fn removing_a_consumed_op_without_rewire_is_rejected() {
        let mut g = conv_graph();
        let conv = g.op_by_name("c1").unwrap().id;
        let mut p = GraphPatch::new("bad-remove", &g);
        p.remove_op(conv);
        let err = p.apply(&mut g).unwrap_err().to_string();
        assert!(err.contains("rewire its consumers first"), "got: {err}");
    }

    #[test]
    fn report_maps_track_ids_across_the_sweep() {
        let g = conv_graph();
        let fc_old = g.op_by_name("fc").unwrap().id;
        let mut patched = g.clone();
        let rep = identity_patch(&g).unwrap().apply(&mut patched).unwrap();
        // identity op swept; fc survives and the map finds it
        let fc_new = rep.op_map[fc_old].expect("fc survives");
        assert_eq!(patched.ops[fc_new].name, "fc");
        let drop_old = g.op_by_name("drop").unwrap().id;
        assert!(rep.op_map[drop_old].is_none(), "identity must be swept");
        // every surviving base data maps to a node with the same name
        for (old, new) in rep.data_map.iter().enumerate() {
            if let Some(new) = new {
                assert_eq!(g.datas[old].name, patched.datas[*new].name);
            }
        }
    }

    #[test]
    fn optimize_as_patches_matches_pass_numerics() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut via_patch = zoo::resnet18(cfg, 9);
        let mut via_pass = via_patch.clone();
        let mut rng = Rng::new(6);
        let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 192, -1.0, 1.0));
        let reports =
            optimize_as_patches(&mut via_patch, crate::check::CheckLevel::Strict).unwrap();
        assert!(!reports.is_empty());
        passes::eliminate_identity(&mut via_pass).unwrap();
        passes::fold_batchnorm(&mut via_pass).unwrap();
        assert_eq!(via_patch.ops.len(), via_pass.ops.len());
        let a = engine::predict(&via_patch, x.clone()).unwrap();
        let b = engine::predict(&via_pass, x).unwrap();
        assert_allclose(&a, &b, 1e-5, 1e-5);
    }
}
