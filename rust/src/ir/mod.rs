//! SPA-IR: the standardized computational graph (the paper's ONNX analog,
//! §3.1).
//!
//! The graph holds three node taxonomies exactly as Fig. 2 of the paper:
//! *operator nodes* ([`OpNode`]), *normal data nodes* and *parameter data
//! nodes* (both [`DataNode`], distinguished by [`DataKind`]). Unlike a
//! dependency graph, data nodes are first-class: every operator records
//! which tensors it reads/writes, and every tensor records its producer
//! and consumers — this is what makes the mask-propagation analysis of
//! §3.2 architecture-agnostic.

pub mod build;
pub mod passes;
pub mod patch;
pub mod serde;
pub mod shape;

pub use build::GraphBuilder;
pub use patch::{GraphPatch, PatchReport};

use crate::tensor::Tensor;
use std::collections::HashMap;

/// Index of a data node within [`Graph::datas`].
pub type DataId = usize;
/// Index of an operator node within [`Graph::ops`].
pub type OpId = usize;

/// Operator vocabulary. These mirror the fundamental ONNX operators the
/// paper's §A.3 defines propagation rules over, restricted to the set our
/// model zoo exercises (conv/gemm/norm/attention/etc.).
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// inputs: x[N,Ci,H,W], w[Co,Ci/g,kh,kw], optional b[Co]
    Conv2d {
        stride: usize,
        pad: usize,
        groups: usize,
    },
    /// inputs: x[..., K], w[Cout, K], optional b[Cout] — the paper's GeMM
    Gemm,
    /// inputs: x, gamma[C], beta[C], mean[C], var[C] (channel dim = 1)
    BatchNorm { eps: f32 },
    /// inputs: x[..., D], gamma[D], beta[D]
    LayerNorm { eps: f32 },
    Relu,
    Gelu,
    Silu,
    Sigmoid,
    Tanh,
    /// elementwise a + b; shapes equal, or b broadcast with shape [C] /
    /// [1,C,1,1] against channel dim
    Add,
    /// elementwise a * b (same broadcast semantics as Add; used by SE)
    Mul,
    MaxPool2d {
        k: usize,
        stride: usize,
        pad: usize,
    },
    AvgPool2d {
        k: usize,
        stride: usize,
        pad: usize,
    },
    /// [N,C,H,W] → [N,C]
    GlobalAvgPool,
    /// [N,C,H,W] → [N, C·H·W]
    Flatten,
    /// concatenate along `axis`
    Concat { axis: usize },
    /// softmax over the last dim
    Softmax,
    /// batched matmul over the last two dims
    MatMul,
    Transpose { perm: Vec<usize> },
    /// [N,T,D] → [N,h,T,D/h]: split hidden into heads (transformer)
    SplitHeads { heads: usize },
    /// [N,h,T,D/h] → [N,T,D]
    MergeHeads,
    /// multiply by constant (attention 1/√d etc.)
    Scale { c: f32 },
    /// ids [N,T] + table [V,D] → [N,T,D]
    Embedding,
    /// mean over `axis` keeping other dims ([N,T,D] --axis 1--> [N,D])
    ReduceMean { axis: usize },
    /// [N,C,H,W] → [N, H·W, C]: patch-embedding to token sequence (ViT)
    NchwToTokens,
    /// no-op (dropout at inference, identity branches)
    Identity,
}

impl OpKind {
    /// Short stable name used in serialization and reports.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Conv2d { .. } => "conv2d",
            OpKind::Gemm => "gemm",
            OpKind::BatchNorm { .. } => "batchnorm",
            OpKind::LayerNorm { .. } => "layernorm",
            OpKind::Relu => "relu",
            OpKind::Gelu => "gelu",
            OpKind::Silu => "silu",
            OpKind::Sigmoid => "sigmoid",
            OpKind::Tanh => "tanh",
            OpKind::Add => "add",
            OpKind::Mul => "mul",
            OpKind::MaxPool2d { .. } => "maxpool2d",
            OpKind::AvgPool2d { .. } => "avgpool2d",
            OpKind::GlobalAvgPool => "globalavgpool",
            OpKind::Flatten => "flatten",
            OpKind::Concat { .. } => "concat",
            OpKind::Softmax => "softmax",
            OpKind::MatMul => "matmul",
            OpKind::Transpose { .. } => "transpose",
            OpKind::SplitHeads { .. } => "splitheads",
            OpKind::MergeHeads => "mergeheads",
            OpKind::Scale { .. } => "scale",
            OpKind::Embedding => "embedding",
            OpKind::ReduceMean { .. } => "reducemean",
            OpKind::NchwToTokens => "nchwtotokens",
            OpKind::Identity => "identity",
        }
    }
}

/// What a data node holds.
#[derive(Debug, Clone, PartialEq)]
pub enum DataKind {
    /// Graph input (activations fed at call time).
    Input,
    /// Intermediate activation produced by an operator.
    Activation,
    /// Parameter with materialized weights (the paper's v_param).
    Param(Tensor),
}

/// A tensor-valued node: graph input, intermediate, or parameter.
#[derive(Debug, Clone)]
pub struct DataNode {
    pub id: DataId,
    pub name: String,
    /// Static shape. Batch dim of activations uses the builder's nominal
    /// batch size; shape inference re-derives it for any actual batch.
    pub shape: Vec<usize>,
    pub kind: DataKind,
    /// Operator writing this tensor (None for inputs/params).
    pub producer: Option<OpId>,
    /// Operators reading this tensor.
    pub consumers: Vec<OpId>,
}

impl DataNode {
    pub fn is_param(&self) -> bool {
        matches!(self.kind, DataKind::Param(_))
    }

    pub fn param(&self) -> Option<&Tensor> {
        match &self.kind {
            DataKind::Param(t) => Some(t),
            _ => None,
        }
    }

    pub fn param_mut(&mut self) -> Option<&mut Tensor> {
        match &mut self.kind {
            DataKind::Param(t) => Some(t),
            _ => None,
        }
    }
}

/// An operator node linking data nodes.
#[derive(Debug, Clone)]
pub struct OpNode {
    pub id: OpId,
    pub name: String,
    pub kind: OpKind,
    /// Inputs in positional order (activations first, then params — e.g.
    /// Conv2d: [x, w] or [x, w, b]).
    pub inputs: Vec<DataId>,
    pub outputs: Vec<DataId>,
}

/// The SPA computational graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub ops: Vec<OpNode>,
    pub datas: Vec<DataNode>,
    pub inputs: Vec<DataId>,
    pub outputs: Vec<DataId>,
}

impl Graph {
    pub fn data(&self, id: DataId) -> &DataNode {
        &self.datas[id]
    }

    pub fn op(&self, id: OpId) -> &OpNode {
        &self.ops[id]
    }

    /// Data node lookup by name (tests / debugging).
    pub fn data_by_name(&self, name: &str) -> Option<&DataNode> {
        self.datas.iter().find(|d| d.name == name)
    }

    pub fn op_by_name(&self, name: &str) -> Option<&OpNode> {
        self.ops.iter().find(|o| o.name == name)
    }

    /// All operators touching data node `id` (producer + consumers) — the
    /// `neighbor(u, CG)` of the paper's Alg. 1.
    pub fn neighbor_ops(&self, id: DataId) -> Vec<OpId> {
        let d = &self.datas[id];
        let mut out = Vec::with_capacity(d.consumers.len() + 1);
        if let Some(p) = d.producer {
            out.push(p);
        }
        out.extend_from_slice(&d.consumers);
        out
    }

    /// Topological order of operators (Kahn). Errors on cycles.
    pub fn topo_order(&self) -> anyhow::Result<Vec<OpId>> {
        let mut indeg = vec![0usize; self.ops.len()];
        for op in &self.ops {
            for &i in &op.inputs {
                if self.datas[i].producer.is_some() {
                    indeg[op.id] += 1;
                }
            }
        }
        let mut queue: Vec<OpId> = (0..self.ops.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.ops.len());
        let mut qi = 0;
        while qi < queue.len() {
            let op = queue[qi];
            qi += 1;
            order.push(op);
            for &out in &self.ops[op].outputs {
                for &cons in &self.datas[out].consumers {
                    indeg[cons] -= 1;
                    if indeg[cons] == 0 {
                        queue.push(cons);
                    }
                }
            }
        }
        if order.len() != self.ops.len() {
            anyhow::bail!(
                "graph `{}` has a cycle ({} of {} ops ordered)",
                self.name,
                order.len(),
                self.ops.len()
            );
        }
        Ok(order)
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.datas
            .iter()
            .filter_map(|d| d.param().map(|t| t.numel()))
            .sum()
    }

    /// All parameter data ids.
    pub fn param_ids(&self) -> Vec<DataId> {
        self.datas
            .iter()
            .filter(|d| d.is_param())
            .map(|d| d.id)
            .collect()
    }

    /// Structural validation: ids consistent, producer/consumer symmetric,
    /// shapes consistent with operator semantics (via shape inference).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, d) in self.datas.iter().enumerate() {
            anyhow::ensure!(d.id == i, "data id mismatch at {i}");
            if let Some(p) = d.producer {
                anyhow::ensure!(
                    self.ops[p].outputs.contains(&i),
                    "data `{}` claims producer `{}` which does not output it",
                    d.name,
                    self.ops[p].name
                );
            }
            for &c in &d.consumers {
                anyhow::ensure!(
                    self.ops[c].inputs.contains(&i),
                    "data `{}` claims consumer `{}` which does not input it",
                    d.name,
                    self.ops[c].name
                );
            }
        }
        for (i, op) in self.ops.iter().enumerate() {
            anyhow::ensure!(op.id == i, "op id mismatch at {i}");
            for &d in op.inputs.iter().chain(&op.outputs) {
                anyhow::ensure!(d < self.datas.len(), "op `{}` references bad data id", op.name);
            }
            for &o in &op.outputs {
                anyhow::ensure!(
                    self.datas[o].producer == Some(i),
                    "output `{}` of op `{}` has wrong producer",
                    self.datas[o].name,
                    op.name
                );
            }
        }
        self.topo_order()?;
        // Shape inference must succeed and agree with recorded shapes.
        let shapes = shape::infer_shapes(self)?;
        for d in &self.datas {
            if let Some(s) = shapes.get(&d.id) {
                anyhow::ensure!(
                    s == &d.shape,
                    "shape mismatch on `{}`: recorded {:?}, inferred {:?}",
                    d.name,
                    d.shape,
                    s
                );
            }
        }
        Ok(())
    }

    /// Re-run shape inference and overwrite recorded activation shapes
    /// (used by the pruner after structural deletion).
    pub fn refresh_shapes(&mut self) -> anyhow::Result<()> {
        let shapes = shape::infer_shapes(self)?;
        for d in &mut self.datas {
            if let Some(s) = shapes.get(&d.id) {
                d.shape = s.clone();
            }
        }
        Ok(())
    }

    /// Map from data name to id (serde + tests).
    pub fn name_index(&self) -> HashMap<String, DataId> {
        self.datas
            .iter()
            .map(|d| (d.name.clone(), d.id))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::GraphBuilder;

    fn tiny_graph() -> Graph {
        // input → conv(4) → bn → relu → gap → gemm(3)
        let mut b = GraphBuilder::new("tiny", 2);
        let x = b.input("x", vec![2, 3, 8, 8]);
        let c = b.conv2d("conv1", x, 4, 3, 1, 1, 1, true);
        let n = b.batchnorm("bn1", c);
        let r = b.relu("relu1", n);
        let g = b.global_avgpool("gap", r);
        let out = b.gemm("fc", g, 3, true);
        b.output(out);
        b.finish().unwrap()
    }

    #[test]
    fn builds_and_validates() {
        let g = tiny_graph();
        g.validate().unwrap();
        assert_eq!(g.inputs.len(), 1);
        assert_eq!(g.outputs.len(), 1);
        assert_eq!(g.ops.len(), 5);
    }

    #[test]
    fn neighbor_ops_symmetric() {
        let g = tiny_graph();
        for d in &g.datas {
            for op in g.neighbor_ops(d.id) {
                let o = g.op(op);
                assert!(
                    o.inputs.contains(&d.id) || o.outputs.contains(&d.id),
                    "asymmetric link"
                );
            }
        }
    }

    #[test]
    fn topo_order_respects_deps() {
        let g = tiny_graph();
        let order = g.topo_order().unwrap();
        let pos: HashMap<OpId, usize> = order.iter().enumerate().map(|(i, &o)| (o, i)).collect();
        for op in &g.ops {
            for &inp in &op.inputs {
                if let Some(p) = g.datas[inp].producer {
                    assert!(pos[&p] < pos[&op.id], "producer after consumer");
                }
            }
        }
    }

    #[test]
    fn num_params_counts() {
        let g = tiny_graph();
        // conv w 4*3*3*3 + b 4 + bn 4*4 + fc w 3*4 + b 3
        assert_eq!(g.num_params(), 108 + 4 + 16 + 12 + 3);
    }

    #[test]
    fn validate_catches_broken_producer() {
        let mut g = tiny_graph();
        // corrupt: point an activation's producer at the wrong op
        let act = g
            .datas
            .iter()
            .find(|d| matches!(d.kind, DataKind::Activation) && d.producer == Some(0))
            .unwrap()
            .id;
        g.datas[act].producer = Some(2);
        assert!(g.validate().is_err());
    }
}
