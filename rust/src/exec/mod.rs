//! `spa::exec` — compiled execution plans for inference.
//!
//! The interpreter (`crate::engine`) re-walks the graph, re-allocates
//! every intermediate, and re-derives every decision on each call — the
//! right trade-off for autodiff and shape-shifting training, and the
//! wrong one for the paper's "any time" serving story, where a pruned
//! graph is evaluated thousands of times (BN recalibration, OBSPA
//! calibration sweeps, fine-tune eval loops, benchmark tables). This
//! module compiles a graph **once** into an immutable [`Plan`] and then
//! executes it many times against a reusable [`Workspace`]:
//!
//! * **Topological schedule** — op dispatch order, input/output
//!   locations, and fusion decisions are resolved at compile time;
//! * **Buffer arena** — a liveness analysis maps intermediates onto a
//!   small set of reusable slots, so steady-state inference allocates
//!   nothing and peak activation memory drops well below the
//!   interpreter's keep-everything strategy ([`PlanReport`] quantifies
//!   both);
//! * **Op fusion** — eval-mode BatchNorm collapses into its producer as
//!   an in-place per-channel affine, and unary activations collapse into
//!   an in-place map, so Conv→BN→ReLU / Gemm→Act chains run as single
//!   kernels with **bit-identical** results (the fused arithmetic is the
//!   same per-element expressions the interpreter evaluates);
//! * **Batched inference** — [`Batcher`] fans independent requests out
//!   over the `crate::util::par` worker pool, deterministically: outputs
//!   are byte-equal at any `SPA_THREADS` width.
//!
//! [`OptLevel::Exact`] (the default) performs no graph rewriting, which
//! makes plan outputs bit-identical to `engine::forward` in
//! [`crate::engine::Mode::Eval`] — `tests/exec_parity.rs` enforces this
//! across randomly pruned zoo models. [`OptLevel::Fast`] additionally
//! runs the [`crate::ir::passes::optimize`] pipeline (dead nodes →
//! identities → BN fold → constant fold) on the plan's private graph
//! copy; numerics then agree up to the float reassociation of BN weight
//! folding.
//!
//! ```no_run
//! use spa::criteria::Criterion;
//! use spa::{Session, Target};
//! # fn main() -> anyhow::Result<()> {
//! let model = spa::zoo::resnet18(spa::zoo::ImageCfg::default(), 42);
//! let pruned = Session::on(&model)
//!     .criterion(Criterion::L1)
//!     .target(Target::FlopsRf(2.0))
//!     .plan()?
//!     .apply()?;
//! let plan = pruned.compile()?;             // compile once
//! let mut runner = plan.runner();           // owns a reusable Workspace
//! # let x = spa::tensor::Tensor::zeros(&[8, 3, 32, 32]);
//! let logits = runner.predict(&x)?;         // run many
//! println!("peak arena: {} bytes", plan.report().peak_arena_bytes);
//! # Ok(()) }
//! ```
//!
//! [`Runner`] is the single entry point for repeated inference: it pairs
//! a plan with an owned, reusable [`Workspace`] so callers (the serve
//! batch loop, [`Batcher`], `train::evaluate`, OBSPA capture) stop
//! hand-rolling workspace management. [`Plan::predict`] remains as a
//! one-shot convenience shim over a throwaway runner.

use crate::check::CheckLevel;
use crate::ir::passes::{self, OptReport};
use crate::ir::shape::infer_op_output_shapes;
use crate::ir::{DataId, DataKind, Graph, OpId, OpKind, OpNode, PatchReport};
use crate::tensor::{ops, Tensor};
use crate::util::par;
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

/// How aggressively [`Plan::compile`] may transform the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum OptLevel {
    /// Schedule + arena only; no fusion. The debugging baseline.
    None,
    /// Schedule + arena + in-place BN/activation fusion. No graph
    /// rewriting, so data ids stay valid and outputs are bit-identical
    /// to the interpreter in eval mode. The default.
    #[default]
    Exact,
    /// `Exact` plus the [`crate::ir::passes::optimize`] rewrite pipeline
    /// on the plan's private graph copy. Fastest; numerics agree with
    /// the interpreter up to BN-fold float reassociation, and data ids
    /// are remapped (use [`Plan::inputs`] / [`Plan::outputs`]).
    Fast,
}

/// Options for [`Plan::compile`].
#[derive(Debug, Clone, Default)]
pub struct PlanOpts {
    /// Optimization level (default [`OptLevel::Exact`]).
    pub level: OptLevel,
    /// Data ids whose values must remain readable from the [`Workspace`]
    /// after a run ([`Plan::value`]) — the activation-collection hook
    /// OBSPA uses for its layer-wise Hessians. Retained ids are pinned
    /// out of arena reuse and block fusion across themselves. Only valid
    /// with id-stable levels (`None` / `Exact`).
    pub retain: Vec<DataId>,
    /// Static verification level: when enabled, the compiled plan is
    /// verified by [`crate::check::check_plan`] before it is returned, and
    /// at [`CheckLevel::Strict`] the plan's (possibly rewritten) graph is
    /// additionally re-checked by [`crate::check::check_graph`]. Defaults
    /// to [`CheckLevel::Debug`] under `debug_assertions`, `Off` in
    /// release.
    pub check: CheckLevel,
}

/// What [`Plan::compile`] produced, in numbers.
#[derive(Debug, Clone, Default)]
pub struct PlanReport {
    /// Executable steps (fused chains count once).
    pub steps: usize,
    /// Operators folded into a predecessor step as in-place post-ops.
    pub fused_ops: usize,
    /// Reshape-only operators (Identity / Flatten) resolved to aliases.
    pub aliased_ops: usize,
    /// Distinct arena slots backing all intermediates.
    pub arena_slots: usize,
    /// Total arena bytes at the graph's nominal shapes.
    pub peak_arena_bytes: usize,
    /// Bytes the interpreter materializes for the same graph (every
    /// activation simultaneously, nominal shapes).
    pub interp_intermediate_bytes: usize,
    /// Bytes of precomputed Gemm weight transposes the plan carries on
    /// top of its graph copy (a compile-time space-for-time trade the
    /// arena numbers above do not include).
    pub gemm_wt_bytes: usize,
    /// Maximal runs of consecutive patch-dirtied schedule items an
    /// incremental [`Plan::recompile`] rebuilt. 0 for a fresh compile.
    pub recompiled_regions: usize,
    /// Steps an incremental recompile carried over untouched (their op,
    /// fused chain, and params were outside every recompiled region).
    pub reused_steps: usize,
    /// Pre-transposed Gemm weights an incremental recompile reused from
    /// the old plan instead of re-packing.
    pub reused_gemm_wt: usize,
    /// Rewrite-pass report when compiled at [`OptLevel::Fast`].
    pub opt: Option<OptReport>,
}

impl PlanReport {
    /// Fraction of steps an incremental recompile reused (0.0 for a
    /// fresh compile; 1.0 when a patch dirtied nothing that executes).
    pub fn reuse_ratio(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.reused_steps as f64 / self.steps as f64
        }
    }
}

/// Where a data node's value lives at run time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Loc {
    /// `k`-th graph input, bound per run.
    Feed(usize),
    /// Parameter on the plan's graph.
    Param(DataId),
    /// Arena slot.
    Slot(usize),
}

/// Fused in-place epilogue applied to a step's output buffer.
#[derive(Debug, Clone)]
pub(crate) enum PostOp {
    /// Eval-mode BatchNorm as a per-channel affine (`v·scale + shift`,
    /// exactly [`ops::batchnorm_infer`]'s arithmetic).
    Bn {
        gamma: DataId,
        beta: DataId,
        mean: DataId,
        var: DataId,
        eps: f32,
    },
    Act(Act),
}

/// Unary activations that fuse (same per-element expressions as the
/// interpreter's eval path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Act {
    Relu,
    Gelu,
    Silu,
    Sigmoid,
    Tanh,
}

fn apply_act(a: Act, buf: &mut [f32]) {
    match a {
        Act::Relu => {
            for v in buf {
                *v = v.max(0.0);
            }
        }
        Act::Gelu => {
            for v in buf {
                *v = ops::gelu(*v);
            }
        }
        Act::Silu => {
            for v in buf {
                *v = *v / (1.0 + (-*v).exp());
            }
        }
        Act::Sigmoid => {
            for v in buf {
                *v = 1.0 / (1.0 + (-*v).exp());
            }
        }
        Act::Tanh => {
            for v in buf {
                *v = v.tanh();
            }
        }
    }
}

pub(crate) fn act_of(kind: &OpKind) -> Option<Act> {
    match kind {
        OpKind::Relu => Some(Act::Relu),
        OpKind::Gelu => Some(Act::Gelu),
        OpKind::Silu => Some(Act::Silu),
        OpKind::Sigmoid => Some(Act::Sigmoid),
        OpKind::Tanh => Some(Act::Tanh),
        _ => None,
    }
}

/// One schedule entry.
#[derive(Debug, Clone)]
pub(crate) enum Item {
    /// Reshape-only op: the output aliases the input's location; only
    /// the shape changes.
    Alias { op: OpId },
    /// A real kernel dispatch writing `out_slot`, then applying `post`
    /// in place. `out_data` is the data id whose value the slot holds
    /// afterwards (the end of the fused chain).
    Step {
        op: OpId,
        out_data: DataId,
        out_slot: usize,
        post: Vec<PostOp>,
    },
}

/// An immutable, reusable execution plan — see the [module docs](self).
/// Internals are `pub(crate)` so [`crate::check::check_plan`] can verify
/// the schedule/arena invariants without an accessor per field.
pub struct Plan {
    pub(crate) graph: Graph,
    pub(crate) schedule: Vec<Item>,
    pub(crate) loc: Vec<Option<Loc>>,
    pub(crate) slot_count: usize,
    pub(crate) readable: HashSet<DataId>,
    /// Per graph-input: whether a readable id resolves to this feed, so
    /// its tensor must be copied into the workspace at run time.
    pub(crate) keep_feeds: Vec<bool>,
    /// Pre-transposed `[K, N]` weights per Gemm op, so the multi-row
    /// GEMM path skips the interpreter's per-call `w.t2()`.
    pub(crate) gemm_wt: HashMap<OpId, Tensor>,
    pub(crate) report: PlanReport,
}

/// Conv im2col / GEMM scratch, reused across runs (the interpreter
/// re-allocates the equivalent buffers on every call).
#[derive(Default)]
struct Scratch {
    cols: Vec<f32>,
    yb: Vec<f32>,
}

/// Reusable per-thread run state for a [`Plan`]: the arena buffers plus
/// per-run shapes and feed copies. Create with [`Plan::workspace`]; reuse
/// across calls to avoid all steady-state allocation.
pub struct Workspace {
    slots: Vec<Vec<f32>>,
    shapes: Vec<Vec<usize>>,
    feeds: Vec<Option<Tensor>>,
    scratch: Scratch,
}

/// Work carried from an old [`Plan`] into an incremental recompile —
/// keyed by ids in the *patched* graph (mapped through the
/// [`PatchReport`] before construction).
struct Reuse {
    /// Pre-transposed Gemm weights whose op and weight param survived
    /// the patch untouched.
    gemm_wt: HashMap<OpId, Tensor>,
    /// Old arena slot per surviving step output, preferred when free so
    /// untouched schedule regions keep their slot assignment.
    preferred: HashMap<DataId, usize>,
    /// Ops the patch dirtied (patched-graph ids): rewired inputs, edited
    /// params, or patch-added. Everything else may be carried over.
    dirty: HashSet<OpId>,
}

impl Plan {
    /// Compile `graph` into an execution plan. The graph is cloned (the
    /// plan is self-contained and immutable); at [`OptLevel::Fast`] the
    /// private copy is additionally rewritten by
    /// [`crate::ir::passes::optimize`].
    pub fn compile(g: &Graph, opts: PlanOpts) -> anyhow::Result<Plan> {
        Plan::compile_impl(g, opts, None)
    }

    /// Incrementally recompile this plan for `patched` — the result of
    /// applying a [`crate::ir::GraphPatch`] (built against this plan's
    /// graph) whose [`PatchReport`] is `rep`. Only schedule regions the
    /// patch dirtied are rebuilt from scratch: untouched steps keep
    /// their arena slots and untouched Gemms keep their pre-transposed
    /// weights ([`PlanReport::recompiled_regions`] /
    /// [`PlanReport::reused_steps`] / [`PlanReport::reused_gemm_wt`]
    /// quantify the split). The compiled plan is bit-identical to a
    /// fresh [`Plan::compile`] of `patched` at the same options.
    pub fn recompile(
        &self,
        patched: &Graph,
        rep: &PatchReport,
        opts: PlanOpts,
    ) -> anyhow::Result<Plan> {
        anyhow::ensure!(
            opts.level != OptLevel::Fast,
            "incremental recompile requires an id-stable level (None/Exact), not Fast"
        );
        anyhow::ensure!(
            rep.base_ops == self.graph.ops.len() && rep.base_datas == self.graph.datas.len(),
            "patch report was built against a different graph ({} ops / {} datas; this plan has {} / {})",
            rep.base_ops,
            rep.base_datas,
            self.graph.ops.len(),
            self.graph.datas.len()
        );
        let dirty: HashSet<OpId> = rep.touched_ops.iter().copied().collect();
        let edited: HashSet<DataId> = rep.edited_params.iter().copied().collect();
        // Carry packed Gemm weights: the op must survive clean and its
        // weight param must map through the sweep unedited.
        let mut carry: HashMap<OpId, Tensor> = HashMap::new();
        for (&old_op, wt) in &self.gemm_wt {
            let Some(new_op) = rep.op_map.get(old_op).copied().flatten() else {
                continue;
            };
            if dirty.contains(&new_op) {
                continue;
            }
            let old_w = self.graph.ops[old_op].inputs[1];
            if edited.contains(&old_w) {
                continue;
            }
            let mapped = rep.data_map.get(old_w).copied().flatten();
            if mapped.is_some() && mapped == patched.ops[new_op].inputs.get(1).copied() {
                carry.insert(new_op, wt.clone());
            }
        }
        let mut preferred: HashMap<DataId, usize> = HashMap::new();
        for item in &self.schedule {
            if let Item::Step {
                out_data, out_slot, ..
            } = item
            {
                if let Some(new_id) = rep.data_map.get(*out_data).copied().flatten() {
                    preferred.insert(new_id, *out_slot);
                }
            }
        }
        Plan::compile_impl(
            patched,
            opts,
            Some(Reuse {
                gemm_wt: carry,
                preferred,
                dirty,
            }),
        )
    }

    fn compile_impl(g: &Graph, opts: PlanOpts, mut reuse: Option<Reuse>) -> anyhow::Result<Plan> {
        let _span = crate::obs::trace::span_with(
            if reuse.is_some() {
                "exec.recompile"
            } else {
                "exec.compile"
            },
            || format!("{} ops", g.ops.len()),
        );
        anyhow::ensure!(
            !(opts.level == OptLevel::Fast && !opts.retain.is_empty()),
            "PlanOpts::retain requires an id-stable level (None/Exact), not Fast"
        );
        let mut graph = g.clone();
        let opt = match opts.level {
            // thread the plan's check level through the rewrite pipeline
            // so every pass state is verified at the level the caller
            // asked for (not just the build-profile default)
            OptLevel::Fast => Some(passes::optimize_checked(&mut graph, opts.check)?),
            _ => None,
        };
        for &id in &opts.retain {
            anyhow::ensure!(
                id < graph.datas.len(),
                "retain id {id} out of range ({} data nodes)",
                graph.datas.len()
            );
        }
        let order = graph.topo_order()?;
        let nd = graph.datas.len();
        let mut loc: Vec<Option<Loc>> = vec![None; nd];
        for (k, &i) in graph.inputs.iter().enumerate() {
            loc[i] = Some(Loc::Feed(k));
        }
        for d in &graph.datas {
            if d.is_param() {
                loc[d.id] = Some(Loc::Param(d.id));
            }
        }
        let retain: HashSet<DataId> = opts.retain.iter().copied().collect();
        let outputs: HashSet<DataId> = graph.outputs.iter().copied().collect();

        // ---- Phase A: emit the schedule skeleton (fusion + aliases) ----
        struct Proto {
            op: OpId,
            out_data: DataId,
            post: Vec<PostOp>,
            /// Recompile only: the step's op or any op fused into it was
            /// dirtied by the patch, so the step is inside a rebuilt
            /// region.
            dirty: bool,
        }
        enum ProtoItem {
            Alias(OpId),
            Step(Proto),
        }
        let mut alias_src: HashMap<DataId, DataId> = HashMap::new();
        let mut fused: HashSet<OpId> = HashSet::new();
        let mut proto: Vec<ProtoItem> = Vec::new();
        let mut fused_ops = 0usize;
        let mut aliased_ops = 0usize;
        for &op_id in &order {
            if fused.contains(&op_id) {
                continue;
            }
            let op = &graph.ops[op_id];
            if op.outputs.is_empty() {
                continue; // neutralized leftover
            }
            if matches!(op.kind, OpKind::Identity | OpKind::Flatten) {
                alias_src.insert(op.outputs[0], op.inputs[0]);
                proto.push(ProtoItem::Alias(op_id));
                aliased_ops += 1;
                continue;
            }
            let mut out_data = op.outputs[0];
            let mut post: Vec<PostOp> = Vec::new();
            let mut dirty = reuse.as_ref().is_some_and(|r| r.dirty.contains(&op_id));
            if opts.level != OptLevel::None {
                loop {
                    let d = &graph.datas[out_data];
                    if d.consumers.len() != 1
                        || outputs.contains(&out_data)
                        || retain.contains(&out_data)
                    {
                        break;
                    }
                    let c = d.consumers[0];
                    let cop = &graph.ops[c];
                    match cop.kind {
                        OpKind::BatchNorm { eps } if cop.inputs[0] == out_data => {
                            post.push(PostOp::Bn {
                                gamma: cop.inputs[1],
                                beta: cop.inputs[2],
                                mean: cop.inputs[3],
                                var: cop.inputs[4],
                                eps,
                            });
                            fused.insert(c);
                            dirty |= reuse.as_ref().is_some_and(|r| r.dirty.contains(&c));
                            out_data = cop.outputs[0];
                        }
                        _ => {
                            if let Some(a) = act_of(&cop.kind) {
                                post.push(PostOp::Act(a));
                                fused.insert(c);
                                dirty |= reuse.as_ref().is_some_and(|r| r.dirty.contains(&c));
                                out_data = cop.outputs[0];
                            } else {
                                break;
                            }
                        }
                    }
                }
            }
            fused_ops += post.len();
            proto.push(ProtoItem::Step(Proto {
                op: op_id,
                out_data,
                post,
                dirty,
            }));
        }

        // Recompile bookkeeping: count maximal runs of dirty schedule
        // items (the regions actually rebuilt) and the clean steps
        // carried over around them.
        let mut recompiled_regions = 0usize;
        let mut reused_steps = 0usize;
        if let Some(r) = &reuse {
            let mut in_run = false;
            for item in &proto {
                let d = match item {
                    ProtoItem::Alias(op) => r.dirty.contains(op),
                    ProtoItem::Step(p) => p.dirty,
                };
                if d && !in_run {
                    recompiled_regions += 1;
                }
                in_run = d;
                if !d {
                    if let ProtoItem::Step(_) = item {
                        reused_steps += 1;
                    }
                }
            }
        }

        // Resolve a read of `d` to the data id whose slot (if any) backs
        // it, following reshape aliases.
        let resolve = |mut d: DataId| -> DataId {
            while let Some(&s) = alias_src.get(&d) {
                d = s;
            }
            d
        };

        // ---- Phase B: liveness (last schedule index reading each slot-
        // backed data id; usize::MAX pins outputs/retained) ----
        let mut write_at: HashMap<DataId, usize> = HashMap::new();
        let mut last_read: HashMap<DataId, usize> = HashMap::new();
        for (pi, item) in proto.iter().enumerate() {
            if let ProtoItem::Step(p) = item {
                for &i in &graph.ops[p.op].inputs {
                    let r = resolve(i);
                    if write_at.contains_key(&r) {
                        last_read.insert(r, pi);
                    }
                }
                write_at.insert(p.out_data, pi);
            }
        }
        for &d in outputs.iter().chain(retain.iter()) {
            let r = resolve(d);
            if write_at.contains_key(&r) {
                last_read.insert(r, usize::MAX);
            }
        }

        // ---- Phase C: greedy arena slot assignment ----
        let mut schedule: Vec<Item> = Vec::with_capacity(proto.len());
        let mut free: Vec<usize> = Vec::new();
        let mut active: Vec<(usize, usize)> = Vec::new(); // (end, slot)
        let mut slot_nominal: Vec<usize> = Vec::new();
        let mut steps = 0usize;
        for (pi, item) in proto.into_iter().enumerate() {
            match item {
                ProtoItem::Alias(op_id) => {
                    let (inp, out) = {
                        let op = &graph.ops[op_id];
                        (op.inputs[0], op.outputs[0])
                    };
                    loc[out] = loc[inp];
                    schedule.push(Item::Alias { op: op_id });
                }
                ProtoItem::Step(p) => {
                    let mut i = 0;
                    while i < active.len() {
                        if active[i].0 < pi {
                            free.push(active[i].1);
                            active.swap_remove(i);
                        } else {
                            i += 1;
                        }
                    }
                    // An incremental recompile prefers the slot the old
                    // plan used for this output, when it is free — clean
                    // regions then keep their slot assignment verbatim.
                    let mut slot = None;
                    if let Some(r) = reuse.as_ref() {
                        if let Some(&want) = r.preferred.get(&p.out_data) {
                            if let Some(at) = free.iter().position(|&s| s == want) {
                                slot = Some(free.swap_remove(at));
                            }
                        }
                    }
                    let slot = slot.or_else(|| free.pop()).unwrap_or_else(|| {
                        slot_nominal.push(0);
                        slot_nominal.len() - 1
                    });
                    let end = last_read.get(&p.out_data).copied().unwrap_or(pi);
                    active.push((end, slot));
                    let numel: usize = graph.datas[p.out_data].shape.iter().product();
                    slot_nominal[slot] = slot_nominal[slot].max(numel);
                    loc[p.out_data] = Some(Loc::Slot(slot));
                    steps += 1;
                    schedule.push(Item::Step {
                        op: p.op,
                        out_data: p.out_data,
                        out_slot: slot,
                        post: p.post,
                    });
                }
            }
        }

        let interp_intermediate_bytes: usize = graph
            .datas
            .iter()
            .filter(|d| matches!(d.kind, DataKind::Activation))
            .map(|d| d.shape.iter().product::<usize>() * std::mem::size_of::<f32>())
            .sum();
        let peak_arena_bytes: usize =
            slot_nominal.iter().sum::<usize>() * std::mem::size_of::<f32>();
        let mut readable: HashSet<DataId> = retain;
        readable.extend(graph.outputs.iter().copied());
        // Feed indices that must be copied into the workspace so reads
        // after the run can see them — a readable id may be the input
        // itself or a reshape alias of it (e.g. OBSPA retaining the
        // Flatten of the graph input that feeds mlp's first Gemm).
        let mut keep_feeds = vec![false; graph.inputs.len()];
        for &id in &readable {
            if let Some(Loc::Feed(k)) = loc.get(id).copied().flatten() {
                keep_feeds[k] = true;
            }
        }
        let mut gemm_wt: HashMap<OpId, Tensor> = HashMap::new();
        let mut reused_gemm_wt = 0usize;
        for op in &graph.ops {
            if matches!(op.kind, OpKind::Gemm) {
                // carry the old plan's transpose when the recompile
                // proved the weight unchanged (t2 is deterministic, so
                // the carried tensor is bit-identical to a re-pack)
                if let Some(t) = reuse.as_mut().and_then(|r| r.gemm_wt.remove(&op.id)) {
                    gemm_wt.insert(op.id, t);
                    reused_gemm_wt += 1;
                } else if let Some(w) = op.inputs.get(1).and_then(|&i| graph.datas[i].param()) {
                    gemm_wt.insert(op.id, w.t2());
                }
            }
        }
        let gemm_wt_bytes: usize = gemm_wt
            .values()
            .map(|t| t.numel() * std::mem::size_of::<f32>())
            .sum();
        let report = PlanReport {
            steps,
            fused_ops,
            aliased_ops,
            arena_slots: slot_nominal.len(),
            peak_arena_bytes,
            interp_intermediate_bytes,
            gemm_wt_bytes,
            recompiled_regions,
            reused_steps,
            reused_gemm_wt,
            opt,
        };
        let plan = Plan {
            graph,
            schedule,
            loc,
            slot_count: slot_nominal.len(),
            readable,
            keep_feeds,
            gemm_wt,
            report,
        };
        if opts.check.enabled() {
            if opts.check == CheckLevel::Strict {
                crate::check::check_graph(&plan.graph)
                    .map_err(|e| anyhow::anyhow!("plan graph failed static checks: {e}"))?;
            }
            crate::check::check_plan(&plan)?;
        }
        Ok(plan)
    }

    /// Compile stats: step/fusion/alias counts and the arena-vs-
    /// interpreter memory comparison.
    pub fn report(&self) -> &PlanReport {
        &self.report
    }

    /// The plan's own (possibly rewritten) graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Input data ids to feed ([`OptLevel::Fast`] remaps ids, so always
    /// address feeds through this).
    pub fn inputs(&self) -> &[DataId] {
        &self.graph.inputs
    }

    /// Output data ids.
    pub fn outputs(&self) -> &[DataId] {
        &self.graph.outputs
    }

    /// A fresh workspace sized for this plan.
    pub fn workspace(&self) -> Workspace {
        let mut shapes = vec![Vec::new(); self.graph.datas.len()];
        for d in &self.graph.datas {
            if let Some(p) = d.param() {
                shapes[d.id] = p.shape.clone();
            }
        }
        Workspace {
            slots: vec![Vec::new(); self.slot_count],
            shapes,
            feeds: vec![None; self.graph.inputs.len()],
            scratch: Scratch::default(),
        }
    }

    /// A [`Runner`] over this plan with a fresh owned workspace — the
    /// preferred entry point for repeated inference.
    pub fn runner(&self) -> Runner<'_> {
        Runner::new(self)
    }

    /// Execute the plan and return the first graph output (logits for
    /// classifiers). Feeds bind input data ids to tensors; the batch dim
    /// may differ from the nominal compile-time shape.
    pub fn run(&self, ws: &mut Workspace, feeds: &[(DataId, &Tensor)]) -> anyhow::Result<Tensor> {
        self.execute(ws, feeds)?;
        self.value(ws, self.graph.outputs[0])
    }

    /// One-shot convenience: fresh workspace, single-input graph. A thin
    /// shim over [`Plan::runner`]; repeated callers should hold a
    /// [`Runner`] instead to reuse its workspace.
    pub fn predict(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        self.runner().predict(x)
    }

    /// Read a value from the workspace after [`Plan::run`]: graph
    /// outputs, parameters, and every id listed in
    /// [`PlanOpts::retain`] (inputs included — list an input there to
    /// read it back). Anything else is rejected: intermediates because
    /// their arena slots may have been reused, non-retained inputs
    /// because the plan does not copy feeds it was not asked to keep.
    pub fn value(&self, ws: &Workspace, id: DataId) -> anyhow::Result<Tensor> {
        match self.loc.get(id).copied().flatten() {
            Some(Loc::Param(p)) => Ok(self.graph.datas[p].param().expect("param loc").clone()),
            Some(Loc::Feed(k)) => {
                let t = ws.feeds[k].clone().ok_or_else(|| {
                    anyhow::anyhow!(
                        "input `{}` is not retained by this plan (add it to PlanOpts::retain)",
                        self.graph.datas[id].name
                    )
                })?;
                // a reshape alias of an input (e.g. Flatten) shares the
                // feed's data under its own shape
                if !ws.shapes[id].is_empty() && ws.shapes[id] != t.shape {
                    Ok(t.reshaped(ws.shapes[id].clone()))
                } else {
                    Ok(t)
                }
            }
            Some(Loc::Slot(s)) => {
                anyhow::ensure!(
                    self.readable.contains(&id),
                    "data `{}` is not retained by this plan (add it to PlanOpts::retain)",
                    self.graph.datas[id].name
                );
                anyhow::ensure!(
                    !ws.shapes[id].is_empty(),
                    "data `{}` has no value (run the plan first)",
                    self.graph.datas[id].name
                );
                Ok(Tensor::new(ws.shapes[id].clone(), ws.slots[s].clone()))
            }
            None => anyhow::bail!(
                "data `{}` is fused away in this plan",
                self.graph.datas[id].name
            ),
        }
    }

    /// Execute all steps, leaving results in the workspace.
    pub fn execute(&self, ws: &mut Workspace, feeds: &[(DataId, &Tensor)]) -> anyhow::Result<()> {
        self.execute_obs(ws, feeds, None)
    }

    /// [`Plan::execute`] while accumulating per-step wall time, bytes
    /// moved, and GEMM dimensions into `prof`. Identical results to an
    /// unprofiled run — the only difference is two clock reads per step.
    pub fn execute_profiled(
        &self,
        ws: &mut Workspace,
        feeds: &[(DataId, &Tensor)],
        prof: &mut crate::obs::Profiler,
    ) -> anyhow::Result<()> {
        self.execute_obs(ws, feeds, Some(prof))
    }

    fn execute_obs(
        &self,
        ws: &mut Workspace,
        feeds: &[(DataId, &Tensor)],
        mut prof: Option<&mut crate::obs::Profiler>,
    ) -> anyhow::Result<()> {
        let t_run = prof.as_ref().map(|_| std::time::Instant::now());
        if let Some(p) = prof.as_deref_mut() {
            p.ensure(self.schedule.len());
        }
        // Param shapes are static (pre-filled by `workspace`); only
        // feed/activation shapes reset per run.
        for (id, l) in self.loc.iter().enumerate() {
            if !matches!(l, Some(Loc::Param(_))) {
                ws.shapes[id].clear();
            }
        }
        for f in ws.feeds.iter_mut() {
            *f = None;
        }
        // Kernels read feeds through these borrows; a copy is kept in the
        // workspace only for inputs the plan must expose after the run
        // (retained ids — e.g. OBSPA capturing a first layer's input).
        let mut feed_refs: Vec<Option<&Tensor>> = vec![None; self.graph.inputs.len()];
        for (id, t) in feeds {
            let k = self
                .graph
                .inputs
                .iter()
                .position(|&i| i == *id)
                .ok_or_else(|| {
                    anyhow::anyhow!("feed target `{}` is not an input", self.graph.datas[*id].name)
                })?;
            feed_refs[k] = Some(*t);
            if self.keep_feeds[k] {
                ws.feeds[k] = Some((*t).clone());
            }
            ws.shapes[*id] = t.shape.clone();
        }
        for (idx, item) in self.schedule.iter().enumerate() {
            match item {
                Item::Alias { op } => {
                    let o = &self.graph.ops[*op];
                    anyhow::ensure!(
                        !ws.shapes[o.inputs[0]].is_empty(),
                        "missing input to `{}`",
                        o.name
                    );
                    let ins = vec![ws.shapes[o.inputs[0]].clone()];
                    let out = infer_op_output_shapes(&o.kind, &ins)
                        .map_err(|e| anyhow::anyhow!("op `{}`: {e}", o.name))?
                        .swap_remove(0);
                    ws.shapes[o.outputs[0]] = out;
                }
                Item::Step {
                    op,
                    out_data,
                    out_slot,
                    post,
                } => {
                    let o = &self.graph.ops[*op];
                    let mut in_shapes: Vec<Vec<usize>> = Vec::with_capacity(o.inputs.len());
                    for &i in &o.inputs {
                        anyhow::ensure!(
                            !ws.shapes[i].is_empty(),
                            "missing input to `{}`",
                            o.name
                        );
                        in_shapes.push(ws.shapes[i].clone());
                    }
                    let out_shape = infer_op_output_shapes(&o.kind, &in_shapes)
                        .map_err(|e| anyhow::anyhow!("op `{}`: {e}", o.name))?
                        .swap_remove(0);
                    let numel: usize = out_shape.iter().product();
                    let _step_span = crate::obs::trace::span_with("exec.step", || o.name.clone());
                    let t_step = prof.as_ref().map(|_| std::time::Instant::now());
                    let mut buf = std::mem::take(&mut ws.slots[*out_slot]);
                    buf.resize(numel, 0.0);
                    let mut scratch = std::mem::take(&mut ws.scratch);
                    let r = self.run_step(
                        ws,
                        &feed_refs,
                        o,
                        &in_shapes,
                        &out_shape,
                        &mut scratch,
                        &mut buf,
                    );
                    ws.scratch = scratch;
                    r?;
                    for p in post {
                        match p {
                            PostOp::Bn {
                                gamma,
                                beta,
                                mean,
                                var,
                                eps,
                            } => ops::batchnorm_affine_inplace(
                                &mut buf,
                                &out_shape,
                                self.param(*gamma)?,
                                self.param(*beta)?,
                                self.param(*mean)?,
                                self.param(*var)?,
                                *eps,
                            ),
                            PostOp::Act(a) => apply_act(*a, &mut buf),
                        }
                    }
                    ws.slots[*out_slot] = buf;
                    ws.shapes[*out_data] = out_shape;
                    if let (Some(p), Some(t0)) = (prof.as_deref_mut(), t_step) {
                        let in_numel: usize =
                            in_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
                        let bytes = ((in_numel + numel) * std::mem::size_of::<f32>()) as u64;
                        p.record_step(
                            idx,
                            t0.elapsed().as_nanos() as u64,
                            bytes,
                            self.gemm_dims(o, &in_shapes, &out_shape),
                        );
                    }
                }
            }
        }
        if let (Some(p), Some(t0)) = (prof.as_deref_mut(), t_run) {
            p.record_run(t0.elapsed().as_nanos() as u64);
        }
        Ok(())
    }

    /// GEMM dimensions `[M, K, N]` a step dispatches, for Gemm and
    /// (im2col'd) Conv2d ops — the profiler's kernel-shape attribution.
    fn gemm_dims(
        &self,
        op: &OpNode,
        in_shapes: &[Vec<usize>],
        out_shape: &[usize],
    ) -> Option<[usize; 3]> {
        match &op.kind {
            OpKind::Gemm => {
                let k = *in_shapes[0].last()?;
                let m = in_shapes[0].iter().product::<usize>() / k.max(1);
                let n = *out_shape.last()?;
                Some([m, k, n])
            }
            OpKind::Conv2d { .. } => {
                // weight [OC, C/g, KH, KW]; one GEMM of [N·OH·OW, C/g·KH·KW]
                // by [C/g·KH·KW, OC] per group (summed over groups as N=OC)
                let w = &self.graph.datas[*op.inputs.get(1)?].shape;
                if w.len() != 4 || out_shape.len() != 4 {
                    return None;
                }
                let m = out_shape[0] * out_shape[2] * out_shape[3];
                let k = w[1] * w[2] * w[3];
                Some([m, k, w[0]])
            }
            _ => None,
        }
    }

    fn param(&self, id: DataId) -> anyhow::Result<&Tensor> {
        self.graph.datas[id].param().ok_or_else(|| {
            anyhow::anyhow!(
                "compiled plans require `{}` to be a parameter",
                self.graph.datas[id].name
            )
        })
    }

    fn data_slice<'a>(
        &'a self,
        ws: &'a Workspace,
        feeds: &[Option<&'a Tensor>],
        id: DataId,
    ) -> anyhow::Result<&'a [f32]> {
        match self.loc.get(id).copied().flatten() {
            Some(Loc::Feed(k)) => feeds[k].map(|t| t.data.as_slice()).ok_or_else(|| {
                anyhow::anyhow!("input `{}` was not fed", self.graph.datas[id].name)
            }),
            Some(Loc::Param(p)) => {
                Ok(self.graph.datas[p].param().expect("param loc").data.as_slice())
            }
            Some(Loc::Slot(s)) => Ok(ws.slots[s].as_slice()),
            None => anyhow::bail!(
                "internal: data `{}` has no location",
                self.graph.datas[id].name
            ),
        }
    }

    /// Dispatch one base kernel into `out`. Every branch reproduces the
    /// interpreter's arithmetic exactly (most delegate to the shared
    /// `tensor::ops` `_into` kernels), which is what makes Exact-level
    /// plans bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn run_step(
        &self,
        ws: &Workspace,
        feeds: &[Option<&Tensor>],
        op: &OpNode,
        in_shapes: &[Vec<usize>],
        out_shape: &[usize],
        scratch: &mut Scratch,
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let x = self.data_slice(ws, feeds, op.inputs[0])?;
        let xs = &in_shapes[0];
        match &op.kind {
            OpKind::Conv2d { stride, pad, groups } => {
                let w = self.param(op.inputs[1])?;
                let b = match op.inputs.get(2) {
                    Some(&bid) => Some(self.param(bid)?),
                    None => None,
                };
                if xs[0] > 1 {
                    // one GEMM per group over all images — bit-identical
                    // MAC order, far better inner-loop amortization
                    ops::conv2d_batched_into(
                        x,
                        xs,
                        w,
                        b,
                        *stride,
                        *pad,
                        *groups,
                        &mut scratch.cols,
                        &mut scratch.yb,
                        out,
                    );
                } else {
                    ops::conv2d_into(x, xs, w, b, *stride, *pad, *groups, out);
                }
            }
            OpKind::Gemm => {
                let w = self.param(op.inputs[1])?;
                let b = match op.inputs.get(2) {
                    Some(&bid) => Some(self.param(bid)?),
                    None => None,
                };
                let kin = *xs.last().unwrap();
                // same kernel as the interpreter, with the per-call
                // w.t2() replaced by the plan's precomputed transpose
                ops::linear_into(x, kin, w, b, self.gemm_wt.get(&op.id), out);
            }
            OpKind::BatchNorm { eps } => ops::batchnorm_infer_into(
                x,
                xs,
                self.param(op.inputs[1])?,
                self.param(op.inputs[2])?,
                self.param(op.inputs[3])?,
                self.param(op.inputs[4])?,
                *eps,
                out,
            ),
            OpKind::LayerNorm { eps } => {
                let d = *xs.last().unwrap();
                ops::layernorm_eval_into(
                    x,
                    d,
                    self.param(op.inputs[1])?,
                    self.param(op.inputs[2])?,
                    *eps,
                    out,
                );
            }
            OpKind::Relu | OpKind::Gelu | OpKind::Silu | OpKind::Sigmoid | OpKind::Tanh => {
                out.copy_from_slice(x);
                apply_act(act_of(&op.kind).expect("activation kind"), out);
            }
            OpKind::Add | OpKind::Mul => {
                let b = self.data_slice(ws, feeds, op.inputs[1])?;
                bcast_binary(
                    x,
                    xs,
                    b,
                    &in_shapes[1],
                    out,
                    matches!(op.kind, OpKind::Mul),
                )?;
            }
            OpKind::MaxPool2d { k, stride, pad } => {
                ops::maxpool2d_eval_into(x, xs, *k, *stride, *pad, out)
            }
            OpKind::AvgPool2d { k, stride, pad } => {
                ops::avgpool2d_into(x, xs, *k, *stride, *pad, out)
            }
            OpKind::GlobalAvgPool => ops::global_avgpool_into(x, xs, out),
            OpKind::Concat { axis } => {
                let outer: usize = out_shape[..*axis].iter().product();
                let inner: usize = out_shape[*axis + 1..].iter().product();
                let mut w = 0usize;
                for o in 0..outer {
                    for (slot, s) in op.inputs.iter().zip(in_shapes) {
                        let t = self.data_slice(ws, feeds, *slot)?;
                        let d = s[*axis];
                        let base = o * d * inner;
                        out[w..w + d * inner].copy_from_slice(&t[base..base + d * inner]);
                        w += d * inner;
                    }
                }
            }
            OpKind::Softmax => {
                let d = *xs.last().unwrap();
                ops::softmax_lastdim_into(x, d, out);
            }
            OpKind::MatMul => {
                let b = self.data_slice(ws, feeds, op.inputs[1])?;
                ops::batch_matmul_into(x, xs, b, &in_shapes[1], out);
            }
            OpKind::Transpose { perm } => ops::transpose_into(x, xs, perm, out),
            OpKind::SplitHeads { heads } => {
                // [N,T,D] reshaped to [N,T,h,D/h], then transposed —
                // the reshape shares the row-major data.
                let (n, t, d) = (xs[0], xs[1], xs[2]);
                let rs = [n, t, *heads, d / *heads];
                ops::transpose_into(x, &rs, &[0, 2, 1, 3], out);
            }
            OpKind::MergeHeads => {
                // transpose [N,h,T,d] → [N,T,h,d]; reshape is free
                ops::transpose_into(x, xs, &[0, 2, 1, 3], out);
            }
            OpKind::Scale { c } => {
                for (o, &v) in out.iter_mut().zip(x) {
                    *o = v * c;
                }
            }
            OpKind::Embedding => {
                let table = self.param(op.inputs[1])?;
                ops::embedding_into(x, table, out);
            }
            OpKind::ReduceMean { axis } => {
                let outer: usize = xs[..*axis].iter().product();
                let d = xs[*axis];
                let inner: usize = xs[*axis + 1..].iter().product();
                let inv = 1.0 / d as f32;
                out.iter_mut().for_each(|v| *v = 0.0);
                for o in 0..outer {
                    for k in 0..d {
                        for i in 0..inner {
                            out[o * inner + i] += x[(o * d + k) * inner + i] * inv;
                        }
                    }
                }
            }
            OpKind::NchwToTokens => {
                // [N,C,H,W] → transpose to [N,H,W,C]; reshape to
                // [N,HW,C] is free
                ops::transpose_into(x, xs, &[0, 2, 3, 1], out);
            }
            OpKind::Identity | OpKind::Flatten => {
                unreachable!("reshape-only ops are aliased at compile time")
            }
        }
        Ok(())
    }
}

/// Elementwise `a + b` / `a * b` with the interpreter's channel/row
/// broadcast semantics — the value pairing matches
/// `engine::broadcast_to` case-for-case, so results are bit-identical
/// without materializing the broadcast.
fn bcast_binary(
    a: &[f32],
    ashape: &[usize],
    b: &[f32],
    bshape: &[usize],
    out: &mut [f32],
    mul: bool,
) -> anyhow::Result<()> {
    let op = |x: f32, y: f32| if mul { x * y } else { x + y };
    if ashape == bshape {
        for (o, (&x, &y)) in out.iter_mut().zip(a.iter().zip(b)) {
            *o = op(x, y);
        }
    } else if bshape.len() == 1 {
        let c = b.len();
        match ashape.len() {
            2 | 3 => {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = op(a[i], b[i % c]);
                }
            }
            4 => {
                let inner = ashape[2] * ashape[3];
                for (i, o) in out.iter_mut().enumerate() {
                    *o = op(a[i], b[(i / inner) % c]);
                }
            }
            _ => anyhow::bail!("unsupported broadcast {bshape:?} -> {ashape:?}"),
        }
    } else if bshape.len() == 4 && bshape[2] == 1 && bshape[3] == 1 {
        let inner = ashape[2] * ashape[3];
        for (i, o) in out.iter_mut().enumerate() {
            *o = op(a[i], b[i / inner]);
        }
    } else if bshape.len() == 2 && ashape.len() == 4 {
        let inner = ashape[2] * ashape[3];
        for (i, o) in out.iter_mut().enumerate() {
            *o = op(a[i], b[i / inner]);
        }
    } else if bshape.len() == 3 && bshape[0] == 1 {
        let block = b.len();
        for (i, o) in out.iter_mut().enumerate() {
            *o = op(a[i], b[i % block]);
        }
    } else {
        anyhow::bail!("unsupported broadcast {bshape:?} -> {ashape:?}");
    }
    Ok(())
}

/// A [`Plan`] paired with an owned, reusable [`Workspace`] — the unified
/// entry point for repeated inference. Every in-repo execution path
/// (serve batch loop, [`Batcher`] workers, `train::evaluate`, OBSPA
/// capture) drives a plan through one of these instead of hand-rolling
/// `workspace()` / `run()` pairs; steady-state calls allocate nothing.
pub struct Runner<'p> {
    plan: &'p Plan,
    ws: Workspace,
}

impl<'p> Runner<'p> {
    /// A runner with a fresh workspace sized for `plan`.
    pub fn new(plan: &'p Plan) -> Runner<'p> {
        Runner {
            plan,
            ws: plan.workspace(),
        }
    }

    /// A runner over an existing workspace (e.g. one recycled from a
    /// [`Batcher`] pool). The workspace must have been created by
    /// [`Plan::workspace`] on this same plan.
    pub fn from_parts(plan: &'p Plan, ws: Workspace) -> Runner<'p> {
        Runner { plan, ws }
    }

    /// The plan this runner executes.
    pub fn plan(&self) -> &'p Plan {
        self.plan
    }

    /// Tear down into the owned workspace (for returning it to a pool).
    pub fn into_workspace(self) -> Workspace {
        self.ws
    }

    /// Execute and return the first graph output (logits).
    pub fn run(&mut self, feeds: &[(DataId, &Tensor)]) -> anyhow::Result<Tensor> {
        self.plan.run(&mut self.ws, feeds)
    }

    /// Single-input convenience: feed `x` to the graph's one input.
    pub fn predict(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
        anyhow::ensure!(
            self.plan.graph.inputs.len() == 1,
            "predict requires a single-input graph"
        );
        let input = self.plan.graph.inputs[0];
        self.run(&[(input, x)])
    }

    /// Execute all steps, leaving results readable via [`Runner::value`].
    pub fn execute(&mut self, feeds: &[(DataId, &Tensor)]) -> anyhow::Result<()> {
        self.plan.execute(&mut self.ws, feeds)
    }

    /// [`Runner::predict`] while accumulating per-step timings into
    /// `prof` (see [`crate::obs::Profiler`]). Bit-identical outputs.
    pub fn predict_profiled(
        &mut self,
        x: &Tensor,
        prof: &mut crate::obs::Profiler,
    ) -> anyhow::Result<Tensor> {
        anyhow::ensure!(
            self.plan.graph.inputs.len() == 1,
            "predict requires a single-input graph"
        );
        let input = self.plan.graph.inputs[0];
        self.plan.execute_profiled(&mut self.ws, &[(input, x)], prof)?;
        self.plan.value(&self.ws, self.plan.graph.outputs[0])
    }

    /// Read a retained/output value after a run (see [`Plan::value`]).
    pub fn value(&self, id: DataId) -> anyhow::Result<Tensor> {
        self.plan.value(&self.ws, id)
    }
}

/// Deterministic concurrent inference over one [`Plan`]: requests fan
/// out across the `crate::util::par` pool, each executed by a [`Runner`]
/// over a pooled [`Workspace`]. Outputs are bit-identical at any
/// `SPA_THREADS` width and independent of which worker served which
/// request.
pub struct Batcher<'p> {
    plan: &'p Plan,
    pool: Mutex<Vec<Workspace>>,
}

impl<'p> Batcher<'p> {
    pub fn new(plan: &'p Plan) -> Batcher<'p> {
        Batcher::with_pool(plan, Vec::new())
    }

    /// A batcher seeded with previously warmed workspaces (the serve
    /// loop persists pools across ticks this way). Workspaces must come
    /// from [`Plan::workspace`] on this same plan.
    pub fn with_pool(plan: &'p Plan, pool: Vec<Workspace>) -> Batcher<'p> {
        Batcher {
            plan,
            pool: Mutex::new(pool),
        }
    }

    /// Tear down into the warmed workspace pool (for reuse next tick).
    pub fn into_pool(self) -> Vec<Workspace> {
        self.pool.into_inner().unwrap_or_else(|e| e.into_inner())
    }

    /// Run one tensor per request through the plan (single-input
    /// graphs), preserving request order in the results.
    pub fn run_batch(&self, requests: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        if requests.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(
            self.plan.graph.inputs.len() == 1,
            "Batcher requires a single-input graph"
        );
        let results: Vec<anyhow::Result<Tensor>> = par::par_map(requests, |x| {
            let ws = {
                let mut pool = self.pool.lock().unwrap();
                pool.pop()
            }
            .unwrap_or_else(|| self.plan.workspace());
            let mut runner = Runner::from_parts(self.plan, ws);
            let r = runner.predict(x);
            self.pool.lock().unwrap().push(runner.into_workspace());
            r
        });
        results.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, Mode};
    use crate::util::Rng;
    use crate::zoo::{self, ImageCfg, TextCfg};

    fn cfg() -> ImageCfg {
        ImageCfg {
            hw: 8,
            ..Default::default()
        }
    }

    fn assert_bits_eq(a: &Tensor, b: &Tensor) {
        assert_eq!(a.shape, b.shape);
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "bit mismatch at {i}: {x} vs {y}");
        }
    }

    fn rand_input(g: &Graph, batch: usize, rng: &mut Rng) -> Tensor {
        let mut shape = g.data(g.inputs[0]).shape.clone();
        shape[0] = batch;
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0))
    }

    #[test]
    fn exact_plan_bit_identical_on_resnet18() {
        let g = zoo::resnet18(cfg(), 3);
        let mut rng = Rng::new(1);
        let x = rand_input(&g, 4, &mut rng);
        let want = engine::forward(&g, &[(g.inputs[0], x.clone())], Mode::Eval)
            .unwrap()
            .logits(&g)
            .clone();
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        assert!(plan.report().fused_ops > 0, "resnet must fuse BN/ReLU");
        let mut ws = plan.workspace();
        let got = plan.run(&mut ws, &[(g.inputs[0], &x)]).unwrap();
        assert_bits_eq(&got, &want);
        // a second run through the same workspace reuses buffers and
        // must reproduce the result
        let again = plan.run(&mut ws, &[(g.inputs[0], &x)]).unwrap();
        assert_bits_eq(&again, &want);
    }

    #[test]
    fn exact_plan_bit_identical_on_transformers() {
        let tcfg = TextCfg::default();
        let g = zoo::distilbert(tcfg, 5);
        let mut rng = Rng::new(2);
        let ids = Tensor::new(
            vec![2, tcfg.seq],
            (0..2 * tcfg.seq)
                .map(|_| rng.below(tcfg.vocab) as f32)
                .collect(),
        );
        let want = engine::predict(&g, ids.clone()).unwrap();
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let got = plan.predict(&ids).unwrap();
        assert_bits_eq(&got, &want);
        // ViT covers NchwToTokens / concat-free attention over images
        let v = zoo::vit(cfg(), 6);
        let xv = rand_input(&v, 2, &mut rng);
        let want_v = engine::predict(&v, xv.clone()).unwrap();
        let got_v = Plan::compile(&v, PlanOpts::default())
            .unwrap()
            .predict(&xv)
            .unwrap();
        assert_bits_eq(&got_v, &want_v);
    }

    #[test]
    fn arena_is_smaller_than_interpreter_intermediates() {
        for name in ["resnet18", "vgg16", "mobilenetv2", "densenet"] {
            let g = zoo::by_name(name, cfg(), 2).unwrap();
            let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
            let r = plan.report();
            assert!(
                r.peak_arena_bytes < r.interp_intermediate_bytes,
                "{name}: arena {} !< interp {}",
                r.peak_arena_bytes,
                r.interp_intermediate_bytes
            );
            assert!(r.arena_slots < r.steps, "{name}: no slot reuse");
        }
    }

    #[test]
    fn plan_runs_at_other_batch_sizes() {
        let g = zoo::resnet18(cfg(), 4);
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let mut ws = plan.workspace();
        let mut rng = Rng::new(3);
        for batch in [1usize, 3, 9] {
            let x = rand_input(&g, batch, &mut rng);
            let want = engine::predict(&g, x.clone()).unwrap();
            let got = plan.run(&mut ws, &[(g.inputs[0], &x)]).unwrap();
            assert_bits_eq(&got, &want);
        }
    }

    #[test]
    fn retained_values_match_interpreter_activations() {
        let g = zoo::resnet18(cfg(), 7);
        // retain the inputs of every conv/gemm — the OBSPA hook
        let retain: Vec<DataId> = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Conv2d { .. } | OpKind::Gemm))
            .map(|o| o.inputs[0])
            .collect();
        let plan = Plan::compile(
            &g,
            PlanOpts {
                retain: retain.clone(),
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = Rng::new(4);
        let x = rand_input(&g, 2, &mut rng);
        let fwd = engine::forward(&g, &[(g.inputs[0], x.clone())], Mode::Eval).unwrap();
        let mut ws = plan.workspace();
        plan.run(&mut ws, &[(g.inputs[0], &x)]).unwrap();
        for &id in &retain {
            let got = plan.value(&ws, id).unwrap();
            assert_bits_eq(&got, fwd.value(id));
        }
    }

    #[test]
    fn retained_alias_of_input_is_readable() {
        // mlp is input → Flatten → Gemm: OBSPA retains the Flatten
        // output, which aliases the graph input under a new shape
        let g = zoo::mlp(cfg(), &[16], 3);
        let retain: Vec<DataId> = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Gemm))
            .map(|o| o.inputs[0])
            .collect();
        let plan = Plan::compile(
            &g,
            PlanOpts {
                retain: retain.clone(),
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = Rng::new(12);
        let x = rand_input(&g, 2, &mut rng);
        let fwd = engine::forward(&g, &[(g.inputs[0], x.clone())], Mode::Eval).unwrap();
        let mut ws = plan.workspace();
        plan.run(&mut ws, &[(g.inputs[0], &x)]).unwrap();
        for &id in &retain {
            let got = plan.value(&ws, id).unwrap();
            assert_bits_eq(&got, fwd.value(id));
        }
    }

    #[test]
    fn unretained_intermediates_are_rejected() {
        let g = zoo::resnet18(cfg(), 8);
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let mut rng = Rng::new(5);
        let x = rand_input(&g, 2, &mut rng);
        let mut ws = plan.workspace();
        plan.run(&mut ws, &[(g.inputs[0], &x)]).unwrap();
        // some activation that is neither input, output, nor retained
        let mid = g
            .datas
            .iter()
            .find(|d| {
                matches!(d.kind, DataKind::Activation) && !g.outputs.contains(&d.id)
            })
            .unwrap()
            .id;
        assert!(plan.value(&ws, mid).is_err());
    }

    #[test]
    fn fast_plan_matches_interpreter_closely() {
        use crate::tensor::assert_allclose;
        let mut g = zoo::vgg16(cfg(), 9);
        // non-trivial BN stats so folding changes the arithmetic path
        let mut rng = Rng::new(6);
        for d in &mut g.datas {
            let name = d.name.clone();
            if let Some(t) = d.param_mut() {
                if name.ends_with(".mean") {
                    t.data = rng.uniform_vec(t.numel(), -0.5, 0.5);
                } else if name.ends_with(".var") {
                    t.data = rng.uniform_vec(t.numel(), 0.5, 2.0);
                }
            }
        }
        let x = rand_input(&g, 2, &mut rng);
        let want = engine::predict(&g, x.clone()).unwrap();
        let plan = Plan::compile(
            &g,
            PlanOpts {
                level: OptLevel::Fast,
                ..Default::default()
            },
        )
        .unwrap();
        let r = plan.report();
        assert!(r.opt.is_some_and(|o| o.bn_folded > 0));
        let got = plan
            .run(&mut plan.workspace(), &[(plan.inputs()[0], &x)])
            .unwrap();
        assert_allclose(&got, &want, 1e-3, 1e-3);
    }

    #[test]
    fn fast_plus_retain_is_a_compile_error() {
        let g = zoo::resnet18(cfg(), 1);
        let err = Plan::compile(
            &g,
            PlanOpts {
                level: OptLevel::Fast,
                retain: vec![0],
                ..Default::default()
            },
        );
        assert!(err.is_err());
    }

    #[test]
    fn batcher_is_deterministic_across_widths() {
        let _serial = par::test_lock();
        let g = zoo::resnet18(cfg(), 11);
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let mut rng = Rng::new(7);
        let requests: Vec<Tensor> = (0..6).map(|_| rand_input(&g, 1, &mut rng)).collect();
        let serial = par::with_threads(1, || {
            Batcher::new(&plan).run_batch(&requests).unwrap()
        });
        for width in [2usize, 4, 8] {
            let outs = par::with_threads(width, || {
                Batcher::new(&plan).run_batch(&requests).unwrap()
            });
            assert_eq!(outs.len(), requests.len());
            for (a, b) in outs.iter().zip(&serial) {
                assert_bits_eq(a, b);
            }
        }
        // and each matches the interpreter
        for (req, out) in requests.iter().zip(&serial) {
            let want = engine::predict(&g, req.clone()).unwrap();
            assert_bits_eq(out, &want);
        }
    }

    #[test]
    fn batcher_empty_input_is_a_noop() {
        let g = zoo::mlp(cfg(), &[8], 13);
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let batcher = Batcher::new(&plan);
        let outs = batcher.run_batch(&[]).unwrap();
        assert!(outs.is_empty());
        assert!(batcher.into_pool().is_empty());
    }

    #[test]
    fn runner_reuses_workspace_and_matches_predict() {
        let g = zoo::resnet18(cfg(), 14);
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let mut rng = Rng::new(9);
        let mut runner = plan.runner();
        for batch in [1usize, 2, 5] {
            let x = rand_input(&g, batch, &mut rng);
            let got = runner.predict(&x).unwrap();
            assert_bits_eq(&got, &plan.predict(&x).unwrap());
        }
        // round-trip the workspace through a pool, as Batcher does
        let ws = runner.into_workspace();
        let mut again = Runner::from_parts(&plan, ws);
        let x = rand_input(&g, 2, &mut rng);
        assert_bits_eq(&again.predict(&x).unwrap(), &plan.predict(&x).unwrap());
    }

    // The `arena_micro_*` tests are deliberately tiny (mlp at hw 4, no
    // timing, no file IO) so CI's Miri lane can run them: they drive the
    // whole arena/workspace machinery — exactly the unsafe-adjacent slot
    // reuse the static checker reasons about — under the interpreter.
    #[test]
    fn arena_micro_plan_reuses_slots_and_runs() {
        let g = zoo::mlp(
            ImageCfg {
                hw: 4,
                ..Default::default()
            },
            &[6, 5],
            21,
        );
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        crate::check::check_plan(&plan).unwrap();
        let mut rng = Rng::new(31);
        let x = rand_input(&g, 1, &mut rng);
        let mut runner = plan.runner();
        let a = runner.predict(&x).unwrap();
        let b = runner.predict(&x).unwrap();
        assert_bits_eq(&a, &b);
    }

    #[test]
    fn arena_micro_workspace_roundtrip() {
        let g = zoo::mlp(
            ImageCfg {
                hw: 4,
                ..Default::default()
            },
            &[6],
            22,
        );
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let mut rng = Rng::new(32);
        let x = rand_input(&g, 2, &mut rng);
        let want = plan.predict(&x).unwrap();
        let ws = plan.runner().into_workspace();
        let mut again = Runner::from_parts(&plan, ws);
        assert_bits_eq(&again.predict(&x).unwrap(), &want);
    }

    #[test]
    fn recompile_after_param_edit_matches_fresh_compile() {
        use crate::ir::GraphPatch;
        let g = zoo::resnet18(cfg(), 17);
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        // scale one conv weight — the localized edit a re-prune makes
        let conv = g
            .ops
            .iter()
            .find(|o| matches!(o.kind, OpKind::Conv2d { .. }))
            .unwrap();
        let wid = conv.inputs[1];
        let mut w = g.datas[wid].param().unwrap().clone();
        for v in &mut w.data {
            *v *= 1.5;
        }
        let mut p = GraphPatch::new("scale-conv", &g);
        p.set_param(wid, w);
        let mut patched = g.clone();
        let rep = p.apply(&mut patched).unwrap();

        let fresh = Plan::compile(&patched, PlanOpts::default()).unwrap();
        let inc = plan.recompile(&patched, &rep, PlanOpts::default()).unwrap();
        let r = inc.report();
        assert_eq!(r.recompiled_regions, 1, "one conv dirtied, one region");
        assert!(r.reused_steps > 0, "clean steps must be carried over");
        assert_eq!(r.steps, fresh.report().steps);
        assert_eq!(r.arena_slots, fresh.report().arena_slots);
        assert_eq!(
            r.reused_gemm_wt, 1,
            "the untouched fc transpose must carry over"
        );
        assert!(r.reuse_ratio() > 0.5, "ratio {}", r.reuse_ratio());
        let mut rng = Rng::new(40);
        let x = rand_input(&patched, 2, &mut rng);
        assert_bits_eq(&inc.predict(&x).unwrap(), &fresh.predict(&x).unwrap());
    }

    #[test]
    fn recompile_after_structural_patch_matches_fresh_compile() {
        use crate::ir::{DataKind, GraphPatch};
        let g = zoo::resnet18(cfg(), 18);
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        // splice a Scale op in front of the classifier head
        let fc = g
            .ops
            .iter()
            .find(|o| matches!(o.kind, OpKind::Gemm))
            .unwrap();
        let fc_in = fc.inputs[0];
        let mut p = GraphPatch::new("insert-scale", &g);
        let scaled = p.add_data(
            "head.scaled",
            g.datas[fc_in].shape.clone(),
            DataKind::Activation,
        );
        p.rewire(fc_in, scaled);
        p.add_op(
            "head.scale",
            OpKind::Scale { c: 0.5 },
            vec![fc_in],
            vec![scaled],
        );
        let mut patched = g.clone();
        let rep = p.apply(&mut patched).unwrap();

        let fresh = Plan::compile(&patched, PlanOpts::default()).unwrap();
        let inc = plan.recompile(&patched, &rep, PlanOpts::default()).unwrap();
        let r = inc.report();
        assert!(r.recompiled_regions >= 1);
        assert!(r.reused_steps > 0);
        let mut rng = Rng::new(41);
        let x = rand_input(&patched, 2, &mut rng);
        assert_bits_eq(&inc.predict(&x).unwrap(), &fresh.predict(&x).unwrap());
    }

    #[test]
    fn recompile_rejects_mismatched_reports_and_fast_level() {
        use crate::ir::GraphPatch;
        let g = zoo::mlp(cfg(), &[16], 19);
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        // a report built against a different graph must be refused
        let other = zoo::resnet18(cfg(), 19);
        let wid = other
            .ops
            .iter()
            .find(|o| matches!(o.kind, OpKind::Conv2d { .. }))
            .unwrap()
            .inputs[1];
        let mut p = GraphPatch::new("other", &other);
        p.set_param(wid, other.datas[wid].param().unwrap().clone());
        let mut patched_other = other.clone();
        let rep = p.apply(&mut patched_other).unwrap();
        let err = plan
            .recompile(&patched_other, &rep, PlanOpts::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("different graph"), "got: {err}");
        // Fast is not id-stable, so incremental recompile refuses it
        let err = plan
            .recompile(
                &patched_other,
                &rep,
                PlanOpts {
                    level: OptLevel::Fast,
                    ..Default::default()
                },
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("id-stable"), "got: {err}");
    }

    #[test]
    fn every_zoo_model_compiles_and_matches() {
        let mut rng = Rng::new(8);
        for name in zoo::IMAGE_MODELS {
            let g = zoo::by_name(name, cfg(), 2).unwrap();
            let x = rand_input(&g, 2, &mut rng);
            let want = engine::predict(&g, x.clone()).unwrap();
            let plan = Plan::compile(&g, PlanOpts::default())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            let got = plan.predict(&x).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_bits_eq(&got, &want);
        }
    }
}
