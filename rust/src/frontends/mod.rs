//! Framework frontends — "Prune Any Framework" (paper §3.1, Tab. 1).
//!
//! The paper funnels PyTorch / TensorFlow / MXNet / JAX models through
//! ONNX into one standardized computational graph. We reproduce the same
//! pipeline with four *dialects*: serialized model descriptions in each
//! framework's idiom, normalized by [`import_model`] into SPA-IR:
//!
//! | dialect | layout | conventions normalized at import |
//! |---|---|---|
//! | `torch`  | NCHW | separate conv/bias, `Linear` weight `[out,in]` |
//! | `tf`     | NHWC | HWIO conv kernels, bias fused into `Conv2D`, `Dense` weight `[in,out]` |
//! | `jax`    | NHWC | flax-style `Conv`/`Dense` (HWIO, `[in,out]`), functional naming |
//! | `mxnet`  | NCHW | `Convolution`/`FullyConnected`, BN with `fix_gamma` |
//!
//! [`export_model`] writes a SPA-IR graph *into* a dialect (simulating "a
//! model trained in framework X" — the sandbox has no real PyTorch/TF/
//! MXNet). The importer is the code path under test: heterogeneous
//! layouts and op vocabularies all normalize to one graph, after which
//! pruning is framework-agnostic. Import/export round-trips preserve
//! numerics exactly (see tests), mirroring the paper's Tab. 6 conversion
//! measurements.

use crate::ir::{DataKind, Graph, OpKind};
use crate::tensor::{ops as tops, Tensor};
use crate::util::json::{Json, JsonObj};
use crate::util::parse_json;

/// A source/target framework dialect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dialect {
    Torch,
    Tf,
    Jax,
    Mxnet,
}

impl Dialect {
    pub const ALL: [Dialect; 4] = [Dialect::Torch, Dialect::Tf, Dialect::Jax, Dialect::Mxnet];

    pub fn name(&self) -> &'static str {
        match self {
            Dialect::Torch => "torch",
            Dialect::Tf => "tf",
            Dialect::Jax => "jax",
            Dialect::Mxnet => "mxnet",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Dialect> {
        Ok(match s {
            "torch" | "pytorch" => Dialect::Torch,
            "tf" | "tensorflow" => Dialect::Tf,
            "jax" => Dialect::Jax,
            "mxnet" => Dialect::Mxnet,
            _ => anyhow::bail!("unknown dialect `{s}`"),
        })
    }

    /// Channels-last frameworks store conv kernels HWIO and dense [in,out].
    fn channels_last(&self) -> bool {
        matches!(self, Dialect::Tf | Dialect::Jax)
    }

    /// Framework-idiomatic name for an operator.
    fn op_name(&self, kind: &OpKind) -> String {
        let s = match (self, kind) {
            (Dialect::Torch, OpKind::Conv2d { .. }) => "Conv2d",
            (Dialect::Tf, OpKind::Conv2d { .. }) => "Conv2D",
            (Dialect::Jax, OpKind::Conv2d { .. }) => "Conv",
            (Dialect::Mxnet, OpKind::Conv2d { .. }) => "Convolution",
            (Dialect::Torch, OpKind::Gemm) => "Linear",
            (Dialect::Tf, OpKind::Gemm) | (Dialect::Jax, OpKind::Gemm) => "Dense",
            (Dialect::Mxnet, OpKind::Gemm) => "FullyConnected",
            (Dialect::Torch, OpKind::BatchNorm { .. }) => "BatchNorm2d",
            (Dialect::Tf, OpKind::BatchNorm { .. }) => "FusedBatchNorm",
            (Dialect::Jax, OpKind::BatchNorm { .. }) => "BatchNorm",
            (Dialect::Mxnet, OpKind::BatchNorm { .. }) => "BatchNorm",
            (Dialect::Torch, OpKind::MaxPool2d { .. }) => "MaxPool2d",
            (_, OpKind::MaxPool2d { .. }) => "MaxPool",
            (Dialect::Torch, OpKind::AvgPool2d { .. }) => "AvgPool2d",
            (_, OpKind::AvgPool2d { .. }) => "AvgPool",
            (Dialect::Torch, OpKind::GlobalAvgPool) => "AdaptiveAvgPool2d",
            (_, OpKind::GlobalAvgPool) => "GlobalAveragePooling",
            (_, OpKind::Relu) => "ReLU",
            (_, OpKind::Gelu) => "GELU",
            (_, OpKind::Silu) => "SiLU",
            (_, OpKind::Sigmoid) => "Sigmoid",
            (_, OpKind::Tanh) => "Tanh",
            (_, OpKind::Add) => "Add",
            (_, OpKind::Mul) => "Mul",
            (_, OpKind::Flatten) => "Flatten",
            (_, OpKind::Concat { .. }) => "Concat",
            (_, OpKind::Softmax) => "Softmax",
            (_, OpKind::MatMul) => "MatMul",
            (_, OpKind::Transpose { .. }) => "Transpose",
            (_, OpKind::LayerNorm { .. }) => "LayerNorm",
            (_, OpKind::SplitHeads { .. }) => "SplitHeads",
            (_, OpKind::MergeHeads) => "MergeHeads",
            (_, OpKind::Scale { .. }) => "Scale",
            (_, OpKind::Embedding) => "Embedding",
            (_, OpKind::ReduceMean { .. }) => "ReduceMean",
            (_, OpKind::NchwToTokens) => "PatchFlatten",
            (_, OpKind::Identity) => "Identity",
        };
        s.to_string()
    }
}

/// OIHW ↔ HWIO kernel layout conversion.
fn oihw_to_hwio(t: &Tensor) -> Tensor {
    tops::transpose(t, &[2, 3, 1, 0])
}

fn hwio_to_oihw(t: &Tensor) -> Tensor {
    tops::transpose(t, &[3, 2, 0, 1])
}

/// Export a SPA-IR graph into a framework dialect document.
///
/// The document lists tensors (with framework-native layouts) and a node
/// list using framework-native op names and attribute spellings.
pub fn export_model(g: &Graph, dialect: Dialect) -> Json {
    let mut root = JsonObj::new();
    root.insert("framework", dialect.name());
    root.insert("format_version", 1usize);
    root.insert("name", g.name.as_str());
    let mut tensors: Vec<Json> = Vec::new();
    for d in &g.datas {
        let mut o = JsonObj::new();
        o.insert("name", d.name.as_str());
        match &d.kind {
            DataKind::Input => {
                o.insert("role", "input");
                // channels-last dialects declare NHWC input signatures
                let shape = if dialect.channels_last() && d.shape.len() == 4 {
                    vec![d.shape[0], d.shape[2], d.shape[3], d.shape[1]]
                } else {
                    d.shape.clone()
                };
                o.insert("shape", shape.as_slice());
            }
            DataKind::Activation => {
                o.insert("role", "activation");
            }
            DataKind::Param(t) => {
                o.insert("role", "param");
                // convert layouts: conv kernels + dense weights
                let native = native_param(g, d.id, t, dialect);
                o.insert("shape", native.shape.as_slice());
                o.insert("data", native.data.as_slice());
            }
        }
        tensors.push(Json::Obj(o));
    }
    root.insert("tensors", tensors);
    let nodes: Vec<Json> = g
        .ops
        .iter()
        .map(|op| {
            let mut o = JsonObj::new();
            o.insert("op", dialect.op_name(&op.kind));
            o.insert("name", op.name.as_str());
            o.insert(
                "inputs",
                op.inputs.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
            );
            o.insert(
                "outputs",
                op.outputs.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
            );
            let mut attrs = JsonObj::new();
            match &op.kind {
                OpKind::Conv2d { stride, pad, groups } => {
                    attrs.insert("stride", *stride);
                    match dialect {
                        Dialect::Tf | Dialect::Jax => {
                            attrs.insert("padding", if *pad > 0 { "SAME" } else { "VALID" });
                            attrs.insert("pad_amount", *pad);
                            attrs.insert("feature_group_count", *groups);
                        }
                        _ => {
                            attrs.insert("pad", *pad);
                            attrs.insert("groups", *groups);
                        }
                    }
                }
                OpKind::BatchNorm { eps } | OpKind::LayerNorm { eps } => {
                    attrs.insert("eps", *eps as f64);
                    if matches!(dialect, Dialect::Mxnet) {
                        attrs.insert("fix_gamma", false);
                    }
                }
                OpKind::MaxPool2d { k, stride, pad } | OpKind::AvgPool2d { k, stride, pad } => {
                    attrs.insert("kernel", *k);
                    attrs.insert("stride", *stride);
                    attrs.insert("pad", *pad);
                }
                OpKind::Concat { axis } => {
                    // channels-last dialects concat on the last axis
                    let native_axis = if dialect.channels_last() && *axis == 1 { 3 } else { *axis };
                    attrs.insert("axis", native_axis);
                }
                OpKind::Transpose { perm } => attrs.insert("perm", perm.as_slice()),
                OpKind::SplitHeads { heads } => attrs.insert("heads", *heads),
                OpKind::Scale { c } => attrs.insert("c", *c as f64),
                OpKind::ReduceMean { axis } => attrs.insert("axis", *axis),
                _ => {}
            }
            o.insert("attrs", attrs);
            Json::Obj(o)
        })
        .collect();
    root.insert("nodes", nodes);
    root.insert(
        "inputs",
        g.inputs.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
    );
    root.insert(
        "outputs",
        g.outputs.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
    );
    Json::Obj(root)
}

/// Convert a parameter to the dialect's native layout.
fn native_param(g: &Graph, id: usize, t: &Tensor, dialect: Dialect) -> Tensor {
    if !dialect.channels_last() {
        return t.clone();
    }
    // which op consumes this param and in which slot?
    for op in &g.ops {
        if let Some(slot) = op.inputs.iter().position(|&i| i == id) {
            match (&op.kind, slot) {
                (OpKind::Conv2d { .. }, 1) => return oihw_to_hwio(t),
                (OpKind::Gemm, 1) => return t.t2(),
                _ => {}
            }
        }
    }
    t.clone()
}

/// Import a framework dialect document into SPA-IR — the paper's
/// "convert to ONNX" step. All layouts normalize to NCHW / `[out,in]`.
pub fn import_model(doc: &Json) -> anyhow::Result<Graph> {
    let dialect = Dialect::parse(
        doc.field("framework")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("framework not a string"))?,
    )?;
    let name = doc.field("name")?.as_str().unwrap_or("model").to_string();
    let mut g = Graph {
        name: format!("{name}@{}", dialect.name()),
        ..Default::default()
    };
    // Pass 1: create data nodes (shapes for activations filled by
    // inference afterwards).
    let tensors = doc
        .field("tensors")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("tensors not an array"))?;
    for (id, tj) in tensors.iter().enumerate() {
        let tname = tj.field("name")?.as_str().unwrap_or("").to_string();
        let role = tj.field("role")?.as_str().unwrap_or("");
        let (kind, shape) = match role {
            "input" => {
                let mut shape = tj.field("shape")?.usize_vec()?;
                if dialect.channels_last() && shape.len() == 4 {
                    shape = vec![shape[0], shape[3], shape[1], shape[2]];
                }
                (DataKind::Input, shape)
            }
            "activation" => (DataKind::Activation, Vec::new()),
            "param" => {
                let shape = tj.field("shape")?.usize_vec()?;
                let data = tj.field("data")?.f32_vec()?;
                (DataKind::Param(Tensor::new(shape.clone(), data)), shape)
            }
            other => anyhow::bail!("bad tensor role `{other}`"),
        };
        g.datas.push(crate::ir::DataNode {
            id,
            name: tname,
            shape,
            kind,
            producer: None,
            consumers: Vec::new(),
        });
    }
    // Pass 2: nodes.
    let nodes = doc
        .field("nodes")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("nodes not an array"))?;
    for (op_id, nj) in nodes.iter().enumerate() {
        let op_name = nj.field("name")?.as_str().unwrap_or("").to_string();
        let native = nj.field("op")?.as_str().unwrap_or("");
        let attrs = nj.field("attrs")?;
        let au = |k: &str| -> usize {
            attrs
                .as_obj()
                .and_then(|o| o.get(k))
                .and_then(|v| v.as_usize())
                .unwrap_or(0)
        };
        let af = |k: &str| -> f32 {
            attrs
                .as_obj()
                .and_then(|o| o.get(k))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0) as f32
        };
        let kind = match native {
            "Conv2d" | "Conv2D" | "Conv" | "Convolution" => {
                let groups = if dialect.channels_last() {
                    au("feature_group_count").max(1)
                } else {
                    au("groups").max(1)
                };
                let pad = if dialect.channels_last() {
                    au("pad_amount")
                } else {
                    au("pad")
                };
                OpKind::Conv2d {
                    stride: au("stride").max(1),
                    pad,
                    groups,
                }
            }
            "Linear" | "Dense" | "FullyConnected" => OpKind::Gemm,
            "BatchNorm2d" | "FusedBatchNorm" | "BatchNorm" => {
                OpKind::BatchNorm { eps: af("eps").max(1e-6) }
            }
            "LayerNorm" => OpKind::LayerNorm { eps: af("eps").max(1e-6) },
            "ReLU" => OpKind::Relu,
            "GELU" => OpKind::Gelu,
            "SiLU" => OpKind::Silu,
            "Sigmoid" => OpKind::Sigmoid,
            "Tanh" => OpKind::Tanh,
            "Add" => OpKind::Add,
            "Mul" => OpKind::Mul,
            "MaxPool2d" | "MaxPool" => OpKind::MaxPool2d {
                k: au("kernel").max(1),
                stride: au("stride").max(1),
                pad: au("pad"),
            },
            "AvgPool2d" | "AvgPool" => OpKind::AvgPool2d {
                k: au("kernel").max(1),
                stride: au("stride").max(1),
                pad: au("pad"),
            },
            "AdaptiveAvgPool2d" | "GlobalAveragePooling" => OpKind::GlobalAvgPool,
            "Flatten" => OpKind::Flatten,
            "Concat" => {
                let native_axis = au("axis");
                let axis = if dialect.channels_last() && native_axis == 3 {
                    1
                } else {
                    native_axis
                };
                OpKind::Concat { axis }
            }
            "Softmax" => OpKind::Softmax,
            "MatMul" => OpKind::MatMul,
            "Transpose" => OpKind::Transpose {
                perm: attrs.field("perm")?.usize_vec()?,
            },
            "SplitHeads" => OpKind::SplitHeads { heads: au("heads").max(1) },
            "MergeHeads" => OpKind::MergeHeads,
            "Scale" => OpKind::Scale { c: af("c") },
            "Embedding" => OpKind::Embedding,
            "ReduceMean" => OpKind::ReduceMean { axis: au("axis") },
            "PatchFlatten" => OpKind::NchwToTokens,
            "Identity" => OpKind::Identity,
            other => anyhow::bail!("dialect {} has unknown op `{other}`", dialect.name()),
        };
        let inputs = nj.field("inputs")?.usize_vec()?;
        let outputs = nj.field("outputs")?.usize_vec()?;
        // normalize param layouts for channels-last dialects
        if dialect.channels_last() {
            match kind {
                OpKind::Conv2d { .. } => {
                    if let Some(&w) = inputs.get(1) {
                        if let Some(t) = g.datas[w].param() {
                            if t.rank() == 4 {
                                let conv = hwio_to_oihw(t);
                                g.datas[w].shape = conv.shape.clone();
                                g.datas[w].kind = DataKind::Param(conv);
                            }
                        }
                    }
                }
                OpKind::Gemm => {
                    if let Some(&w) = inputs.get(1) {
                        if let Some(t) = g.datas[w].param() {
                            if t.rank() == 2 {
                                let conv = t.t2();
                                g.datas[w].shape = conv.shape.clone();
                                g.datas[w].kind = DataKind::Param(conv);
                            }
                        }
                    }
                }
                _ => {}
            }
        }
        for &i in &inputs {
            g.datas[i].consumers.push(op_id);
        }
        for &o in &outputs {
            g.datas[o].producer = Some(op_id);
        }
        g.ops.push(crate::ir::OpNode {
            id: op_id,
            name: op_name,
            kind,
            inputs,
            outputs,
        });
    }
    g.inputs = doc.field("inputs")?.usize_vec()?;
    g.outputs = doc.field("outputs")?.usize_vec()?;
    g.refresh_shapes()?;
    g.validate()?;
    Ok(g)
}

/// Serialize + parse convenience used by the conversion-time bench.
pub fn export_to_string(g: &Graph, dialect: Dialect) -> String {
    export_model(g, dialect).to_string()
}

pub fn import_from_string(s: &str) -> anyhow::Result<Graph> {
    import_model(&parse_json(s)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine;
    use crate::util::Rng;
    use crate::zoo::{self, ImageCfg};

    fn check_round_trip(dialect: Dialect) {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let g = zoo::resnet18(cfg, 42);
        let doc = export_model(&g, dialect);
        let g2 = import_model(&doc).unwrap_or_else(|e| panic!("{}: {e}", dialect.name()));
        g2.validate().unwrap();
        assert_eq!(g.num_params(), g2.num_params(), "{}", dialect.name());
        // numerics identical after layout round-trip
        let mut rng = Rng::new(7);
        let x = crate::tensor::Tensor::new(
            vec![2, 3, 8, 8],
            rng.uniform_vec(2 * 3 * 64, -1.0, 1.0),
        );
        let y1 = engine::predict(&g, x.clone()).unwrap();
        let y2 = engine::predict(&g2, x).unwrap();
        crate::tensor::assert_allclose(&y2, &y1, 1e-4, 1e-4);
    }

    #[test]
    fn torch_round_trip() {
        check_round_trip(Dialect::Torch);
    }

    #[test]
    fn tf_round_trip() {
        check_round_trip(Dialect::Tf);
    }

    #[test]
    fn jax_round_trip() {
        check_round_trip(Dialect::Jax);
    }

    #[test]
    fn mxnet_round_trip() {
        check_round_trip(Dialect::Mxnet);
    }

    #[test]
    fn tf_uses_native_conventions() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let g = zoo::resnet18(cfg, 1);
        let doc = export_model(&g, Dialect::Tf);
        let s = doc.to_string();
        assert!(s.contains("\"Conv2D\""), "tf conv name");
        assert!(s.contains("FusedBatchNorm"), "tf bn name");
        // input signature NHWC
        let tensors = doc.field("tensors").unwrap().as_arr().unwrap();
        let input = tensors
            .iter()
            .find(|t| t.field("role").unwrap().as_str() == Some("input"))
            .unwrap();
        let shape = input.field("shape").unwrap().usize_vec().unwrap();
        assert_eq!(shape, vec![cfg.batch, 8, 8, 3], "NHWC signature");
        // conv kernel stored HWIO: stem conv is [3,3,3,16] not [16,3,3,3]
        let stem = tensors
            .iter()
            .find(|t| t.field("name").unwrap().as_str() == Some("stem.conv.w"))
            .unwrap();
        let kshape = stem.field("shape").unwrap().usize_vec().unwrap();
        assert_eq!(kshape, vec![3, 3, 3, 16], "HWIO kernel layout");
    }

    #[test]
    fn import_rejects_unknown_op() {
        let doc = parse_json(
            r#"{"framework":"torch","format_version":1,"name":"x",
                "tensors":[{"name":"x","role":"input","shape":[1,3,4,4]}],
                "nodes":[{"op":"FancyNewLayer","name":"f","inputs":[0],"outputs":[0],"attrs":{}}],
                "inputs":[0],"outputs":[0]}"#,
        )
        .unwrap();
        let err = import_model(&doc).unwrap_err().to_string();
        assert!(err.contains("FancyNewLayer"), "{err}");
    }

    #[test]
    fn all_dialects_produce_prunable_graphs() {
        use crate::prune::{self, build_groups, score_groups, Agg, Norm};
        use std::collections::HashMap;
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        for d in Dialect::ALL {
            let src = zoo::resnet18(cfg, 3);
            let mut g = import_model(&export_model(&src, d)).unwrap();
            let groups = build_groups(&g).unwrap();
            let mut scores = HashMap::new();
            for pid in g.param_ids() {
                scores.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
            }
            let ranked = score_groups(&g, &groups, &scores, Agg::Sum, Norm::Mean);
            let sel = prune::select_lowest(&groups, &ranked, 0.4, 1);
            prune::apply_pruning(&mut g, &groups, &sel)
                .unwrap_or_else(|e| panic!("{}: {e}", d.name()));
            g.validate().unwrap();
        }
    }
}
