//! Structured pruning core — the paper's four-step procedure (§3.2):
//!
//! 1. **Coupling channels via mask propagation** ([`rules`], [`propagate`])
//!    — per-operator rules move channel masks between the data nodes an
//!    operator touches; a worklist closure finds every coupled channel.
//! 2. **Grouping coupled channels** ([`grouping`]) — one propagation per
//!    source channel, organized into groups of identically-patterned
//!    coupled channel sets (Alg. 2).
//! 3. **Importance estimation** ([`importance`]) — Eq. 1:
//!    `Norm ∘ AGG ∘ S` over each coupled set, with pluggable criteria.
//! 4. **Pruning** ([`pruner`]) — physical deletion of channels from
//!    parameter tensors, attribute fix-up (e.g. depthwise group counts),
//!    shape re-inference, and validation.

pub mod grouping;
pub mod importance;
pub mod pruner;
pub mod rules;

pub use grouping::{build_groups, CoupledChannels, Group, Groups};
pub use importance::{score_groups, score_groups_scoped, Agg, GroupScore, Norm, Scope};
pub use pruner::{
    apply_pruning, select_by_flops_target, select_by_metric_target, select_lowest,
    select_lowest_n, PruneOutcome, TargetedSelection,
};
pub use rules::{propagate, Mask};

use crate::ir::DataId;

/// A single channel location: index `idx` along dimension `dim` of data
/// node `data`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Loc {
    pub data: DataId,
    pub dim: usize,
    pub idx: usize,
}
