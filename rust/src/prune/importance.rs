//! Group-level importance estimation — Eq. 1 of the paper:
//!
//! `s_{i,j} = Norm_{CC_l ∈ g_i}( { AGG( S(θ_k), ∀θ_k ∈ CC_j ) } )`
//!
//! Per-parameter scores `S` come from a criterion (`crate::criteria`) as a
//! map from parameter data id to a score tensor of the parameter's shape.
//! `AGG` collapses each coupled channel set to a scalar; `Norm` rescales
//! scalars within each group so scores are comparable *across* groups for
//! global ranking (the paper's Alg. 3).

use super::grouping::{Group, Groups};
use super::Loc;
use crate::ir::{DataId, Graph};
use crate::tensor::Tensor;
use crate::util::par;
use std::collections::HashMap;

/// Aggregation operator over the scores of a coupled channel set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Agg {
    Sum,
    Mean,
    Max,
    Prod,
    /// L2 norm of the score vector.
    L2,
}

impl Agg {
    pub fn apply(&self, scores: &[f32]) -> f32 {
        if scores.is_empty() {
            return 0.0;
        }
        match self {
            Agg::Sum => scores.iter().sum(),
            Agg::Mean => scores.iter().sum::<f32>() / scores.len() as f32,
            Agg::Max => scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
            Agg::Prod => scores.iter().fold(1.0, |a, &b| a * b.abs().max(1e-30)),
            Agg::L2 => scores.iter().map(|s| s * s).sum::<f32>().sqrt(),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Agg> {
        Ok(match s {
            "sum" => Agg::Sum,
            "mean" => Agg::Mean,
            "max" => Agg::Max,
            "prod" => Agg::Prod,
            "l2" => Agg::L2,
            _ => anyhow::bail!("unknown AGG `{s}`"),
        })
    }
}

/// Normalization of CC scores within a group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Norm {
    /// Divide by the group sum.
    Sum,
    /// Divide by the group max.
    Max,
    /// Divide by the group mean.
    Mean,
    /// Divide by the group median.
    Median,
    /// No normalization.
    None,
}

impl Norm {
    pub fn apply(&self, scores: &mut [f32]) {
        if scores.is_empty() {
            return;
        }
        let denom = match self {
            Norm::Sum => scores.iter().sum::<f32>(),
            Norm::Max => scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
            Norm::Mean => scores.iter().sum::<f32>() / scores.len() as f32,
            Norm::Median => {
                let mut s = scores.to_vec();
                s.sort_by(|a, b| a.total_cmp(b));
                s[s.len() / 2]
            }
            Norm::None => 1.0,
        };
        if denom.abs() > 1e-30 {
            for v in scores.iter_mut() {
                *v /= denom;
            }
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Norm> {
        Ok(match s {
            "sum" => Norm::Sum,
            "max" => Norm::Max,
            "mean" => Norm::Mean,
            "median" => Norm::Median,
            "none" => Norm::None,
            _ => anyhow::bail!("unknown Norm `{s}`"),
        })
    }
}

/// The score of one coupled channel set.
#[derive(Debug, Clone, Copy)]
pub struct GroupScore {
    pub group: usize,
    pub cc: usize,
    pub score: f32,
}

/// Gather the per-parameter scores at channel location `loc` (the whole
/// slice along `loc.dim` at `loc.idx`).
fn slice_scores(score: &Tensor, loc: &Loc, out: &mut Vec<f32>) {
    let dim = loc.dim;
    let d = score.shape[dim];
    let outer: usize = score.shape[..dim].iter().product();
    let inner: usize = score.shape[dim + 1..].iter().product();
    for o in 0..outer {
        let base = (o * d + loc.idx) * inner;
        out.extend_from_slice(&score.data[base..base + inner]);
    }
}

/// Which parameters of a coupled channel set contribute to its score.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// SPA's grouped estimation: every coupled weight slice (Eq. 1).
    FullCc,
    /// The classic "structured" baselines (SNAP, structured-CroP/GraSP,
    /// ungrouped L1): only the source operator's own filter slice.
    SourceOnly,
}

/// Apply Eq. 1 over all prunable groups. `param_scores` maps parameter
/// data ids to score tensors (criteria that do not score a parameter —
/// e.g. BN running stats — are simply skipped).
pub fn score_groups(
    g: &Graph,
    groups: &Groups,
    param_scores: &HashMap<DataId, Tensor>,
    agg: Agg,
    norm: Norm,
) -> Vec<GroupScore> {
    score_groups_scoped(g, groups, param_scores, agg, norm, Scope::FullCc)
}

/// [`score_groups`] with an explicit scoring [`Scope`].
///
/// Groups are scored independently (Eq. 1 normalizes within a group), so
/// per-group aggregation fans out across the `util::par` worker pool;
/// results are flattened back in group order, making the output — order
/// and bits — identical at any `SPA_THREADS`.
pub fn score_groups_scoped(
    g: &Graph,
    groups: &Groups,
    param_scores: &HashMap<DataId, Tensor>,
    agg: Agg,
    norm: Norm,
    scope: Scope,
) -> Vec<GroupScore> {
    let prunable: Vec<&Group> = groups.groups.iter().filter(|gr| gr.prunable).collect();
    let score_one = |group: &Group| -> Vec<GroupScore> {
        // For SourceOnly scoring, restrict to the source op's weight dim 0.
        let src_w = g.op(group.source_op).inputs.get(1).copied();
        let mut scores: Vec<f32> = Vec::with_capacity(group.ccs.len());
        for cc in &group.ccs {
            let mut vals = Vec::new();
            for loc in &cc.locs {
                if scope == Scope::SourceOnly && (Some(loc.data) != src_w || loc.dim != 0) {
                    continue;
                }
                if let Some(s) = param_scores.get(&loc.data) {
                    slice_scores(s, loc, &mut vals);
                }
            }
            scores.push(agg.apply(&vals));
        }
        norm.apply(&mut scores);
        scores
            .iter()
            .enumerate()
            .map(|(cc, &score)| GroupScore {
                group: group.id,
                cc,
                score,
            })
            .collect()
    };
    // Small graphs stay serial — a handful of tiny groups is cheaper
    // than thread spawns (util::par design constraint).
    let total_ccs: usize = prunable.iter().map(|gr| gr.ccs.len()).sum();
    let scored: Vec<Vec<GroupScore>> = if par::max_threads() <= 1 || total_ccs < 64 {
        prunable.iter().map(|group| score_one(group)).collect()
    } else {
        par::par_map(&prunable, |group| score_one(group))
    };
    scored.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::prune::build_groups;

    #[test]
    fn agg_operators() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(Agg::Sum.apply(&v), 6.0);
        assert_eq!(Agg::Mean.apply(&v), 2.0);
        assert_eq!(Agg::Max.apply(&v), 3.0);
        assert!((Agg::L2.apply(&v) - 14.0f32.sqrt()).abs() < 1e-6);
        assert_eq!(Agg::Prod.apply(&v), 6.0);
    }

    #[test]
    fn norm_operators() {
        let mut v = [1.0, 3.0];
        Norm::Sum.apply(&mut v);
        assert_eq!(v, [0.25, 0.75]);
        let mut v = [1.0, 4.0];
        Norm::Max.apply(&mut v);
        assert_eq!(v, [0.25, 1.0]);
        let mut v = [2.0, 6.0];
        Norm::Mean.apply(&mut v);
        assert_eq!(v, [0.5, 1.5]);
        let mut v = [5.0, 7.0];
        Norm::None.apply(&mut v);
        assert_eq!(v, [5.0, 7.0]);
    }

    #[test]
    fn scores_rank_planted_channel_lowest() {
        // zero out channel 2 of c0: with L1 scores it must rank lowest
        let mut b = GraphBuilder::new("rank", 1);
        let x = b.input("x", vec![1, 3, 6, 6]);
        let c0 = b.conv2d("c0", x, 6, 3, 1, 1, 1, false);
        let gp = b.global_avgpool("gap", c0);
        let fc = b.gemm("fc", gp, 2, false);
        b.output(fc);
        let mut g = b.finish().unwrap();
        let w0 = g.data_by_name("c0.w").unwrap().id;
        {
            let t = g.datas[w0].param_mut().unwrap();
            let inner = 3 * 3 * 3;
            for i in 2 * inner..3 * inner {
                t.data[i] = 0.0;
            }
        }
        let groups = build_groups(&g).unwrap();
        // L1 magnitude scores
        let mut scores = HashMap::new();
        for pid in g.param_ids() {
            scores.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
        }
        let ranked = score_groups(&g, &groups, &scores, Agg::Sum, Norm::Mean);
        let group0: Vec<&GroupScore> = ranked.iter().filter(|s| s.group == 0).collect();
        assert_eq!(group0.len(), 6);
        let min = group0
            .iter()
            .min_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
            .unwrap();
        assert_eq!(min.cc, 2, "planted zero channel should score lowest");
    }

    #[test]
    fn unprunable_groups_excluded() {
        let mut b = GraphBuilder::new("x", 2);
        let x = b.input("x", vec![1, 3, 4, 4]);
        let gp = b.global_avgpool("gap", x);
        let fc = b.gemm("fc", gp, 2, false);
        b.output(fc);
        let g = b.finish().unwrap();
        let groups = build_groups(&g).unwrap();
        let scores = HashMap::new();
        let ranked = score_groups(&g, &groups, &scores, Agg::Sum, Norm::None);
        assert!(ranked.is_empty(), "only group is the classifier → nothing");
    }
}
