//! Channel grouping — the paper's Alg. 2.
//!
//! Loops over operators with prunable output dimensions (conv / gemm
//! weights), propagates a mask per not-yet-covered output channel, and
//! collects the resulting coupled channel sets into [`Group`]s. Operators
//! whose channels were already swept into an earlier group are skipped
//! (the paper's `analyzed_ops` marking), so e.g. all convs tied by a
//! residual chain form ONE group.

use super::rules::{param_locs, propagate, Mask};
use super::Loc;
use crate::ir::{DataId, DataKind, Graph, OpId, OpKind};
use std::collections::HashSet;

/// One set of channels that must be pruned together (same color in the
/// paper's Fig. 5). `locs` are parameter channel locations; `acts` are the
/// activation locations the mask sweep covered (used for prunability
/// checks against graph inputs/outputs).
#[derive(Debug, Clone)]
pub struct CoupledChannels {
    pub locs: Vec<Loc>,
    pub acts: Vec<Loc>,
}

/// A group of identically-patterned coupled channel sets.
#[derive(Debug, Clone)]
pub struct Group {
    pub id: usize,
    /// The operator whose output channels seeded this group.
    pub source_op: OpId,
    pub ccs: Vec<CoupledChannels>,
    /// False when the group touches a graph input/output (e.g. classifier
    /// logits) or an embedding-id path and must not be pruned.
    pub prunable: bool,
}

#[derive(Debug, Clone, Default)]
pub struct Groups {
    pub groups: Vec<Group>,
}

impl Groups {
    /// Number of prunable coupled-channel sets across all groups.
    pub fn num_prunable_ccs(&self) -> usize {
        self.groups
            .iter()
            .filter(|g| g.prunable)
            .map(|g| g.ccs.len())
            .sum()
    }
}

/// The prunable source parameter of an operator: (param data id, out dim).
pub fn prunable_source(g: &Graph, op_id: OpId) -> Option<(DataId, usize)> {
    let op = g.op(op_id);
    match op.kind {
        OpKind::Conv2d { .. } | OpKind::Gemm => Some((op.inputs[1], 0)),
        _ => None,
    }
}

/// Build all groups for a graph (paper Alg. 2). `O(|E|)` per group sweep
/// as analyzed in §3.2 — each channel's propagation touches each edge a
/// bounded number of times and channels covered by earlier groups are
/// never re-propagated.
pub fn build_groups(g: &Graph) -> anyhow::Result<Groups> {
    let mut covered: HashSet<Loc> = HashSet::new();
    let mut groups = Vec::new();
    let graph_io: HashSet<DataId> = g.inputs.iter().chain(&g.outputs).copied().collect();
    for op_id in g.topo_order()? {
        let Some((src, out_dim)) = prunable_source(g, op_id) else {
            continue;
        };
        let channels = g.data(src).shape[out_dim];
        let mut ccs = Vec::new();
        let mut prunable = true;
        for c in 0..channels {
            let seed = Loc {
                data: src,
                dim: out_dim,
                idx: c,
            };
            if covered.contains(&seed) {
                continue;
            }
            let masks = propagate(g, Mask::single(g, src, out_dim, c));
            let locs = param_locs(g, &masks);
            let mut acts = Vec::new();
            for ((data, dim), m) in &masks {
                let dn = g.data(*data);
                if matches!(dn.kind, DataKind::Param(_)) {
                    continue;
                }
                for idx in m.indices() {
                    acts.push(Loc {
                        data: *data,
                        dim: *dim,
                        idx,
                    });
                }
                // Touching channel dims of a graph input or output makes
                // the whole group un-prunable (e.g. logits, RGB input).
                if graph_io.contains(data) {
                    prunable = false;
                }
            }
            // Mark every prunable-source channel in this CC as covered so
            // coupled operators are not re-analyzed (paper l.11-13).
            for l in &locs {
                covered.insert(*l);
            }
            acts.sort();
            ccs.push(CoupledChannels { locs, acts });
        }
        if !ccs.is_empty() {
            groups.push(Group {
                id: groups.len(),
                source_op: op_id,
                ccs,
                prunable,
            });
        }
    }
    Ok(Groups { groups })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn resnet_like() -> Graph {
        let mut b = GraphBuilder::new("resnetish", 1);
        let x = b.input("x", vec![1, 3, 8, 8]);
        let c0 = b.conv2d("c0", x, 8, 3, 1, 1, 1, false);
        let n0 = b.batchnorm("bn0", c0);
        let r0 = b.relu("r0", n0);
        // block: two convs + residual
        let c1 = b.conv2d("c1", r0, 8, 3, 1, 1, 1, false);
        let n1 = b.batchnorm("bn1", c1);
        let r1 = b.relu("r1", n1);
        let c2 = b.conv2d("c2", r1, 8, 3, 1, 1, 1, false);
        let n2 = b.batchnorm("bn2", c2);
        let s = b.add("add", n2, r0);
        let r2 = b.relu("r2", s);
        let gp = b.global_avgpool("gap", r2);
        let fc = b.gemm("fc", gp, 4, true);
        b.output(fc);
        b.finish().unwrap()
    }

    #[test]
    fn residual_chain_forms_one_group() {
        let g = resnet_like();
        let groups = build_groups(&g).unwrap();
        // c0 and c2 are residual-coupled (via add) → same group;
        // c1 is independent (inner channels); fc is output → un-prunable
        let by_src: Vec<(&str, usize, bool)> = groups
            .groups
            .iter()
            .map(|gr| {
                (
                    g.op(gr.source_op).name.as_str(),
                    gr.ccs.len(),
                    gr.prunable,
                )
            })
            .collect();
        assert_eq!(by_src.len(), 3, "{by_src:?}");
        assert_eq!(by_src[0], ("c0", 8, true));
        assert_eq!(by_src[1], ("c1", 8, true));
        assert_eq!(by_src[2].0, "fc");
        assert!(!by_src[2].2, "classifier output must be un-prunable");
        // the c0 group's CCs include both c0.w dim0 and c2.w dim0
        let w0 = g.data_by_name("c0.w").unwrap().id;
        let w2 = g.data_by_name("c2.w").unwrap().id;
        let cc = &groups.groups[0].ccs[0];
        assert!(cc.locs.iter().any(|l| l.data == w0 && l.dim == 0));
        assert!(cc.locs.iter().any(|l| l.data == w2 && l.dim == 0));
    }

    #[test]
    fn ccs_partition_source_channels() {
        let g = resnet_like();
        let groups = build_groups(&g).unwrap();
        // every (source param, dim0, channel) appears in exactly one CC
        let mut seen: HashSet<Loc> = HashSet::new();
        for gr in &groups.groups {
            for cc in &gr.ccs {
                for l in &cc.locs {
                    if l.dim == 0 && g.data(l.data).name.ends_with(".w") {
                        assert!(seen.insert(*l), "duplicate loc {:?}", l);
                    }
                }
            }
        }
        for d in &g.datas {
            if d.name.ends_with(".w") && d.shape.len() >= 2 {
                for c in 0..d.shape[0] {
                    assert!(
                        seen.contains(&Loc { data: d.id, dim: 0, idx: c }),
                        "{}[{}] not covered",
                        d.name,
                        c
                    );
                }
            }
        }
    }

    #[test]
    fn grouped_conv_ccs_span_groups() {
        let mut b = GraphBuilder::new("grp", 2);
        let x = b.input("x", vec![1, 4, 6, 6]);
        let c0 = b.conv2d("c0", x, 8, 1, 1, 0, 1, false);
        let c1 = b.conv2d("c1", c0, 8, 3, 1, 1, 4, false);
        let gp = b.global_avgpool("gap", c1);
        let fc = b.gemm("fc", gp, 2, false);
        b.output(fc);
        let g = b.finish().unwrap();
        let groups = build_groups(&g).unwrap();
        let g0 = &groups.groups[0];
        // c0 has 8 output channels but closure ties pairs {c, c+2, ...}
        // across the 4 groups of c1 (cig=2): each CC covers 4 channels →
        // only 2 CCs
        assert_eq!(g.op(g0.source_op).name, "c0");
        assert_eq!(g0.ccs.len(), 2, "position closure should merge channels");
    }

    #[test]
    fn densenet_concat_groups() {
        let mut b = GraphBuilder::new("dense", 3);
        let x = b.input("x", vec![1, 4, 6, 6]);
        let c1 = b.conv2d("c1", x, 4, 3, 1, 1, 1, false);
        let cat = b.concat("cat", &[x, c1], 1);
        let c2 = b.conv2d("c2", cat, 6, 3, 1, 1, 1, false);
        let gp = b.global_avgpool("gap", c2);
        let fc = b.gemm("fc", gp, 2, false);
        b.output(fc);
        let g = b.finish().unwrap();
        let groups = build_groups(&g).unwrap();
        // c1's group: prunable (concat carries x but x channels only occupy
        // offsets 0..4; c1's channels occupy 4..8 and do not touch x)
        let gc1 = groups
            .groups
            .iter()
            .find(|gr| g.op(gr.source_op).name == "c1")
            .unwrap();
        assert!(gc1.prunable);
        let w2 = g.data_by_name("c2.w").unwrap().id;
        // each c1 CC hits c2's in-dim at offset+4
        let cc0 = &gc1.ccs[0];
        assert!(cc0.locs.iter().any(|l| l.data == w2 && l.dim == 1 && l.idx == 4));
    }
}
