//! Step 4 — physical pruning (paper §3.2).
//!
//! Given the coupled channel sets selected for removal, delete the
//! corresponding slices from every parameter tensor, fix operator
//! attributes whose semantics depend on channel counts (depthwise conv
//! group counts), re-run shape inference, and validate the rewritten
//! graph. Selection helpers implement global lowest-score pruning and
//! FLOPs-targeted pruning (used to hit the paper's "~2× RF" setups).

use super::grouping::Groups;
use super::importance::GroupScore;
use crate::analysis;
use crate::ir::{DataId, Graph, OpKind};
use std::collections::{HashMap, HashSet};

/// Result of a pruning application.
#[derive(Debug, Clone)]
pub struct PruneOutcome {
    /// Channels deleted per parameter (data id → per-dim index lists).
    pub deleted: HashMap<DataId, HashMap<usize, Vec<usize>>>,
    /// Number of coupled channel sets removed.
    pub ccs_removed: usize,
}

/// Select the `frac` lowest-scoring CCs globally, but never remove all
/// CCs of one group — at least `min_keep` survive per group.
pub fn select_lowest(
    groups: &Groups,
    scores: &[GroupScore],
    frac: f64,
    min_keep: usize,
) -> Vec<(usize, usize)> {
    let target = ((scores.len() as f64) * frac).round() as usize;
    select_lowest_n(groups, scores, target, min_keep)
}

/// Select (up to) the `n` lowest-scoring CCs globally, never dropping a
/// group below `min_keep` surviving CCs. NaN saliencies of either sign
/// rank last (pruned last) instead of panicking the comparator — note
/// plain [`f32::total_cmp`] would rank a negative NaN *first*.
pub fn select_lowest_n(
    groups: &Groups,
    scores: &[GroupScore],
    n: usize,
    min_keep: usize,
) -> Vec<(usize, usize)> {
    let mut ranked: Vec<&GroupScore> = scores.iter().collect();
    ranked.sort_by(|a, b| {
        a.score
            .is_nan()
            .cmp(&b.score.is_nan())
            .then(a.score.total_cmp(&b.score))
    });
    let mut kept_per_group: HashMap<usize, usize> = HashMap::new();
    for gr in &groups.groups {
        kept_per_group.insert(gr.id, gr.ccs.len());
    }
    let mut selected = Vec::new();
    for s in ranked {
        if selected.len() >= n {
            break;
        }
        let kept = kept_per_group.get_mut(&s.group).unwrap();
        if *kept <= min_keep {
            continue;
        }
        *kept -= 1;
        selected.push((s.group, s.cc));
    }
    selected
}

/// A selection produced by bisecting toward a reduction-ratio target.
#[derive(Debug, Clone)]
pub struct TargetedSelection {
    /// Selected CCs, in ascending-score order.
    pub selected: Vec<(usize, usize)>,
    /// The reduction ratio this selection actually achieves (trial-apply
    /// measured). Equals/exceeds the requested target unless `clamped`.
    pub achieved: f64,
    /// True when the target was unreachable under `min_keep` and the
    /// selection was clamped to the feasible maximum.
    pub clamped: bool,
}

/// Bisect the global pruning fraction until a cost metric (FLOPs,
/// params, ...) drops by `target` (ratio before/after). When the target
/// is unreachable under `min_keep`, the selection is **clamped** to the
/// feasible maximum — trimmed of its flat tail, i.e. the highest-score
/// CCs whose removal no longer improves the metric — and the result is
/// flagged `clamped` with the `achieved` ratio, instead of silently
/// returning a maximal selection that pretends to meet the target.
pub fn select_by_metric_target(
    g: &Graph,
    groups: &Groups,
    scores: &[GroupScore],
    target: f64,
    min_keep: usize,
    metric: impl Fn(&Graph) -> f64,
) -> anyhow::Result<TargetedSelection> {
    let base = metric(g);
    let ratio_of = |sel: &[(usize, usize)]| -> anyhow::Result<f64> {
        let mut trial = g.clone();
        apply_pruning(&mut trial, groups, sel)?;
        Ok(base / metric(&trial).max(1.0))
    };
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    let mut best = Vec::new();
    let mut best_ratio = 1.0f64;
    for _ in 0..12 {
        let mid = 0.5 * (lo + hi);
        let sel = select_lowest(groups, scores, mid, min_keep);
        let ratio = ratio_of(&sel)?;
        if ratio < target {
            lo = mid;
        } else {
            hi = mid;
            best = sel;
            best_ratio = ratio;
        }
    }
    if best.is_empty() {
        let all = select_lowest(groups, scores, 1.0, min_keep);
        let max_ratio = ratio_of(&all)?;
        if max_ratio < target {
            // Unreachable: keep the shortest ascending-score prefix that
            // still achieves the feasible maximum (ratio is monotone in
            // prefix length, so bisection over the length is exact).
            let (mut plo, mut phi) = (0usize, all.len());
            while plo < phi {
                let mid = (plo + phi) / 2;
                if ratio_of(&all[..mid])? >= max_ratio {
                    phi = mid;
                } else {
                    plo = mid + 1;
                }
            }
            return Ok(TargetedSelection {
                selected: all[..plo].to_vec(),
                achieved: max_ratio,
                clamped: true,
            });
        }
        // Target met only by (near-)empty selections: mirror the
        // bisection's final `hi` fraction.
        best = select_lowest(groups, scores, hi, min_keep);
        best_ratio = ratio_of(&best)?;
    }
    Ok(TargetedSelection {
        selected: best,
        achieved: best_ratio,
        clamped: false,
    })
}

/// Iteratively grow the selection until the pruned model's FLOPs drop by
/// `target_rf` (e.g. 2.0 for the paper's ~2× settings). Bisects the
/// global fraction via [`select_by_metric_target`]; returns the selected
/// CCs (clamped to the feasible maximum when the target is unreachable —
/// use [`crate::session::Session`] to also observe the achieved ratio).
pub fn select_by_flops_target(
    g: &Graph,
    groups: &Groups,
    scores: &[GroupScore],
    target_rf: f64,
    min_keep: usize,
) -> anyhow::Result<Vec<(usize, usize)>> {
    let t = select_by_metric_target(g, groups, scores, target_rf, min_keep, |m| {
        analysis::flops(m) as f64
    })?;
    Ok(t.selected)
}

/// Apply the selected CC deletions to the graph in place.
pub fn apply_pruning(
    g: &mut Graph,
    groups: &Groups,
    selected: &[(usize, usize)],
) -> anyhow::Result<PruneOutcome> {
    // Gather per-(data, dim) deletion sets.
    let mut by_loc: HashMap<(DataId, usize), HashSet<usize>> = HashMap::new();
    let mut ccs_removed = 0usize;
    for &(gid, cc) in selected {
        let group = &groups.groups[gid];
        anyhow::ensure!(group.prunable, "group {gid} is not prunable");
        let cc = &group.ccs[cc];
        ccs_removed += 1;
        for loc in &cc.locs {
            by_loc.entry((loc.data, loc.dim)).or_default().insert(loc.idx);
        }
    }
    // Sanity: never delete an entire dimension.
    for ((data, dim), idxs) in &by_loc {
        let n = g.data(*data).shape[*dim];
        anyhow::ensure!(
            idxs.len() < n,
            "refusing to delete all {n} channels of `{}` dim {dim}",
            g.data(*data).name
        );
    }
    // Delete slices from parameter tensors.
    let mut deleted: HashMap<DataId, HashMap<usize, Vec<usize>>> = HashMap::new();
    // Per-data: apply higher dims first so indices stay valid (dims are
    // independent, but record sorted lists).
    let mut by_data: HashMap<DataId, Vec<(usize, Vec<usize>)>> = HashMap::new();
    for ((data, dim), idxs) in by_loc {
        let mut v: Vec<usize> = idxs.into_iter().collect();
        v.sort();
        by_data.entry(data).or_default().push((dim, v));
    }
    for (data, mut dims) in by_data {
        dims.sort_by_key(|(d, _)| *d);
        let dn = &mut g.datas[data];
        let t = dn
            .param_mut()
            .ok_or_else(|| anyhow::anyhow!("pruning a non-param data node"))?;
        for (dim, idxs) in &dims {
            *t = t.delete_indices(*dim, idxs);
        }
        dn.shape = dn.param().unwrap().shape.clone();
        let entry = deleted.entry(data).or_default();
        for (dim, idxs) in dims {
            entry.insert(dim, idxs);
        }
    }
    // Fix conv attributes: depthwise-style convs (weight in-dim 1) must
    // track the new input channel count in `groups`.
    refresh_depthwise_groups(g)?;
    g.refresh_shapes()?;
    g.validate()?;
    Ok(PruneOutcome {
        deleted,
        ccs_removed,
    })
}

/// Recompute `groups` for convs whose weight in-dim is 1 (depthwise /
/// depthwise-multiplier convs): groups must equal the current input
/// channel count.
fn refresh_depthwise_groups(g: &mut Graph) -> anyhow::Result<()> {
    // Input channel counts come from shape inference with current params;
    // iterate ops in topo order, tracking shapes manually.
    let order = g.topo_order()?;
    let mut shapes: HashMap<DataId, Vec<usize>> = HashMap::new();
    for d in &g.datas {
        if d.producer.is_none() {
            shapes.insert(d.id, d.shape.clone());
        }
    }
    for op_id in order {
        // compute input shapes
        let ins: Vec<Vec<usize>> = g.ops[op_id]
            .inputs
            .iter()
            .map(|&i| shapes.get(&i).cloned().unwrap_or_default())
            .collect();
        if let OpKind::Conv2d { groups: grp, .. } = &mut g.ops[op_id].kind {
            let w = &ins[1];
            if w.len() == 4 && w[1] == 1 && *grp > 1 {
                let ci = ins[0][1];
                *grp = ci;
            }
        }
        let op = &g.ops[op_id];
        let outs = crate::ir::shape::infer_op_output_shapes(&op.kind, &ins)
            .map_err(|e| anyhow::anyhow!("post-prune shape check at `{}`: {e}", op.name))?;
        for (&o, s) in op.outputs.iter().zip(outs) {
            shapes.insert(o, s);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine;
    use crate::ir::GraphBuilder;
    use crate::prune::{build_groups, score_groups, Agg, Norm};
    use crate::tensor::Tensor;
    use crate::util::Rng;
    use std::collections::HashMap as Map;

    fn l1_scores(g: &Graph) -> Map<DataId, Tensor> {
        g.param_ids()
            .into_iter()
            .map(|id| (id, g.data(id).param().unwrap().map(f32::abs)))
            .collect()
    }

    fn resnet_like(seed: u64) -> Graph {
        let mut b = GraphBuilder::new("resnetish", seed);
        let x = b.input("x", vec![1, 3, 8, 8]);
        let c0 = b.conv2d("c0", x, 8, 3, 1, 1, 1, false);
        let n0 = b.batchnorm("bn0", c0);
        let r0 = b.relu("r0", n0);
        let c1 = b.conv2d("c1", r0, 8, 3, 1, 1, 1, false);
        let n1 = b.batchnorm("bn1", c1);
        let r1 = b.relu("r1", n1);
        let c2 = b.conv2d("c2", r1, 8, 3, 1, 1, 1, false);
        let n2 = b.batchnorm("bn2", c2);
        let s = b.add("add", n2, r0);
        let r2 = b.relu("r2", s);
        let gp = b.global_avgpool("gap", r2);
        let fc = b.gemm("fc", gp, 4, true);
        b.output(fc);
        b.finish().unwrap()
    }

    #[test]
    fn prune_residual_network_stays_valid_and_runs() {
        let mut g = resnet_like(1);
        let before = analysis::flops(&g);
        let groups = build_groups(&g).unwrap();
        let scores = score_groups(&g, &groups, &l1_scores(&g), Agg::Sum, Norm::Mean);
        let sel = select_lowest(&groups, &scores, 0.5, 1);
        assert!(!sel.is_empty());
        apply_pruning(&mut g, &groups, &sel).unwrap();
        g.validate().unwrap();
        assert!(analysis::flops(&g) < before);
        // executes end-to-end
        let mut rng = Rng::new(2);
        let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 3 * 64, -1.0, 1.0));
        let y = engine::predict(&g, x).unwrap();
        assert_eq!(y.shape, vec![2, 4]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pruned_outputs_match_manual_channel_removal() {
        // prune CC {channel k of c1} and verify logits equal the original
        // model with that channel's weights zeroed (structural deletion is
        // exact for inner channels feeding only conv+bn+relu)
        let mut g = resnet_like(3);
        // make BN an identity so zeroing a conv channel is exactly
        // equivalent to deleting it (running stats already mean 0 var 1)
        let groups = build_groups(&g).unwrap();
        // group seeded by c1 (inner channels)
        let gid = groups
            .groups
            .iter()
            .find(|gr| g.op(gr.source_op).name == "c1")
            .unwrap()
            .id;
        let cc = 5usize;
        // zero reference: zero out c1.w[5], bn1 gamma/beta[5], and c2.w[:,5]
        let mut zeroed = g.clone();
        for loc in &groups.groups[gid].ccs[cc].locs {
            let t = zeroed.datas[loc.data].param_mut().unwrap();
            let d = t.shape[loc.dim];
            let outer: usize = t.shape[..loc.dim].iter().product();
            let inner: usize = t.shape[loc.dim + 1..].iter().product();
            for o in 0..outer {
                let base = (o * d + loc.idx) * inner;
                for v in &mut t.data[base..base + inner] {
                    *v = 0.0;
                }
            }
        }
        let mut pruned = g.clone();
        apply_pruning(&mut pruned, &groups, &[(gid, cc)]).unwrap();
        let mut rng = Rng::new(4);
        let x = Tensor::new(vec![1, 3, 8, 8], rng.uniform_vec(3 * 64, -1.0, 1.0));
        let y_zero = engine::predict(&zeroed, x.clone()).unwrap();
        let y_pruned = engine::predict(&pruned, x).unwrap();
        // NOTE: zeroing a BN'd channel is not perfectly identical to
        // deletion (beta shift remains), so compare with loose tolerance
        // after also zeroing beta — our CC includes beta, so exact:
        crate::tensor::assert_allclose(&y_pruned, &y_zero, 1e-4, 1e-3);
    }

    #[test]
    fn depthwise_groups_updated() {
        let mut b = GraphBuilder::new("dw", 5);
        let x = b.input("x", vec![1, 3, 8, 8]);
        let c0 = b.conv2d("c0", x, 8, 1, 1, 0, 1, false);
        let dw = b.conv2d("dw", c0, 8, 3, 1, 1, 8, false);
        let c2 = b.conv2d("c2", dw, 6, 1, 1, 0, 1, false);
        let gp = b.global_avgpool("gap", c2);
        let fc = b.gemm("fc", gp, 3, false);
        b.output(fc);
        let mut g = b.finish().unwrap();
        let groups = build_groups(&g).unwrap();
        let scores = score_groups(&g, &groups, &l1_scores(&g), Agg::Sum, Norm::Mean);
        let sel = select_lowest(&groups, &scores, 0.4, 1);
        apply_pruning(&mut g, &groups, &sel).unwrap();
        let dw_op = g.op_by_name("dw").unwrap();
        if let OpKind::Conv2d { groups: grp, .. } = dw_op.kind {
            let ci = g.data(g.op_by_name("c0").unwrap().inputs[1]).shape[0];
            assert_eq!(grp, ci, "depthwise groups must track channel count");
        }
        let mut rng = Rng::new(6);
        let x = Tensor::new(vec![1, 3, 64], rng.uniform_vec(3 * 64, -1.0, 1.0))
            .reshaped(vec![1, 3, 8, 8]);
        assert!(engine::predict(&g, x).is_ok());
    }

    #[test]
    fn flops_target_selection_hits_ratio() {
        let g = resnet_like(7);
        let groups = build_groups(&g).unwrap();
        let scores = score_groups(&g, &groups, &l1_scores(&g), Agg::Sum, Norm::Mean);
        let sel = select_by_flops_target(&g, &groups, &scores, 1.7, 1).unwrap();
        let mut pruned = g.clone();
        apply_pruning(&mut pruned, &groups, &sel).unwrap();
        let r = analysis::reduction(&g, &pruned);
        assert!(r.rf >= 1.7, "rf {} below target", r.rf);
        assert!(r.rf < 3.5, "rf {} wildly above target", r.rf);
    }

    #[test]
    fn select_tolerates_nan_scores() {
        // regression: the ranking sort used partial_cmp().unwrap() and
        // panicked on NaN saliency; NaN of either sign must rank last
        // (signed criteria like GraSP can produce negative NaN)
        let g = resnet_like(10);
        let groups = build_groups(&g).unwrap();
        let mut scores = score_groups(&g, &groups, &l1_scores(&g), Agg::Sum, Norm::Mean);
        let pos_nan_cc = (scores[0].group, scores[0].cc);
        let neg_nan_cc = (scores[1].group, scores[1].cc);
        scores[0].score = f32::NAN;
        scores[1].score = -f32::NAN;
        let sel = select_lowest(&groups, &scores, 0.3, 1);
        assert!(!sel.is_empty());
        assert!(!sel.contains(&pos_nan_cc), "NaN-scored CC must rank last");
        assert!(!sel.contains(&neg_nan_cc), "-NaN-scored CC must rank last");
    }

    #[test]
    fn unreachable_target_clamps_to_feasible_max() {
        let g = resnet_like(11);
        let groups = build_groups(&g).unwrap();
        let scores = score_groups(&g, &groups, &l1_scores(&g), Agg::Sum, Norm::Mean);
        let t = select_by_metric_target(&g, &groups, &scores, 1000.0, 2, |m| {
            analysis::flops(m) as f64
        })
        .unwrap();
        assert!(t.clamped, "RF 1000x must be reported as clamped");
        assert!(t.achieved > 1.0 && t.achieved < 1000.0);
        // the trimmed selection still achieves the feasible-max ratio
        let mut pruned = g.clone();
        apply_pruning(&mut pruned, &groups, &t.selected).unwrap();
        let r = analysis::reduction(&g, &pruned);
        assert!((r.rf - t.achieved).abs() < 1e-9, "rf {} vs {}", r.rf, t.achieved);
        // and never exceeds the maximal feasible selection
        let all = select_lowest(&groups, &scores, 1.0, 2);
        assert!(t.selected.len() <= all.len());
    }

    #[test]
    fn refuses_to_delete_whole_group() {
        let mut g = resnet_like(8);
        let groups = build_groups(&g).unwrap();
        let gid = groups.groups[0].id;
        let all: Vec<(usize, usize)> =
            (0..groups.groups[0].ccs.len()).map(|c| (gid, c)).collect();
        assert!(apply_pruning(&mut g, &groups, &all).is_err());
    }

    #[test]
    fn min_keep_respected() {
        let g = resnet_like(9);
        let groups = build_groups(&g).unwrap();
        let scores = score_groups(&g, &groups, &l1_scores(&g), Agg::Sum, Norm::Mean);
        let sel = select_lowest(&groups, &scores, 1.0, 2);
        // per group at most ccs-2 selected
        let mut count: HashMap<usize, usize> = HashMap::new();
        for (g_, _) in &sel {
            *count.entry(*g_).or_default() += 1;
        }
        for gr in &groups.groups {
            if let Some(&c) = count.get(&gr.id) {
                assert!(c + 2 <= gr.ccs.len());
            }
        }
    }
}
