//! Mask propagation rules per operator — the paper's Alg. 1 + App. A.3.
//!
//! A [`Mask`] marks a channel set along one dimension of one data node.
//! For each operator we define how a mask on any connected data node
//! induces masks on the operator's other data nodes (the paper's Tab. 5
//! documents exactly this for GeMM). Rules are *locally* primitive; the
//! worklist in [`propagate`] iterates them to a fixed point, which
//! automatically computes non-trivial closures:
//!
//! * grouped conv — an input-channel mask maps to a weight in-position,
//!   which maps back to the same position in *every* group;
//! * flatten — a feature mask maps back to its source channel, which maps
//!   forward to the channel's whole `H·W` feature block;
//! * attention heads — a hidden-channel mask maps to a per-head
//!   sub-position, which maps back to that sub-position in every head
//!   (heads stay intact, head dim shrinks uniformly — the adaptation
//!   DepGraph/OTO-v2 need manual treatment for, §2).

use super::Loc;
use crate::ir::{DataId, Graph, OpId, OpKind, OpNode};
use std::collections::{HashMap, HashSet, VecDeque};

/// A channel mask: `set[i]` marks channel `i` along `dim` of data node
/// `data`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mask {
    pub data: DataId,
    pub dim: usize,
    pub set: Vec<bool>,
}

impl Mask {
    pub fn single(g: &Graph, data: DataId, dim: usize, idx: usize) -> Mask {
        let n = g.data(data).shape[dim];
        let mut set = vec![false; n];
        set[idx] = true;
        Mask { data, dim, set }
    }

    pub fn indices(&self) -> Vec<usize> {
        self.set
            .iter()
            .enumerate()
            .filter(|(_, &b)| b)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn count(&self) -> usize {
        self.set.iter().filter(|&&b| b).count()
    }
}

/// Partial mask emitted by a rule before merging.
type Emit = (DataId, usize, Vec<usize>);

fn idxs(set: &[bool]) -> Vec<usize> {
    set.iter()
        .enumerate()
        .filter(|(_, &b)| b)
        .map(|(i, _)| i)
        .collect()
}

/// Identity coupling of `dim` between two data nodes.
fn ident(to: DataId, dim: usize, set: &[bool]) -> Emit {
    (to, dim, idxs(set))
}

/// Apply the propagation rule of operator `op` to a mask sitting on
/// `(from_data, from_dim)`. Returns induced masks on the op's other data
/// nodes (and possibly closure masks on the source node itself).
pub fn op_rule(
    g: &Graph,
    op: &OpNode,
    from_data: DataId,
    from_dim: usize,
    set: &[bool],
) -> Vec<Emit> {
    let i = op.inputs.iter().position(|&d| d == from_data);
    let o = op.outputs.iter().position(|&d| d == from_data);
    let x = op.inputs.first().copied();
    let y = op.outputs[0];
    let mut out: Vec<Emit> = Vec::new();
    match &op.kind {
        OpKind::Conv2d { groups, .. } => {
            let w = op.inputs[1];
            let b = op.inputs.get(2).copied();
            let w_shape = &g.data(w).shape;
            let (co, cig) = (w_shape[0], w_shape[1]);
            let gcount = *groups;
            let cog = co / gcount;
            let ci = cig * gcount;
            match (i, o, from_dim) {
                // output-channel mask: couple w out-dim (+ bias)
                (None, Some(_), 1) => {
                    out.push(ident(w, 0, set));
                    if let Some(b) = b {
                        out.push(ident(b, 0, set));
                    }
                    if cig == 1 {
                        // depthwise(-multiplier): out block [q·cog,(q+1)·cog)
                        // couples to input channel q
                        let mut xs = vec![false; ci];
                        for j in idxs(set) {
                            xs[j / cog] = true;
                        }
                        out.push((x.unwrap(), 1, idxs(&xs)));
                    } else if gcount > 1 {
                        // grouped: same within-group position in every group
                        let mut ys = vec![false; co];
                        for j in idxs(set) {
                            let r = j % cog;
                            for k in 0..gcount {
                                ys[r + k * cog] = true;
                            }
                        }
                        out.push((y, 1, idxs(&ys)));
                    }
                }
                // weight out-dim mask: mirror onto y dim1 (+ bias)
                (Some(1), None, 0) => {
                    out.push(ident(y, 1, set));
                    if let Some(b) = b {
                        out.push(ident(b, 0, set));
                    }
                }
                // weight in-dim mask: every group's matching input channel.
                // For depthwise (cig==1) the in-dim is never deleted — the
                // coupling runs through w dim0 instead.
                (Some(1), None, 1) if cig > 1 => {
                    let mut xs = vec![false; ci];
                    for r in idxs(set) {
                        for k in 0..gcount {
                            xs[r + k * cig] = true;
                        }
                    }
                    out.push((x.unwrap(), 1, idxs(&xs)));
                }
                // bias mask
                (Some(2), None, 0) => {
                    out.push(ident(y, 1, set));
                    out.push(ident(w, 0, set));
                }
                // input-channel mask: weight in-position (+ depthwise out)
                (Some(0), None, 1) => {
                    if cig > 1 {
                        let mut ws = vec![false; cig];
                        for c in idxs(set) {
                            ws[c % cig] = true;
                        }
                        out.push((w, 1, idxs(&ws)));
                    }
                    if cig == 1 {
                        let mut ys = vec![false; co];
                        for c in idxs(set) {
                            for j in c * cog..(c + 1) * cog {
                                ys[j] = true;
                            }
                        }
                        out.push((y, 1, idxs(&ys)));
                        out.push((w, 0, idxs(&ys)));
                    }
                }
                // batch dim passthrough
                (Some(0), None, 0) => out.push(ident(y, 0, set)),
                (None, Some(_), 0) => out.push(ident(x.unwrap(), 0, set)),
                _ => {}
            }
        }
        OpKind::Gemm => {
            let w = op.inputs[1];
            let b = op.inputs.get(2).copied();
            let x_id = x.unwrap();
            let x_rank = g.data(x_id).shape.len();
            let y_rank = g.data(y).shape.len();
            match (i, o, from_dim) {
                (Some(0), None, d) if d == x_rank - 1 => out.push((w, 1, idxs(set))),
                (Some(0), None, d) => out.push(ident(y, d, set)), // batch/time dims
                (Some(1), None, 0) => {
                    out.push((y, y_rank - 1, idxs(set)));
                    if let Some(b) = b {
                        out.push(ident(b, 0, set));
                    }
                }
                (Some(1), None, 1) => out.push((x_id, x_rank - 1, idxs(set))),
                (Some(2), None, 0) => {
                    out.push((y, y_rank - 1, idxs(set)));
                    out.push((w, 0, idxs(set)));
                }
                (None, Some(_), d) if d == y_rank - 1 => {
                    out.push((w, 0, idxs(set)));
                    if let Some(b) = b {
                        out.push(ident(b, 0, set));
                    }
                }
                (None, Some(_), d) => out.push(ident(x_id, d, set)),
                _ => {}
            }
        }
        OpKind::BatchNorm { .. } => {
            // x dim1 ⇔ y dim1 ⇔ all four params dim0; other dims x⇔y
            let x_id = x.unwrap();
            let params = &op.inputs[1..];
            let from_bn_param = matches!(i, Some(s) if s >= 1);
            match (i, o, from_dim) {
                (Some(0), None, 1) | (None, Some(_), 1) | (Some(_), None, 0)
                    if from_bn_param || from_dim == 1 =>
                {
                    let from_param = from_bn_param;
                    if from_param || i == Some(0) {
                        out.push(ident(y, 1, set));
                    }
                    if from_param || o.is_some() {
                        out.push(ident(x_id, 1, set));
                    }
                    for &p in params {
                        if p != from_data {
                            out.push(ident(p, 0, set));
                        }
                    }
                }
                (Some(0), None, d) => out.push(ident(y, d, set)),
                (None, Some(_), d) => out.push(ident(x_id, d, set)),
                _ => {}
            }
        }
        OpKind::LayerNorm { .. } => {
            let x_id = x.unwrap();
            let last = g.data(x_id).shape.len() - 1;
            let params = &op.inputs[1..];
            match (i, o, from_dim) {
                (Some(0), None, d) if d == last => {
                    out.push(ident(y, d, set));
                    for &p in params {
                        out.push(ident(p, 0, set));
                    }
                }
                (None, Some(_), d) if d == last => {
                    out.push(ident(x_id, d, set));
                    for &p in params {
                        out.push(ident(p, 0, set));
                    }
                }
                (Some(_), None, 0) if from_data != x_id => {
                    out.push(ident(x_id, last, set));
                    out.push(ident(y, last, set));
                    for &p in params {
                        if p != from_data {
                            out.push(ident(p, 0, set));
                        }
                    }
                }
                (Some(0), None, d) => out.push(ident(y, d, set)),
                (None, Some(_), d) => out.push(ident(x_id, d, set)),
                _ => {}
            }
        }
        // shape-preserving unary ops: every dim couples x⇔y
        OpKind::Relu
        | OpKind::Gelu
        | OpKind::Silu
        | OpKind::Sigmoid
        | OpKind::Tanh
        | OpKind::Softmax
        | OpKind::Scale { .. }
        | OpKind::Identity => {
            let x_id = x.unwrap();
            if i == Some(0) {
                out.push(ident(y, from_dim, set));
            } else if o.is_some() {
                out.push(ident(x_id, from_dim, set));
            }
        }
        OpKind::Add | OpKind::Mul => {
            // identity coupling across a, b, y with broadcast dim mapping
            let a = op.inputs[0];
            let bb = op.inputs[1];
            let a_shape = g.data(a).shape.clone();
            let b_shape = g.data(bb).shape.clone();
            let same = a_shape == b_shape;
            // [N,C] gate against [N,C,H,W] (SE): couple dims 0,1 directly
            if !same && a_shape.len() == 4 && b_shape.len() == 2 {
                match (i, o) {
                    (Some(0), None) => {
                        out.push(ident(y, from_dim, set));
                        if from_dim <= 1 {
                            out.push(ident(bb, from_dim, set));
                        }
                    }
                    (Some(1), None) => {
                        out.push(ident(a, from_dim, set));
                        out.push(ident(y, from_dim, set));
                    }
                    (None, Some(_)) => {
                        out.push(ident(a, from_dim, set));
                        if from_dim <= 1 {
                            out.push(ident(bb, from_dim, set));
                        }
                    }
                    _ => {}
                }
                return out;
            }
            // channel dim of the full-shape operand for 1-D broadcast
            let bcast_dim = match a_shape.len() {
                2 => 1,
                3 => 2,
                4 => 1,
                _ => usize::MAX,
            };
            match (i, o) {
                (Some(0), None) => {
                    out.push(ident(y, from_dim, set));
                    if same {
                        out.push(ident(bb, from_dim, set));
                    } else if b_shape.len() == 1 && from_dim == bcast_dim {
                        out.push(ident(bb, 0, set));
                    } else if b_shape.len() == a_shape.len() {
                        // [N,C,1,1] or [1,T,D]-style: couple dims of size>1
                        if b_shape[from_dim] == a_shape[from_dim] {
                            out.push(ident(bb, from_dim, set));
                        }
                    }
                }
                (Some(1), None) => {
                    if same {
                        out.push(ident(a, from_dim, set));
                        out.push(ident(y, from_dim, set));
                    } else if b_shape.len() == 1 {
                        out.push(ident(a, bcast_dim, set));
                        out.push(ident(y, bcast_dim, set));
                    } else if b_shape[from_dim] == a_shape[from_dim] {
                        out.push(ident(a, from_dim, set));
                        out.push(ident(y, from_dim, set));
                    }
                }
                (None, Some(_)) => {
                    out.push(ident(a, from_dim, set));
                    if same {
                        out.push(ident(bb, from_dim, set));
                    } else if b_shape.len() == 1 && from_dim == bcast_dim {
                        out.push(ident(bb, 0, set));
                    } else if b_shape.len() == a_shape.len()
                        && b_shape[from_dim] == a_shape[from_dim]
                    {
                        out.push(ident(bb, from_dim, set));
                    }
                }
                _ => {}
            }
        }
        OpKind::MaxPool2d { .. } | OpKind::AvgPool2d { .. } => {
            // spatial dims change; batch + channel couple
            let x_id = x.unwrap();
            if from_dim <= 1 {
                if i == Some(0) {
                    out.push(ident(y, from_dim, set));
                } else {
                    out.push(ident(x_id, from_dim, set));
                }
            }
        }
        OpKind::GlobalAvgPool => {
            let x_id = x.unwrap();
            if from_dim <= 1 {
                if i == Some(0) {
                    out.push(ident(y, from_dim, set));
                } else {
                    out.push(ident(x_id, from_dim, set));
                }
            }
        }
        OpKind::Flatten => {
            let x_id = x.unwrap();
            let x_shape = g.data(x_id).shape.clone();
            let block: usize = x_shape[2..].iter().product::<usize>().max(1);
            match (i, o, from_dim) {
                (Some(0), None, 0) | (None, Some(_), 0) => {
                    let other = if i.is_some() { y } else { x_id };
                    out.push(ident(other, 0, set));
                }
                (Some(0), None, 1) => {
                    // channel c → feature block
                    let feat = g.data(y).shape[1];
                    let mut ys = vec![false; feat];
                    for c in idxs(set) {
                        for f in c * block..(c + 1) * block {
                            ys[f] = true;
                        }
                    }
                    out.push((y, 1, idxs(&ys)));
                }
                (None, Some(_), 1) => {
                    // feature f → source channel (worklist closes the block)
                    let mut xs = vec![false; x_shape[1]];
                    for f in idxs(set) {
                        xs[f / block] = true;
                    }
                    out.push((x_id, 1, idxs(&xs)));
                }
                _ => {}
            }
        }
        OpKind::Concat { axis } => {
            let offsets: Vec<usize> = {
                let mut acc = 0;
                op.inputs
                    .iter()
                    .map(|&d| {
                        let o = acc;
                        acc += g.data(d).shape[*axis];
                        o
                    })
                    .collect()
            };
            match (i, o) {
                (Some(slot), None) => {
                    if from_dim == *axis {
                        let ylen = g.data(y).shape[*axis];
                        let mut ys = vec![false; ylen];
                        for k in idxs(set) {
                            ys[offsets[slot] + k] = true;
                        }
                        out.push((y, *axis, idxs(&ys)));
                    } else {
                        out.push(ident(y, from_dim, set));
                        for (s, &other) in op.inputs.iter().enumerate() {
                            if s != slot {
                                out.push(ident(other, from_dim, set));
                            }
                        }
                    }
                }
                (None, Some(_)) => {
                    if from_dim == *axis {
                        for (slot, &inp) in op.inputs.iter().enumerate() {
                            let d = g.data(inp).shape[*axis];
                            let mut s = vec![false; d];
                            let mut any = false;
                            for j in idxs(set) {
                                if j >= offsets[slot] && j < offsets[slot] + d {
                                    s[j - offsets[slot]] = true;
                                    any = true;
                                }
                            }
                            if any {
                                out.push((inp, *axis, idxs(&s)));
                            }
                        }
                    } else {
                        for &inp in &op.inputs {
                            out.push(ident(inp, from_dim, set));
                        }
                    }
                }
                _ => {}
            }
        }
        OpKind::MatMul => {
            // a[...,M,K] · b[...,K,N] = y[...,M,N]
            let a = op.inputs[0];
            let bb = op.inputs[1];
            let rank = g.data(a).shape.len();
            let (mdim, kdim_a) = (rank - 2, rank - 1);
            let (kdim_b, ndim) = (rank - 2, rank - 1);
            match (i, o, from_dim) {
                (Some(0), None, d) if d == kdim_a => out.push((bb, kdim_b, idxs(set))),
                (Some(0), None, d) if d == mdim => out.push((y, mdim, idxs(set))),
                (Some(0), None, d) => {
                    out.push(ident(bb, d, set));
                    out.push(ident(y, d, set));
                }
                (Some(1), None, d) if d == kdim_b => out.push((a, kdim_a, idxs(set))),
                (Some(1), None, d) if d == ndim => out.push((y, ndim, idxs(set))),
                (Some(1), None, d) => {
                    out.push(ident(a, d, set));
                    out.push(ident(y, d, set));
                }
                (None, Some(_), d) if d == mdim => out.push((a, mdim, idxs(set))),
                (None, Some(_), d) if d == ndim => out.push((bb, ndim, idxs(set))),
                (None, Some(_), d) => {
                    out.push(ident(a, d, set));
                    out.push(ident(bb, d, set));
                }
                _ => {}
            }
        }
        OpKind::Transpose { perm } => {
            let x_id = x.unwrap();
            match (i, o) {
                (Some(0), None) => {
                    // y dim j has x dim perm[j]; find j with perm[j]==from_dim
                    let j = perm.iter().position(|&p| p == from_dim).unwrap();
                    out.push(ident(y, j, set));
                }
                (None, Some(_)) => out.push(ident(x_id, perm[from_dim], set)),
                _ => {}
            }
        }
        OpKind::SplitHeads { heads } => {
            // x [N,T,D] → y [N,h,T,d]; hidden channel c ↔ (head c/d, sub c%d)
            let x_id = x.unwrap();
            let d_sub = g.data(x_id).shape[2] / heads;
            match (i, o, from_dim) {
                (Some(0), None, 2) => {
                    // channel → sub-position (closure re-expands across heads)
                    let mut ys = vec![false; d_sub];
                    for c in idxs(set) {
                        ys[c % d_sub] = true;
                    }
                    out.push((y, 3, idxs(&ys)));
                }
                (None, Some(_), 3) => {
                    let dd = g.data(x_id).shape[2];
                    let mut xs = vec![false; dd];
                    for s in idxs(set) {
                        for k in 0..*heads {
                            xs[s + k * d_sub] = true;
                        }
                    }
                    out.push((x_id, 2, idxs(&xs)));
                }
                (Some(0), None, 0) => out.push(ident(y, 0, set)),
                (Some(0), None, 1) => out.push(ident(y, 2, set)),
                (None, Some(_), 0) => out.push(ident(x_id, 0, set)),
                (None, Some(_), 2) => out.push(ident(x_id, 1, set)),
                _ => {}
            }
        }
        OpKind::MergeHeads => {
            // x [N,h,T,d] → y [N,T,D]
            let x_id = x.unwrap();
            let (h, d_sub) = (g.data(x_id).shape[1], g.data(x_id).shape[3]);
            match (i, o, from_dim) {
                (Some(0), None, 3) => {
                    let mut ys = vec![false; h * d_sub];
                    for s in idxs(set) {
                        for k in 0..h {
                            ys[s + k * d_sub] = true;
                        }
                    }
                    out.push((y, 2, idxs(&ys)));
                }
                (None, Some(_), 2) => {
                    let mut xs = vec![false; d_sub];
                    for c in idxs(set) {
                        xs[c % d_sub] = true;
                    }
                    out.push((x_id, 3, idxs(&xs)));
                }
                (Some(0), None, 0) => out.push(ident(y, 0, set)),
                (Some(0), None, 2) => out.push(ident(y, 1, set)),
                (None, Some(_), 0) => out.push(ident(x_id, 0, set)),
                (None, Some(_), 1) => out.push(ident(x_id, 2, set)),
                _ => {}
            }
        }
        OpKind::Embedding => {
            let table = op.inputs[1];
            let y_rank = g.data(y).shape.len();
            match (i, o, from_dim) {
                (Some(1), None, 1) => out.push((y, y_rank - 1, idxs(set))),
                (None, Some(_), d) if d == y_rank - 1 => out.push((table, 1, idxs(set))),
                _ => {}
            }
        }
        OpKind::NchwToTokens => {
            // x [N,C,H,W] → y [N,HW,C]: C ↔ last dim, N ↔ N
            let x_id = x.unwrap();
            match (i, o, from_dim) {
                (Some(0), None, 1) => out.push(ident(y, 2, set)),
                (None, Some(_), 2) => out.push(ident(x_id, 1, set)),
                (Some(0), None, 0) => out.push(ident(y, 0, set)),
                (None, Some(_), 0) => out.push(ident(x_id, 0, set)),
                _ => {}
            }
        }
        OpKind::ReduceMean { axis } => {
            let x_id = x.unwrap();
            match (i, o) {
                (Some(0), None) => {
                    if from_dim != *axis {
                        let yd = if from_dim > *axis { from_dim - 1 } else { from_dim };
                        out.push(ident(y, yd, set));
                    }
                }
                (None, Some(_)) => {
                    let xd = if from_dim >= *axis { from_dim + 1 } else { from_dim };
                    out.push(ident(x_id, xd, set));
                }
                _ => {}
            }
        }
    }
    out
}

/// The paper's Alg. 1: worklist closure of mask propagation starting from
/// a source mask. Returns the final mask per (data, dim) location.
pub fn propagate(g: &Graph, source: Mask) -> HashMap<(DataId, usize), Mask> {
    let mut masks: HashMap<(DataId, usize), Mask> = HashMap::new();
    let mut queue: VecDeque<(DataId, usize)> = VecDeque::new();
    masks.insert((source.data, source.dim), source.clone());
    queue.push_back((source.data, source.dim));
    // Track which (op, data, dim, revision) have been applied to avoid
    // re-running rules whose input has not grown.
    let mut applied: HashSet<(OpId, DataId, usize, usize)> = HashSet::new();
    while let Some((data, dim)) = queue.pop_front() {
        let cur = masks[&(data, dim)].clone();
        let rev = cur.count();
        for op_id in g.neighbor_ops(data) {
            if !applied.insert((op_id, data, dim, rev)) {
                continue;
            }
            let op = g.op(op_id);
            for (to, to_dim, add) in op_rule(g, op, data, dim, &cur.set) {
                if add.is_empty() {
                    continue;
                }
                let n = g.data(to).shape[to_dim];
                let entry = masks.entry((to, to_dim)).or_insert_with(|| Mask {
                    data: to,
                    dim: to_dim,
                    set: vec![false; n],
                });
                let mut grew = false;
                for idx in add {
                    debug_assert!(idx < entry.set.len());
                    if !entry.set[idx] {
                        entry.set[idx] = true;
                        grew = true;
                    }
                }
                if grew {
                    queue.push_back((to, to_dim));
                }
            }
        }
    }
    masks
}

/// All param channel locations covered by a propagation result.
pub fn param_locs(g: &Graph, masks: &HashMap<(DataId, usize), Mask>) -> Vec<Loc> {
    let mut out = Vec::new();
    for ((data, dim), m) in masks {
        if g.data(*data).is_param() {
            for idx in m.indices() {
                out.push(Loc {
                    data: *data,
                    dim: *dim,
                    idx,
                });
            }
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn gemm_chain_matches_paper_fig6() {
        // Two connected GeMMs; masking W1's first output channel must mask
        // the first input channel of W2 and nothing in X1/X3 (App. A.3).
        let mut b = GraphBuilder::new("gemm2", 1);
        let x1 = b.input("x1", vec![3, 4]);
        let h = b.gemm("g1", x1, 4, false);
        let out = b.gemm("g2", h, 5, false);
        b.output(out);
        let g = b.finish().unwrap();
        let w1 = g.data_by_name("g1.w").unwrap().id;
        let w2 = g.data_by_name("g2.w").unwrap().id;
        let masks = propagate(&g, Mask::single(&g, w1, 0, 0));
        assert_eq!(masks[&(w1, 0)].indices(), vec![0]);
        assert_eq!(masks[&(w2, 1)].indices(), vec![0]);
        // X2 (g1 output) channel 0 masked
        let x2 = g.op_by_name("g1").unwrap().outputs[0];
        assert_eq!(masks[&(x2, 1)].indices(), vec![0]);
        // X1 and final output unaffected
        assert!(!masks.contains_key(&(x1, 1)));
        let x3 = g.op_by_name("g2").unwrap().outputs[0];
        assert!(!masks.contains_key(&(x3, 1)));
    }

    #[test]
    fn residual_couples_both_convs() {
        // conv1 and conv2 feed an Add: pruning conv1's out channel c must
        // also prune conv2's out channel c (Fig. 5 of the paper).
        let mut b = GraphBuilder::new("res", 2);
        let x = b.input("x", vec![1, 4, 6, 6]);
        let c1 = b.conv2d("c1", x, 8, 3, 1, 1, 1, false);
        let n1 = b.batchnorm("bn1", c1);
        let r1 = b.relu("r1", n1);
        let c2 = b.conv2d("c2", r1, 8, 3, 1, 1, 1, false);
        let n2 = b.batchnorm("bn2", c2);
        let s = b.add("add", n2, n1);
        b.output(s);
        let g = b.finish().unwrap();
        let w1 = g.data_by_name("c1.w").unwrap().id;
        let w2 = g.data_by_name("c2.w").unwrap().id;
        let masks = propagate(&g, Mask::single(&g, w1, 0, 3));
        // w2 out-dim 3 coupled through the Add
        assert_eq!(masks[&(w2, 0)].indices(), vec![3]);
        // w2 in-dim 3 coupled through r1 feeding conv2
        assert_eq!(masks[&(w2, 1)].indices(), vec![3]);
        // both BN gammas coupled
        let g1 = g.data_by_name("bn1.gamma").unwrap().id;
        let g2 = g.data_by_name("bn2.gamma").unwrap().id;
        assert_eq!(masks[&(g1, 0)].indices(), vec![3]);
        assert_eq!(masks[&(g2, 0)].indices(), vec![3]);
    }

    #[test]
    fn flatten_expands_feature_block() {
        let mut b = GraphBuilder::new("flat", 3);
        let x = b.input("x", vec![1, 3, 4, 4]);
        let c = b.conv2d("c", x, 5, 3, 1, 1, 1, false);
        let f = b.flatten("f", c);
        let out = b.gemm("fc", f, 2, false);
        b.output(out);
        let g = b.finish().unwrap();
        let cw = g.data_by_name("c.w").unwrap().id;
        let fcw = g.data_by_name("fc.w").unwrap().id;
        let masks = propagate(&g, Mask::single(&g, cw, 0, 2));
        // channel 2 of 5, spatial 4x4 → features 32..48 of fc's in-dim
        let want: Vec<usize> = (32..48).collect();
        assert_eq!(masks[&(fcw, 1)].indices(), want);
    }

    #[test]
    fn grouped_conv_position_closure() {
        // conv(8→8, groups=4): input channels couple across groups
        let mut b = GraphBuilder::new("grp", 4);
        let x = b.input("x", vec![1, 8, 4, 4]);
        let c0 = b.conv2d("c0", x, 8, 1, 1, 0, 1, false);
        let c1 = b.conv2d("c1", c0, 8, 3, 1, 1, 4, false);
        b.output(c1);
        let g = b.finish().unwrap();
        let w0 = g.data_by_name("c0.w").unwrap().id;
        let w1 = g.data_by_name("c1.w").unwrap().id;
        // pruning c0 out-channel 0 hits c1's input position 0 → closure to
        // channels {0, 2, 4, 6} (cig = 2), which are c0's outputs 0,2,4,6
        let masks = propagate(&g, Mask::single(&g, w0, 0, 0));
        assert_eq!(masks[&(w0, 0)].indices(), vec![0, 2, 4, 6]);
        assert_eq!(masks[&(w1, 1)].indices(), vec![0]);
    }

    #[test]
    fn depthwise_couples_in_and_out() {
        let mut b = GraphBuilder::new("dw", 5);
        let x = b.input("x", vec![1, 6, 4, 4]);
        let c0 = b.conv2d("c0", x, 6, 1, 1, 0, 1, false);
        let dw = b.conv2d("dw", c0, 6, 3, 1, 1, 6, false);
        let c2 = b.conv2d("c2", dw, 4, 1, 1, 0, 1, false);
        b.output(c2);
        let g = b.finish().unwrap();
        let w0 = g.data_by_name("c0.w").unwrap().id;
        let wdw = g.data_by_name("dw.w").unwrap().id;
        let w2 = g.data_by_name("c2.w").unwrap().id;
        let masks = propagate(&g, Mask::single(&g, w0, 0, 2));
        // depthwise filter 2 and c2's input 2 coupled; no closure beyond
        assert_eq!(masks[&(w0, 0)].indices(), vec![2]);
        assert_eq!(masks[&(wdw, 0)].indices(), vec![2]);
        assert_eq!(masks[&(w2, 1)].indices(), vec![2]);
        assert!(!masks.contains_key(&(w2, 0)));
    }

    #[test]
    fn concat_offsets() {
        let mut b = GraphBuilder::new("cat", 6);
        let x = b.input("x", vec![1, 3, 4, 4]);
        let a = b.conv2d("a", x, 4, 3, 1, 1, 1, false);
        let c = b.conv2d("c", x, 6, 3, 1, 1, 1, false);
        let cat = b.concat("cat", &[a, c], 1);
        let d = b.conv2d("d", cat, 5, 1, 1, 0, 1, false);
        b.output(d);
        let g = b.finish().unwrap();
        let wc = g.data_by_name("c.w").unwrap().id;
        let wd = g.data_by_name("d.w").unwrap().id;
        // channel 1 of conv c lands at concat offset 4+1=5
        let masks = propagate(&g, Mask::single(&g, wc, 0, 1));
        assert_eq!(masks[&(wd, 1)].indices(), vec![5]);
        let wa = g.data_by_name("a.w").unwrap().id;
        assert!(!masks.contains_key(&(wa, 0)), "branch a must be untouched");
    }

    #[test]
    fn attention_head_subposition_closure() {
        // q/k/v projections with 2 heads of dim 4: pruning q.w out-channel 1
        // couples the same sub-position in head 2 (channel 5) and k.w via
        // the QKᵀ contraction.
        let mut b = GraphBuilder::new("attn", 7);
        let x = b.input("x", vec![1, 3, 8]);
        let q = b.gemm("q", x, 8, false);
        let k = b.gemm("k", x, 8, false);
        let v = b.gemm("v", x, 8, false);
        let qh = b.split_heads("qh", q, 2);
        let kh = b.split_heads("kh", k, 2);
        let vh = b.split_heads("vh", v, 2);
        let kt = b.transpose("kt", kh, vec![0, 1, 3, 2]);
        let sc = b.matmul("qk", qh, kt);
        let sm = b.softmax("sm", sc);
        let ctx = b.matmul("av", sm, vh);
        let mh = b.merge_heads("mh", ctx);
        let o = b.gemm("o", mh, 8, false);
        b.output(o);
        let g = b.finish().unwrap();
        let qw = g.data_by_name("q.w").unwrap().id;
        let kw = g.data_by_name("k.w").unwrap().id;
        let vw = g.data_by_name("v.w").unwrap().id;
        let ow = g.data_by_name("o.w").unwrap().id;
        let masks = propagate(&g, Mask::single(&g, qw, 0, 1));
        // sub-position 1 in both heads: channels {1, 5}
        assert_eq!(masks[&(qw, 0)].indices(), vec![1, 5]);
        assert_eq!(masks[&(kw, 0)].indices(), vec![1, 5], "QKᵀ couples k");
        // v is NOT coupled through the scores (contraction eliminates d)
        assert!(!masks.contains_key(&(vw, 0)));
        assert!(!masks.contains_key(&(ow, 1)));
        // pruning v couples o's input instead
        let masks_v = propagate(&g, Mask::single(&g, vw, 0, 2));
        assert_eq!(masks_v[&(vw, 0)].indices(), vec![2, 6]);
        assert_eq!(masks_v[&(ow, 1)].indices(), vec![2, 6]);
        assert!(!masks_v.contains_key(&(qw, 0)));
    }

    #[test]
    fn propagation_is_symmetric() {
        // if source a couples channel x of b, then source b couples a
        let mut b = GraphBuilder::new("sym", 8);
        let x = b.input("x", vec![1, 4, 6, 6]);
        let c1 = b.conv2d("c1", x, 8, 3, 1, 1, 1, false);
        let n1 = b.batchnorm("bn1", c1);
        let c2 = b.conv2d("c2", n1, 8, 3, 1, 1, 1, false);
        let s = b.add("add", c2, n1);
        b.output(s);
        let g = b.finish().unwrap();
        let w1 = g.data_by_name("c1.w").unwrap().id;
        let w2 = g.data_by_name("c2.w").unwrap().id;
        let m1 = propagate(&g, Mask::single(&g, w1, 0, 5));
        assert!(m1[&(w2, 0)].set[5]);
        let m2 = propagate(&g, Mask::single(&g, w2, 0, 5));
        assert!(m2[&(w1, 0)].set[5]);
        // full coupled sets identical
        assert_eq!(param_locs(&g, &m1), param_locs(&g, &m2));
    }
}
