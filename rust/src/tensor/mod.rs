//! Dense f32 tensor substrate.
//!
//! The fine-tuning / evaluation engine (`crate::engine`) interprets SPA-IR
//! graphs directly on these kernels — this is the role PyTorch plays in
//! the paper (§3.3: ONNX is converted to PyTorch for gradient computation
//! and fine-tuning). Layout is row-major; images are NCHW.

pub mod ops;

use crate::util::Rng;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    /// Kaiming-normal initialization for a weight with `fan_in`.
    pub fn kaiming(shape: &[usize], fan_in: usize, rng: &mut Rng) -> Self {
        let std = (2.0 / fan_in.max(1) as f32).sqrt();
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(shape.iter().product(), std),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Dimension size with python-style negative indexing.
    pub fn dim(&self, i: isize) -> usize {
        let n = self.shape.len() as isize;
        let i = if i < 0 { n + i } else { i };
        self.shape[i as usize]
    }

    pub fn reshaped(&self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.numel());
        Tensor {
            shape,
            data: self.data.clone(),
        }
    }

    /// Strides (in elements) for the row-major layout.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, c: f32) -> Tensor {
        self.map(|x| x * c)
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn abs_sum(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    pub fn sq_sum(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// L2 distance to another tensor (for numeric cross-checks).
    pub fn l2_dist(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    /// Remove the given (sorted, unique) indices along `dim`, returning a
    /// structurally smaller tensor. This is the physical channel deletion
    /// primitive of the pruner (paper §3.2 step 4).
    pub fn delete_indices(&self, dim: usize, del: &[usize]) -> Tensor {
        assert!(dim < self.shape.len(), "dim {dim} out of range");
        debug_assert!(del.windows(2).all(|w| w[0] < w[1]), "indices must be sorted unique");
        assert!(
            del.iter().all(|&i| i < self.shape[dim]),
            "delete index out of range"
        );
        let keep: Vec<usize> = (0..self.shape[dim])
            .filter(|i| del.binary_search(i).is_err())
            .collect();
        self.take_indices(dim, &keep)
    }

    /// Keep only the given indices along `dim` (gather).
    pub fn take_indices(&self, dim: usize, keep: &[usize]) -> Tensor {
        let mut new_shape = self.shape.clone();
        new_shape[dim] = keep.len();
        let outer: usize = self.shape[..dim].iter().product();
        let inner: usize = self.shape[dim + 1..].iter().product();
        let d = self.shape[dim];
        let mut out = Vec::with_capacity(outer * keep.len() * inner);
        for o in 0..outer {
            for &k in keep {
                let base = (o * d + k) * inner;
                out.extend_from_slice(&self.data[base..base + inner]);
            }
        }
        Tensor::new(new_shape, out)
    }

    /// Transpose a 2-D tensor.
    pub fn t2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![c, r], out)
    }
}

/// Assert element-wise closeness, reporting the worst offender.
pub fn assert_allclose(a: &Tensor, b: &Tensor, atol: f32, rtol: f32) {
    assert_eq!(a.shape, b.shape, "shape mismatch {:?} vs {:?}", a.shape, b.shape);
    let mut worst = (0usize, 0.0f32);
    for (i, (&x, &y)) in a.data.iter().zip(&b.data).enumerate() {
        let err = (x - y).abs();
        let tol = atol + rtol * y.abs();
        if err > tol && err > worst.1 {
            worst = (i, err);
        }
    }
    assert!(
        worst.1 == 0.0,
        "tensors differ: idx {} err {} (a={} b={})",
        worst.0,
        worst.1,
        a.data[worst.0],
        b.data[worst.0]
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_strides() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.dim(-1), 4);
        assert_eq!(t.dim(0), 2);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn delete_indices_dim0() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let d = t.delete_indices(0, &[1]);
        assert_eq!(d.shape, vec![2, 2]);
        assert_eq!(d.data, vec![1., 2., 5., 6.]);
    }

    #[test]
    fn delete_indices_dim1() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let d = t.delete_indices(1, &[0, 2]);
        assert_eq!(d.shape, vec![2, 1]);
        assert_eq!(d.data, vec![2., 5.]);
    }

    #[test]
    fn delete_inner_dim_of_4d() {
        // conv weight [2,2,1,1], delete input channel 0
        let t = Tensor::new(vec![2, 2, 1, 1], vec![1., 2., 3., 4.]);
        let d = t.delete_indices(1, &[0]);
        assert_eq!(d.shape, vec![2, 1, 1, 1]);
        assert_eq!(d.data, vec![2., 4.]);
    }

    #[test]
    fn transpose2() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.t2();
        assert_eq!(tt.shape, vec![3, 2]);
        assert_eq!(tt.data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn kaiming_scale() {
        let mut rng = Rng::new(1);
        let t = Tensor::kaiming(&[64, 64], 64, &mut rng);
        let var = t.sq_sum() / t.numel() as f32;
        assert!((var - 2.0 / 64.0).abs() < 0.01, "var {var}");
    }

    #[test]
    fn allclose_passes_and_fails() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]);
        let b = Tensor::new(vec![2], vec![1.0 + 1e-7, 2.0]);
        assert_allclose(&a, &b, 1e-5, 1e-5);
        let c = Tensor::new(vec![2], vec![1.5, 2.0]);
        let r = std::panic::catch_unwind(|| assert_allclose(&a, &c, 1e-5, 1e-5));
        assert!(r.is_err());
    }
}
