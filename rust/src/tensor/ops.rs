//! Neural-network kernels over [`Tensor`]: GEMM, im2col convolution (with
//! stride / padding / groups / depthwise), pooling, normalization,
//! activations, softmax / cross-entropy, embedding — forward *and* the
//! backward primitives the autodiff engine composes.
//!
//! GEMM is the hot kernel: a blocked microkernel (`MC`×`NC` tiles with an
//! unrolled inner product) keeps it cache-friendly; everything convolution
//! lowers onto it via im2col.

use super::Tensor;
use crate::util::par;

// Cache-blocking parameters for the GEMM microkernel.
const MC: usize = 128;
const NC: usize = 256;

/// Below this many multiply-accumulates a GEMM stays single-threaded —
/// thread spawn costs dominate tiny kernels.
const PAR_GEMM_MIN_MACS: usize = 64 * 1024;

/// C[m,n] = A[m,k] · B[k,n]
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.rank(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    gemm_into(&a.data, &b.data, &mut out, m, k, n);
    Tensor::new(vec![m, n], out)
}

/// out[m,n] += A[m,k] · B[k,n] on raw slices (row-major).
///
/// Rows of `out` are independent, so large GEMMs split into row bands
/// executed on the `util::par` worker pool. Each row's arithmetic is
/// identical to the serial path (same loop order per row), so results are
/// bit-identical at any `SPA_THREADS`.
pub fn gemm_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    let threads = par::max_threads();
    if threads <= 1 || m * k * n < PAR_GEMM_MIN_MACS {
        gemm_band(a, b, out, m, k, n);
        return;
    }
    // Row bands: MC for cache friendliness, shrunk when m is small so
    // wide-but-short GEMMs (FC layers at small batch) still fan out.
    // Band size affects scheduling only — each row's arithmetic is
    // self-contained — so any banding yields bit-identical results.
    let band = MC.min(m.div_ceil(threads)).max(1);
    par::par_chunks_mut(out, band * n, |bi, oband| {
        let r0 = bi * band;
        let rows = oband.len() / n;
        gemm_band(&a[r0 * k..(r0 + rows) * k], b, oband, rows, k, n);
    });
}

/// Serial blocked GEMM microkernel over one row band.
fn gemm_band(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    // i-k-j loop order with j-blocking: streams B rows, accumulates into
    // the C row held in cache.
    for jc in (0..n).step_by(NC) {
        let jn = (jc + NC).min(n);
        for ic in (0..m).step_by(MC) {
            let im = (ic + MC).min(m);
            for i in ic..im {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut out[i * n..(i + 1) * n];
                for (p, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n + jc..p * n + jn];
                    let cslice = &mut crow[jc..jn];
                    for (c, &bv) in cslice.iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
            }
        }
    }
}

/// Batched matmul on the last two dims: a[..., M, K] · b[..., K, N].
/// Leading dims must match exactly.
pub fn batch_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert!(a.rank() >= 2 && b.rank() >= 2, "batch_matmul ranks");
    let (m, n) = (a.dim(-2), b.dim(-1));
    let batch: usize = a.shape[..a.rank() - 2].iter().product();
    let mut shape = a.shape[..a.rank() - 2].to_vec();
    shape.push(m);
    shape.push(n);
    let mut out = vec![0.0f32; batch * m * n];
    batch_matmul_into(&a.data, &a.shape, &b.data, &b.shape, &mut out);
    Tensor::new(shape, out)
}

/// [`batch_matmul`] into a caller-provided buffer (overwritten);
/// bit-identical to [`batch_matmul`] at any `SPA_THREADS`.
pub fn batch_matmul_into(
    a: &[f32],
    ashape: &[usize],
    b: &[f32],
    bshape: &[usize],
    out: &mut [f32],
) {
    assert!(ashape.len() >= 2 && bshape.len() >= 2);
    assert_eq!(ashape.len(), bshape.len(), "batch_matmul rank mismatch");
    assert_eq!(
        ashape[..ashape.len() - 2],
        bshape[..bshape.len() - 2],
        "batch dims mismatch"
    );
    let (m, k) = (ashape[ashape.len() - 2], ashape[ashape.len() - 1]);
    let (k2, n) = (bshape[bshape.len() - 2], bshape[bshape.len() - 1]);
    assert_eq!(k, k2, "batch_matmul inner dim mismatch");
    let batch: usize = ashape[..ashape.len() - 2].iter().product();
    assert_eq!(out.len(), batch * m * n, "batch_matmul_into output size");
    out.iter_mut().for_each(|v| *v = 0.0);
    if m * n > 0 && batch * m * k * n >= PAR_GEMM_MIN_MACS && par::workers_for(batch) > 1 {
        par::par_chunks_mut(out, m * n, |bi, obatch| {
            gemm_band(
                &a[bi * m * k..(bi + 1) * m * k],
                &b[bi * k * n..(bi + 1) * k * n],
                obatch,
                m,
                k,
                n,
            );
        });
    } else {
        for bi in 0..batch {
            gemm_into(
                &a[bi * m * k..(bi + 1) * m * k],
                &b[bi * k * n..(bi + 1) * k * n],
                &mut out[bi * m * n..(bi + 1) * m * n],
                m,
                k,
                n,
            );
        }
    }
}

/// Linear layer: x[..., K] · wᵀ where w is [N, K]; bias optional [N].
///
/// Perf note (§Perf iteration 1): the naive per-row dot walked `w`
/// column-major through the inner product; transposing `w` once and
/// running the blocked [`gemm_into`] keeps both operands streaming
/// row-major. For single-row inputs the transpose overhead dominates, so
/// the dot path is kept for `rows == 1`.
pub fn linear(x: &Tensor, w: &Tensor, b: Option<&Tensor>) -> Tensor {
    assert_eq!(w.rank(), 2, "linear weight must be [out, in]");
    let kin = x.dim(-1);
    let rows: usize = x.numel() / kin;
    let n = w.shape[0];
    let mut out = vec![0.0f32; rows * n];
    linear_into(&x.data, kin, w, b, None, &mut out);
    let mut shape = x.shape[..x.rank() - 1].to_vec();
    shape.push(n);
    Tensor::new(shape, out)
}

/// [`linear`] into a caller-provided buffer (overwritten); `kin` is the
/// input feature dim (`x.len()` must be a multiple). Bit-identical to
/// [`linear`], including its `rows == 1` dot-product special case. `wt`
/// may supply a precomputed `[K, N]` transpose of `w` (the compiled-plan
/// executor caches one per Gemm) — values must equal `w.t2()`, which
/// keeps the arithmetic identical while skipping the per-call transpose.
pub fn linear_into(
    x: &[f32],
    kin: usize,
    w: &Tensor,
    b: Option<&Tensor>,
    wt: Option<&Tensor>,
    out: &mut [f32],
) {
    assert_eq!(w.rank(), 2, "linear weight must be [out, in]");
    assert_eq!(kin, w.shape[1], "linear in-dim mismatch");
    let rows: usize = x.len() / kin;
    let n = w.shape[0];
    assert_eq!(out.len(), rows * n, "linear_into output size");
    if rows == 1 {
        for j in 0..n {
            let wr = &w.data[j * kin..(j + 1) * kin];
            let mut acc = 0.0f32;
            for p in 0..kin {
                acc += x[p] * wr[p];
            }
            out[j] = acc;
        }
    } else {
        out.iter_mut().for_each(|v| *v = 0.0);
        match wt {
            Some(wt) => {
                assert_eq!(wt.shape, [kin, n], "wt must be the [K, N] transpose of w");
                gemm_into(x, &wt.data, out, rows, kin, n);
            }
            None => {
                let wt = w.t2(); // [kin, n]
                gemm_into(x, &wt.data, out, rows, kin, n);
            }
        }
    }
    if let Some(b) = b {
        assert_eq!(b.numel(), n, "bias dim mismatch");
        for i in 0..rows {
            for j in 0..n {
                out[i * n + j] += b.data[j];
            }
        }
    }
}

/// Spatial conv output size for one dimension.
pub fn conv_out_dim(input: usize, k: usize, stride: usize, pad: usize) -> usize {
    (input + 2 * pad - k) / stride + 1
}

/// im2col for one image group-slice: x[ci, h, w] → cols[(ci·kh·kw), (ho·wo)].
fn im2col_single(
    x: &[f32],
    ci: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    cols: &mut [f32],
) {
    let ho = conv_out_dim(h, kh, stride, pad);
    let wo = conv_out_dim(w, kw, stride, pad);
    let owh = ho * wo;
    for c in 0..ci {
        for ky in 0..kh {
            for kx in 0..kw {
                let row = (c * kh + ky) * kw + kx;
                let dst = &mut cols[row * owh..(row + 1) * owh];
                for oy in 0..ho {
                    let iy = oy * stride + ky;
                    let iy = iy as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        for v in &mut dst[oy * wo..(oy + 1) * wo] {
                            *v = 0.0;
                        }
                        continue;
                    }
                    let src_base = (c * h + iy as usize) * w;
                    for ox in 0..wo {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        dst[oy * wo + ox] = if ix < 0 || ix >= w as isize {
                            0.0
                        } else {
                            x[src_base + ix as usize]
                        };
                    }
                }
            }
        }
    }
}

/// col2im: scatter-add of cols[(ci·kh·kw), (ho·wo)] back into x[ci, h, w].
fn col2im_single(
    cols: &[f32],
    ci: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    x: &mut [f32],
) {
    let ho = conv_out_dim(h, kh, stride, pad);
    let wo = conv_out_dim(w, kw, stride, pad);
    let owh = ho * wo;
    for c in 0..ci {
        for ky in 0..kh {
            for kx in 0..kw {
                let row = (c * kh + ky) * kw + kx;
                let src = &cols[row * owh..(row + 1) * owh];
                for oy in 0..ho {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let dst_base = (c * h + iy as usize) * w;
                    for ox in 0..wo {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix >= 0 && ix < w as isize {
                            x[dst_base + ix as usize] += src[oy * wo + ox];
                        }
                    }
                }
            }
        }
    }
}

/// 2-D convolution: x[N,Ci,H,W] * w[Co,Ci/g,kh,kw] (+ b[Co]) → y[N,Co,Ho,Wo].
pub fn conv2d(
    x: &Tensor,
    w: &Tensor,
    b: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
) -> Tensor {
    assert_eq!(x.rank(), 4, "conv2d input must be NCHW");
    assert_eq!(w.rank(), 4, "conv2d weight must be [Co,Ci/g,kh,kw]");
    let (n, co) = (x.shape[0], w.shape[0]);
    let ho = conv_out_dim(x.shape[2], w.shape[2], stride, pad);
    let wo = conv_out_dim(x.shape[3], w.shape[3], stride, pad);
    let mut out = vec![0.0f32; n * co * ho * wo];
    conv2d_into(&x.data, &x.shape, w, b, stride, pad, groups, &mut out);
    Tensor::new(vec![n, co, ho, wo], out)
}

/// [`conv2d`] into a caller-provided buffer of exactly the output numel
/// (overwritten) — the allocation-free form the compiled-plan executor
/// (`crate::exec`) runs on. Same arithmetic as [`conv2d`], so results are
/// bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into(
    x: &[f32],
    xshape: &[usize],
    w: &Tensor,
    b: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
    out: &mut [f32],
) {
    assert_eq!(xshape.len(), 4, "conv2d input must be NCHW");
    assert_eq!(w.rank(), 4, "conv2d weight must be [Co,Ci/g,kh,kw]");
    let (n, ci, h, wd) = (xshape[0], xshape[1], xshape[2], xshape[3]);
    let (co, cig, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    assert_eq!(ci % groups, 0, "Ci {ci} not divisible by groups {groups}");
    assert_eq!(co % groups, 0, "Co {co} not divisible by groups {groups}");
    assert_eq!(cig, ci / groups, "weight in-channels mismatch");
    let ho = conv_out_dim(h, kh, stride, pad);
    let wo = conv_out_dim(wd, kw, stride, pad);
    let cog = co / groups;
    let kdim = cig * kh * kw;
    let owh = ho * wo;
    assert_eq!(out.len(), n * co * owh, "conv2d_into output size");
    out.iter_mut().for_each(|v| *v = 0.0);
    let macs = n * co * owh * kdim;
    if co * owh > 0 && macs >= PAR_GEMM_MIN_MACS && par::workers_for(n) > 1 {
        // One image per chunk: im2col + GEMM are fully image-local, so
        // images fan out across the pool with bit-identical per-image
        // arithmetic (each worker runs the same serial kernel).
        par::par_chunks_mut(out, co * owh, |img, oimg| {
            let mut cols = vec![0.0f32; kdim * owh];
            for g in 0..groups {
                let xs = &x[(img * ci + g * cig) * h * wd..(img * ci + (g + 1) * cig) * h * wd];
                im2col_single(xs, cig, h, wd, kh, kw, stride, pad, &mut cols);
                let wg = &w.data[g * cog * kdim..(g + 1) * cog * kdim];
                let ys = &mut oimg[g * cog * owh..(g + 1) * cog * owh];
                gemm_band(wg, &cols, ys, cog, kdim, owh);
            }
        });
    } else {
        let mut cols = vec![0.0f32; kdim * owh];
        for img in 0..n {
            for g in 0..groups {
                let xs = &x[(img * ci + g * cig) * h * wd..(img * ci + (g + 1) * cig) * h * wd];
                im2col_single(xs, cig, h, wd, kh, kw, stride, pad, &mut cols);
                // w_g [cog, kdim] · cols [kdim, owh] → y_g [cog, owh]
                let wg = &w.data[g * cog * kdim..(g + 1) * cog * kdim];
                let ys = &mut out[(img * co + g * cog) * owh..(img * co + (g + 1) * cog) * owh];
                gemm_into(wg, &cols, ys, cog, kdim, owh);
            }
        }
    }
    if let Some(b) = b {
        assert_eq!(b.numel(), co);
        for img in 0..n {
            for c in 0..co {
                let base = (img * co + c) * owh;
                let bv = b.data[c];
                for v in &mut out[base..base + owh] {
                    *v += bv;
                }
            }
        }
    }
}

/// Images per partial-gradient block in [`conv2d_backward`]. Fixed (not
/// derived from the worker count) so the floating-point reduction order
/// is identical at any `SPA_THREADS`; 4 gives 8-way parallelism at the
/// typical batch 32 while capping partial-buffer memory at n/4 weights.
const BWD_IMG_BLOCK: usize = 4;

/// Batched-image convolution for the compiled-plan executor
/// (`crate::exec`): one im2col matrix `[kdim, N·Ho·Wo]` per group and a
/// single GEMM per group, instead of N small per-image GEMMs. Per output
/// element the multiply-accumulate order is unchanged (ascending kdim),
/// so results are **bit-identical** to [`conv2d`]; wall-clock improves
/// because the microkernel's inner loops amortize over `N·Ho·Wo`-wide
/// rows instead of `Ho·Wo`. `cols`/`yb` are caller-owned scratch buffers
/// (resized as needed) so steady-state runs allocate nothing.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_batched_into(
    x: &[f32],
    xshape: &[usize],
    w: &Tensor,
    b: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
    cols: &mut Vec<f32>,
    yb: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert_eq!(xshape.len(), 4, "conv2d input must be NCHW");
    assert_eq!(w.rank(), 4, "conv2d weight must be [Co,Ci/g,kh,kw]");
    let (n, ci, h, wd) = (xshape[0], xshape[1], xshape[2], xshape[3]);
    let (co, cig, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    assert_eq!(ci % groups, 0, "Ci {ci} not divisible by groups {groups}");
    assert_eq!(co % groups, 0, "Co {co} not divisible by groups {groups}");
    assert_eq!(cig, ci / groups, "weight in-channels mismatch");
    let ho = conv_out_dim(h, kh, stride, pad);
    let wo = conv_out_dim(wd, kw, stride, pad);
    let cog = co / groups;
    let kdim = cig * kh * kw;
    let owh = ho * wo;
    let ncol = n * owh;
    assert_eq!(out.len(), n * co * owh, "conv2d_batched_into output size");
    cols.resize(kdim * ncol, 0.0);
    yb.resize(cog * ncol, 0.0);
    for g in 0..groups {
        // batched im2col: image `img` occupies columns [img·owh, (img+1)·owh)
        for c in 0..cig {
            for ky in 0..kh {
                for kx in 0..kw {
                    let row = (c * kh + ky) * kw + kx;
                    for img in 0..n {
                        let xi = &x[(img * ci + g * cig + c) * h * wd..][..h * wd];
                        let dst = &mut cols[row * ncol + img * owh..][..owh];
                        for oy in 0..ho {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                for v in &mut dst[oy * wo..(oy + 1) * wo] {
                                    *v = 0.0;
                                }
                                continue;
                            }
                            let src = &xi[iy as usize * wd..][..wd];
                            for ox in 0..wo {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                dst[oy * wo + ox] = if ix < 0 || ix >= wd as isize {
                                    0.0
                                } else {
                                    src[ix as usize]
                                };
                            }
                        }
                    }
                }
            }
        }
        yb.iter_mut().for_each(|v| *v = 0.0);
        let wg = &w.data[g * cog * kdim..(g + 1) * cog * kdim];
        gemm_into(wg, cols, yb, cog, kdim, ncol);
        // scatter [cog, N·owh] back to NCHW
        for img in 0..n {
            for c in 0..cog {
                let src = &yb[c * ncol + img * owh..][..owh];
                out[(img * co + g * cog + c) * owh..][..owh].copy_from_slice(src);
            }
        }
    }
    if let Some(b) = b {
        assert_eq!(b.numel(), co);
        for img in 0..n {
            for c in 0..co {
                let base = (img * co + c) * owh;
                let bv = b.data[c];
                for v in &mut out[base..base + owh] {
                    *v += bv;
                }
            }
        }
    }
}

/// Gradients of conv2d: returns (dx, dw, db).
///
/// Images are independent: `dx` slices are disjoint per image, and the
/// `dw`/`db` contributions are accumulated per fixed-size image block
/// into partial buffers that are reduced in block order afterwards. Both
/// the serial and parallel paths use the same block structure, so the
/// element-wise addition sequence — and therefore every output bit — is
/// identical at any `SPA_THREADS`, while peak memory scales with
/// `n / BWD_IMG_BLOCK` partials rather than `n`.
pub fn conv2d_backward(
    x: &Tensor,
    w: &Tensor,
    dy: &Tensor,
    stride: usize,
    pad: usize,
    groups: usize,
) -> (Tensor, Tensor, Tensor) {
    let (n, ci, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (co, cig, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (ho, wo) = (dy.shape[2], dy.shape[3]);
    let cog = co / groups;
    let kdim = cig * kh * kw;
    let owh = ho * wo;
    let per_img = ci * h * wd;
    let mut dx = vec![0.0f32; x.numel()];
    let mut dw = vec![0.0f32; w.numel()];
    let mut db = vec![0.0f32; co];
    // One image's backward, accumulating into the given dx slice and
    // dw/db buffers. Shared by the serial and parallel paths so the
    // per-element addition sequence (image-major) is identical.
    let image_backward =
        |img: usize, dxi: &mut [f32], dwi: &mut [f32], dbi: &mut [f32], scratch: &mut [f32]| {
            let (cols, dcols) = scratch.split_at_mut(kdim * owh);
            for g in 0..groups {
                let xs =
                    &x.data[(img * ci + g * cig) * h * wd..(img * ci + (g + 1) * cig) * h * wd];
                im2col_single(xs, cig, h, wd, kh, kw, stride, pad, cols);
                let dys = &dy.data[(img * co + g * cog) * owh..(img * co + (g + 1) * cog) * owh];
                // dw_g [cog, kdim] += dy_g [cog, owh] · cols^T [owh, kdim]
                let dwg = &mut dwi[g * cog * kdim..(g + 1) * cog * kdim];
                for oc in 0..cog {
                    let dyr = &dys[oc * owh..(oc + 1) * owh];
                    let dwr = &mut dwg[oc * kdim..(oc + 1) * kdim];
                    for p in 0..kdim {
                        let colr = &cols[p * owh..(p + 1) * owh];
                        let mut acc = 0.0f32;
                        for q in 0..owh {
                            acc += dyr[q] * colr[q];
                        }
                        dwr[p] += acc;
                    }
                }
                // dcols [kdim, owh] = w_g^T [kdim, cog] · dy_g [cog, owh]
                dcols.iter_mut().for_each(|v| *v = 0.0);
                let wg = &w.data[g * cog * kdim..(g + 1) * cog * kdim];
                for oc in 0..cog {
                    let dyr = &dys[oc * owh..(oc + 1) * owh];
                    let wr = &wg[oc * kdim..(oc + 1) * kdim];
                    for p in 0..kdim {
                        let wv = wr[p];
                        if wv == 0.0 {
                            continue;
                        }
                        let dcr = &mut dcols[p * owh..(p + 1) * owh];
                        for q in 0..owh {
                            dcr[q] += wv * dyr[q];
                        }
                    }
                }
                let dxs = &mut dxi[g * cig * h * wd..(g + 1) * cig * h * wd];
                col2im_single(dcols, cig, h, wd, kh, kw, stride, pad, dxs);
            }
            for c in 0..co {
                let base = (img * co + c) * owh;
                dbi[c] += dy.data[base..base + owh].iter().sum::<f32>();
            }
        };
    // One block = up to BWD_IMG_BLOCK consecutive images accumulated (in
    // image order) into one dw/db partial and a contiguous dx range.
    let n_blocks = n.div_ceil(BWD_IMG_BLOCK).max(1);
    let block_backward = |blk: usize, dxb: &mut [f32], dwb: &mut [f32], dbb: &mut [f32]| {
        let mut scratch = vec![0.0f32; 2 * kdim * owh];
        let lo = blk * BWD_IMG_BLOCK;
        let hi = (lo + BWD_IMG_BLOCK).min(n);
        for img in lo..hi {
            let off = (img - lo) * per_img;
            image_backward(img, &mut dxb[off..off + per_img], dwb, dbb, &mut scratch);
        }
    };
    let macs = n * co * owh * kdim;
    if per_img > 0 && macs >= PAR_GEMM_MIN_MACS && par::workers_for(n_blocks) > 1 {
        let blocks: Vec<usize> = (0..n_blocks).collect();
        let partials: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = par::par_map(&blocks, |&blk| {
            let imgs = ((blk + 1) * BWD_IMG_BLOCK).min(n) - blk * BWD_IMG_BLOCK;
            let mut dxb = vec![0.0f32; imgs * per_img];
            let mut dwb = vec![0.0f32; w.numel()];
            let mut dbb = vec![0.0f32; co];
            block_backward(blk, &mut dxb, &mut dwb, &mut dbb);
            (dxb, dwb, dbb)
        });
        for (blk, (dxb, dwb, dbb)) in partials.into_iter().enumerate() {
            let lo = blk * BWD_IMG_BLOCK * per_img;
            dx[lo..lo + dxb.len()].copy_from_slice(&dxb);
            for (acc, v) in dw.iter_mut().zip(&dwb) {
                *acc += v;
            }
            for (acc, v) in db.iter_mut().zip(&dbb) {
                *acc += v;
            }
        }
    } else {
        // Serial: identical block structure, one partial reused per block.
        let mut dwb = vec![0.0f32; w.numel()];
        let mut dbb = vec![0.0f32; co];
        for blk in 0..n_blocks {
            dwb.iter_mut().for_each(|v| *v = 0.0);
            dbb.iter_mut().for_each(|v| *v = 0.0);
            let lo = blk * BWD_IMG_BLOCK;
            let hi = (lo + BWD_IMG_BLOCK).min(n);
            let dxb = &mut dx[lo * per_img..hi * per_img];
            block_backward(blk, dxb, &mut dwb, &mut dbb);
            for (acc, v) in dw.iter_mut().zip(&dwb) {
                *acc += v;
            }
            for (acc, v) in db.iter_mut().zip(&dbb) {
                *acc += v;
            }
        }
    }
    (
        Tensor::new(x.shape.clone(), dx),
        Tensor::new(w.shape.clone(), dw),
        Tensor::new(vec![co], db),
    )
}

/// Unfold conv inputs to GEMM form for OBSPA's layer-wise Hessian
/// (H = X·Xᵀ over the im2col matrix, App. A.5 Eq. 12): returns one
/// [kdim, N·Ho·Wo] matrix per conv group.
pub fn unfold_conv_inputs(
    x: &Tensor,
    w_shape: &[usize],
    stride: usize,
    pad: usize,
    groups: usize,
) -> Vec<Tensor> {
    let (n, ci, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cig, kh, kw) = (w_shape[1], w_shape[2], w_shape[3]);
    assert_eq!(cig, ci / groups);
    let ho = conv_out_dim(h, kh, stride, pad);
    let wo = conv_out_dim(wd, kw, stride, pad);
    let kdim = cig * kh * kw;
    let owh = ho * wo;
    let mut out: Vec<Vec<f32>> = vec![vec![0.0; kdim * n * owh]; groups];
    let mut cols = vec![0.0f32; kdim * owh];
    for img in 0..n {
        for g in 0..groups {
            let xs = &x.data[(img * ci + g * cig) * h * wd..(img * ci + (g + 1) * cig) * h * wd];
            im2col_single(xs, cig, h, wd, kh, kw, stride, pad, &mut cols);
            // scatter image block into [kdim, n*owh] at column offset img*owh
            let dst = &mut out[g];
            for row in 0..kdim {
                dst[row * n * owh + img * owh..row * n * owh + (img + 1) * owh]
                    .copy_from_slice(&cols[row * owh..(row + 1) * owh]);
            }
        }
    }
    out.into_iter()
        .map(|d| Tensor::new(vec![kdim, n * owh], d))
        .collect()
}

/// Max pooling: returns (y, argmax) with argmax flat indices into x for backward.
pub fn maxpool2d(x: &Tensor, k: usize, stride: usize, pad: usize) -> (Tensor, Vec<usize>) {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = conv_out_dim(h, k, stride, pad);
    let wo = conv_out_dim(w, k, stride, pad);
    let mut out = vec![f32::NEG_INFINITY; n * c * ho * wo];
    let mut arg = vec![0usize; n * c * ho * wo];
    for img in 0..n {
        for ch in 0..c {
            let xbase = (img * c + ch) * h * w;
            for oy in 0..ho {
                for ox in 0..wo {
                    let oidx = ((img * c + ch) * ho + oy) * wo + ox;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let xi = xbase + iy as usize * w + ix as usize;
                            if x.data[xi] > out[oidx] {
                                out[oidx] = x.data[xi];
                                arg[oidx] = xi;
                            }
                        }
                    }
                }
            }
        }
    }
    (Tensor::new(vec![n, c, ho, wo], out), arg)
}

/// Eval-only [`maxpool2d`] into a caller-provided buffer: same window
/// iteration and comparisons, no argmax bookkeeping — bit-identical
/// pooled values.
pub fn maxpool2d_eval_into(
    x: &[f32],
    xshape: &[usize],
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut [f32],
) {
    let (n, c, h, w) = (xshape[0], xshape[1], xshape[2], xshape[3]);
    let ho = conv_out_dim(h, k, stride, pad);
    let wo = conv_out_dim(w, k, stride, pad);
    assert_eq!(out.len(), n * c * ho * wo, "maxpool2d_eval_into output size");
    out.iter_mut().for_each(|v| *v = f32::NEG_INFINITY);
    for img in 0..n {
        for ch in 0..c {
            let xbase = (img * c + ch) * h * w;
            for oy in 0..ho {
                for ox in 0..wo {
                    let oidx = ((img * c + ch) * ho + oy) * wo + ox;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let xi = xbase + iy as usize * w + ix as usize;
                            if x[xi] > out[oidx] {
                                out[oidx] = x[xi];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Scatter pooled gradients back to the argmax positions; returns a flat
/// tensor the caller reshapes to the input shape.
pub fn maxpool2d_backward(dy: &Tensor, argmax: &[usize], x_numel: usize) -> Tensor {
    let mut dx = vec![0.0f32; x_numel];
    for (i, &a) in argmax.iter().enumerate() {
        dx[a] += dy.data[i];
    }
    Tensor::new(vec![x_numel], dx)
}

/// Average pooling.
pub fn avgpool2d(x: &Tensor, k: usize, stride: usize, pad: usize) -> Tensor {
    let (n, c) = (x.shape[0], x.shape[1]);
    let ho = conv_out_dim(x.shape[2], k, stride, pad);
    let wo = conv_out_dim(x.shape[3], k, stride, pad);
    let mut out = vec![0.0f32; n * c * ho * wo];
    avgpool2d_into(&x.data, &x.shape, k, stride, pad, &mut out);
    Tensor::new(vec![n, c, ho, wo], out)
}

/// [`avgpool2d`] into a caller-provided buffer (overwritten).
pub fn avgpool2d_into(
    x: &[f32],
    xshape: &[usize],
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut [f32],
) {
    let (n, c, h, w) = (xshape[0], xshape[1], xshape[2], xshape[3]);
    let ho = conv_out_dim(h, k, stride, pad);
    let wo = conv_out_dim(w, k, stride, pad);
    assert_eq!(out.len(), n * c * ho * wo, "avgpool2d_into output size");
    let inv = 1.0 / (k * k) as f32;
    for img in 0..n {
        for ch in 0..c {
            let xbase = (img * c + ch) * h * w;
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0f32;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix >= 0 && ix < w as isize {
                                acc += x[xbase + iy as usize * w + ix as usize];
                            }
                        }
                    }
                    out[((img * c + ch) * ho + oy) * wo + ox] = acc * inv;
                }
            }
        }
    }
}

pub fn avgpool2d_backward(
    dy: &Tensor,
    x_shape: &[usize],
    k: usize,
    stride: usize,
    pad: usize,
) -> Tensor {
    let (n, c, h, w) = (x_shape[0], x_shape[1], x_shape[2], x_shape[3]);
    let (ho, wo) = (dy.shape[2], dy.shape[3]);
    let inv = 1.0 / (k * k) as f32;
    let mut dx = vec![0.0f32; n * c * h * w];
    for img in 0..n {
        for ch in 0..c {
            let xbase = (img * c + ch) * h * w;
            for oy in 0..ho {
                for ox in 0..wo {
                    let g = dy.data[((img * c + ch) * ho + oy) * wo + ox] * inv;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix >= 0 && ix < w as isize {
                                dx[xbase + iy as usize * w + ix as usize] += g;
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor::new(x_shape.to_vec(), dx)
}

/// Global average pool [N,C,H,W] → [N,C].
pub fn global_avgpool(x: &Tensor) -> Tensor {
    let (n, c) = (x.shape[0], x.shape[1]);
    let mut out = vec![0.0f32; n * c];
    global_avgpool_into(&x.data, &x.shape, &mut out);
    Tensor::new(vec![n, c], out)
}

/// [`global_avgpool`] into a caller-provided buffer (overwritten).
pub fn global_avgpool_into(x: &[f32], xshape: &[usize], out: &mut [f32]) {
    let (n, c, h, w) = (xshape[0], xshape[1], xshape[2], xshape[3]);
    assert_eq!(out.len(), n * c, "global_avgpool_into output size");
    let inv = 1.0 / (h * w) as f32;
    for (i, o) in out.iter_mut().enumerate() {
        *o = x[i * h * w..(i + 1) * h * w].iter().sum::<f32>() * inv;
    }
}

pub fn global_avgpool_backward(dy: &Tensor, x_shape: &[usize]) -> Tensor {
    let (h, w) = (x_shape[2], x_shape[3]);
    let inv = 1.0 / (h * w) as f32;
    let mut dx = vec![0.0f32; x_shape.iter().product()];
    for i in 0..dy.numel() {
        let g = dy.data[i] * inv;
        for v in &mut dx[i * h * w..(i + 1) * h * w] {
            *v = g;
        }
    }
    Tensor::new(x_shape.to_vec(), dx)
}

/// BatchNorm inference: y = γ·(x−μ)/√(σ²+ε) + β over the channel dim (dim 1
/// for 4-D, last-as-feature for 2-D [N,C]).
pub fn batchnorm_infer(
    x: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    mean: &Tensor,
    var: &Tensor,
    eps: f32,
) -> Tensor {
    let mut out = vec![0.0f32; x.numel()];
    batchnorm_infer_into(&x.data, &x.shape, gamma, beta, mean, var, eps, &mut out);
    Tensor::new(x.shape.clone(), out)
}

/// [`batchnorm_infer`] into a caller-provided buffer (overwritten).
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_infer_into(
    x: &[f32],
    xshape: &[usize],
    gamma: &Tensor,
    beta: &Tensor,
    mean: &Tensor,
    var: &Tensor,
    eps: f32,
    out: &mut [f32],
) {
    let c = xshape[1];
    assert_eq!(gamma.numel(), c);
    let inner: usize = xshape[2..].iter().product();
    let n = xshape[0];
    assert_eq!(out.len(), x.len(), "batchnorm_infer_into output size");
    for img in 0..n {
        for ch in 0..c {
            let scale = gamma.data[ch] / (var.data[ch] + eps).sqrt();
            let shift = beta.data[ch] - mean.data[ch] * scale;
            let base = (img * c + ch) * inner;
            for i in 0..inner {
                out[base + i] = x[base + i] * scale + shift;
            }
        }
    }
}

/// Apply the eval-mode BatchNorm affine map *in place* — the fused
/// Conv→BN / Gemm→BN post-op of the compiled-plan executor. Per element
/// it computes exactly `v·scale + shift` like [`batchnorm_infer`], so a
/// fused step is bit-identical to the unfused op pair.
#[allow(clippy::too_many_arguments)]
pub fn batchnorm_affine_inplace(
    y: &mut [f32],
    yshape: &[usize],
    gamma: &Tensor,
    beta: &Tensor,
    mean: &Tensor,
    var: &Tensor,
    eps: f32,
) {
    let c = yshape[1];
    assert_eq!(gamma.numel(), c);
    let inner: usize = yshape[2..].iter().product();
    let n = yshape[0];
    for img in 0..n {
        for ch in 0..c {
            let scale = gamma.data[ch] / (var.data[ch] + eps).sqrt();
            let shift = beta.data[ch] - mean.data[ch] * scale;
            let base = (img * c + ch) * inner;
            for v in &mut y[base..base + inner] {
                *v = *v * scale + shift;
            }
        }
    }
}

/// BatchNorm training forward: returns (y, batch_mean, batch_var, x_hat).
pub fn batchnorm_train(
    x: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    eps: f32,
) -> (Tensor, Tensor, Tensor, Tensor) {
    let c = x.shape[1];
    let inner: usize = x.shape[2..].iter().product();
    let n = x.shape[0];
    let cnt = (n * inner) as f32;
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    for img in 0..n {
        for ch in 0..c {
            let base = (img * c + ch) * inner;
            mean[ch] += x.data[base..base + inner].iter().sum::<f32>();
        }
    }
    for m in &mut mean {
        *m /= cnt;
    }
    for img in 0..n {
        for ch in 0..c {
            let base = (img * c + ch) * inner;
            let m = mean[ch];
            var[ch] += x.data[base..base + inner]
                .iter()
                .map(|&v| (v - m) * (v - m))
                .sum::<f32>();
        }
    }
    for v in &mut var {
        *v /= cnt;
    }
    let mut xhat = vec![0.0f32; x.numel()];
    let mut out = vec![0.0f32; x.numel()];
    for img in 0..n {
        for ch in 0..c {
            let inv_std = 1.0 / (var[ch] + eps).sqrt();
            let base = (img * c + ch) * inner;
            for i in 0..inner {
                let xh = (x.data[base + i] - mean[ch]) * inv_std;
                xhat[base + i] = xh;
                out[base + i] = gamma.data[ch] * xh + beta.data[ch];
            }
        }
    }
    (
        Tensor::new(x.shape.clone(), out),
        Tensor::new(vec![c], mean),
        Tensor::new(vec![c], var),
        Tensor::new(x.shape.clone(), xhat),
    )
}

/// BatchNorm backward: returns (dx, dgamma, dbeta).
pub fn batchnorm_backward(
    dy: &Tensor,
    xhat: &Tensor,
    gamma: &Tensor,
    var: &Tensor,
    eps: f32,
) -> (Tensor, Tensor, Tensor) {
    let c = dy.shape[1];
    let inner: usize = dy.shape[2..].iter().product();
    let n = dy.shape[0];
    let cnt = (n * inner) as f32;
    let mut dgamma = vec![0.0f32; c];
    let mut dbeta = vec![0.0f32; c];
    for img in 0..n {
        for ch in 0..c {
            let base = (img * c + ch) * inner;
            for i in 0..inner {
                dgamma[ch] += dy.data[base + i] * xhat.data[base + i];
                dbeta[ch] += dy.data[base + i];
            }
        }
    }
    let mut dx = vec![0.0f32; dy.numel()];
    for img in 0..n {
        for ch in 0..c {
            let inv_std = 1.0 / (var.data[ch] + eps).sqrt();
            let base = (img * c + ch) * inner;
            let k = gamma.data[ch] * inv_std / cnt;
            for i in 0..inner {
                dx[base + i] = k
                    * (cnt * dy.data[base + i]
                        - dbeta[ch]
                        - xhat.data[base + i] * dgamma[ch]);
            }
        }
    }
    (
        Tensor::new(dy.shape.clone(), dx),
        Tensor::new(vec![c], dgamma),
        Tensor::new(vec![c], dbeta),
    )
}

/// LayerNorm over the last dim: returns (y, mean, inv_std, xhat).
pub fn layernorm(
    x: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    eps: f32,
) -> (Tensor, Vec<f32>, Vec<f32>, Tensor) {
    let d = x.dim(-1);
    assert_eq!(gamma.numel(), d);
    let rows = x.numel() / d;
    let mut out = vec![0.0f32; x.numel()];
    let mut xhat = vec![0.0f32; x.numel()];
    let mut means = vec![0.0f32; rows];
    let mut inv_stds = vec![0.0f32; rows];
    for r in 0..rows {
        let xs = &x.data[r * d..(r + 1) * d];
        let mean = xs.iter().sum::<f32>() / d as f32;
        let var = xs.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv_std = 1.0 / (var + eps).sqrt();
        means[r] = mean;
        inv_stds[r] = inv_std;
        for i in 0..d {
            let xh = (xs[i] - mean) * inv_std;
            xhat[r * d + i] = xh;
            out[r * d + i] = gamma.data[i] * xh + beta.data[i];
        }
    }
    (
        Tensor::new(x.shape.clone(), out),
        means,
        inv_stds,
        Tensor::new(x.shape.clone(), xhat),
    )
}

/// Forward-only [`layernorm`] into a caller-provided buffer: identical
/// per-row mean/var/normalize arithmetic, none of the backward state —
/// the compiled-plan executor's inference path.
pub fn layernorm_eval_into(
    x: &[f32],
    d: usize,
    gamma: &Tensor,
    beta: &Tensor,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(gamma.numel(), d);
    assert_eq!(out.len(), x.len(), "layernorm_eval_into output size");
    let rows = x.len() / d;
    for r in 0..rows {
        let xs = &x[r * d..(r + 1) * d];
        let mean = xs.iter().sum::<f32>() / d as f32;
        let var = xs.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv_std = 1.0 / (var + eps).sqrt();
        for i in 0..d {
            let xh = (xs[i] - mean) * inv_std;
            out[r * d + i] = gamma.data[i] * xh + beta.data[i];
        }
    }
}

/// LayerNorm backward: (dx, dgamma, dbeta).
pub fn layernorm_backward(
    dy: &Tensor,
    xhat: &Tensor,
    gamma: &Tensor,
    inv_stds: &[f32],
) -> (Tensor, Tensor, Tensor) {
    let d = dy.dim(-1);
    let rows = dy.numel() / d;
    let mut dgamma = vec![0.0f32; d];
    let mut dbeta = vec![0.0f32; d];
    let mut dx = vec![0.0f32; dy.numel()];
    for r in 0..rows {
        let dys = &dy.data[r * d..(r + 1) * d];
        let xhs = &xhat.data[r * d..(r + 1) * d];
        let mut sum_dy_g = 0.0f32;
        let mut sum_dy_g_xh = 0.0f32;
        for i in 0..d {
            let g = dys[i] * gamma.data[i];
            sum_dy_g += g;
            sum_dy_g_xh += g * xhs[i];
            dgamma[i] += dys[i] * xhs[i];
            dbeta[i] += dys[i];
        }
        let inv_d = 1.0 / d as f32;
        for i in 0..d {
            let g = dys[i] * gamma.data[i];
            dx[r * d + i] =
                inv_stds[r] * (g - inv_d * sum_dy_g - xhs[i] * inv_d * sum_dy_g_xh);
        }
    }
    (
        Tensor::new(dy.shape.clone(), dx),
        Tensor::new(vec![d], dgamma),
        Tensor::new(vec![d], dbeta),
    )
}

/// Softmax along the last dim.
pub fn softmax_lastdim(x: &Tensor) -> Tensor {
    let mut out = vec![0.0f32; x.numel()];
    softmax_lastdim_into(&x.data, x.dim(-1), &mut out);
    Tensor::new(x.shape.clone(), out)
}

/// [`softmax_lastdim`] into a caller-provided buffer (overwritten).
pub fn softmax_lastdim_into(x: &[f32], d: usize, out: &mut [f32]) {
    assert_eq!(out.len(), x.len(), "softmax_lastdim_into output size");
    let rows = x.len() / d;
    for r in 0..rows {
        let xs = &x[r * d..(r + 1) * d];
        let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for i in 0..d {
            let e = (xs[i] - mx).exp();
            out[r * d + i] = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for v in &mut out[r * d..(r + 1) * d] {
            *v *= inv;
        }
    }
}

/// Softmax backward given y = softmax(x): dx = y ⊙ (dy − Σ dy·y).
pub fn softmax_backward(dy: &Tensor, y: &Tensor) -> Tensor {
    let d = y.dim(-1);
    let rows = y.numel() / d;
    let mut dx = vec![0.0f32; y.numel()];
    for r in 0..rows {
        let ys = &y.data[r * d..(r + 1) * d];
        let dys = &dy.data[r * d..(r + 1) * d];
        let dot: f32 = ys.iter().zip(dys).map(|(&a, &b)| a * b).sum();
        for i in 0..d {
            dx[r * d + i] = ys[i] * (dys[i] - dot);
        }
    }
    Tensor::new(y.shape.clone(), dx)
}

/// Mean softmax cross-entropy over a batch of logits [N, K] with integer
/// labels; returns (loss, dlogits).
pub fn cross_entropy(logits: &Tensor, labels: &[usize]) -> (f32, Tensor) {
    assert_eq!(logits.rank(), 2);
    let (n, k) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), n);
    let probs = softmax_lastdim(logits);
    let mut loss = 0.0f32;
    let mut dl = probs.data.clone();
    let inv_n = 1.0 / n as f32;
    for i in 0..n {
        let p = probs.data[i * k + labels[i]].max(1e-12);
        loss -= p.ln();
        dl[i * k + labels[i]] -= 1.0;
    }
    for v in &mut dl {
        *v *= inv_n;
    }
    (loss * inv_n, Tensor::new(vec![n, k], dl))
}

/// Classification accuracy of logits [N, K] against labels.
pub fn accuracy(logits: &Tensor, labels: &[usize]) -> f32 {
    let (n, k) = (logits.shape[0], logits.shape[1]);
    let mut correct = 0usize;
    for i in 0..n {
        let row = &logits.data[i * k..(i + 1) * k];
        let mut best = 0usize;
        for j in 1..k {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == labels[i] {
            correct += 1;
        }
    }
    correct as f32 / n as f32
}

/// Top-k accuracy.
pub fn topk_accuracy(logits: &Tensor, labels: &[usize], kk: usize) -> f32 {
    let (n, k) = (logits.shape[0], logits.shape[1]);
    let mut correct = 0usize;
    for i in 0..n {
        let row = &logits.data[i * k..(i + 1) * k];
        let mut idx: Vec<usize> = (0..k).collect();
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        if idx[..kk.min(k)].contains(&labels[i]) {
            correct += 1;
        }
    }
    correct as f32 / n as f32
}

/// Embedding lookup: ids [N,T] (stored as f32 indices), table [V,D] → [N,T,D].
pub fn embedding(ids: &Tensor, table: &Tensor) -> Tensor {
    let d = table.shape[1];
    let mut out = vec![0.0f32; ids.numel() * d];
    embedding_into(&ids.data, table, &mut out);
    let mut shape = ids.shape.clone();
    shape.push(d);
    Tensor::new(shape, out)
}

/// [`embedding`] into a caller-provided buffer (overwritten).
pub fn embedding_into(ids: &[f32], table: &Tensor, out: &mut [f32]) {
    assert_eq!(table.rank(), 2);
    let (v, d) = (table.shape[0], table.shape[1]);
    assert_eq!(out.len(), ids.len() * d, "embedding_into output size");
    for (i, &id) in ids.iter().enumerate() {
        let id = id as usize;
        assert!(id < v, "embedding id {id} out of range {v}");
        out[i * d..(i + 1) * d].copy_from_slice(&table.data[id * d..(id + 1) * d]);
    }
}

/// Embedding backward: accumulate dy rows into dtable.
pub fn embedding_backward(ids: &Tensor, dy: &Tensor, table_shape: &[usize]) -> Tensor {
    let d = table_shape[1];
    let mut dt = vec![0.0f32; table_shape.iter().product()];
    for (i, &id) in ids.data.iter().enumerate() {
        let id = id as usize;
        for j in 0..d {
            dt[id * d + j] += dy.data[i * d + j];
        }
    }
    Tensor::new(table_shape.to_vec(), dt)
}

/// Transpose arbitrary-rank tensor by `perm`.
pub fn transpose(x: &Tensor, perm: &[usize]) -> Tensor {
    let out_shape: Vec<usize> = perm.iter().map(|&p| x.shape[p]).collect();
    let mut out = vec![0.0f32; x.numel()];
    transpose_into(&x.data, &x.shape, perm, &mut out);
    Tensor::new(out_shape, out)
}

/// [`transpose`] into a caller-provided buffer (overwritten). Also serves
/// reshape-then-transpose ops (SplitHeads / NchwToTokens): pass the
/// reshaped `xshape` — the data is shared row-major either way.
pub fn transpose_into(x: &[f32], xshape: &[usize], perm: &[usize], out: &mut [f32]) {
    assert_eq!(perm.len(), xshape.len());
    assert_eq!(out.len(), x.len(), "transpose_into output size");
    let mut in_strides = vec![1usize; xshape.len()];
    for i in (0..xshape.len().saturating_sub(1)).rev() {
        in_strides[i] = in_strides[i + 1] * xshape[i + 1];
    }
    let out_shape: Vec<usize> = perm.iter().map(|&p| xshape[p]).collect();
    let mut out_strides = vec![1usize; perm.len()];
    for i in (0..perm.len().saturating_sub(1)).rev() {
        out_strides[i] = out_strides[i + 1] * out_shape[i + 1];
    }
    // Walk output in order, gather from input.
    let rank = perm.len();
    let mut idx = vec![0usize; rank];
    for (o, ov) in out.iter_mut().enumerate() {
        let mut rem = o;
        for i in 0..rank {
            idx[i] = rem / out_strides[i];
            rem %= out_strides[i];
        }
        let mut src = 0usize;
        for i in 0..rank {
            src += idx[i] * in_strides[perm[i]];
        }
        *ov = x[src];
    }
}

/// GELU activation, tanh approximation (matches jax.nn.gelu default
/// closely). Shared by the interpreter and the compiled-plan executor so
/// fused and unfused activations are bit-identical.
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d gelu / dx of the tanh approximation.
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let u = C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Inverse permutation.
pub fn inverse_perm(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::assert_allclose;
    use crate::util::Rng;

    fn t(shape: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(shape.to_vec(), data.to_vec())
    }

    /// Assert exact bit-equality (the `_into` contract vs the allocating
    /// originals).
    fn assert_bits_eq(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "bit mismatch at {i}: {x} vs {y}");
        }
    }

    #[test]
    fn into_variants_bit_identical_to_originals() {
        let mut rng = Rng::new(77);
        // conv2d (grouped, biased)
        let x = t(&[2, 4, 6, 6], &rng.uniform_vec(2 * 4 * 36, -1.0, 1.0));
        let w = t(&[8, 2, 3, 3], &rng.uniform_vec(8 * 2 * 9, -1.0, 1.0));
        let b = t(&[8], &rng.uniform_vec(8, -1.0, 1.0));
        let y = conv2d(&x, &w, Some(&b), 1, 1, 2);
        let mut out = vec![1.0f32; y.numel()];
        conv2d_into(&x.data, &x.shape, &w, Some(&b), 1, 1, 2, &mut out);
        assert_bits_eq(&out, &y.data);
        // batched-image conv: same bits through the one-GEMM-per-group path
        let (mut cols, mut yb) = (Vec::new(), Vec::new());
        let mut bout = vec![1.0f32; y.numel()];
        conv2d_batched_into(
            &x.data, &x.shape, &w, Some(&b), 1, 1, 2, &mut cols, &mut yb, &mut bout,
        );
        assert_bits_eq(&bout, &y.data);
        let ys = conv2d(&x, &w, None, 2, 1, 2);
        let mut sout = vec![1.0f32; ys.numel()];
        conv2d_batched_into(
            &x.data, &x.shape, &w, None, 2, 1, 2, &mut cols, &mut yb, &mut sout,
        );
        assert_bits_eq(&sout, &ys.data);
        // linear (multi-row and single-row paths)
        let lw = t(&[5, 7], &rng.uniform_vec(35, -1.0, 1.0));
        for rows in [1usize, 3] {
            let lx = t(&[rows, 7], &rng.uniform_vec(rows * 7, -1.0, 1.0));
            let ly = linear(&lx, &lw, None);
            let mut lout = vec![1.0f32; rows * 5];
            linear_into(&lx.data, 7, &lw, None, None, &mut lout);
            assert_bits_eq(&lout, &ly.data);
            // precomputed-transpose path is the same arithmetic
            let wt = lw.t2();
            let mut lout2 = vec![1.0f32; rows * 5];
            linear_into(&lx.data, 7, &lw, None, Some(&wt), &mut lout2);
            assert_bits_eq(&lout2, &ly.data);
        }
        // batchnorm infer + in-place affine
        let gamma = t(&[4], &rng.uniform_vec(4, 0.5, 1.5));
        let beta = t(&[4], &rng.uniform_vec(4, -0.5, 0.5));
        let mean = t(&[4], &rng.uniform_vec(4, -0.5, 0.5));
        let var = t(&[4], &rng.uniform_vec(4, 0.5, 2.0));
        let bn = batchnorm_infer(&x, &gamma, &beta, &mean, &var, 1e-5);
        let mut inplace = x.data.clone();
        batchnorm_affine_inplace(&mut inplace, &x.shape, &gamma, &beta, &mean, &var, 1e-5);
        assert_bits_eq(&inplace, &bn.data);
        // maxpool eval
        let (mp, _) = maxpool2d(&x, 2, 2, 0);
        let mut mout = vec![0.0f32; mp.numel()];
        maxpool2d_eval_into(&x.data, &x.shape, 2, 2, 0, &mut mout);
        assert_bits_eq(&mout, &mp.data);
        // layernorm eval
        let lx = t(&[3, 8], &rng.uniform_vec(24, -1.0, 1.0));
        let lg = t(&[8], &rng.uniform_vec(8, 0.5, 1.5));
        let lb = t(&[8], &rng.uniform_vec(8, -0.5, 0.5));
        let (ln, _, _, _) = layernorm(&lx, &lg, &lb, 1e-5);
        let mut lnout = vec![0.0f32; 24];
        layernorm_eval_into(&lx.data, 8, &lg, &lb, 1e-5, &mut lnout);
        assert_bits_eq(&lnout, &ln.data);
        // batch_matmul
        let a = t(&[2, 3, 4], &rng.uniform_vec(24, -1.0, 1.0));
        let bb = t(&[2, 4, 5], &rng.uniform_vec(40, -1.0, 1.0));
        let mm = batch_matmul(&a, &bb);
        let mut mmout = vec![1.0f32; mm.numel()];
        batch_matmul_into(&a.data, &a.shape, &bb.data, &bb.shape, &mut mmout);
        assert_bits_eq(&mmout, &mm.data);
        // softmax + transpose
        let sm = softmax_lastdim(&a);
        let mut smout = vec![0.0f32; 24];
        softmax_lastdim_into(&a.data, 4, &mut smout);
        assert_bits_eq(&smout, &sm.data);
        let tr = transpose(&a, &[2, 0, 1]);
        let mut trout = vec![0.0f32; 24];
        transpose_into(&a.data, &a.shape, &[2, 0, 1], &mut trout);
        assert_bits_eq(&trout, &tr.data);
    }

    #[test]
    fn matmul_small() {
        let a = t(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let b = t(&[3, 2], &[7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let (m, k, n) = (1 + rng.below(20), 1 + rng.below(20), 1 + rng.below(20));
            let a = Tensor::new(vec![m, k], rng.uniform_vec(m * k, -1.0, 1.0));
            let b = Tensor::new(vec![k, n], rng.uniform_vec(k * n, -1.0, 1.0));
            let c = matmul(&a, &b);
            let mut naive = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    for p in 0..k {
                        naive[i * n + j] += a.data[i * k + p] * b.data[p * n + j];
                    }
                }
            }
            assert_allclose(&c, &Tensor::new(vec![m, n], naive), 1e-4, 1e-4);
        }
    }

    #[test]
    fn linear_matches_matmul() {
        let mut rng = Rng::new(3);
        let x = Tensor::new(vec![4, 6], rng.uniform_vec(24, -1.0, 1.0));
        let w = Tensor::new(vec![5, 6], rng.uniform_vec(30, -1.0, 1.0));
        let y = linear(&x, &w, None);
        let y2 = matmul(&x, &w.t2());
        assert_allclose(&y, &y2, 1e-5, 1e-5);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weight = passthrough
        let x = t(&[1, 2, 2, 2], &[1., 2., 3., 4., 5., 6., 7., 8.]);
        let w = t(&[2, 2, 1, 1], &[1., 0., 0., 1.]);
        let y = conv2d(&x, &w, None, 1, 0, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_known_3x3() {
        // single channel 3x3 input, 3x3 averaging-ish kernel, pad 1
        let x = t(&[1, 1, 3, 3], &[1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        let w = Tensor::ones(&[1, 1, 3, 3]);
        let y = conv2d(&x, &w, None, 1, 1, 1);
        assert_eq!(y.shape, vec![1, 1, 3, 3]);
        // center = sum of all = 45
        assert_eq!(y.data[4], 45.0);
        // top-left = 1+2+4+5 = 12
        assert_eq!(y.data[0], 12.0);
    }

    #[test]
    fn conv_stride_and_shape() {
        let x = Tensor::ones(&[2, 3, 8, 8]);
        let w = Tensor::ones(&[4, 3, 3, 3]);
        let y = conv2d(&x, &w, None, 2, 1, 1);
        assert_eq!(y.shape, vec![2, 4, 4, 4]);
    }

    #[test]
    fn grouped_conv_matches_blockdiag() {
        // groups=2 conv equals two independent convs concatenated
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![1, 4, 5, 5], rng.uniform_vec(100, -1.0, 1.0));
        let w = Tensor::new(vec![6, 2, 3, 3], rng.uniform_vec(6 * 2 * 9, -1.0, 1.0));
        let y = conv2d(&x, &w, None, 1, 1, 2);
        // manual: first group = x[:, :2] conv w[:3], second = x[:, 2:] conv w[3:]
        let x1 = x.take_indices(1, &[0, 1]);
        let x2 = x.take_indices(1, &[2, 3]);
        let w1 = w.take_indices(0, &[0, 1, 2]);
        let w2 = w.take_indices(0, &[3, 4, 5]);
        let y1 = conv2d(&x1, &w1, None, 1, 1, 1);
        let y2 = conv2d(&x2, &w2, None, 1, 1, 1);
        let y1c = y.take_indices(1, &[0, 1, 2]);
        let y2c = y.take_indices(1, &[3, 4, 5]);
        assert_allclose(&y1c, &y1, 1e-5, 1e-5);
        assert_allclose(&y2c, &y2, 1e-5, 1e-5);
    }

    #[test]
    fn depthwise_conv() {
        let x = t(&[1, 2, 2, 2], &[1., 2., 3., 4., 10., 20., 30., 40.]);
        let w = t(&[2, 1, 1, 1], &[2., 3.]);
        let y = conv2d(&x, &w, None, 1, 0, 2);
        assert_eq!(y.data, vec![2., 4., 6., 8., 30., 60., 90., 120.]);
    }

    #[test]
    fn conv_backward_gradcheck() {
        let mut rng = Rng::new(7);
        let x = Tensor::new(vec![1, 2, 4, 4], rng.uniform_vec(32, -1.0, 1.0));
        let w = Tensor::new(vec![3, 2, 3, 3], rng.uniform_vec(54, -0.5, 0.5));
        let dy = Tensor::ones(&[1, 3, 4, 4]);
        let (dx, dw, _db) = conv2d_backward(&x, &w, &dy, 1, 1, 1);
        // finite-difference check a few coordinates
        let f = |x: &Tensor, w: &Tensor| conv2d(x, w, None, 1, 1, 1).sum();
        let eps = 1e-3;
        for &i in &[0usize, 7, 31] {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (f(&xp, &w) - f(&xm, &w)) / (2.0 * eps);
            assert!((num - dx.data[i]).abs() < 2e-2, "dx[{i}]: {num} vs {}", dx.data[i]);
        }
        for &i in &[0usize, 20, 53] {
            let mut wp = w.clone();
            wp.data[i] += eps;
            let mut wm = w.clone();
            wm.data[i] -= eps;
            let num = (f(&x, &wp) - f(&x, &wm)) / (2.0 * eps);
            assert!((num - dw.data[i]).abs() < 2e-2, "dw[{i}]: {num} vs {}", dw.data[i]);
        }
    }

    #[test]
    fn maxpool_and_backward() {
        let x = t(&[1, 1, 2, 2], &[1., 5., 3., 2.]);
        let (y, arg) = maxpool2d(&x, 2, 2, 0);
        assert_eq!(y.data, vec![5.0]);
        let dy = t(&[1, 1, 1, 1], &[2.0]);
        let dx = maxpool2d_backward(&dy, &arg, 4);
        assert_eq!(dx.data, vec![0., 2., 0., 0.]);
    }

    #[test]
    fn avgpool_known() {
        let x = t(&[1, 1, 2, 2], &[1., 2., 3., 4.]);
        let y = avgpool2d(&x, 2, 2, 0);
        assert_eq!(y.data, vec![2.5]);
        let dx = avgpool2d_backward(&t(&[1, 1, 1, 1], &[4.0]), &[1, 1, 2, 2], 2, 2, 0);
        assert_eq!(dx.data, vec![1., 1., 1., 1.]);
    }

    #[test]
    fn global_avgpool_roundtrip() {
        let x = t(&[1, 2, 1, 2], &[1., 3., 10., 30.]);
        let y = global_avgpool(&x);
        assert_eq!(y.shape, vec![1, 2]);
        assert_eq!(y.data, vec![2., 20.]);
        let dx = global_avgpool_backward(&y, &[1, 2, 1, 2]);
        assert_eq!(dx.data, vec![1., 1., 10., 10.]);
    }

    #[test]
    fn batchnorm_train_normalizes() {
        let mut rng = Rng::new(9);
        let x = Tensor::new(vec![4, 3, 2, 2], rng.uniform_vec(48, -3.0, 7.0));
        let g = Tensor::ones(&[3]);
        let b = Tensor::zeros(&[3]);
        let (y, _m, _v, _xh) = batchnorm_train(&x, &g, &b, 1e-5);
        // per-channel mean ≈ 0, var ≈ 1
        for ch in 0..3 {
            let mut vals = Vec::new();
            for img in 0..4 {
                let base = (img * 3 + ch) * 4;
                vals.extend_from_slice(&y.data[base..base + 4]);
            }
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let var: f32 =
                vals.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / vals.len() as f32;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn batchnorm_backward_gradcheck() {
        let mut rng = Rng::new(10);
        let x = Tensor::new(vec![2, 2, 2, 2], rng.uniform_vec(16, -1.0, 1.0));
        let g = Tensor::new(vec![2], vec![1.5, 0.7]);
        let b = Tensor::new(vec![2], vec![0.1, -0.2]);
        let dy = Tensor::new(vec![2, 2, 2, 2], rng.uniform_vec(16, -1.0, 1.0));
        let (_y, _m, v, xh) = batchnorm_train(&x, &g, &b, 1e-5);
        let (dx, dgamma, dbeta) = batchnorm_backward(&dy, &xh, &g, &v, 1e-5);
        let f = |x: &Tensor| {
            let (y, _, _, _) = batchnorm_train(x, &g, &b, 1e-5);
            y.data.iter().zip(&dy.data).map(|(&a, &b)| a * b).sum::<f32>()
        };
        let eps = 1e-3;
        for &i in &[0usize, 5, 15] {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!(
                (num - dx.data[i]).abs() < 5e-2,
                "dx[{i}]: {num} vs {}",
                dx.data[i]
            );
        }
        assert_eq!(dgamma.numel(), 2);
        assert_eq!(dbeta.numel(), 2);
    }

    #[test]
    fn layernorm_backward_gradcheck() {
        let mut rng = Rng::new(11);
        let x = Tensor::new(vec![3, 5], rng.uniform_vec(15, -1.0, 1.0));
        let g = Tensor::new(vec![5], rng.uniform_vec(5, 0.5, 1.5));
        let b = Tensor::zeros(&[5]);
        let dy = Tensor::new(vec![3, 5], rng.uniform_vec(15, -1.0, 1.0));
        let (_y, _m, inv, xh) = layernorm(&x, &g, &b, 1e-5);
        let (dx, _dg, _db) = layernorm_backward(&dy, &xh, &g, &inv);
        let f = |x: &Tensor| {
            let (y, _, _, _) = layernorm(x, &g, &b, 1e-5);
            y.data.iter().zip(&dy.data).map(|(&a, &b)| a * b).sum::<f32>()
        };
        let eps = 1e-3;
        for &i in &[0usize, 7, 14] {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!((num - dx.data[i]).abs() < 5e-2, "dx[{i}]");
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(12);
        let x = Tensor::new(vec![4, 7], rng.uniform_vec(28, -5.0, 5.0));
        let y = softmax_lastdim(&x);
        for r in 0..4 {
            let s: f32 = y.data[r * 7..(r + 1) * 7].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn cross_entropy_uniform() {
        let logits = Tensor::zeros(&[2, 4]);
        let (loss, dl) = cross_entropy(&logits, &[0, 3]);
        assert!((loss - (4.0f32).ln()).abs() < 1e-5);
        assert_eq!(dl.shape, vec![2, 4]);
        // gradient rows sum to zero
        for r in 0..2 {
            let s: f32 = dl.data[r * 4..(r + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn accuracy_and_topk() {
        let logits = t(&[2, 3], &[0.1, 0.9, 0.0, 0.8, 0.1, 0.3]);
        assert_eq!(accuracy(&logits, &[1, 0]), 1.0);
        assert_eq!(accuracy(&logits, &[0, 0]), 0.5);
        assert_eq!(topk_accuracy(&logits, &[2, 2], 1), 0.0);
        assert_eq!(topk_accuracy(&logits, &[0, 2], 2), 1.0);
    }

    #[test]
    fn embedding_and_backward() {
        let ids = t(&[1, 3], &[0., 2., 0.]);
        let table = t(&[3, 2], &[1., 2., 3., 4., 5., 6.]);
        let y = embedding(&ids, &table);
        assert_eq!(y.shape, vec![1, 3, 2]);
        assert_eq!(y.data, vec![1., 2., 5., 6., 1., 2.]);
        let dy = Tensor::ones(&[1, 3, 2]);
        let dt = embedding_backward(&ids, &dy, &[3, 2]);
        assert_eq!(dt.data, vec![2., 2., 0., 0., 1., 1.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(13);
        let x = Tensor::new(vec![2, 3, 4], rng.uniform_vec(24, -1.0, 1.0));
        let perm = vec![2, 0, 1];
        let y = transpose(&x, &perm);
        assert_eq!(y.shape, vec![4, 2, 3]);
        let back = transpose(&y, &inverse_perm(&perm));
        assert_eq!(back, x);
    }

    #[test]
    fn batch_matmul_matches_loop() {
        let mut rng = Rng::new(14);
        let a = Tensor::new(vec![2, 3, 4], rng.uniform_vec(24, -1.0, 1.0));
        let b = Tensor::new(vec![2, 4, 5], rng.uniform_vec(40, -1.0, 1.0));
        let c = batch_matmul(&a, &b);
        assert_eq!(c.shape, vec![2, 3, 5]);
        for bi in 0..2 {
            let am = Tensor::new(vec![3, 4], a.data[bi * 12..(bi + 1) * 12].to_vec());
            let bm = Tensor::new(vec![4, 5], b.data[bi * 20..(bi + 1) * 20].to_vec());
            let cm = matmul(&am, &bm);
            assert_eq!(&c.data[bi * 15..(bi + 1) * 15], &cm.data[..]);
        }
    }
}
