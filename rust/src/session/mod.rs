//! `spa::session` — one entry point for structured pruning at any time.
//!
//! The paper's four-step procedure (§3.2: couple → group → estimate →
//! prune) used to be threaded by hand through free functions
//! (`build_groups` → `score_groups` → `select_*` → `apply_pruning`) at
//! every call site. [`Session`] packages it as a staged builder, shared
//! by all three prune-time pipelines (§3.3) and open to user-defined
//! criteria through the [`crate::criteria::Saliency`] trait:
//!
//! ```no_run
//! use spa::criteria::Criterion;
//! use spa::{Session, Target};
//! # fn main() -> anyhow::Result<()> {
//! let model = spa::zoo::resnet18(spa::zoo::ImageCfg::default(), 42);
//! let plan = Session::on(&model)          // 1-2. couple + group
//!     .criterion(Criterion::L1)           // 3. importance: S of Eq. 1
//!     .target(Target::FlopsRf(2.0))       //    select toward ~2x FLOPs
//!     .plan()?;                           //    (inspectable, not applied)
//! println!("{} CCs selected, predicted RF {:.2}x", plan.num_selected(), plan.achieved_rf);
//! let pruned = plan.apply()?;             // 4. physical pruning
//! pruned.graph.validate()?;
//! # Ok(()) }
//! ```
//!
//! Staging is enforced at runtime: [`Session::plan`] fails with a clear
//! error when no criterion was set, or when a gradient-based criterion
//! was given no [`Session::batch`]. The intermediate [`Plan`] exposes
//! per-CC scores, the selected coupled-channel sets, and the achieved
//! reduction ratios ([`Plan::achieved_rf`] / [`Plan::achieved_rp`]) —
//! including whether an unreachable target was clamped to the feasible
//! maximum — while the session's own graph stays untouched.

use crate::analysis;
use crate::check::CheckLevel;
use crate::criteria::{Batch, Saliency, SaliencyRef};
use crate::ir::Graph;
use crate::prune::{
    self, build_groups, score_groups_scoped, select_by_metric_target, select_lowest,
    select_lowest_n, Agg, GroupScore, Groups, Norm, Scope,
};
use crate::tensor::Tensor;

/// What the selection bisects toward.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Target {
    /// FLOPs reduction ratio `RF = FLOPs_before / FLOPs_after` (paper
    /// App. B.2, Eq. 15). The paper's "~2× settings" are `FlopsRf(2.0)`.
    FlopsRf(f64),
    /// Parameter reduction ratio `RP = params_before / params_after`
    /// (Eq. 16).
    ParamsRp(f64),
    /// Remove this fraction of all prunable coupled-channel sets.
    Sparsity(f64),
    /// Remove exactly this many coupled-channel sets (fewer when
    /// `min_keep` makes the budget infeasible).
    ChannelBudget(usize),
}

/// Staged pruning-session builder — see the [module docs](self).
///
/// Defaults: `Target::FlopsRf(2.0)`, `Scope::FullCc`, `Agg::Sum`,
/// `Norm::Mean`, `min_keep = 1`. The criterion has no default; `plan()`
/// without one is a staging error.
pub struct Session<'g> {
    graph: &'g Graph,
    criterion: Option<SaliencyRef>,
    batch: Option<(Tensor, Vec<usize>)>,
    target: Target,
    scope: Scope,
    agg: Agg,
    norm: Norm,
    min_keep: usize,
    check: CheckLevel,
}

impl<'g> Session<'g> {
    /// Start a session on `graph`. The graph is only borrowed and never
    /// modified; [`Plan::apply`] returns a pruned clone.
    pub fn on(graph: &'g Graph) -> Session<'g> {
        Session {
            graph,
            criterion: None,
            batch: None,
            target: Target::FlopsRf(2.0),
            scope: Scope::FullCc,
            agg: Agg::Sum,
            norm: Norm::Mean,
            min_keep: 1,
            check: CheckLevel::default(),
        }
    }

    /// Set the saliency criterion (required). Accepts any built-in
    /// [`crate::criteria::Criterion`], a [`SaliencyRef`] from
    /// `Criterion::parse`, or a user [`crate::criteria::Saliency`] impl.
    pub fn criterion(mut self, criterion: impl Into<SaliencyRef>) -> Self {
        self.criterion = Some(criterion.into());
        self
    }

    /// Supply a labelled batch for gradient-based criteria (SNIP, GraSP,
    /// CroP, Taylor, Fisher, ...).
    pub fn batch(mut self, x: Tensor, labels: Vec<usize>) -> Self {
        self.batch = Some((x, labels));
        self
    }

    /// Set the selection target (default `Target::FlopsRf(2.0)`).
    pub fn target(mut self, target: Target) -> Self {
        self.target = target;
        self
    }

    /// Score over the full coupled set (SPA-grouped, the default) or the
    /// source filter only (the classic "structured" baselines).
    pub fn scope(mut self, scope: Scope) -> Self {
        self.scope = scope;
        self
    }

    /// Eq. 1 aggregation over a coupled set (default `Agg::Sum`).
    pub fn agg(mut self, agg: Agg) -> Self {
        self.agg = agg;
        self
    }

    /// Eq. 1 within-group normalization (default `Norm::Mean`).
    pub fn norm(mut self, norm: Norm) -> Self {
        self.norm = norm;
        self
    }

    /// Minimum surviving CCs per group (default 1).
    pub fn min_keep(mut self, min_keep: usize) -> Self {
        self.min_keep = min_keep;
        self
    }

    /// Static-check level for the pruned result (default
    /// [`CheckLevel::default`]: `Debug` under debug assertions, `Off` in
    /// release). When enabled, [`Session::plan`] audits the pruned clone
    /// with [`crate::check::check_pruned`] (every coupled group kept the
    /// same channel set) and [`crate::check::check_graph`] before handing
    /// it out.
    pub fn check(mut self, check: CheckLevel) -> Self {
        self.check = check;
        self
    }

    /// Run steps 1-3 (couple, group, estimate) and the selection toward
    /// the target, producing an inspectable [`Plan`]. The session graph
    /// is never modified; the plan pre-computes the pruned clone that
    /// [`Plan::apply`] hands out.
    pub fn plan(self) -> anyhow::Result<Plan> {
        let criterion = self.criterion.ok_or_else(|| {
            anyhow::anyhow!(
                "Session::plan called before .criterion(..): set a saliency \
                 criterion first (e.g. .criterion(Criterion::L1))"
            )
        })?;
        let batch = self
            .batch
            .as_ref()
            .map(|(x, labels)| Batch { x, labels: labels.as_slice() });
        anyhow::ensure!(
            !(criterion.needs_data() && batch.is_none()),
            "criterion `{}` needs a data batch: call .batch(x, labels) before .plan()",
            criterion.name()
        );
        let param_scores = criterion.score(self.graph, batch.as_ref())?;
        let groups = build_groups(self.graph)?;
        let scores = score_groups_scoped(
            self.graph,
            &groups,
            &param_scores,
            self.agg,
            self.norm,
            self.scope,
        );
        let (selected, clamped) = match self.target {
            Target::FlopsRf(rf) => {
                anyhow::ensure!(rf >= 1.0, "FLOPs target RF must be >= 1.0 (got {rf})");
                let flops = |m: &Graph| analysis::flops(m) as f64;
                let keep = self.min_keep;
                let t = select_by_metric_target(self.graph, &groups, &scores, rf, keep, flops)?;
                (t.selected, t.clamped)
            }
            Target::ParamsRp(rp) => {
                anyhow::ensure!(rp >= 1.0, "params target RP must be >= 1.0 (got {rp})");
                let params = |m: &Graph| analysis::params(m) as f64;
                let keep = self.min_keep;
                let t = select_by_metric_target(self.graph, &groups, &scores, rp, keep, params)?;
                (t.selected, t.clamped)
            }
            Target::Sparsity(frac) => {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&frac),
                    "sparsity must be in [0, 1] (got {frac})"
                );
                let want = ((scores.len() as f64) * frac).round() as usize;
                let sel = select_lowest(&groups, &scores, frac, self.min_keep);
                let clamped = sel.len() < want;
                (sel, clamped)
            }
            Target::ChannelBudget(n) => {
                let sel = select_lowest_n(&groups, &scores, n, self.min_keep);
                let clamped = sel.len() < n;
                (sel, clamped)
            }
        };
        // Materialize the pruned clone once; `apply` hands out copies.
        let _span = crate::obs::trace::span_with("session.prune", || {
            format!("{} ({} CCs)", criterion.name(), selected.len())
        });
        let t0 = std::time::Instant::now();
        let mut pruned = self.graph.clone();
        let outcome = prune::apply_pruning(&mut pruned, &groups, &selected)?;
        if self.check.enabled() {
            crate::check::check_pruned(self.graph, &groups, &selected, &pruned)?;
            crate::check::check_graph(&pruned)?;
        }
        let prune_seconds = t0.elapsed().as_secs_f64();
        let r = analysis::reduction(self.graph, &pruned);
        Ok(Plan {
            criterion: criterion.name().to_string(),
            target: self.target,
            groups,
            scores,
            selected,
            pruned,
            ccs_removed: outcome.ccs_removed,
            prune_seconds,
            achieved_rf: r.rf,
            achieved_rp: r.rp,
            clamped,
        })
    }
}

/// An inspectable pruning plan: scores, selection, and the achieved
/// reductions — produced by [`Session::plan`], consumed by
/// [`Plan::apply`]. Owns its data (including the pre-computed pruned
/// graph), so it does not borrow the session graph.
pub struct Plan {
    criterion: String,
    target: Target,
    groups: Groups,
    scores: Vec<GroupScore>,
    selected: Vec<(usize, usize)>,
    pruned: Graph,
    ccs_removed: usize,
    prune_seconds: f64,
    /// FLOPs reduction this plan achieves when applied.
    pub achieved_rf: f64,
    /// Parameter reduction this plan achieves when applied.
    pub achieved_rp: f64,
    /// True when the requested target was unreachable under `min_keep`
    /// and the selection was clamped to the feasible maximum (for
    /// `Sparsity`/`ChannelBudget`: fewer CCs selected than requested).
    pub clamped: bool,
}

impl Plan {
    /// Name of the criterion that scored this plan.
    pub fn criterion(&self) -> &str {
        &self.criterion
    }

    /// The target the selection was bisected toward.
    pub fn target(&self) -> Target {
        self.target
    }

    /// The discovered coupled-channel groups (paper Alg. 2).
    pub fn groups(&self) -> &Groups {
        &self.groups
    }

    /// Per-CC importance scores (Eq. 1), one entry per prunable CC.
    pub fn scores(&self) -> &[GroupScore] {
        &self.scores
    }

    /// The `(group, cc)` pairs selected for removal, ascending by score.
    pub fn selected(&self) -> &[(usize, usize)] {
        &self.selected
    }

    pub fn num_selected(&self) -> usize {
        self.selected.len()
    }

    pub fn num_groups(&self) -> usize {
        self.groups.groups.len()
    }

    pub fn num_prunable_ccs(&self) -> usize {
        self.groups.num_prunable_ccs()
    }

    /// Step 4: hand out the pruned model (the physical pruning ran once
    /// at [`Session::plan`] time; this copies the stored result, so it
    /// cannot fail and may be called repeatedly).
    pub fn apply(&self) -> anyhow::Result<PrunedModel> {
        Ok(PrunedModel {
            graph: self.pruned.clone(),
            report: PruneReport {
                criterion: self.criterion.clone(),
                ccs_removed: self.ccs_removed,
                rf: self.achieved_rf,
                rp: self.achieved_rp,
                seconds: self.prune_seconds,
            },
        })
    }

    /// Dismantle the plan into its groups and selection, for algorithms
    /// that edit weights between planning and deletion (OBSPA's OBS
    /// reconstruction) and then call `prune::apply_pruning` themselves.
    pub fn into_parts(self) -> (Groups, Vec<(usize, usize)>) {
        (self.groups, self.selected)
    }

    /// Derive a [`crate::ir::GraphPatch`] that rewrites `base` into this
    /// plan's pruned graph. Structured pruning slices channels out of
    /// parameter tensors but never rewrites topology, so the patch is
    /// parameter-edits-only — exactly the localized diff
    /// [`crate::exec::Plan::recompile`] and the serve layer's live swap
    /// consume. `base` must be the graph this session planned against
    /// (or an identically-shaped clone, e.g. a serving plan's private
    /// copy); a topology mismatch is an error, not a bigger patch.
    pub fn as_patch(&self, base: &Graph) -> anyhow::Result<crate::ir::GraphPatch> {
        anyhow::ensure!(
            base.ops.len() == self.pruned.ops.len()
                && base.datas.len() == self.pruned.datas.len(),
            "pruned graph's topology differs from the base ({} ops / {} datas vs {} / {})",
            self.pruned.ops.len(),
            self.pruned.datas.len(),
            base.ops.len(),
            base.datas.len()
        );
        for (a, b) in base.ops.iter().zip(&self.pruned.ops) {
            anyhow::ensure!(
                a.name == b.name && a.inputs == b.inputs && a.outputs == b.outputs,
                "op `{}` was rewired between base and pruned graph — \
                 as_patch requires identical topology",
                a.name
            );
        }
        let mut p = crate::ir::GraphPatch::new(
            format!("re-prune:{}:rf{:.2}", self.criterion, self.achieved_rf),
            base,
        );
        for (db, dp) in base.datas.iter().zip(&self.pruned.datas) {
            match (db.param(), dp.param()) {
                (Some(old), Some(new)) => {
                    let same = old.shape == new.shape
                        && old
                            .data
                            .iter()
                            .zip(&new.data)
                            .all(|(x, y)| x.to_bits() == y.to_bits());
                    if !same {
                        p.set_param(db.id, new.clone());
                    }
                }
                (None, None) => {}
                _ => anyhow::bail!(
                    "data `{}` changed kind between base and pruned graph",
                    db.name
                ),
            }
        }
        Ok(p)
    }
}

/// The output of [`Plan::apply`]: the pruned graph plus its report.
pub struct PrunedModel {
    pub graph: Graph,
    pub report: PruneReport,
}

impl PrunedModel {
    /// Compile the pruned graph into a reusable [`crate::exec::Plan`] —
    /// the serving path that actually cashes in the FLOPs reduction.
    /// Bit-identical to interpreting the graph in eval mode; see
    /// [`crate::exec`] for the execution model.
    pub fn compile(&self) -> anyhow::Result<crate::exec::Plan> {
        crate::exec::Plan::compile(&self.graph, crate::exec::PlanOpts::default())
    }

    /// [`PrunedModel::compile`] with explicit [`crate::exec::PlanOpts`]
    /// (optimization level, retained activations).
    pub fn compile_with(&self, opts: crate::exec::PlanOpts) -> anyhow::Result<crate::exec::Plan> {
        crate::exec::Plan::compile(&self.graph, opts)
    }
}

/// What a [`Plan::apply`] did, in the paper's metrics.
#[derive(Debug, Clone)]
pub struct PruneReport {
    /// Criterion name the selection was scored with.
    pub criterion: String,
    /// Coupled-channel sets physically removed.
    pub ccs_removed: usize,
    /// FLOPs reduction ratio (Eq. 15).
    pub rf: f64,
    /// Parameter reduction ratio (Eq. 16).
    pub rp: f64,
    /// Wallclock of the physical pruning (measured when the plan was
    /// built).
    pub seconds: f64,
}

impl PruneReport {
    /// A stable string identifying the prune configuration this report
    /// describes, for use in a [`PlanKey`]. Two identically-configured
    /// prunes of the same model produce the same tag; the unpruned
    /// baseline uses the empty tag.
    pub fn cache_tag(&self) -> String {
        format!(
            "{}:cc{}:rf{:.4}:rp{:.4}",
            self.criterion, self.ccs_removed, self.rf, self.rp
        )
    }
}

/// Process-global plan-cache key: `(model, prune config, OptLevel)`.
/// The serve layer compiles one [`crate::exec::Plan`] per distinct key
/// and shares it across requests (see `crate::serve::PlanCache`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Zoo model name (or any caller-chosen model identifier).
    pub model: String,
    /// Prune-configuration tag from [`PruneReport::cache_tag`]; empty
    /// for the unpruned baseline.
    pub prune: String,
    /// Optimization level the plan was compiled at.
    pub level: crate::exec::OptLevel,
}

impl PlanKey {
    /// Key for an unpruned model at `level`.
    pub fn baseline(model: &str, level: crate::exec::OptLevel) -> PlanKey {
        PlanKey {
            model: model.to_string(),
            prune: String::new(),
            level,
        }
    }

    /// Key for a pruned model, deriving the prune tag from its report.
    pub fn pruned(model: &str, report: &PruneReport, level: crate::exec::OptLevel) -> PlanKey {
        PlanKey {
            model: model.to_string(),
            prune: report.cache_tag(),
            level,
        }
    }
}

impl std::fmt::Display for PlanKey {
    /// The form serve-layer error paths print — enough to tell two
    /// cache entries for the same model apart in a log line.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "model `{}` at {:?}", self.model, self.level)?;
        if !self.prune.is_empty() {
            write!(f, " (prune {})", self.prune)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::criteria::Criterion;
    use crate::zoo::{self, ImageCfg};

    fn mini() -> Graph {
        zoo::resnet18(
            ImageCfg {
                hw: 8,
                ..Default::default()
            },
            3,
        )
    }

    #[test]
    fn plan_matches_free_function_pipeline() {
        // the session must be a pure repackaging: identical scores and
        // selection to the hand-threaded four-step calls
        let g = mini();
        let plan = Session::on(&g)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(1.7))
            .plan()
            .unwrap();
        let groups = build_groups(&g).unwrap();
        let l1 = Criterion::L1.score(&g, None).unwrap();
        let scores =
            prune::score_groups(&g, &groups, &l1, Agg::Sum, Norm::Mean);
        let sel =
            prune::select_by_flops_target(&g, &groups, &scores, 1.7, 1).unwrap();
        assert_eq!(plan.selected(), sel.as_slice());
        assert_eq!(plan.scores().len(), scores.len());
        for (a, b) in plan.scores().iter().zip(&scores) {
            assert_eq!((a.group, a.cc), (b.group, b.cc));
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
    }

    #[test]
    fn pruned_model_compiles_to_matching_plan() {
        use crate::engine;
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let g = mini();
        let pruned = Session::on(&g)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(1.6))
            .plan()
            .unwrap()
            .apply()
            .unwrap();
        let plan = pruned.compile().unwrap();
        let mut rng = Rng::new(11);
        let shape = pruned.graph.data(pruned.graph.inputs[0]).shape.clone();
        let n: usize = shape.iter().product();
        let x = Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0));
        let want = engine::predict(&pruned.graph, x.clone()).unwrap();
        let got = plan.predict(&x).unwrap();
        assert_eq!(want.shape, got.shape);
        for (a, b) in want.data.iter().zip(&got.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn plan_keys_distinguish_prune_configs_and_levels() {
        use crate::exec::OptLevel;
        let g = mini();
        let mk = |rf: f64| {
            Session::on(&g)
                .criterion(Criterion::L1)
                .target(Target::FlopsRf(rf))
                .plan()
                .unwrap()
                .apply()
                .unwrap()
        };
        let a = mk(1.5);
        let b = mk(1.9);
        let ka = PlanKey::pruned("resnet18", &a.report, OptLevel::Exact);
        let kb = PlanKey::pruned("resnet18", &b.report, OptLevel::Exact);
        assert_ne!(ka, kb, "different prune configs must key differently");
        assert_eq!(ka, PlanKey::pruned("resnet18", &a.report, OptLevel::Exact));
        assert_ne!(ka, PlanKey::pruned("resnet18", &a.report, OptLevel::Fast));
        let base = PlanKey::baseline("resnet18", OptLevel::Exact);
        assert!(base.prune.is_empty());
        assert_ne!(base, ka);
    }

    #[test]
    fn strict_checks_accept_a_clean_prune() {
        // .check(Strict) must be invisible on a healthy pipeline: same
        // selection, same result, no error
        let g = mini();
        let plan = Session::on(&g)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(1.5))
            .check(CheckLevel::Strict)
            .plan()
            .unwrap();
        let pruned = plan.apply().unwrap();
        crate::check::check_graph(&pruned.graph).unwrap();
    }

    #[test]
    fn as_patch_reproduces_the_pruned_graph() {
        use crate::engine;
        use crate::tensor::Tensor;
        use crate::util::Rng;
        let g = mini();
        let plan = Session::on(&g)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(1.6))
            .plan()
            .unwrap();
        let pruned = plan.apply().unwrap();
        let patch = plan.as_patch(&g).unwrap();
        assert!(!patch.is_empty());
        let mut patched = g.clone();
        let rep = patch.apply(&mut patched).unwrap();
        assert_eq!(
            rep.added_ops + rep.removed_ops + rep.rewired,
            0,
            "a re-prune patch is parameter edits only"
        );
        assert!(rep.param_edits > 0);
        assert_eq!(patched.num_params(), pruned.graph.num_params());
        let mut rng = Rng::new(21);
        let shape = patched.data(patched.inputs[0]).shape.clone();
        let n: usize = shape.iter().product();
        let x = Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0));
        let a = engine::predict(&patched, x.clone()).unwrap();
        let b = engine::predict(&pruned.graph, x).unwrap();
        assert_eq!(a.shape, b.shape);
        for (p, q) in a.data.iter().zip(&b.data) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
        // the incremental recompile path accepts the derived patch
        let base_plan =
            crate::exec::Plan::compile(&g, crate::exec::PlanOpts::default()).unwrap();
        let inc = base_plan
            .recompile(&patched, &rep, crate::exec::PlanOpts::default())
            .unwrap();
        let fresh =
            crate::exec::Plan::compile(&patched, crate::exec::PlanOpts::default()).unwrap();
        assert!(inc.report().recompiled_regions >= 1);
        let shape = patched.data(patched.inputs[0]).shape.clone();
        let n: usize = shape.iter().product();
        let x = Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0));
        let yi = inc.predict(&x).unwrap();
        let yf = fresh.predict(&x).unwrap();
        for (p, q) in yi.data.iter().zip(&yf.data) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }

    #[test]
    fn as_patch_rejects_a_mismatched_base() {
        let g = mini();
        let plan = Session::on(&g)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(1.5))
            .plan()
            .unwrap();
        let other = zoo::vgg16(
            ImageCfg {
                hw: 8,
                ..Default::default()
            },
            3,
        );
        let err = plan.as_patch(&other).unwrap_err().to_string();
        assert!(err.contains("topology differs"), "got: {err}");
    }

    #[test]
    fn apply_reports_match_prediction() {
        let g = mini();
        let plan = Session::on(&g)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(1.5))
            .plan()
            .unwrap();
        let pruned = plan.apply().unwrap();
        pruned.graph.validate().unwrap();
        assert_eq!(pruned.report.ccs_removed, plan.num_selected());
        assert!((pruned.report.rf - plan.achieved_rf).abs() < 1e-9);
        assert!((pruned.report.rp - plan.achieved_rp).abs() < 1e-9);
        assert_eq!(pruned.report.criterion, "l1");
    }
}
