//! SPA-IR interpreter with reverse-mode autodiff.
//!
//! This is the substrate the paper obtains by converting ONNX models back
//! to PyTorch (§3.3): a framework that can run *pruned* graphs of any
//! shape forward (evaluation, calibration, BN recalibration) and backward
//! (gradient-based criteria, fine-tuning, prune-train). It interprets the
//! computational graph directly — no conversion step can desynchronize
//! the pruned structure from the executed model.
//!
//! Fixed-shape *unpruned* models additionally run through the PJRT
//! artifact path (`crate::runtime`); an integration test cross-checks the
//! two executors' numerics.

use crate::ir::{DataId, DataKind, Graph, OpId, OpKind};
use crate::tensor::{ops, Tensor};
use std::collections::HashMap;

/// Execution mode: `Train` uses batch statistics in BatchNorm (and records
/// them for running-stat updates); `Eval` uses running statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Train,
    Eval,
}

/// Per-op saved state needed by the backward pass.
#[derive(Debug, Clone)]
enum Aux {
    None,
    MaxPool { argmax: Vec<usize> },
    BatchNorm { xhat: Tensor, var: Tensor, mean: Tensor },
    LayerNorm { xhat: Tensor, inv_stds: Vec<f32> },
    Softmax { y: Tensor },
    Act { x: Tensor },
}

/// Result of a forward pass: every computed data-node value plus backward
/// state.
pub struct Forward {
    /// Value per data id for graph inputs and activations. Parameters are
    /// *not* copied here — they are read from the graph on demand (see
    /// [`value_or_param`]); cloning every parameter tensor per call made
    /// the interpreter's fixed cost proportional to model size.
    pub values: Vec<Option<Tensor>>,
    aux: HashMap<OpId, Aux>,
    mode: Mode,
}

impl Forward {
    /// The computed value of an input or activation node. Panics for
    /// parameter nodes (read those from the graph, or use
    /// [`value_or_param`]).
    pub fn value(&self, id: DataId) -> &Tensor {
        self.values[id]
            .as_ref()
            .unwrap_or_else(|| panic!("data {id} not computed (params live on the graph)"))
    }

    /// The first graph output (logits for classifiers).
    pub fn logits<'a>(&'a self, g: &Graph) -> &'a Tensor {
        self.value(g.outputs[0])
    }
}

/// Gradients from a backward pass.
pub struct Grads {
    /// d loss / d data-node for every reached node.
    pub by_data: HashMap<DataId, Tensor>,
}

impl Grads {
    pub fn param_grad(&self, id: DataId) -> Option<&Tensor> {
        self.by_data.get(&id)
    }
}

use crate::tensor::ops::{gelu, gelu_grad};

/// Resolve a data id to its value: computed activations/inputs come from
/// the forward pass, parameters from the graph (never copied).
pub fn value_or_param<'a>(g: &'a Graph, fwd: &'a Forward, id: DataId) -> &'a Tensor {
    fwd.values[id]
        .as_ref()
        .or_else(|| g.datas[id].param())
        .unwrap_or_else(|| panic!("data `{}` has no value", g.datas[id].name))
}

/// Broadcast-expand `b` to shape `a_shape` (channel/row semantics of
/// `crate::ir::shape::broadcast_ok`).
fn broadcast_to(a_shape: &[usize], b: &Tensor) -> Tensor {
    if b.shape == a_shape {
        return b.clone();
    }
    let mut out = Tensor::zeros(a_shape);
    if b.rank() == 1 {
        let c = b.numel();
        match a_shape.len() {
            2 => {
                for i in 0..a_shape[0] {
                    for j in 0..c {
                        out.data[i * c + j] = b.data[j];
                    }
                }
            }
            3 => {
                let rows = a_shape[0] * a_shape[1];
                for i in 0..rows {
                    for j in 0..c {
                        out.data[i * c + j] = b.data[j];
                    }
                }
            }
            4 => {
                let inner = a_shape[2] * a_shape[3];
                for img in 0..a_shape[0] {
                    for ch in 0..c {
                        let base = (img * c + ch) * inner;
                        for i in 0..inner {
                            out.data[base + i] = b.data[ch];
                        }
                    }
                }
            }
            _ => panic!("unsupported broadcast"),
        }
    } else if b.rank() == 4 && b.shape[2] == 1 && b.shape[3] == 1 {
        let inner = a_shape[2] * a_shape[3];
        for i in 0..b.numel() {
            for j in 0..inner {
                out.data[i * inner + j] = b.data[i];
            }
        }
    } else if b.rank() == 2 && a_shape.len() == 4 {
        // [N,C] gate over [N,C,H,W]
        let inner = a_shape[2] * a_shape[3];
        for i in 0..b.numel() {
            for j in 0..inner {
                out.data[i * inner + j] = b.data[i];
            }
        }
    } else if b.rank() == 3 && b.shape[0] == 1 {
        let block = b.numel();
        for img in 0..a_shape[0] {
            out.data[img * block..(img + 1) * block].copy_from_slice(&b.data);
        }
    } else {
        panic!("unsupported broadcast {:?} -> {:?}", b.shape, a_shape);
    }
    out
}

/// Reduce a full-shaped gradient back to the broadcast operand's shape.
fn reduce_to(b_shape: &[usize], g: &Tensor) -> Tensor {
    if b_shape == g.shape.as_slice() {
        return g.clone();
    }
    let mut out = Tensor::zeros(b_shape);
    if b_shape.len() == 1 {
        let c = b_shape[0];
        match g.rank() {
            2 => {
                for i in 0..g.shape[0] {
                    for j in 0..c {
                        out.data[j] += g.data[i * c + j];
                    }
                }
            }
            3 => {
                let rows = g.shape[0] * g.shape[1];
                for i in 0..rows {
                    for j in 0..c {
                        out.data[j] += g.data[i * c + j];
                    }
                }
            }
            4 => {
                let inner = g.shape[2] * g.shape[3];
                for img in 0..g.shape[0] {
                    for ch in 0..c {
                        let base = (img * c + ch) * inner;
                        out.data[ch] += g.data[base..base + inner].iter().sum::<f32>();
                    }
                }
            }
            _ => panic!("unsupported reduce"),
        }
    } else if (b_shape.len() == 4 && b_shape[2] == 1 && b_shape[3] == 1)
        || (b_shape.len() == 2 && g.rank() == 4)
    {
        let inner = g.shape[2] * g.shape[3];
        for i in 0..out.numel() {
            out.data[i] = g.data[i * inner..(i + 1) * inner].iter().sum::<f32>();
        }
    } else if b_shape.len() == 3 && b_shape[0] == 1 {
        let block: usize = b_shape.iter().product();
        for img in 0..g.shape[0] {
            for i in 0..block {
                out.data[i] += g.data[img * block + i];
            }
        }
    } else {
        panic!("unsupported reduce {:?} -> {:?}", g.shape, b_shape);
    }
    out
}

/// Run the graph forward. `feeds` binds graph-input data ids to values;
/// batch size may differ from the recorded nominal shape (all shape-
/// dependent ops re-derive from actual tensors).
pub fn forward(g: &Graph, feeds: &[(DataId, Tensor)], mode: Mode) -> anyhow::Result<Forward> {
    let mut values: Vec<Option<Tensor>> = vec![None; g.datas.len()];
    for (id, t) in feeds {
        anyhow::ensure!(
            matches!(g.datas[*id].kind, DataKind::Input),
            "feed target `{}` is not an input",
            g.datas[*id].name
        );
        values[*id] = Some(t.clone());
    }
    let mut aux: HashMap<OpId, Aux> = HashMap::new();
    for op_id in g.topo_order()? {
        let op = &g.ops[op_id];
        // Params are borrowed straight from the graph; only activations
        // and feeds live in `values`.
        let ins: Vec<&Tensor> = op
            .inputs
            .iter()
            .map(|&i| {
                values[i]
                    .as_ref()
                    .or_else(|| g.datas[i].param())
                    .ok_or_else(|| anyhow::anyhow!("missing input to `{}`", op.name))
            })
            .collect::<anyhow::Result<_>>()?;
        let (out, a) = eval_op(&op.kind, &ins, mode)?;
        values[op.outputs[0]] = Some(out);
        if !matches!(a, Aux::None) {
            aux.insert(op_id, a);
        }
    }
    Ok(Forward { values, aux, mode })
}

/// Evaluate one operator on already-resolved inputs, discarding backward
/// state — used by the constant-folding pass in `crate::ir::passes`.
pub(crate) fn eval_op_value(kind: &OpKind, ins: &[&Tensor], mode: Mode) -> anyhow::Result<Tensor> {
    Ok(eval_op(kind, ins, mode)?.0)
}

fn eval_op(kind: &OpKind, ins: &[&Tensor], mode: Mode) -> anyhow::Result<(Tensor, Aux)> {
    Ok(match kind {
        OpKind::Conv2d { stride, pad, groups } => (
            ops::conv2d(ins[0], ins[1], ins.get(2).copied(), *stride, *pad, *groups),
            Aux::None,
        ),
        OpKind::Gemm => (ops::linear(ins[0], ins[1], ins.get(2).copied()), Aux::None),
        OpKind::BatchNorm { eps } => match mode {
            Mode::Eval => (
                ops::batchnorm_infer(ins[0], ins[1], ins[2], ins[3], ins[4], *eps),
                Aux::None,
            ),
            Mode::Train => {
                let (y, mean, var, xhat) = ops::batchnorm_train(ins[0], ins[1], ins[2], *eps);
                (y, Aux::BatchNorm { xhat, var, mean })
            }
        },
        OpKind::LayerNorm { eps } => {
            let (y, _m, inv_stds, xhat) = ops::layernorm(ins[0], ins[1], ins[2], *eps);
            (y, Aux::LayerNorm { xhat, inv_stds })
        }
        OpKind::Relu => (
            ins[0].map(|v| v.max(0.0)),
            Aux::Act { x: ins[0].clone() },
        ),
        OpKind::Gelu => (ins[0].map(gelu), Aux::Act { x: ins[0].clone() }),
        OpKind::Silu => (
            ins[0].map(|v| v / (1.0 + (-v).exp())),
            Aux::Act { x: ins[0].clone() },
        ),
        OpKind::Sigmoid => (
            ins[0].map(|v| 1.0 / (1.0 + (-v).exp())),
            Aux::Act { x: ins[0].clone() },
        ),
        OpKind::Tanh => (ins[0].map(f32::tanh), Aux::Act { x: ins[0].clone() }),
        OpKind::Add => {
            let b = broadcast_to(&ins[0].shape, ins[1]);
            (ins[0].add(&b), Aux::None)
        }
        OpKind::Mul => {
            let b = broadcast_to(&ins[0].shape, ins[1]);
            (ins[0].mul(&b), Aux::None)
        }
        OpKind::MaxPool2d { k, stride, pad } => {
            let (y, argmax) = ops::maxpool2d(ins[0], *k, *stride, *pad);
            (y, Aux::MaxPool { argmax })
        }
        OpKind::AvgPool2d { k, stride, pad } => {
            (ops::avgpool2d(ins[0], *k, *stride, *pad), Aux::None)
        }
        OpKind::GlobalAvgPool => (ops::global_avgpool(ins[0]), Aux::None),
        OpKind::Flatten => {
            let n = ins[0].shape[0];
            let rest: usize = ins[0].shape[1..].iter().product();
            (ins[0].reshaped(vec![n, rest]), Aux::None)
        }
        OpKind::Concat { axis } => {
            let shapes: Vec<&[usize]> = ins.iter().map(|t| t.shape.as_slice()).collect();
            let mut out_shape = shapes[0].to_vec();
            out_shape[*axis] = shapes.iter().map(|s| s[*axis]).sum();
            let outer: usize = out_shape[..*axis].iter().product();
            let inner: usize = out_shape[*axis + 1..].iter().product();
            let mut out = Vec::with_capacity(out_shape.iter().product());
            for o in 0..outer {
                for t in ins {
                    let d = t.shape[*axis];
                    let base = o * d * inner;
                    out.extend_from_slice(&t.data[base..base + d * inner]);
                }
            }
            (Tensor::new(out_shape, out), Aux::None)
        }
        OpKind::Softmax => {
            let y = ops::softmax_lastdim(ins[0]);
            (y.clone(), Aux::Softmax { y })
        }
        OpKind::MatMul => (ops::batch_matmul(ins[0], ins[1]), Aux::None),
        OpKind::Transpose { perm } => (ops::transpose(ins[0], perm), Aux::None),
        OpKind::SplitHeads { heads } => {
            let (n, t, d) = (ins[0].shape[0], ins[0].shape[1], ins[0].shape[2]);
            let r = ins[0].reshaped(vec![n, t, *heads, d / heads]);
            (ops::transpose(&r, &[0, 2, 1, 3]), Aux::None)
        }
        OpKind::MergeHeads => {
            let (n, h, t, d) = (
                ins[0].shape[0],
                ins[0].shape[1],
                ins[0].shape[2],
                ins[0].shape[3],
            );
            let tr = ops::transpose(ins[0], &[0, 2, 1, 3]);
            (tr.reshaped(vec![n, t, h * d]), Aux::None)
        }
        OpKind::Scale { c } => (ins[0].scale(*c), Aux::None),
        OpKind::Embedding => (ops::embedding(ins[0], ins[1]), Aux::None),
        OpKind::ReduceMean { axis } => {
            let x = ins[0];
            let outer: usize = x.shape[..*axis].iter().product();
            let d = x.shape[*axis];
            let inner: usize = x.shape[*axis + 1..].iter().product();
            let mut out = vec![0.0f32; outer * inner];
            let inv = 1.0 / d as f32;
            for o in 0..outer {
                for k in 0..d {
                    for i in 0..inner {
                        out[o * inner + i] += x.data[(o * d + k) * inner + i] * inv;
                    }
                }
            }
            let shape: Vec<usize> = x
                .shape
                .iter()
                .enumerate()
                .filter(|(i, _)| i != axis)
                .map(|(_, &v)| v)
                .collect();
            (Tensor::new(shape, out), Aux::None)
        }
        OpKind::NchwToTokens => {
            // [N,C,H,W] → [N,HW,C]
            let t = ops::transpose(ins[0], &[0, 2, 3, 1]);
            let (n, h, w, c) = (
                ins[0].shape[0],
                ins[0].shape[2],
                ins[0].shape[3],
                ins[0].shape[1],
            );
            (t.reshaped(vec![n, h * w, c]), Aux::None)
        }
        OpKind::Identity => (ins[0].clone(), Aux::None),
    })
}

/// Reverse pass: seed gradients at `out_grads` (usually dLoss/dLogits on
/// the graph output) and propagate to every parameter and input.
pub fn backward(g: &Graph, fwd: &Forward, out_grads: &[(DataId, Tensor)]) -> anyhow::Result<Grads> {
    let mut by_data: HashMap<DataId, Tensor> = HashMap::new();
    for (id, t) in out_grads {
        by_data.insert(*id, t.clone());
    }
    let order = g.topo_order()?;
    for &op_id in order.iter().rev() {
        let op = &g.ops[op_id];
        let out_id = op.outputs[0];
        let dy = match by_data.get(&out_id) {
            Some(t) => t.clone(),
            None => continue, // output unused by the loss
        };
        let ins: Vec<&Tensor> = op
            .inputs
            .iter()
            .map(|&i| value_or_param(g, fwd, i))
            .collect();
        let aux = fwd.aux.get(&op_id).unwrap_or(&Aux::None);
        let din = backprop_op(&op.kind, &ins, &dy, aux, fwd.mode)?;
        for (slot, grad) in din.into_iter().enumerate() {
            if let Some(grad) = grad {
                let id = op.inputs[slot];
                match by_data.get_mut(&id) {
                    Some(acc) => *acc = acc.add(&grad),
                    None => {
                        by_data.insert(id, grad);
                    }
                }
            }
        }
    }
    Ok(Grads { by_data })
}

/// Per-op VJP: returns one optional gradient per positional input.
fn backprop_op(
    kind: &OpKind,
    ins: &[&Tensor],
    dy: &Tensor,
    aux: &Aux,
    mode: Mode,
) -> anyhow::Result<Vec<Option<Tensor>>> {
    Ok(match kind {
        OpKind::Conv2d { stride, pad, groups } => {
            let (dx, dw, db) = ops::conv2d_backward(ins[0], ins[1], dy, *stride, *pad, *groups);
            let mut out = vec![Some(dx), Some(dw)];
            if ins.len() > 2 {
                out.push(Some(db));
            }
            out
        }
        OpKind::Gemm => {
            // x [rows,K] w [N,K]: dx = dy·w ; dw = dyᵀ·x ; db = Σ dy
            let k = ins[0].dim(-1);
            let rows = ins[0].numel() / k;
            let n = ins[1].shape[0];
            let x2 = ins[0].reshaped(vec![rows, k]);
            let dy2 = dy.reshaped(vec![rows, n]);
            let dx = ops::matmul(&dy2, ins[1]).reshaped(ins[0].shape.clone());
            let dw = ops::matmul(&dy2.t2(), &x2);
            let mut out = vec![Some(dx), Some(dw)];
            if ins.len() > 2 {
                let mut db = vec![0.0f32; n];
                for r in 0..rows {
                    for j in 0..n {
                        db[j] += dy2.data[r * n + j];
                    }
                }
                out.push(Some(Tensor::new(vec![n], db)));
            }
            out
        }
        OpKind::BatchNorm { eps } => match (mode, aux) {
            (Mode::Train, Aux::BatchNorm { xhat, var, .. }) => {
                let (dx, dgamma, dbeta) = ops::batchnorm_backward(dy, xhat, ins[1], var, *eps);
                vec![Some(dx), Some(dgamma), Some(dbeta), None, None]
            }
            _ => {
                // eval-mode BN is an affine map per channel
                let c = ins[0].shape[1];
                let inner: usize = ins[0].shape[2..].iter().product();
                let nimg = ins[0].shape[0];
                let mut dx = Tensor::zeros(&ins[0].shape);
                let mut dgamma = vec![0.0f32; c];
                let mut dbeta = vec![0.0f32; c];
                for img in 0..nimg {
                    for ch in 0..c {
                        let inv_std = 1.0 / (ins[4].data[ch] + eps).sqrt();
                        let scale = ins[1].data[ch] * inv_std;
                        let base = (img * c + ch) * inner;
                        for i in 0..inner {
                            dx.data[base + i] = dy.data[base + i] * scale;
                            dgamma[ch] += dy.data[base + i]
                                * (ins[0].data[base + i] - ins[3].data[ch])
                                * inv_std;
                            dbeta[ch] += dy.data[base + i];
                        }
                    }
                }
                vec![
                    Some(dx),
                    Some(Tensor::new(vec![c], dgamma)),
                    Some(Tensor::new(vec![c], dbeta)),
                    None,
                    None,
                ]
            }
        },
        OpKind::LayerNorm { .. } => {
            if let Aux::LayerNorm { xhat, inv_stds } = aux {
                let (dx, dgamma, dbeta) = ops::layernorm_backward(dy, xhat, ins[1], inv_stds);
                vec![Some(dx), Some(dgamma), Some(dbeta)]
            } else {
                anyhow::bail!("layernorm missing aux")
            }
        }
        OpKind::Relu => {
            let x = match aux {
                Aux::Act { x } => x,
                _ => ins[0],
            };
            vec![Some(dy.zip(x, |g, v| if v > 0.0 { g } else { 0.0 }))]
        }
        OpKind::Gelu => {
            let x = match aux {
                Aux::Act { x } => x,
                _ => ins[0],
            };
            vec![Some(dy.zip(x, |g, v| g * gelu_grad(v)))]
        }
        OpKind::Silu => {
            let x = match aux {
                Aux::Act { x } => x,
                _ => ins[0],
            };
            vec![Some(dy.zip(x, |g, v| {
                let s = 1.0 / (1.0 + (-v).exp());
                g * (s + v * s * (1.0 - s))
            }))]
        }
        OpKind::Sigmoid => {
            let x = match aux {
                Aux::Act { x } => x,
                _ => ins[0],
            };
            vec![Some(dy.zip(x, |g, v| {
                let s = 1.0 / (1.0 + (-v).exp());
                g * s * (1.0 - s)
            }))]
        }
        OpKind::Tanh => {
            let x = match aux {
                Aux::Act { x } => x,
                _ => ins[0],
            };
            vec![Some(dy.zip(x, |g, v| {
                let t = v.tanh();
                g * (1.0 - t * t)
            }))]
        }
        OpKind::Add => {
            let db = reduce_to(&ins[1].shape, dy);
            vec![Some(dy.clone()), Some(db)]
        }
        OpKind::Mul => {
            let b_full = broadcast_to(&ins[0].shape, ins[1]);
            let da = dy.mul(&b_full);
            let db_full = dy.mul(ins[0]);
            let db = reduce_to(&ins[1].shape, &db_full);
            vec![Some(da), Some(db)]
        }
        OpKind::MaxPool2d { .. } => {
            if let Aux::MaxPool { argmax } = aux {
                let dx = ops::maxpool2d_backward(dy, argmax, ins[0].numel());
                vec![Some(dx.reshaped(ins[0].shape.clone()))]
            } else {
                anyhow::bail!("maxpool missing aux")
            }
        }
        OpKind::AvgPool2d { k, stride, pad } => {
            vec![Some(ops::avgpool2d_backward(dy, &ins[0].shape, *k, *stride, *pad))]
        }
        OpKind::GlobalAvgPool => {
            vec![Some(ops::global_avgpool_backward(dy, &ins[0].shape))]
        }
        OpKind::Flatten => vec![Some(dy.reshaped(ins[0].shape.clone()))],
        OpKind::Concat { axis } => {
            let out_shape_axis: usize = ins.iter().map(|t| t.shape[*axis]).sum();
            let outer: usize = ins[0].shape[..*axis].iter().product();
            let inner: usize = ins[0].shape[*axis + 1..].iter().product();
            let mut grads: Vec<Option<Tensor>> = Vec::with_capacity(ins.len());
            let mut offset = 0usize;
            for t in ins {
                let d = t.shape[*axis];
                let mut gdat = Vec::with_capacity(t.numel());
                for o in 0..outer {
                    let base = (o * out_shape_axis + offset) * inner;
                    gdat.extend_from_slice(&dy.data[base..base + d * inner]);
                }
                grads.push(Some(Tensor::new(t.shape.clone(), gdat)));
                offset += d;
            }
            grads
        }
        OpKind::Softmax => {
            if let Aux::Softmax { y } = aux {
                vec![Some(ops::softmax_backward(dy, y))]
            } else {
                anyhow::bail!("softmax missing aux")
            }
        }
        OpKind::MatMul => {
            // y = a·b: da = dy·bᵀ, db = aᵀ·dy (batched)
            let rank = ins[0].rank();
            let mut perm: Vec<usize> = (0..rank).collect();
            perm.swap(rank - 1, rank - 2);
            let bt = ops::transpose(ins[1], &perm);
            let at = ops::transpose(ins[0], &perm);
            vec![
                Some(ops::batch_matmul(dy, &bt)),
                Some(ops::batch_matmul(&at, dy)),
            ]
        }
        OpKind::Transpose { perm } => {
            vec![Some(ops::transpose(dy, &ops::inverse_perm(perm)))]
        }
        OpKind::SplitHeads { .. } => {
            // forward: [N,T,D] -> reshape -> transpose(0,2,1,3)
            let tr = ops::transpose(dy, &[0, 2, 1, 3]);
            vec![Some(tr.reshaped(ins[0].shape.clone()))]
        }
        OpKind::MergeHeads => {
            let (n, h, t, d) = (
                ins[0].shape[0],
                ins[0].shape[1],
                ins[0].shape[2],
                ins[0].shape[3],
            );
            let r = dy.reshaped(vec![n, t, h, d]);
            vec![Some(ops::transpose(&r, &[0, 2, 1, 3]))]
        }
        OpKind::Scale { c } => vec![Some(dy.scale(*c))],
        OpKind::Embedding => {
            let dt = ops::embedding_backward(ins[0], dy, &ins[1].shape);
            vec![None, Some(dt)]
        }
        OpKind::ReduceMean { axis } => {
            let x = ins[0];
            let outer: usize = x.shape[..*axis].iter().product();
            let d = x.shape[*axis];
            let inner: usize = x.shape[*axis + 1..].iter().product();
            let inv = 1.0 / d as f32;
            let mut dx = Tensor::zeros(&x.shape);
            for o in 0..outer {
                for k in 0..d {
                    for i in 0..inner {
                        dx.data[(o * d + k) * inner + i] = dy.data[o * inner + i] * inv;
                    }
                }
            }
            vec![Some(dx)]
        }
        OpKind::NchwToTokens => {
            let (n, c, h, w) = (
                ins[0].shape[0],
                ins[0].shape[1],
                ins[0].shape[2],
                ins[0].shape[3],
            );
            let r = dy.reshaped(vec![n, h, w, c]);
            vec![Some(ops::transpose(&r, &[0, 3, 1, 2]))]
        }
        OpKind::Identity => vec![Some(dy.clone())],
    })
}

/// Update BatchNorm running statistics from a training forward pass
/// (momentum-EMA, PyTorch semantics).
pub fn update_bn_stats(g: &mut Graph, fwd: &Forward, momentum: f32) {
    for op in 0..g.ops.len() {
        if let Some(Aux::BatchNorm { mean, var, .. }) = fwd.aux.get(&op) {
            let (mean, var) = (mean.clone(), var.clone());
            let mean_id = g.ops[op].inputs[3];
            let var_id = g.ops[op].inputs[4];
            if let Some(rm) = g.datas[mean_id].param_mut() {
                for (r, &b) in rm.data.iter_mut().zip(&mean.data) {
                    *r = (1.0 - momentum) * *r + momentum * b;
                }
            }
            if let Some(rv) = g.datas[var_id].param_mut() {
                for (r, &b) in rv.data.iter_mut().zip(&var.data) {
                    *r = (1.0 - momentum) * *r + momentum * b;
                }
            }
        }
    }
}

/// Convenience: eval-mode logits for a batch of images/ids.
pub fn predict(g: &Graph, x: Tensor) -> anyhow::Result<Tensor> {
    let fwd = forward(g, &[(g.inputs[0], x)], Mode::Eval)?;
    Ok(fwd.logits(g).clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::tensor::assert_allclose;
    use crate::util::Rng;

    fn small_cnn() -> Graph {
        let mut b = GraphBuilder::new("cnn", 7);
        let x = b.input("x", vec![2, 3, 8, 8]);
        let c1 = b.conv2d("c1", x, 8, 3, 1, 1, 1, true);
        let n1 = b.batchnorm("bn1", c1);
        let r1 = b.relu("r1", n1);
        let c2 = b.conv2d("c2", r1, 8, 3, 1, 1, 1, false);
        let n2 = b.batchnorm("bn2", c2);
        let s = b.add("res", n2, r1);
        let r2 = b.relu("r2", s);
        let p = b.maxpool2d("mp", r2, 2, 2, 0);
        let g = b.global_avgpool("gap", p);
        let out = b.gemm("fc", g, 5, true);
        b.output(out);
        b.finish().unwrap()
    }

    #[test]
    fn forward_shapes() {
        let g = small_cnn();
        let mut rng = Rng::new(1);
        let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 3 * 64, -1.0, 1.0));
        let fwd = forward(&g, &[(g.inputs[0], x)], Mode::Eval).unwrap();
        assert_eq!(fwd.logits(&g).shape, vec![2, 5]);
    }

    #[test]
    fn batch_size_flexible() {
        // nominal batch is 2; run with 5
        let g = small_cnn();
        let mut rng = Rng::new(2);
        let x = Tensor::new(vec![5, 3, 8, 8], rng.uniform_vec(5 * 3 * 64, -1.0, 1.0));
        let fwd = forward(&g, &[(g.inputs[0], x)], Mode::Eval).unwrap();
        assert_eq!(fwd.logits(&g).shape, vec![5, 5]);
    }

    #[test]
    fn end_to_end_gradcheck() {
        // numerical gradient of sum(logits·seed) w.r.t. a few params
        let g = small_cnn();
        let mut rng = Rng::new(3);
        let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 3 * 64, -0.5, 0.5));
        let seed = Tensor::new(vec![2, 5], rng.uniform_vec(10, -1.0, 1.0));
        let loss = |g: &Graph| {
            let fwd = forward(g, &[(g.inputs[0], x.clone())], Mode::Train).unwrap();
            fwd.logits(g)
                .data
                .iter()
                .zip(&seed.data)
                .map(|(&a, &b)| a * b)
                .sum::<f32>()
        };
        let fwd = forward(&g, &[(g.inputs[0], x.clone())], Mode::Train).unwrap();
        let grads = backward(&g, &fwd, &[(g.outputs[0], seed.clone())]).unwrap();
        // check conv1 weight, fc weight, bn gamma
        for pname in ["c1.w", "fc.w", "bn1.gamma"] {
            let pid = g.data_by_name(pname).unwrap().id;
            let analytic = grads.param_grad(pid).unwrap().clone();
            let idxs = [0usize, analytic.numel() / 2];
            for &i in &idxs {
                let eps = 1e-2;
                let mut gp = g.clone();
                gp.datas[pid].param_mut().unwrap().data[i] += eps;
                let mut gm = g.clone();
                gm.datas[pid].param_mut().unwrap().data[i] -= eps;
                let num = (loss(&gp) - loss(&gm)) / (2.0 * eps);
                let ana = analytic.data[i];
                assert!(
                    (num - ana).abs() < 0.05 * (1.0 + ana.abs()),
                    "{pname}[{i}]: numeric {num} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn bn_stats_update() {
        let mut g = small_cnn();
        let mut rng = Rng::new(4);
        let x = Tensor::new(vec![2, 3, 8, 8], rng.uniform_vec(2 * 3 * 64, 1.0, 3.0));
        let before = g.data_by_name("bn1.mean").unwrap().param().unwrap().clone();
        let fwd = forward(&g, &[(g.inputs[0], x)], Mode::Train).unwrap();
        update_bn_stats(&mut g, &fwd, 0.5);
        let after = g.data_by_name("bn1.mean").unwrap().param().unwrap().clone();
        assert!(before.l2_dist(&after) > 1e-3, "running mean should move");
    }

    #[test]
    fn transformer_block_runs_and_gradchecks() {
        let mut b = GraphBuilder::new("tf", 5);
        let x = b.input("x", vec![2, 4, 16]);
        let ln = b.layernorm("ln", x);
        let q = b.gemm("q", ln, 16, true);
        let k = b.gemm("k", ln, 16, true);
        let v = b.gemm("v", ln, 16, true);
        let qh = b.split_heads("qh", q, 4);
        let kh = b.split_heads("kh", k, 4);
        let vh = b.split_heads("vh", v, 4);
        let kt = b.transpose("kt", kh, vec![0, 1, 3, 2]);
        let sc = b.matmul("qk", qh, kt);
        let scl = b.scale("scl", sc, 0.5);
        let sm = b.softmax("sm", scl);
        let ctx = b.matmul("av", sm, vh);
        let mh = b.merge_heads("mh", ctx);
        let o = b.gemm("o", mh, 16, true);
        let res = b.add("res", o, x);
        let pooled = b.reduce_mean("pool", res, 1);
        let out = b.gemm("cls", pooled, 3, true);
        b.output(out);
        let g = b.finish().unwrap();
        let mut rng = Rng::new(6);
        let x = Tensor::new(vec![2, 4, 16], rng.uniform_vec(128, -1.0, 1.0));
        let seed = Tensor::new(vec![2, 3], rng.uniform_vec(6, -1.0, 1.0));
        let loss = |g: &Graph| {
            let fwd = forward(g, &[(g.inputs[0], x.clone())], Mode::Train).unwrap();
            fwd.logits(g)
                .data
                .iter()
                .zip(&seed.data)
                .map(|(&a, &b)| a * b)
                .sum::<f32>()
        };
        let fwd = forward(&g, &[(g.inputs[0], x.clone())], Mode::Train).unwrap();
        let grads = backward(&g, &fwd, &[(g.outputs[0], seed.clone())]).unwrap();
        for pname in ["q.w", "o.w", "ln.gamma", "cls.w"] {
            let pid = g.data_by_name(pname).unwrap().id;
            let analytic = grads.param_grad(pid).unwrap().clone();
            let i = analytic.numel() / 3;
            let eps = 1e-2;
            let mut gp = g.clone();
            gp.datas[pid].param_mut().unwrap().data[i] += eps;
            let mut gm = g.clone();
            gm.datas[pid].param_mut().unwrap().data[i] -= eps;
            let num = (loss(&gp) - loss(&gm)) / (2.0 * eps);
            let ana = analytic.data[i];
            assert!(
                (num - ana).abs() < 0.05 * (1.0 + ana.abs()),
                "{pname}[{i}]: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn grouped_and_depthwise_forward() {
        let mut b = GraphBuilder::new("grp", 8);
        let x = b.input("x", vec![1, 8, 6, 6]);
        let g1 = b.conv2d("gconv", x, 8, 3, 1, 1, 4, false);
        let d1 = b.conv2d("dwconv", g1, 8, 3, 1, 1, 8, false);
        let gp = b.global_avgpool("gap", d1);
        let out = b.gemm("fc", gp, 2, true);
        b.output(out);
        let g = b.finish().unwrap();
        let mut rng = Rng::new(9);
        let x = Tensor::new(vec![1, 8, 6, 6], rng.uniform_vec(8 * 36, -1.0, 1.0));
        let y = predict(&g, x).unwrap();
        assert_eq!(y.shape, vec![1, 2]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn broadcast_helpers_inverse() {
        let mut rng = Rng::new(10);
        let b = Tensor::new(vec![6], rng.uniform_vec(6, -1.0, 1.0));
        let full = broadcast_to(&[2, 6, 3, 3], &b);
        assert_eq!(full.shape, vec![2, 6, 3, 3]);
        let back = reduce_to(&[6], &Tensor::ones(&[2, 6, 3, 3]));
        assert_eq!(back.data, vec![18.0; 6]);
        // reduce(broadcast(x)) = x * count
        let r = reduce_to(&[6], &full);
        let expect = b.scale(18.0);
        assert_allclose(&r, &expect, 1e-4, 1e-4);
    }
}
