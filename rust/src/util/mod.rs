//! Self-contained utility substrate: JSON, RNG, benchmarking/tables, a
//! mini property-testing harness, and a deterministic std-thread worker
//! pool. The build environment is offline with a small crate cache (no
//! serde/clap/criterion/proptest/rand/rayon), so these are implemented
//! here and used across the whole library.

pub mod bench;
pub mod json;
pub mod par;
pub mod proptest;
pub mod rng;

pub use bench::{bench, time_once, BenchStats, Table};
pub use json::{parse as parse_json, Json, JsonObj};
pub use rng::Rng;
