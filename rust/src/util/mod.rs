//! Self-contained utility substrate: JSON, RNG, benchmarking/tables, a
//! mini property-testing harness, and a deterministic std-thread worker
//! pool. The build environment is offline with a small crate cache (no
//! serde/clap/criterion/proptest/rand/rayon), so these are implemented
//! here and used across the whole library.

pub mod bench;
pub mod json;
pub mod par;
pub mod proptest;
pub mod rng;

pub use bench::{bench, time_once, BenchStats, Table};
pub use json::{parse as parse_json, Json, JsonObj};
pub use rng::Rng;

/// Lock `m`, recovering from a poisoned mutex instead of panicking.
///
/// A worker that panics while holding a lock (e.g. an injected fault in
/// a serve batch thread) poisons it for every later accessor;
/// `lock().unwrap()` would then cascade that one panic through stats,
/// the plan cache, and the admission queue. Every serve-path lock is a
/// single-step or idempotent write, so the guarded data is still
/// consistent after an unwind and recovery is safe.
pub fn relock<T: ?Sized>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod relock_tests {
    use super::relock;
    use std::sync::{Arc, Mutex};

    #[test]
    fn relock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned(), "the panicking holder must poison the lock");
        assert_eq!(*relock(&m), 7, "relock must still hand out the data");
        *relock(&m) = 8;
        assert_eq!(*relock(&m), 8);
    }
}
