//! Mini property-based testing harness.
//!
//! `proptest` is unavailable offline; this gives the same shape of tests —
//! "for N random inputs drawn from a strategy, the invariant holds, and on
//! failure report the seed that reproduces it" — with deterministic
//! seeding so CI failures replay exactly.

use super::rng::Rng;

/// Run `prop` against `cases` randomly-generated inputs.
///
/// `gen` draws one input from the RNG; `prop` returns `Err(msg)` when the
/// invariant is violated. Panics with the violating seed + message.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, base_seed: u64, gen: G, prop: P)
where
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Strategy helpers for common SPA domains.
pub mod strategies {
    use super::Rng;

    /// A random tensor shape with `rank` dims each in [1, max_dim].
    pub fn shape(rng: &mut Rng, rank: usize, max_dim: usize) -> Vec<usize> {
        (0..rank).map(|_| 1 + rng.below(max_dim)).collect()
    }

    /// Random f32 data of length `n` in [-scale, scale].
    pub fn data(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        rng.uniform_vec(n, -scale, scale)
    }

    /// A random subset of [0, n) of size in [1, n-1] (never empty, never
    /// everything) — the shape of a valid channel prune set.
    pub fn proper_subset(rng: &mut Rng, n: usize) -> Vec<usize> {
        assert!(n >= 2);
        let k = 1 + rng.below(n - 1);
        let mut s = rng.sample_indices(n, k);
        s.sort();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            "sort-idempotent",
            50,
            1,
            |rng| strategies::data(rng, 20, 10.0),
            |xs| {
                let mut a = xs.clone();
                a.sort_by(|x, y| x.partial_cmp(y).unwrap());
                let mut b = a.clone();
                b.sort_by(|x, y| x.partial_cmp(y).unwrap());
                if a == b {
                    Ok(())
                } else {
                    Err("sort not idempotent".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn failing_property_reports_seed() {
        check(
            "always-fails",
            3,
            2,
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn proper_subset_bounds() {
        check(
            "proper-subset",
            100,
            3,
            |rng| {
                let n = 2 + rng.below(30);
                (n, strategies::proper_subset(rng, n))
            },
            |(n, s)| {
                if s.is_empty() || s.len() >= *n {
                    return Err(format!("bad size {} of {}", s.len(), n));
                }
                if s.iter().any(|&i| i >= *n) {
                    return Err("out of range".into());
                }
                Ok(())
            },
        );
    }
}
