//! Minimal JSON parser and writer.
//!
//! The build sandbox has no `serde`/`serde_json`, so SPA-IR serialization,
//! experiment configs, and frontend dialect files use this self-contained
//! implementation. It supports the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null) and preserves object key
//! insertion order, which keeps graph round-trips deterministic.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys keep a sorted map plus an insertion-order
/// index so serialization is stable and diffs are readable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// JSON object preserving insertion order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    map: BTreeMap<String, Json>,
    order: Vec<String>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<Json>) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.order.push(key.clone());
        }
        self.map.insert(key, value.into());
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.order.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}
impl From<JsonObj> for Json {
    fn from(v: JsonObj) -> Self {
        Json::Obj(v)
    }
}
impl From<&[usize]> for Json {
    fn from(v: &[usize]) -> Self {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}
impl From<&[f32]> for Json {
    fn from(v: &[f32]) -> Self {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Fetch `key` from an object value; error if missing or not an object.
    pub fn field(&self, key: &str) -> anyhow::Result<&Json> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }

    /// Parse a usize-vector field (e.g. tensor shapes).
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("expected number"))
            })
            .collect()
    }

    /// Parse an f32-vector field (e.g. weights).
    pub fn f32_vec(&self) -> anyhow::Result<Vec<f32>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as f32)
                    .ok_or_else(|| anyhow::anyhow!("expected number"))
            })
            .collect()
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serializes to compact JSON text (`Json::to_string` comes from this
/// impl via [`ToString`]).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse JSON text into a [`Json`] value.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of json"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected `{}` got `{}` at byte {}",
                b as char,
                got as char,
                self.pos - 1
            );
        }
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of json"),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid keyword at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number `{s}` at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => anyhow::bail!("bad escape `\\{}`", other as char),
                },
                _ => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let len = if b >= 0xf0 {
                            4
                        } else if b >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        self.pos = start + len;
                        s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                    }
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                other => anyhow::bail!("expected `,` or `]`, got `{}`", other as char),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(obj)),
                other => anyhow::bail!("expected `,` or `}}`, got `{}`", other as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c\nd"}], "e": null}"#).unwrap();
        let obj = v.as_obj().unwrap();
        let arr = obj.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(
            arr[2].as_obj().unwrap().get("b").unwrap().as_str().unwrap(),
            "c\nd"
        );
        assert_eq!(obj.get("e"), Some(&Json::Null));
    }

    #[test]
    fn round_trip() {
        let cases = [
            r#"{"shape":[1,3,32,32],"name":"conv1","nested":{"x":true}}"#,
            r#"[1,2.5,"s",null,false,[]]"#,
            r#"{}"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "case {c}");
        }
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn escapes_round_trip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn usize_and_f32_vec() {
        let v = parse("[1,2,3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
        let v = parse("[1.5,-2]").unwrap();
        assert_eq!(v.f32_vec().unwrap(), vec![1.5, -2.0]);
    }
}
