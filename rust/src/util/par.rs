//! Deterministic std-thread worker pool.
//!
//! The offline sandbox has no `rayon`; this module gives the three hot
//! paths (GEMM/conv in `tensor::ops`, the OBSPA kernels in
//! `runtime::kernels`, per-group scoring in `prune::importance`) a data-
//! parallel substrate built only on `std::thread::scope`.
//!
//! Design constraints, in order:
//!
//! 1. **Bit-identical results at any thread count.** Work is split into
//!    fixed chunks whose outputs are disjoint slices; each chunk performs
//!    exactly the same arithmetic regardless of which worker runs it or
//!    how many workers exist, so `SPA_THREADS=1` and `SPA_THREADS=N`
//!    produce byte-equal tensors (CI relies on this, see
//!    `tests/par_determinism.rs`).
//! 2. **Cheap when the work is small.** Every entry point takes the
//!    serial path when only one worker would be used; callers gate on a
//!    work-size threshold so tiny kernels never pay thread spawn costs.
//!
//! The pool size comes from the `SPA_THREADS` environment variable when
//! set (CI pins `SPA_THREADS=1` for reproducibility), otherwise from
//! [`std::thread::available_parallelism`]. Tests can override it
//! in-process with [`set_threads`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Process-wide override installed by [`set_threads`] (0 = no override).
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Cached `SPA_THREADS` / `available_parallelism` default.
static DEFAULT: OnceLock<usize> = OnceLock::new();

fn default_threads() -> usize {
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("SPA_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// The worker-pool width used for parallel regions.
pub fn max_threads() -> usize {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => default_threads(),
        n => n,
    }
}

/// Override the pool width in-process (tests). `0` restores the
/// `SPA_THREADS` / auto default. Results are bit-identical at any width,
/// so concurrent use from other threads affects only scheduling.
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

/// Run `f` with the pool pinned to `n` workers, then restore the previous
/// override.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let prev = OVERRIDE.swap(n, Ordering::Relaxed);
    let out = f();
    OVERRIDE.store(prev, Ordering::Relaxed);
    out
}

/// Workers for a region with `work_items` independent items: at most one
/// worker per item, never more than the pool width.
pub fn workers_for(work_items: usize) -> usize {
    max_threads().min(work_items.max(1))
}

/// Run `f(i)` for every `i in 0..n` across the pool.
///
/// `f` must keep iterations independent (no shared mutable state beyond
/// what it synchronizes itself). Iterations are claimed from an atomic
/// counter; since each `f(i)` computes the same result wherever it runs,
/// scheduling order cannot change the output.
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    let workers = workers_for(n);
    if workers <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Split `out` into contiguous chunks of `chunk_len` elements and run
/// `f(chunk_index, chunk)` for each, in parallel. The chunking is fixed
/// by `chunk_len` alone, so outputs are identical at any thread count.
pub fn par_chunks_mut<T, F>(out: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = out.len().div_ceil(chunk_len.max(1)).max(1);
    let workers = workers_for(n_chunks);
    if workers <= 1 {
        for (i, chunk) in out.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let queue = Mutex::new(out.chunks_mut(chunk_len).enumerate());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let item = queue.lock().unwrap().next();
                match item {
                    Some((i, chunk)) => f(i, chunk),
                    None => break,
                }
            });
        }
    });
}

/// Parallel map over a slice, preserving order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let mut out: Vec<Option<R>> = Vec::new();
    out.resize_with(items.len(), || None);
    par_chunks_mut(&mut out, 1, |i, slot| {
        slot[0] = Some(f(&items[i]));
    });
    out.into_iter().map(|r| r.expect("par_map slot")).collect()
}

/// Serialize tests that mutate the process-global [`set_threads`]
/// override — the test harness runs tests concurrently in one process,
/// and an override installed by one test must not leak into another's
/// assertions. Used by the unit tests below and
/// `tests/par_determinism.rs`.
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_for_covers_every_index_once() {
        let _serial = test_lock();
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        with_threads(4, || {
            par_for(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_chunks_mut_writes_disjoint_regions() {
        let _serial = test_lock();
        for threads in [1usize, 2, 4, 7] {
            let mut data = vec![0usize; 103];
            with_threads(threads, || {
                par_chunks_mut(&mut data, 10, |ci, chunk| {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = ci * 10 + j;
                    }
                });
            });
            let expect: Vec<usize> = (0..103).collect();
            assert_eq!(data, expect, "threads={threads}");
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let _serial = test_lock();
        let items: Vec<usize> = (0..57).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        let parallel = with_threads(4, || par_map(&items, |&x| x * x + 1));
        assert_eq!(parallel, serial);
    }

    #[test]
    fn with_threads_restores_override() {
        let _serial = test_lock();
        let before = max_threads();
        with_threads(3, || assert_eq!(max_threads(), 3));
        assert_eq!(max_threads(), before);
    }

    #[test]
    fn zero_length_inputs_are_noops() {
        par_for(0, |_| panic!("must not run"));
        let mut empty: [f32; 0] = [];
        par_chunks_mut(&mut empty, 4, |_, _| panic!("must not run"));
        let mapped: Vec<i32> = par_map::<i32, i32, _>(&[], |&x| x);
        assert!(mapped.is_empty());
    }

    #[test]
    fn workers_never_exceed_items() {
        let _serial = test_lock();
        with_threads(16, || {
            assert_eq!(workers_for(3), 3);
            assert_eq!(workers_for(0), 1);
        });
    }
}
