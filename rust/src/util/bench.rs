//! Tiny benchmarking + table-reporting harness.
//!
//! `criterion` is unavailable in the offline sandbox, so `cargo bench`
//! targets use this: warmup + timed iterations with mean/stddev/min, and
//! an ASCII table printer that renders each paper table/figure in the
//! same rows/columns layout the paper reports.

use std::time::Instant;

/// Timing statistics for one benchmark case (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>12.3} ms/iter (±{:.3}, min {:.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.std_ns / 1e6,
            self.min_ns / 1e6,
            self.iters
        )
    }
}

/// Run `f` for `warmup` unmeasured and `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: min,
    };
    println!("{stats}");
    stats
}

/// Time a single invocation, returning (result, seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// ASCII table builder used by every paper-table bench to print the
/// reproduced rows next to the paper's reported numbers.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let stats = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(stats.mean_ns > 0.0);
        assert_eq!(stats.iters, 5);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Tab X", &["model", "acc", "RF"]);
        t.rows_str(&["resnet", "93.2%", "2.1x"]);
        t.rows_str(&["vgg", "91.0%", "2.0x"]);
        let r = t.render();
        assert!(r.contains("Tab X"));
        assert!(r.contains("resnet"));
        assert!(r.lines().filter(|l| l.starts_with('+')).count() == 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.rows_str(&["only-one"]);
    }
}
