//! Tiny benchmarking + table-reporting harness.
//!
//! `criterion` is unavailable in the offline sandbox, so `cargo bench`
//! targets use this: warmup + timed iterations with mean/stddev/min, and
//! an ASCII table printer that renders each paper table/figure in the
//! same rows/columns layout the paper reports.
//!
//! When the `SPA_BENCH_JSON` environment variable names a file, every
//! [`bench`] result is additionally appended to it as a JSON array of
//! `{name, ns_per_iter, iters}` objects — the machine-readable feed CI's
//! bench-smoke lane writes to `BENCH_SMOKE.json` so successive PRs leave
//! a comparable performance trajectory.

use super::json::{self, Json, JsonObj};
use std::time::Instant;

/// Timing statistics for one benchmark case (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>12.3} ms/iter (±{:.3}, min {:.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.std_ns / 1e6,
            self.min_ns / 1e6,
            self.iters
        )
    }
}

/// Run `f` for `warmup` unmeasured and `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: min,
    };
    println!("{stats}");
    record_json(&stats);
    stats
}

/// Append one result to the `SPA_BENCH_JSON` report file (no-op when the
/// variable is unset). Bench binaries run sequentially under
/// `cargo bench`, so read-modify-write of the shared array is safe.
fn record_json(stats: &BenchStats) {
    let Ok(path) = std::env::var("SPA_BENCH_JSON") else {
        return;
    };
    if path.is_empty() {
        return;
    }
    record_json_to(&path, stats);
}

fn record_json_to(path: &str, stats: &BenchStats) {
    let mut entries = match std::fs::read_to_string(path)
        .ok()
        .and_then(|s| json::parse(&s).ok())
    {
        Some(Json::Arr(v)) => v,
        _ => Vec::new(),
    };
    let mut obj = JsonObj::new();
    obj.insert("name", stats.name.as_str());
    obj.insert("ns_per_iter", stats.mean_ns);
    obj.insert("iters", stats.iters as f64);
    entries.push(Json::Obj(obj));
    let _ = std::fs::write(path, Json::Arr(entries).to_string());
}

/// Time a single invocation, returning (result, seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// ASCII table builder used by every paper-table bench to print the
/// reproduced rows next to the paper's reported numbers.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let stats = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(stats.mean_ns > 0.0);
        assert_eq!(stats.iters, 5);
    }

    #[test]
    fn json_report_appends_entries() {
        // drive the writer directly — mutating SPA_BENCH_JSON via
        // set_var would race other threads' getenv under the parallel
        // test harness
        let path = std::env::temp_dir().join(format!("spa_bench_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        for (name, ns) in [("json-probe-a", 10.0), ("json-probe-b", 20.0)] {
            let stats = BenchStats {
                name: name.to_string(),
                iters: 2,
                mean_ns: ns,
                std_ns: 0.0,
                min_ns: ns,
            };
            record_json_to(path.to_str().unwrap(), &stats);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let entries = match json::parse(&text).unwrap() {
            Json::Arr(v) => v,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(entries.len(), 2);
        for (entry, name) in entries.iter().zip(["json-probe-a", "json-probe-b"]) {
            let Json::Obj(o) = entry else { panic!("expected object") };
            assert_eq!(o.get("name"), Some(&Json::Str(name.to_string())));
            match o.get("ns_per_iter") {
                Some(Json::Num(ns)) => assert!(*ns >= 0.0),
                other => panic!("missing ns_per_iter: {other:?}"),
            }
            assert_eq!(o.get("iters"), Some(&Json::Num(2.0)));
        }
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Tab X", &["model", "acc", "RF"]);
        t.rows_str(&["resnet", "93.2%", "2.1x"]);
        t.rows_str(&["vgg", "91.0%", "2.0x"]);
        let r = t.render();
        assert!(r.contains("Tab X"));
        assert!(r.contains("resnet"));
        assert!(r.lines().filter(|l| l.starts_with('+')).count() == 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.rows_str(&["only-one"]);
    }
}
