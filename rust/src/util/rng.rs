//! Deterministic PCG32 random number generator.
//!
//! The sandbox has no `rand` crate; everything stochastic in SPA (weight
//! init, synthetic datasets, random pruning baseline, property tests) is
//! seeded through this generator so experiments are exactly reproducible.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (seed << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-layer / per-shard use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range(lo, hi)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let xs = rng.normal_vec(20_000, 1.0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(5);
        let s = rng.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
