//! Pruning criteria — the `S(θ)` of Eq. 1 (paper App. A.5).
//!
//! Each criterion assigns every parameter element a saliency score; the
//! group-level machinery (`crate::prune::importance`) then aggregates and
//! normalizes them into coupled-channel scores. SPA's claim (§3.3) is
//! that *any* of these transfers to grouped structured pruning through
//! that machinery.
//!
//! The open interface is the [`Saliency`] trait: anything that can map a
//! graph (plus an optional labelled batch) to per-parameter score tensors
//! can drive [`crate::session::Session`]. User-defined criteria are
//! installed with [`register`] and resolved by name through
//! [`Criterion::parse`], exactly like the built-ins:
//!
//! * [`Criterion::L1`] / [`Criterion::L2`] — magnitude (train-prune-finetune),
//! * [`Criterion::Random`] — control baseline,
//! * [`Criterion::Taylor`] — |θ·∂L/∂θ| after training,
//! * [`Criterion::Snip`] — SNIP (Lee et al. 2019), Eq. 4: |g(θ)⊙θ| at init,
//! * [`Criterion::Grasp`] — GraSP (Wang et al. 2020), Eq. 6: −θᵀH g
//!   (gradient-flow preservation; *signed*, lower = keep),
//! * [`Criterion::Crop`] — CroP (Rachwan et al. 2022), Eq. 7: |θᵀH g|,
//! * [`Criterion::Fisher`] — diagonal-Fisher OBD approximation.
//!
//! GraSP/CroP need a Hessian-vector product; with an interpreter-level
//! autodiff we compute `H·g` by central finite differences of the
//! gradient along `g` — two extra backward passes, no second-order tape.

use crate::engine::{self, Mode};
use crate::ir::{DataId, Graph};
use crate::tensor::{ops, Tensor};
use crate::util::Rng;
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex, OnceLock};

/// A saliency criterion: per-parameter importance scores `S(θ)`.
///
/// Implementations return one score tensor per parameter data node, of
/// the parameter's shape (parameters they do not score — e.g. BN running
/// stats — may simply be omitted from the map). Gradient-based criteria
/// report `needs_data() == true` and receive a labelled [`Batch`].
///
/// The trait is object-safe; wrap implementations in a [`SaliencyRef`]
/// (any `impl Saliency` converts via `.into()`) to hand them to
/// [`crate::session::Session::criterion`] or [`register`] them for
/// lookup by name through [`Criterion::parse`].
pub trait Saliency: Send + Sync {
    /// Stable identifier (used by the registry and reports).
    fn name(&self) -> &str;

    /// Does this criterion need a data batch (gradients)?
    fn needs_data(&self) -> bool {
        false
    }

    /// Compute per-parameter scores on `g`. `batch` is `Some` whenever
    /// the caller supplied calibration data; criteria with
    /// `needs_data() == false` may ignore it.
    fn score(
        &self,
        g: &Graph,
        batch: Option<&Batch>,
    ) -> anyhow::Result<HashMap<DataId, Tensor>>;
}

/// A shared, clonable handle to a [`Saliency`] implementation — the
/// currency of [`crate::session::Session`], [`Criterion::parse`], and
/// pipeline configs.
#[derive(Clone)]
pub struct SaliencyRef(Arc<dyn Saliency>);

impl SaliencyRef {
    pub fn new<S: Saliency + 'static>(s: S) -> SaliencyRef {
        SaliencyRef(Arc::new(s))
    }
}

impl std::ops::Deref for SaliencyRef {
    type Target = dyn Saliency;
    fn deref(&self) -> &(dyn Saliency + 'static) {
        &*self.0
    }
}

impl fmt::Debug for SaliencyRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SaliencyRef({})", self.0.name())
    }
}

// Like `anyhow::Error`, `SaliencyRef` itself does NOT implement
// `Saliency`, which is what keeps this blanket conversion coherent.
impl<S: Saliency + 'static> From<S> for SaliencyRef {
    fn from(s: S) -> SaliencyRef {
        SaliencyRef::new(s)
    }
}

/// A saliency built from precomputed per-parameter scores — the bridge
/// for algorithms that derive scores outside the criterion interface
/// (OBSPA's layer-OBS scores, DFPC's BN-gain magnitudes, ...). Each
/// `score()` call hands out a clone of the stored map.
pub struct Precomputed {
    name: String,
    scores: HashMap<DataId, Tensor>,
}

impl Saliency for Precomputed {
    fn name(&self) -> &str {
        &self.name
    }

    fn score(
        &self,
        _g: &Graph,
        _batch: Option<&Batch>,
    ) -> anyhow::Result<HashMap<DataId, Tensor>> {
        Ok(self.scores.clone())
    }
}

/// Wrap an already-computed score map as a [`SaliencyRef`].
pub fn precomputed(
    name: impl Into<String>,
    scores: HashMap<DataId, Tensor>,
) -> SaliencyRef {
    SaliencyRef::new(Precomputed {
        name: name.into(),
        scores,
    })
}

/// The criterion registry: name → saliency. Seeded with the eight
/// built-in [`Criterion`] variants; extended by [`register`].
fn registry() -> &'static Mutex<HashMap<String, SaliencyRef>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, SaliencyRef>>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut m = HashMap::new();
        for c in [
            Criterion::L1,
            Criterion::L2,
            Criterion::Random { seed: 0 },
            Criterion::Taylor,
            Criterion::Snip,
            Criterion::Grasp,
            Criterion::Crop,
            Criterion::Fisher,
        ] {
            m.insert(Criterion::name(&c).to_string(), SaliencyRef::new(c));
        }
        Mutex::new(m)
    })
}

/// Register a user-defined criterion for name-based lookup through
/// [`Criterion::parse`]. Names are process-global; registering a name
/// twice (including shadowing a built-in) is an error.
pub fn register(s: SaliencyRef) -> anyhow::Result<()> {
    let name = s.name().to_string();
    anyhow::ensure!(!name.is_empty(), "criterion name must be non-empty");
    let mut m = registry().lock().unwrap();
    anyhow::ensure!(
        !m.contains_key(&name),
        "criterion `{name}` is already registered"
    );
    m.insert(name, s);
    Ok(())
}

/// Resolve a criterion by registry name (built-in or user-registered).
pub fn resolve(name: &str) -> anyhow::Result<SaliencyRef> {
    let m = registry().lock().unwrap();
    if let Some(s) = m.get(name) {
        return Ok(s.clone());
    }
    let mut known: Vec<&str> = m.keys().map(|k| k.as_str()).collect();
    known.sort_unstable();
    anyhow::bail!("unknown criterion `{name}` (known: {})", known.join(", "))
}

/// Names of every registered criterion, sorted.
pub fn registered_names() -> Vec<String> {
    let m = registry().lock().unwrap();
    let mut v: Vec<String> = m.keys().cloned().collect();
    v.sort_unstable();
    v
}

/// The eight built-in criteria, kept as a plain enum for ergonomic
/// construction (`Criterion::L1`) and as the compatibility shim over the
/// registry ([`Criterion::parse`]). Implements [`Saliency`], so any
/// variant passes directly to [`crate::session::Session::criterion`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Criterion {
    L1,
    L2,
    Random { seed: u64 },
    Taylor,
    Snip,
    Grasp,
    Crop,
    /// Diagonal-Fisher OBD approximation (LeCun et al. 1989, Eq. 10 with
    /// H ≈ diag(g²)): S = θ²·g²/2.
    Fisher,
}

impl Criterion {
    pub fn name(&self) -> &'static str {
        match self {
            Criterion::L1 => "l1",
            Criterion::L2 => "l2",
            Criterion::Random { .. } => "random",
            Criterion::Taylor => "taylor",
            Criterion::Snip => "snip",
            Criterion::Grasp => "grasp",
            Criterion::Crop => "crop",
            Criterion::Fisher => "fisher",
        }
    }

    /// Resolve a criterion by name through the registry — the thin
    /// compatibility shim over [`resolve`]. Returns built-ins as well as
    /// any user-[`register`]ed saliency.
    pub fn parse(s: &str) -> anyhow::Result<SaliencyRef> {
        resolve(s)
    }

    /// Does this criterion need a data batch (gradients)?
    pub fn needs_data(&self) -> bool {
        matches!(
            self,
            Criterion::Taylor
                | Criterion::Snip
                | Criterion::Grasp
                | Criterion::Crop
                | Criterion::Fisher
        )
    }
}

impl Saliency for Criterion {
    fn name(&self) -> &str {
        Criterion::name(self)
    }

    fn needs_data(&self) -> bool {
        Criterion::needs_data(self)
    }

    fn score(
        &self,
        g: &Graph,
        batch: Option<&Batch>,
    ) -> anyhow::Result<HashMap<DataId, Tensor>> {
        param_scores(g, *self, batch)
    }
}

/// A labelled batch for gradient-based criteria.
pub struct Batch<'a> {
    pub x: &'a Tensor,
    pub labels: &'a [usize],
}

/// Gradients of the mean cross-entropy loss w.r.t. all parameters.
fn loss_grads(g: &Graph, batch: &Batch) -> anyhow::Result<HashMap<DataId, Tensor>> {
    let fwd = engine::forward(g, &[(g.inputs[0], batch.x.clone())], Mode::Train)?;
    let logits = fwd.logits(g);
    let (_loss, dlogits) = ops::cross_entropy(logits, batch.labels);
    let grads = engine::backward(g, &fwd, &[(g.outputs[0], dlogits)])?;
    Ok(g.param_ids()
        .into_iter()
        .filter_map(|id| grads.by_data.get(&id).map(|t| (id, t.clone())))
        .collect())
}

/// Hessian-vector product `H·v` by central differences of ∇L along `v`:
/// `H v ≈ (∇L(θ+εv) − ∇L(θ−εv)) / 2ε` with ε scaled to ‖v‖.
fn hessian_vec_product(
    g: &Graph,
    batch: &Batch,
    v: &HashMap<DataId, Tensor>,
) -> anyhow::Result<HashMap<DataId, Tensor>> {
    let vnorm: f32 = v.values().map(|t| t.sq_sum()).sum::<f32>().sqrt();
    let eps = 1e-2 / vnorm.max(1e-8);
    let perturb = |sign: f32| -> Graph {
        let mut gp = g.clone();
        for (&id, dv) in v {
            if let Some(t) = gp.datas[id].param_mut() {
                for (w, &d) in t.data.iter_mut().zip(&dv.data) {
                    *w += sign * eps * d;
                }
            }
        }
        gp
    };
    let gp = loss_grads(&perturb(1.0), batch)?;
    let gm = loss_grads(&perturb(-1.0), batch)?;
    let mut out = HashMap::new();
    for (&id, tp) in &gp {
        if let Some(tm) = gm.get(&id) {
            out.insert(id, tp.sub(tm).scale(1.0 / (2.0 * eps)));
        }
    }
    Ok(out)
}

/// Compute per-parameter scores for a criterion. Gradient-based criteria
/// require `batch`; magnitude criteria ignore it.
pub fn param_scores(
    g: &Graph,
    criterion: Criterion,
    batch: Option<&Batch>,
) -> anyhow::Result<HashMap<DataId, Tensor>> {
    let params = g.param_ids();
    match criterion {
        Criterion::L1 => Ok(params
            .into_iter()
            .map(|id| (id, g.data(id).param().unwrap().map(f32::abs)))
            .collect()),
        Criterion::L2 => Ok(params
            .into_iter()
            .map(|id| (id, g.data(id).param().unwrap().map(|v| v * v)))
            .collect()),
        Criterion::Random { seed } => {
            let mut rng = Rng::new(seed ^ 0xC817_3A2F);
            Ok(params
                .into_iter()
                .map(|id| {
                    let n = g.data(id).param().unwrap().numel();
                    (
                        id,
                        Tensor::new(
                            g.data(id).shape.clone(),
                            rng.uniform_vec(n, 0.0, 1.0),
                        ),
                    )
                })
                .collect())
        }
        Criterion::Fisher => {
            let batch =
                batch.ok_or_else(|| anyhow::anyhow!("{} needs data", criterion.name()))?;
            let grads = loss_grads(g, batch)?;
            Ok(params
                .into_iter()
                .map(|id| {
                    let theta = g.data(id).param().unwrap();
                    let s = match grads.get(&id) {
                        Some(gr) => theta.zip(gr, |t, gg| 0.5 * t * t * gg * gg),
                        None => Tensor::zeros(&theta.shape),
                    };
                    (id, s)
                })
                .collect())
        }
        Criterion::Taylor | Criterion::Snip => {
            let batch =
                batch.ok_or_else(|| anyhow::anyhow!("{} needs data", criterion.name()))?;
            let grads = loss_grads(g, batch)?;
            Ok(params
                .into_iter()
                .map(|id| {
                    let theta = g.data(id).param().unwrap();
                    let s = match grads.get(&id) {
                        Some(gr) => theta.zip(gr, |t, gg| (t * gg).abs()),
                        None => Tensor::zeros(&theta.shape),
                    };
                    (id, s)
                })
                .collect())
        }
        Criterion::Grasp | Criterion::Crop => {
            let batch =
                batch.ok_or_else(|| anyhow::anyhow!("{} needs data", criterion.name()))?;
            let grads = loss_grads(g, batch)?;
            let hg = hessian_vec_product(g, batch, &grads)?;
            Ok(params
                .into_iter()
                .map(|id| {
                    let theta = g.data(id).param().unwrap();
                    let s = match hg.get(&id) {
                        // GraSP keeps the sign (negative = increases flow =
                        // prune first when ranked ascending ⇒ use −θ·Hg so
                        // that LOW scores are pruned, matching Eq. 6)
                        Some(h) if criterion == Criterion::Grasp => {
                            theta.zip(h, |t, hh| t * hh)
                        }
                        Some(h) => theta.zip(h, |t, hh| (t * hh).abs()),
                        None => Tensor::zeros(&theta.shape),
                    };
                    (id, s)
                })
                .collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn toy() -> Graph {
        let mut b = GraphBuilder::new("toy", 11);
        let x = b.input("x", vec![4, 3, 6, 6]);
        let c = b.conv2d("c", x, 6, 3, 1, 1, 1, true);
        let r = b.relu("r", c);
        let gp = b.global_avgpool("gap", r);
        let fc = b.gemm("fc", gp, 3, true);
        b.output(fc);
        b.finish().unwrap()
    }

    fn toy_batch(rng: &mut Rng) -> (Tensor, Vec<usize>) {
        let x = Tensor::new(vec![4, 3, 6, 6], rng.uniform_vec(4 * 3 * 36, -1.0, 1.0));
        let labels = (0..4).map(|_| rng.below(3)).collect();
        (x, labels)
    }

    #[test]
    fn l1_matches_abs() {
        let g = toy();
        let s = param_scores(&g, Criterion::L1, None).unwrap();
        let cid = g.data_by_name("c.w").unwrap().id;
        let w = g.data(cid).param().unwrap();
        assert_eq!(s[&cid].data[0], w.data[0].abs());
    }

    #[test]
    fn gradient_criteria_need_data() {
        let g = toy();
        assert!(param_scores(&g, Criterion::Snip, None).is_err());
        assert!(param_scores(&g, Criterion::Grasp, None).is_err());
    }

    #[test]
    fn snip_nonzero_and_shaped() {
        let g = toy();
        let mut rng = Rng::new(1);
        let (x, labels) = toy_batch(&mut rng);
        let s = param_scores(&g, Criterion::Snip, Some(&Batch { x: &x, labels: &labels }))
            .unwrap();
        let cid = g.data_by_name("c.w").unwrap().id;
        assert_eq!(s[&cid].shape, g.data(cid).shape);
        assert!(s[&cid].abs_sum() > 0.0, "snip scores all zero");
        assert!(s[&cid].data.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn hvp_matches_quadratic_model() {
        // On a single linear layer with fixed input, loss is smooth; check
        // H·g ≈ (∇L(θ+εg)−∇L(θ−εg))/2ε is self-consistent at two scales.
        let g = toy();
        let mut rng = Rng::new(2);
        let (x, labels) = toy_batch(&mut rng);
        let batch = Batch { x: &x, labels: &labels };
        let grads = loss_grads(&g, &batch).unwrap();
        let hg = hessian_vec_product(&g, &batch, &grads).unwrap();
        // Hg should be finite and not identically zero
        let total: f32 = hg.values().map(|t| t.abs_sum()).sum();
        assert!(total.is_finite() && total > 0.0);
    }

    #[test]
    fn grasp_signed_crop_unsigned() {
        let g = toy();
        let mut rng = Rng::new(3);
        let (x, labels) = toy_batch(&mut rng);
        let batch = Batch { x: &x, labels: &labels };
        let crop = param_scores(&g, Criterion::Crop, Some(&batch)).unwrap();
        assert!(crop.values().all(|t| t.data.iter().all(|v| *v >= 0.0)));
        let grasp = param_scores(&g, Criterion::Grasp, Some(&batch)).unwrap();
        let has_neg = grasp
            .values()
            .any(|t| t.data.iter().any(|v| *v < 0.0));
        assert!(has_neg, "grasp scores should be signed");
    }

    #[test]
    fn parse_resolves_builtins_through_registry() {
        for name in ["l1", "l2", "random", "taylor", "snip", "grasp", "crop", "fisher"] {
            let s = Criterion::parse(name).unwrap();
            assert_eq!(s.name(), name);
        }
        let err = Criterion::parse("no-such-criterion").unwrap_err();
        assert!(err.to_string().contains("unknown criterion"));
        assert!(registered_names().contains(&"l1".to_string()));
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        struct Dup;
        impl Saliency for Dup {
            fn name(&self) -> &str {
                "criteria-test-dup"
            }
            fn score(
                &self,
                g: &Graph,
                _batch: Option<&Batch>,
            ) -> anyhow::Result<HashMap<DataId, Tensor>> {
                param_scores(g, Criterion::L1, None)
            }
        }
        register(SaliencyRef::new(Dup)).unwrap();
        assert!(register(SaliencyRef::new(Dup)).is_err());
        assert!(register(SaliencyRef::new(Criterion::L1)).is_err());
    }

    #[test]
    fn precomputed_ignores_graph_and_batch() {
        let g = toy();
        let map = param_scores(&g, Criterion::L2, None).unwrap();
        let s = precomputed("l2-snapshot", map.clone());
        assert_eq!(s.name(), "l2-snapshot");
        assert!(!s.needs_data());
        let out = s.score(&g, None).unwrap();
        let cid = g.data_by_name("c.w").unwrap().id;
        assert_eq!(out[&cid].data, map[&cid].data);
    }

    #[test]
    fn random_deterministic_per_seed() {
        let g = toy();
        let a = param_scores(&g, Criterion::Random { seed: 5 }, None).unwrap();
        let b = param_scores(&g, Criterion::Random { seed: 5 }, None).unwrap();
        let c = param_scores(&g, Criterion::Random { seed: 6 }, None).unwrap();
        let id = g.data_by_name("c.w").unwrap().id;
        assert_eq!(a[&id].data, b[&id].data);
        assert_ne!(a[&id].data, c[&id].data);
    }
}
