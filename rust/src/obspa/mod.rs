//! OBSPA — Optimal Brain SPA (paper §3.3 "Train-Prune" + App. A.6).
//!
//! Structured pruning *without fine-tuning*: coupled channels are scored
//! with the layer-OBS criterion (Eq. 12), selected group-wise (Eq. 1),
//! and the surviving weights are reconstructed with a SparseGPT-style
//! column sweep (Eqs. 13-14) so each layer's output is preserved on the
//! calibration distribution. Calibration can be In-Distribution,
//! Out-Of-Distribution, or fully DataFree (uniform noise, §B.3), and BN
//! statistics are re-calibrated for ID/OOD (never for DataFree — noise
//! would distort them, exactly the paper's observation).
//!
//! The column sweep and Hessian accumulation execute through the PJRT
//! Pallas artifacts (`crate::runtime::kernels`), with native fallback.

use crate::criteria;
use crate::engine::{self, Mode};
use crate::exec;
use crate::ir::{DataId, Graph, OpId, OpKind};
use crate::prune::{self, Agg, Groups, Norm};
use crate::runtime::kernels as rk;
use crate::session::{Session, Target};
use crate::tensor::{ops, Tensor};
use crate::util::Rng;
use std::collections::HashMap;

/// Where calibration data comes from (paper Tab. 4 settings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CalibSource {
    /// Samples from the training distribution.
    InDistribution,
    /// Samples from a different distribution (e.g. CIFAR-100 for CIFAR-10).
    OutOfDistribution,
    /// Uniform noise in [0, 1) — the strictest data-free setting.
    DataFree,
}

impl CalibSource {
    pub fn name(&self) -> &'static str {
        match self {
            CalibSource::InDistribution => "ID",
            CalibSource::OutOfDistribution => "OOD",
            CalibSource::DataFree => "DataFree",
        }
    }
}

/// OBSPA configuration.
#[derive(Debug, Clone)]
pub struct ObspaCfg {
    /// Hessian damping as a fraction of the mean diagonal.
    pub damp: f32,
    /// FLOPs reduction target (paper's RF).
    pub target_rf: f64,
    /// Minimum CCs kept per group.
    pub min_keep: usize,
    /// Re-calibrate BN running stats after reconstruction (ID/OOD only).
    pub bn_recalibrate: bool,
    /// AGG / Norm of the group scoring (Eq. 1 hyper-parameters).
    pub agg: Agg,
    pub norm: Norm,
}

impl Default for ObspaCfg {
    fn default() -> Self {
        ObspaCfg {
            damp: 0.01,
            target_rf: 1.5,
            min_keep: 1,
            bn_recalibrate: true,
            agg: Agg::Sum,
            norm: Norm::Mean,
        }
    }
}

/// Per-layer Hessian state captured from calibration activations.
struct LayerState {
    /// One Hessian per conv group (gemm: single entry). [K, K]
    hessians: Vec<Tensor>,
    /// Diagonal of H⁻¹ per group (for OBS scores).
    hinv_diag: Vec<Vec<f32>>,
    /// Sweep matrix (upper Cholesky of H⁻¹) per group.
    sweeps: Vec<Tensor>,
    /// kdim (columns of the layer's GEMM view).
    kdim: usize,
    /// spatial kernel block (kh·kw for conv, 1 for gemm).
    kblock: usize,
}

/// Report of an OBSPA run.
#[derive(Debug, Clone)]
pub struct ObspaReport {
    pub layers_updated: usize,
    pub ccs_removed: usize,
    pub backend: rk::Backend,
    pub seconds: f64,
}

/// Generate uniform-noise calibration input matching a graph input shape
/// (the paper's DataFree setting: U[0,1)).
pub fn datafree_calib(g: &Graph, samples: usize, rng: &mut Rng) -> Tensor {
    let mut shape = g.data(g.inputs[0]).shape.clone();
    shape[0] = samples;
    let n: usize = shape.iter().product();
    Tensor::new(shape, rng.uniform_vec(n, 0.0, 1.0))
}

/// Which ops get OBS reconstruction.
fn is_obs_layer(kind: &OpKind) -> bool {
    matches!(kind, OpKind::Conv2d { .. } | OpKind::Gemm)
}

/// Capture per-layer input matrices (GEMM view) from calibration data and
/// accumulate Hessians through the runtime kernel. The calibration
/// forward runs on a compiled [`crate::exec::Plan`] with every OBS
/// layer's input retained — bit-identical activations to the
/// interpreter, without materializing the whole forward.
fn capture_hessians(
    g: &Graph,
    calib: &Tensor,
    damp: f32,
) -> anyhow::Result<(HashMap<OpId, LayerState>, rk::Backend)> {
    let retain: Vec<DataId> = g
        .ops
        .iter()
        .filter(|op| is_obs_layer(&op.kind))
        .map(|op| op.inputs[0])
        .collect();
    let plan = exec::Plan::compile(
        g,
        exec::PlanOpts {
            retain,
            ..Default::default()
        },
    )?;
    let mut runner = plan.runner();
    runner.execute(&[(g.inputs[0], calib)])?;
    let mut states = HashMap::new();
    let mut backend = rk::Backend::Native;
    for op in &g.ops {
        if !is_obs_layer(&op.kind) {
            continue;
        }
        let x = &runner.value(op.inputs[0])?;
        let w_shape = &g.data(op.inputs[1]).shape;
        let (xs, kblock): (Vec<Tensor>, usize) = match &op.kind {
            OpKind::Conv2d { stride, pad, groups } => (
                ops::unfold_conv_inputs(x, w_shape, *stride, *pad, *groups),
                w_shape[2] * w_shape[3],
            ),
            OpKind::Gemm => {
                let k = x.dim(-1);
                let rows = x.numel() / k;
                // X [K, rows]
                (vec![x.reshaped(vec![rows, k]).t2()], 1)
            }
            _ => unreachable!(),
        };
        let kdim = xs[0].shape[0];
        let mut hessians = Vec::new();
        let mut hinv_diag = Vec::new();
        let mut sweeps = Vec::new();
        for xg in &xs {
            let (mut h, be) = rk::hessian_accum(&Tensor::zeros(&[kdim, kdim]), xg)?;
            backend = be;
            let mean_diag =
                (0..kdim).map(|i| h.data[i * kdim + i]).sum::<f32>() / kdim as f32;
            let lambda = damp * mean_diag.max(1e-6);
            for i in 0..kdim {
                h.data[i * kdim + i] += lambda;
            }
            let hinv = rk::spd_inverse(&h)?;
            hinv_diag.push((0..kdim).map(|i| hinv.data[i * kdim + i]).collect());
            let l = rk::cholesky(&hinv)?;
            sweeps.push(l.t2());
            hessians.push(h);
        }
        states.insert(
            op.id,
            LayerState {
                hessians,
                hinv_diag,
                sweeps,
                kdim,
                kblock,
            },
        );
    }
    Ok((states, backend))
}

/// Layer-OBS per-parameter scores (Eq. 12): S(θ_rj) = θ²/[H⁻¹]_jj, plus
/// magnitude² for parameters without a Hessian (BN/LN/bias/embedding).
fn obs_param_scores(
    g: &Graph,
    states: &HashMap<OpId, LayerState>,
) -> HashMap<DataId, Tensor> {
    let mut scores: HashMap<DataId, Tensor> = HashMap::new();
    for pid in g.param_ids() {
        scores.insert(pid, g.data(pid).param().unwrap().map(|v| v * v));
    }
    for op in &g.ops {
        let Some(state) = states.get(&op.id) else {
            continue;
        };
        let wid = op.inputs[1];
        let w = g.data(wid).param().unwrap();
        let mut s = w.map(|v| v * v);
        match &op.kind {
            OpKind::Gemm => {
                let (co, k) = (w.shape[0], w.shape[1]);
                let diag = &state.hinv_diag[0];
                for r in 0..co {
                    for j in 0..k {
                        s.data[r * k + j] /= diag[j].max(1e-12);
                    }
                }
            }
            OpKind::Conv2d { groups, .. } => {
                let co = w.shape[0];
                let kdim = state.kdim;
                let cog = co / groups;
                for r in 0..co {
                    let diag = &state.hinv_diag[r / cog];
                    for j in 0..kdim {
                        s.data[r * kdim + j] /= diag[j].max(1e-12);
                    }
                }
            }
            _ => {}
        }
        scores.insert(wid, s);
    }
    scores
}

/// Column prune-mask per OBS layer from the selected coupled channels:
/// dim-1 deletions of the weight map to kblock-wide column spans.
fn column_masks(
    g: &Graph,
    groups: &Groups,
    selected: &[(usize, usize)],
    states: &HashMap<OpId, LayerState>,
) -> HashMap<OpId, Vec<f32>> {
    // param data id → owning OBS op
    let mut owner: HashMap<DataId, OpId> = HashMap::new();
    for op in &g.ops {
        if states.contains_key(&op.id) {
            owner.insert(op.inputs[1], op.id);
        }
    }
    let mut masks: HashMap<OpId, Vec<f32>> = HashMap::new();
    for &(gid, cc) in selected {
        for loc in &groups.groups[gid].ccs[cc].locs {
            if loc.dim != 1 {
                continue;
            }
            let Some(&op_id) = owner.get(&loc.data) else {
                continue;
            };
            let st = &states[&op_id];
            let mask = masks.entry(op_id).or_insert_with(|| vec![0.0; st.kdim]);
            for j in loc.idx * st.kblock..(loc.idx + 1) * st.kblock {
                if j < mask.len() {
                    mask[j] = 1.0;
                }
            }
        }
    }
    masks
}

/// Run OBSPA on a graph in place: score → select → reconstruct → delete
/// (→ optionally recalibrate BN). Returns a report.
pub fn obspa_prune(
    g: &mut Graph,
    calib: &Tensor,
    cfg: &ObspaCfg,
) -> anyhow::Result<ObspaReport> {
    let t0 = std::time::Instant::now();
    let (states, backend) = capture_hessians(g, calib, cfg.damp)?;
    let plan = Session::on(&*g)
        .criterion(criteria::precomputed("obs", obs_param_scores(g, &states)))
        .agg(cfg.agg)
        .norm(cfg.norm)
        .min_keep(cfg.min_keep)
        .target(Target::FlopsRf(cfg.target_rf))
        .plan()?;
    // Reconstruction edits weights in place before the deletion, so the
    // plan is dismantled instead of applied.
    let (groups, selected) = plan.into_parts();
    // Reconstruct each affected layer before deletion.
    let masks = column_masks(g, &groups, &selected, &states);
    let mut layers_updated = 0usize;
    let mut backend_final = backend;
    for (&op_id, mask) in &masks {
        let st = &states[&op_id];
        let wid = g.ops[op_id].inputs[1];
        let w = g.data(wid).param().unwrap().clone();
        let kind = g.ops[op_id].kind.clone();
        let new_w = match kind {
            OpKind::Gemm => {
                let (updated, be) = rk::obs_update(&w, &st.sweeps[0], mask)?;
                backend_final = be;
                updated
            }
            OpKind::Conv2d { groups: gcount, .. } => {
                let co = w.shape[0];
                let cog = co / gcount;
                let kdim = st.kdim;
                let flat = w.reshaped(vec![co, kdim]);
                let mut out = Tensor::zeros(&[co, kdim]);
                for grp in 0..gcount {
                    let rows: Vec<usize> = (grp * cog..(grp + 1) * cog).collect();
                    let wg = flat.take_indices(0, &rows);
                    let (updated, be) = rk::obs_update(&wg, &st.sweeps[grp], mask)?;
                    backend_final = be;
                    for (ri, &r) in rows.iter().enumerate() {
                        out.data[r * kdim..(r + 1) * kdim]
                            .copy_from_slice(&updated.data[ri * kdim..(ri + 1) * kdim]);
                    }
                }
                out.reshaped(w.shape.clone())
            }
            _ => unreachable!(),
        };
        *g.datas[wid].param_mut().unwrap() = new_w;
        layers_updated += 1;
        let _ = &st.hessians; // retained for future iterative variants
    }
    let outcome = prune::apply_pruning(g, &groups, &selected)?;
    if cfg.bn_recalibrate {
        recalibrate_bn(g, calib)?;
    }
    Ok(ObspaReport {
        layers_updated,
        ccs_removed: outcome.ccs_removed,
        backend: backend_final,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// BN statistic re-calibration (paper §B.3): forward the calibration data
/// twice in training-stats mode, EMA-updating running mean/var.
pub fn recalibrate_bn(g: &mut Graph, calib: &Tensor) -> anyhow::Result<()> {
    for pass in 0..2 {
        let fwd = engine::forward(g, &[(g.inputs[0], calib.clone())], Mode::Train)?;
        let momentum = if pass == 0 { 1.0 } else { 0.5 };
        engine::update_bn_stats(g, &fwd, momentum);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis;
    use crate::data;
    use crate::zoo::{self, ImageCfg};

    fn acc_of(g: &Graph, ds: &data::ImageDataset) -> f32 {
        let (x, y) = ds.test_batch(0, 64);
        let logits = engine::predict(g, x).unwrap();
        ops::accuracy(&logits, &y)
    }

    #[test]
    fn obspa_prunes_to_target_and_beats_naive_zeroing() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let ds = data::ImageDataset::synth_cifar(10, 512, 8, 3, 42);
        let mut g = zoo::resnet18(cfg, 7);
        // quick-train so weights encode signal worth preserving
        crate::train::quick_train(&mut g, &ds, 60, 0.05).unwrap();
        let base_acc = acc_of(&g, &ds);
        let (calib, _) = ds.train_batch_seeded(99, 128);
        // OBSPA
        let mut g_obs = g.clone();
        let rep = obspa_prune(
            &mut g_obs,
            &calib,
            &ObspaCfg {
                target_rf: 1.3,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(rep.layers_updated > 0);
        let r = analysis::reduction(&g, &g_obs);
        assert!(r.rf >= 1.3, "rf {}", r.rf);
        let obs_acc = acc_of(&g_obs, &ds);
        // naive baseline: same selection machinery via magnitude, no update
        let g_naive = Session::on(&g)
            .criterion(crate::criteria::Criterion::L1)
            .target(Target::FlopsRf(1.3))
            .plan()
            .unwrap()
            .apply()
            .unwrap()
            .graph;
        let naive_acc = acc_of(&g_naive, &ds);
        // The paper's Tab. 4 shape: OBSPA's acc drop ≪ data-free magnitude
        // drop. Allow slack for the tiny regime but require clear ordering.
        assert!(
            obs_acc >= naive_acc - 0.02,
            "obspa {obs_acc} should not trail naive {naive_acc}"
        );
        assert!(
            base_acc - obs_acc < 0.25,
            "obspa dropped too much: {base_acc} -> {obs_acc}"
        );
    }

    #[test]
    fn datafree_calibration_runs() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::vgg16(cfg, 3);
        let mut rng = Rng::new(5);
        let calib = datafree_calib(&g, 32, &mut rng);
        let rep = obspa_prune(
            &mut g,
            &calib,
            &ObspaCfg {
                target_rf: 1.3,
                bn_recalibrate: false, // paper: never recalibrate on noise
                ..Default::default()
            },
        )
        .unwrap();
        assert!(rep.ccs_removed > 0);
        g.validate().unwrap();
    }

    #[test]
    fn obspa_handles_flattened_input_models() {
        // mlp's first Gemm reads a Flatten of the graph input — the
        // calibration capture must read that aliased activation back
        // from the compiled plan
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::mlp(cfg, &[32, 16], 2);
        let mut rng = Rng::new(8);
        let calib = datafree_calib(&g, 32, &mut rng);
        let rep = obspa_prune(
            &mut g,
            &calib,
            &ObspaCfg {
                target_rf: 1.2,
                bn_recalibrate: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(rep.ccs_removed > 0);
        g.validate().unwrap();
    }

    #[test]
    fn bn_recalibration_moves_stats() {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let mut g = zoo::resnet18(cfg, 9);
        let ds = data::ImageDataset::synth_cifar(10, 128, 8, 3, 43);
        let (calib, _) = ds.train_batch_seeded(1, 64);
        let before: Vec<f32> = g
            .data_by_name("stem.bn.mean")
            .unwrap()
            .param()
            .unwrap()
            .data
            .clone();
        recalibrate_bn(&mut g, &calib).unwrap();
        let after = &g.data_by_name("stem.bn.mean").unwrap().param().unwrap().data;
        assert!(before.iter().zip(after).any(|(a, b)| (a - b).abs() > 1e-4));
    }
}
