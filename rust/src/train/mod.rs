//! Training / fine-tuning loop over the SPA-IR engine: SGD with momentum
//! + cosine LR (the paper's §B.3 optimization recipe), usable for base
//! training, prune-train, and post-prune fine-tuning — the graphs can be
//! pruned to any shape and train identically.

use crate::data::{ImageDataset, TextDataset};
use crate::engine::{self, Mode};
use crate::exec;
use crate::ir::{DataId, Graph};
use crate::tensor::{ops, Tensor};
use crate::util::Rng;
use std::collections::HashMap;

/// Training hyper-parameters.
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Cosine-anneal the LR to ~0 over `steps` (paper uses
    /// CosineAnnealingLR).
    pub cosine: bool,
    pub bn_momentum: f32,
    pub seed: u64,
    /// Log loss every `log_every` steps into the history (0 = never).
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 200,
            batch: 32,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 5e-4,
            cosine: true,
            bn_momentum: 0.1,
            seed: 0x7124,
            log_every: 10,
        }
    }
}

/// Loss-curve entry.
#[derive(Debug, Clone, Copy)]
pub struct LogEntry {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
}

/// Result of a training run.
pub struct TrainReport {
    pub history: Vec<LogEntry>,
    pub final_loss: f32,
}

/// Generic batch source so images and text share the loop.
pub trait BatchSource {
    fn next_batch(&self, rng: &mut Rng, bs: usize) -> (Tensor, Vec<usize>);
}

impl BatchSource for ImageDataset {
    fn next_batch(&self, rng: &mut Rng, bs: usize) -> (Tensor, Vec<usize>) {
        self.train_batch(rng, bs)
    }
}

impl BatchSource for TextDataset {
    fn next_batch(&self, rng: &mut Rng, bs: usize) -> (Tensor, Vec<usize>) {
        self.train_batch(rng, bs)
    }
}

/// SGD train/fine-tune a graph in place.
pub fn train<D: BatchSource>(g: &mut Graph, ds: &D, cfg: &TrainCfg) -> anyhow::Result<TrainReport> {
    let params = g.param_ids();
    // momentum buffers (skip BN running stats: they are not SGD params)
    let trainable: Vec<DataId> = params
        .into_iter()
        .filter(|&id| {
            let n = &g.data(id).name;
            !n.ends_with(".mean") && !n.ends_with(".var")
        })
        .collect();
    let mut velocity: HashMap<DataId, Tensor> = trainable
        .iter()
        .map(|&id| (id, Tensor::zeros(&g.data(id).shape)))
        .collect();
    let mut rng = Rng::new(cfg.seed);
    let mut history = Vec::new();
    let mut last_loss = f32::NAN;
    for step in 0..cfg.steps {
        let lr = if cfg.cosine {
            0.5 * cfg.lr
                * (1.0
                    + (std::f32::consts::PI * step as f32 / cfg.steps.max(1) as f32).cos())
        } else {
            cfg.lr
        };
        let (x, labels) = ds.next_batch(&mut rng, cfg.batch);
        let fwd = engine::forward(g, &[(g.inputs[0], x)], Mode::Train)?;
        let (loss, dlogits) = ops::cross_entropy(fwd.logits(g), &labels);
        last_loss = loss;
        let grads = engine::backward(g, &fwd, &[(g.outputs[0], dlogits)])?;
        engine::update_bn_stats(g, &fwd, cfg.bn_momentum);
        for &id in &trainable {
            let Some(grad) = grads.by_data.get(&id) else {
                continue;
            };
            let v = velocity.get_mut(&id).unwrap();
            let theta = g.datas[id].param_mut().unwrap();
            for i in 0..theta.data.len() {
                let gi = grad.data[i] + cfg.weight_decay * theta.data[i];
                v.data[i] = cfg.momentum * v.data[i] + gi;
                theta.data[i] -= lr * v.data[i];
            }
        }
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            history.push(LogEntry { step, loss, lr });
        }
    }
    Ok(TrainReport {
        history,
        final_loss: last_loss,
    })
}

/// Short-and-simple training used by tests and pipelines.
pub fn quick_train(
    g: &mut Graph,
    ds: &ImageDataset,
    steps: usize,
    lr: f32,
) -> anyhow::Result<TrainReport> {
    train(
        g,
        ds,
        &TrainCfg {
            steps,
            lr,
            batch: 32,
            ..Default::default()
        },
    )
}

/// Test-set accuracy over up to `max_samples` samples.
///
/// Evaluation is a many-batches / one-graph workload, so it runs on a
/// compiled [`crate::exec::Plan`] (one compile, zero steady-state
/// allocation) — bit-identical to interpreting each batch.
pub fn evaluate(g: &Graph, ds: &ImageDataset, max_samples: usize) -> anyhow::Result<f32> {
    let plan = exec::Plan::compile(g, exec::PlanOpts::default())?;
    let mut runner = plan.runner();
    let mut correct = 0.0f32;
    let mut total = 0usize;
    let bs = 64;
    let mut offset = 0;
    while offset < ds.test_len().min(max_samples) {
        let (x, y) = ds.test_batch(offset, bs);
        let n = y.len();
        let logits = runner.predict(&x)?;
        correct += ops::accuracy(&logits, &y) * n as f32;
        total += n;
        offset += n;
        if n < bs {
            break;
        }
    }
    Ok(correct / total.max(1) as f32)
}

/// Test-set accuracy for text datasets (compiled-plan path, like
/// [`evaluate`]).
pub fn evaluate_text(g: &Graph, ds: &TextDataset, max_samples: usize) -> anyhow::Result<f32> {
    let plan = exec::Plan::compile(g, exec::PlanOpts::default())?;
    let mut runner = plan.runner();
    let mut correct = 0.0f32;
    let mut total = 0usize;
    let bs = 64;
    let mut offset = 0;
    while offset < ds.test_len().min(max_samples) {
        let (x, y) = ds.test_batch(offset, bs);
        let n = y.len();
        let logits = runner.predict(&x)?;
        correct += ops::accuracy(&logits, &y) * n as f32;
        total += n;
        offset += n;
        if n < bs {
            break;
        }
    }
    Ok(correct / total.max(1) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ImageDataset;
    use crate::zoo::{self, ImageCfg};

    #[test]
    fn loss_decreases_on_small_cnn() {
        let cfg = ImageCfg {
            hw: 8,
            classes: 4,
            ..Default::default()
        };
        let ds = ImageDataset::synth_cifar(4, 256, 8, 3, 11);
        let mut g = zoo::mlp(cfg, &[32], 1);
        let rep = train(
            &mut g,
            &ds,
            &TrainCfg {
                steps: 80,
                lr: 0.1,
                log_every: 5,
                ..Default::default()
            },
        )
        .unwrap();
        let first = rep.history.first().unwrap().loss;
        assert!(
            rep.final_loss < first * 0.8,
            "loss {first} -> {} did not decrease",
            rep.final_loss
        );
    }

    #[test]
    fn training_beats_chance() {
        let cfg = ImageCfg {
            hw: 8,
            classes: 4,
            ..Default::default()
        };
        let ds = ImageDataset::synth_cifar(4, 512, 8, 3, 12);
        let mut g = zoo::resnet18(cfg, 2);
        quick_train(&mut g, &ds, 80, 0.05).unwrap();
        let acc = evaluate(&g, &ds, 128).unwrap();
        assert!(acc > 0.5, "accuracy {acc} barely above chance (0.25)");
    }

    #[test]
    fn cosine_schedule_decays() {
        let cfg = TrainCfg {
            steps: 100,
            lr: 1.0,
            cosine: true,
            log_every: 1,
            ..Default::default()
        };
        let ds = ImageDataset::synth_cifar(2, 64, 8, 3, 13);
        let mut g = zoo::mlp(
            ImageCfg {
                hw: 8,
                classes: 2,
                ..Default::default()
            },
            &[8],
            3,
        );
        let rep = train(&mut g, &ds, &cfg).unwrap();
        let first_lr = rep.history.first().unwrap().lr;
        let last_lr = rep.history.last().unwrap().lr;
        assert!(first_lr > 0.9 && last_lr < 0.05, "{first_lr} {last_lr}");
    }

    #[test]
    fn finetune_recovers_pruned_model() {
        use crate::prune::{self, build_groups, score_groups, Agg, Norm};
        use std::collections::HashMap as Map;
        let icfg = ImageCfg {
            hw: 8,
            classes: 4,
            ..Default::default()
        };
        let ds = ImageDataset::synth_cifar(4, 512, 8, 3, 14);
        let mut g = zoo::resnet18(icfg, 4);
        quick_train(&mut g, &ds, 100, 0.05).unwrap();
        let base = evaluate(&g, &ds, 128).unwrap();
        let groups = build_groups(&g).unwrap();
        let mut l1 = Map::new();
        for pid in g.param_ids() {
            l1.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
        }
        let ranked = score_groups(&g, &groups, &l1, Agg::Sum, Norm::Mean);
        let sel = prune::select_by_flops_target(&g, &groups, &ranked, 1.6, 1).unwrap();
        prune::apply_pruning(&mut g, &groups, &sel).unwrap();
        let pruned_acc = evaluate(&g, &ds, 128).unwrap();
        quick_train(&mut g, &ds, 60, 0.02).unwrap();
        let finetuned = evaluate(&g, &ds, 128).unwrap();
        assert!(
            finetuned >= pruned_acc - 0.05,
            "finetune should not hurt: {pruned_acc} -> {finetuned}"
        );
        assert!(
            finetuned > base - 0.2,
            "finetuned {finetuned} too far below base {base}"
        );
    }
}
