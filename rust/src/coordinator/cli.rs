//! `spa` command-line interface (hand-rolled — no clap offline).
//!
//! ```text
//! spa info    --model resnet18                       # shapes/params/FLOPs
//! spa train   --model resnet18 --steps 200           # train on SynthCIFAR
//! spa prune   --model resnet18 --time tpf --criterion l1 --target-rf 2.0
//! spa obspa   --model resnet50 --source datafree --target-rf 1.5
//! spa convert --model resnet18 --dialect tf --out model.tf.json
//! spa import  --file model.tf.json --out model.spa.json
//! ```

use super::{train_prune, train_prune_finetune, prune_train, NoFinetuneAlgo, PipelineCfg};
use crate::analysis;
use crate::criteria::Criterion;
use crate::data::ImageDataset;
use crate::frontends::{self, Dialect};
use crate::ir::serde as ir_serde;
use crate::obspa::CalibSource;
use crate::prune::Scope;
use crate::train::TrainCfg;
use crate::util::Table;
use crate::zoo::{self, ImageCfg};
use std::collections::HashMap;

/// Parsed `--key value` flags.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Flags> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let k = &args[i];
            anyhow::ensure!(k.starts_with("--"), "expected --flag, got `{k}`");
            let v = args
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("flag {k} missing value"))?;
            map.insert(k[2..].to_string(), v.clone());
            i += 2;
        }
        Ok(Flags(map))
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.0
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.0
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

const USAGE: &str = "spa — Structurally Prune Anything (Rust+JAX+Pallas reproduction)

USAGE: spa <command> [--flag value ...]

COMMANDS:
  info     --model <name>                      print params/FLOPs/groups
  train    --model <name> [--steps N --lr F]   train on SynthCIFAR
  prune    --model <name> [--time tpf|pt] [--criterion l1|snip|grasp|crop]
           [--target-rf F] [--iterations N]    full pipeline + report row
  obspa    --model <name> [--source id|ood|datafree] [--target-rf F]
  optimize --model <name> [--out <file>]       run the inference-time
           graph passes (dead nodes, identities, BN fold, const fold)
           and report the compiled-plan arena footprint
  convert  --model <name> --dialect <torch|tf|jax|mxnet> --out <file>
  import   --file <dialect json> [--out <spa-ir json>]
  models                                       list zoo models
";

/// CLI entrypoint (used by `rust/src/main.rs`).
pub fn run(args: Vec<String>) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    let icfg = ImageCfg {
        hw: flags.usize("hw", 16),
        classes: flags.usize("classes", 10),
        ..Default::default()
    };
    let seed = flags.usize("seed", 1) as u64;
    match cmd.as_str() {
        "models" => {
            for m in zoo::IMAGE_MODELS {
                println!("{m}");
            }
            println!("{} (also available)", zoo::EXTRA_MODELS.join(" "));
        }
        "info" => {
            let g = zoo::by_name(&flags.get("model", "resnet18"), icfg, seed)?;
            // read-only inspection: grouping alone, no saliency pass
            let groups = crate::prune::build_groups(&g)?;
            println!("model   : {}", g.name);
            println!("ops     : {}", g.ops.len());
            println!("params  : {}", g.num_params());
            println!("flops   : {}", analysis::flops(&g));
            println!(
                "groups  : {} ({} prunable CCs)",
                groups.groups.len(),
                groups.num_prunable_ccs()
            );
        }
        "train" => {
            let mut g = zoo::by_name(&flags.get("model", "resnet18"), icfg, seed)?;
            let ds = ImageDataset::synth_cifar(icfg.classes, 1024, icfg.hw, icfg.channels, seed);
            let cfg = TrainCfg {
                steps: flags.usize("steps", 200),
                lr: flags.f64("lr", 0.05) as f32,
                ..Default::default()
            };
            let rep = crate::train::train(&mut g, &ds, &cfg)?;
            for e in &rep.history {
                println!("step {:>5}  loss {:.4}  lr {:.4}", e.step, e.loss, e.lr);
            }
            let acc = crate::train::evaluate(&g, &ds, 256)?;
            println!("test accuracy: {:.2}%", acc * 100.0);
        }
        "prune" => {
            let model = flags.get("model", "resnet18");
            let g = zoo::by_name(&model, icfg, seed)?;
            let ds = ImageDataset::synth_cifar(icfg.classes, 1024, icfg.hw, icfg.channels, seed);
            let cfg = PipelineCfg {
                criterion: Criterion::parse(&flags.get("criterion", "l1"))?,
                scope: if flags.get("scope", "grouped") == "grouped" {
                    Scope::FullCc
                } else {
                    Scope::SourceOnly
                },
                target_rf: flags.f64("target-rf", 2.0),
                iterations: flags.usize("iterations", 1),
                train: TrainCfg {
                    steps: flags.usize("train-steps", 150),
                    ..Default::default()
                },
                finetune: TrainCfg {
                    steps: flags.usize("finetune-steps", 80),
                    lr: 0.02,
                    ..Default::default()
                },
                ..Default::default()
            };
            let rep = match flags.get("time", "tpf").as_str() {
                "tpf" | "train-prune-finetune" => train_prune_finetune(g, &ds, &cfg)?.1,
                "pt" | "prune-train" => prune_train(g, &ds, &cfg)?.1,
                other => anyhow::bail!("unknown --time `{other}` (tpf|pt)"),
            };
            let mut t = Table::new(
                "pipeline result",
                &["model", "ori acc.", "pruned acc.", "final acc.", "RF", "RP", "secs"],
            );
            t.row(&[
                model,
                format!("{:.2}%", rep.ori_acc * 100.0),
                format!("{:.2}%", rep.pruned_acc * 100.0),
                format!("{:.2}%", rep.final_acc * 100.0),
                format!("{:.2}x", rep.rf),
                format!("{:.2}x", rep.rp),
                format!("{:.1}", rep.seconds),
            ]);
            t.print();
        }
        "obspa" => {
            let model = flags.get("model", "resnet50");
            let g = zoo::by_name(&model, icfg, seed)?;
            let ds = ImageDataset::synth_cifar(icfg.classes, 1024, icfg.hw, icfg.channels, seed);
            let ood = ImageDataset::synth_cifar(
                icfg.classes * 2,
                256,
                icfg.hw,
                icfg.channels,
                seed ^ 0xF00D,
            );
            let source = match flags.get("source", "id").as_str() {
                "id" => CalibSource::InDistribution,
                "ood" => CalibSource::OutOfDistribution,
                "datafree" => CalibSource::DataFree,
                other => anyhow::bail!("unknown --source `{other}`"),
            };
            let cfg = PipelineCfg {
                train: TrainCfg {
                    steps: flags.usize("train-steps", 150),
                    ..Default::default()
                },
                ..Default::default()
            };
            let (_, rep) = train_prune(
                g,
                &ds,
                Some(&ood),
                NoFinetuneAlgo::Obspa(source),
                flags.f64("target-rf", 1.5),
                &cfg,
            )?;
            println!(
                "OBSPA({}) {}: acc {:.2}% -> {:.2}% (drop {:.2}%), RF {:.2}x RP {:.2}x",
                source.name(),
                model,
                rep.ori_acc * 100.0,
                rep.final_acc * 100.0,
                (rep.ori_acc - rep.final_acc) * 100.0,
                rep.rf,
                rep.rp
            );
        }
        "optimize" => {
            let model = flags.get("model", "resnet18");
            let mut g = zoo::by_name(&model, icfg, seed)?;
            let ops_before = g.ops.len();
            let params_before = g.num_params();
            let rep = crate::ir::passes::optimize(&mut g)?;
            println!("model      : {model}");
            println!("ops        : {} -> {}", ops_before, g.ops.len());
            println!("params     : {} -> {}", params_before, g.num_params());
            println!(
                "passes     : {} dead ops, {} identities, {} BN folded, {} const folded",
                rep.dead_ops, rep.identities_removed, rep.bn_folded, rep.constants_folded
            );
            let plan = crate::exec::Plan::compile(&g, crate::exec::PlanOpts::default())?;
            let pr = plan.report();
            println!(
                "exec plan  : {} steps ({} fused, {} aliased), {} arena slots",
                pr.steps, pr.fused_ops, pr.aliased_ops, pr.arena_slots
            );
            println!(
                "activations: {} arena bytes vs {} interpreted bytes (+{} wt cache)",
                pr.peak_arena_bytes, pr.interp_intermediate_bytes, pr.gemm_wt_bytes
            );
            let out = flags.get("out", "");
            if !out.is_empty() {
                ir_serde::save_graph(&g, &out, true)?;
                println!("wrote {out}");
            }
        }
        "convert" => {
            let model = flags.get("model", "resnet18");
            let dialect = Dialect::parse(&flags.get("dialect", "tf"))?;
            let g = zoo::by_name(&model, icfg, seed)?;
            let out = flags.get("out", &format!("{model}.{}.json", dialect.name()));
            std::fs::write(&out, frontends::export_to_string(&g, dialect))?;
            println!("wrote {out}");
        }
        "import" => {
            let file = flags.get("file", "");
            anyhow::ensure!(!file.is_empty(), "import needs --file");
            let g = frontends::import_from_string(&std::fs::read_to_string(&file)?)?;
            println!(
                "imported `{}`: {} ops, {} params, {} flops",
                g.name,
                g.ops.len(),
                g.num_params(),
                analysis::flops(&g)
            );
            let out = flags.get("out", "");
            if !out.is_empty() {
                ir_serde::save_graph(&g, &out, true)?;
                println!("wrote {out}");
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            anyhow::bail!("unknown command `{other}`\n{USAGE}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse() {
        let f = Flags::parse(&[
            "--model".into(),
            "vgg16".into(),
            "--target-rf".into(),
            "2.5".into(),
        ])
        .unwrap();
        assert_eq!(f.get("model", "x"), "vgg16");
        assert_eq!(f.f64("target-rf", 1.0), 2.5);
        assert_eq!(f.usize("missing", 7), 7);
    }

    #[test]
    fn flags_reject_bad_syntax() {
        assert!(Flags::parse(&["model".into()]).is_err());
        assert!(Flags::parse(&["--model".into()]).is_err());
    }

    #[test]
    fn info_command_runs() {
        run(vec![
            "info".into(),
            "--model".into(),
            "mlp".into(),
            "--hw".into(),
            "8".into(),
        ])
        .unwrap();
    }

    #[test]
    fn usage_on_no_args() {
        run(vec![]).unwrap();
    }

    #[test]
    fn optimize_command_runs() {
        run(vec![
            "optimize".into(),
            "--model".into(),
            "vgg16".into(),
            "--hw".into(),
            "8".into(),
        ])
        .unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["frobnicate".into()]).is_err());
    }
}
