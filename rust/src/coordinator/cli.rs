//! `spa` command-line interface (hand-rolled — no clap offline).
//!
//! ```text
//! spa info    --model resnet18                       # shapes/params/FLOPs
//! spa train   --model resnet18 --steps 200           # train on SynthCIFAR
//! spa prune   --model resnet18 --time tpf --criterion l1 --target-rf 2.0
//! spa obspa   --model resnet50 --source datafree --target-rf 1.5
//! spa serve   --addr 127.0.0.1:7878 --tick-ms 2      # batching inference server
//! spa swap    --addr 127.0.0.1:7878 --model resnet18 --target-rf 2.0
//! spa profile --model resnet18 --runs 10             # per-step plan profile
//! spa trace   --model mlp --out trace.json           # Chrome trace demo run
//! spa convert --model resnet18 --dialect tf --out model.tf.json
//! spa import  --file model.tf.json --out model.spa.json
//! ```
//!
//! Flag handling is two-layered: [`Flags`] tokenizes `--key value`
//! pairs, and each subcommand owns a typed args struct
//! ([`PruneArgs`], [`ServeArgs`], ...) that pulls its flags out of the
//! shared pool — so new subcommands add a struct, not a fourth copy of
//! string matching.

use super::{train_prune, train_prune_finetune, prune_train, NoFinetuneAlgo, PipelineCfg};
use crate::analysis;
use crate::check::CheckLevel;
use crate::criteria::Criterion;
use crate::data::ImageDataset;
use crate::exec::{OptLevel, Plan, PlanOpts, Runner};
use crate::frontends::{self, Dialect};
use crate::ir::serde as ir_serde;
use crate::obs::{self, ObsCfg, Profiler};
use crate::obspa::CalibSource;
use crate::prune::Scope;
use crate::serve::{self, FaultPlan, ServeCfg};
use crate::tensor::Tensor;
use crate::train::TrainCfg;
use crate::util::{Json, JsonObj, Rng, Table};
use crate::zoo::{self, ImageCfg};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Parsed `--key value` flags.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Flags> {
        let mut map = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let k = &args[i];
            anyhow::ensure!(k.starts_with("--"), "expected --flag, got `{k}`");
            let v = args
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("flag {k} missing value"))?;
            map.insert(k[2..].to_string(), v.clone());
            i += 2;
        }
        Ok(Flags(map))
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn opt(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(String::as_str)
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.0
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.0
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

/// Flags shared by every model-centric subcommand.
struct CommonArgs {
    model: String,
    icfg: ImageCfg,
    seed: u64,
}

impl CommonArgs {
    fn parse(f: &Flags, default_model: &str) -> CommonArgs {
        CommonArgs {
            model: f.get("model", default_model),
            icfg: ImageCfg {
                hw: f.usize("hw", 16),
                classes: f.usize("classes", 10),
                ..Default::default()
            },
            seed: f.usize("seed", 1) as u64,
        }
    }

    fn graph(&self) -> anyhow::Result<crate::ir::Graph> {
        zoo::by_name(&self.model, self.icfg, self.seed)
    }

    fn dataset(&self) -> ImageDataset {
        ImageDataset::synth_cifar(
            self.icfg.classes,
            1024,
            self.icfg.hw,
            self.icfg.channels,
            self.seed,
        )
    }
}

struct TrainArgs {
    common: CommonArgs,
    cfg: TrainCfg,
}

impl TrainArgs {
    fn parse(f: &Flags) -> TrainArgs {
        TrainArgs {
            common: CommonArgs::parse(f, "resnet18"),
            cfg: TrainCfg {
                steps: f.usize("steps", 200),
                lr: f.f64("lr", 0.05) as f32,
                ..Default::default()
            },
        }
    }
}

enum PruneTime {
    TrainPruneFinetune,
    PruneTrain,
}

struct PruneArgs {
    common: CommonArgs,
    time: PruneTime,
    cfg: PipelineCfg,
}

impl PruneArgs {
    fn parse(f: &Flags) -> anyhow::Result<PruneArgs> {
        let time = match f.get("time", "tpf").as_str() {
            "tpf" | "train-prune-finetune" => PruneTime::TrainPruneFinetune,
            "pt" | "prune-train" => PruneTime::PruneTrain,
            other => anyhow::bail!("unknown --time `{other}` (tpf|pt)"),
        };
        Ok(PruneArgs {
            common: CommonArgs::parse(f, "resnet18"),
            time,
            cfg: PipelineCfg {
                criterion: Criterion::parse(&f.get("criterion", "l1"))?,
                scope: if f.get("scope", "grouped") == "grouped" {
                    Scope::FullCc
                } else {
                    Scope::SourceOnly
                },
                target_rf: f.f64("target-rf", 2.0),
                iterations: f.usize("iterations", 1),
                train: TrainCfg {
                    steps: f.usize("train-steps", 150),
                    ..Default::default()
                },
                finetune: TrainCfg {
                    steps: f.usize("finetune-steps", 80),
                    lr: 0.02,
                    ..Default::default()
                },
                ..Default::default()
            },
        })
    }
}

struct ObspaArgs {
    common: CommonArgs,
    source: CalibSource,
    target_rf: f64,
    cfg: PipelineCfg,
}

impl ObspaArgs {
    fn parse(f: &Flags) -> anyhow::Result<ObspaArgs> {
        let source = match f.get("source", "id").as_str() {
            "id" => CalibSource::InDistribution,
            "ood" => CalibSource::OutOfDistribution,
            "datafree" => CalibSource::DataFree,
            other => anyhow::bail!("unknown --source `{other}`"),
        };
        Ok(ObspaArgs {
            common: CommonArgs::parse(f, "resnet50"),
            source,
            target_rf: f.f64("target-rf", 1.5),
            cfg: PipelineCfg {
                train: TrainCfg {
                    steps: f.usize("train-steps", 150),
                    ..Default::default()
                },
                ..Default::default()
            },
        })
    }
}

struct OptimizeArgs {
    common: CommonArgs,
    out: Option<String>,
}

impl OptimizeArgs {
    fn parse(f: &Flags) -> OptimizeArgs {
        OptimizeArgs {
            common: CommonArgs::parse(f, "resnet18"),
            out: f.opt("out").map(str::to_string),
        }
    }
}

struct ConvertArgs {
    common: CommonArgs,
    dialect: Dialect,
    out: Option<String>,
}

impl ConvertArgs {
    fn parse(f: &Flags) -> anyhow::Result<ConvertArgs> {
        Ok(ConvertArgs {
            common: CommonArgs::parse(f, "resnet18"),
            dialect: Dialect::parse(&f.get("dialect", "tf"))?,
            out: f.opt("out").map(str::to_string),
        })
    }
}

struct ImportArgs {
    file: String,
    out: Option<String>,
}

impl ImportArgs {
    fn parse(f: &Flags) -> anyhow::Result<ImportArgs> {
        let file = f.get("file", "");
        anyhow::ensure!(!file.is_empty(), "import needs --file");
        Ok(ImportArgs {
            file,
            out: f.opt("out").map(str::to_string),
        })
    }
}

fn parse_opt_level(s: &str) -> anyhow::Result<OptLevel> {
    match s {
        "none" => Ok(OptLevel::None),
        "exact" => Ok(OptLevel::Exact),
        "fast" => Ok(OptLevel::Fast),
        other => anyhow::bail!("unknown --opt `{other}` (none|exact|fast)"),
    }
}

/// `spa serve` flags, resolved into a [`ServeCfg`].
struct ServeArgs {
    cfg: ServeCfg,
}

impl ServeArgs {
    fn parse(f: &Flags) -> anyhow::Result<ServeArgs> {
        let common = CommonArgs::parse(f, "resnet18");
        Ok(ServeArgs {
            cfg: ServeCfg {
                addr: f.get("addr", "127.0.0.1:7878"),
                tick: Duration::from_millis(f.usize("tick-ms", 2) as u64),
                max_batch: f.usize("max-batch", 64),
                cache_cap: f.usize("cache-cap", 0),
                level: parse_opt_level(&f.get("opt", "exact"))?,
                image: common.icfg,
                seed: common.seed,
                prune_rf: f.opt("prune-rf").and_then(|v| v.parse().ok()),
                criterion: f.get("criterion", "l1"),
                queue_cap: f.usize("queue-cap", 1024),
                faults: f
                    .opt("faults")
                    .map(FaultPlan::parse)
                    .transpose()?
                    .map(Arc::new),
                obs: f.opt("obs").map(ObsCfg::from_flag).unwrap_or_default(),
            },
        })
    }
}

/// `spa profile` flags: per-step profiling of one compiled plan.
struct ProfileArgs {
    common: CommonArgs,
    runs: usize,
    level: OptLevel,
    json: Option<String>,
}

impl ProfileArgs {
    fn parse(f: &Flags) -> anyhow::Result<ProfileArgs> {
        let runs = f.usize("runs", 10);
        anyhow::ensure!(runs > 0, "profile needs --runs >= 1");
        Ok(ProfileArgs {
            common: CommonArgs::parse(f, "resnet18"),
            runs,
            level: parse_opt_level(&f.get("opt", "exact"))?,
            json: f.opt("json").map(str::to_string),
        })
    }
}

/// `spa trace` flags: a traced in-process serve demo whose events are
/// exported as Chrome `trace_event` JSON.
struct TraceArgs {
    common: CommonArgs,
    requests: usize,
    out: String,
    metrics: Option<String>,
}

impl TraceArgs {
    fn parse(f: &Flags) -> TraceArgs {
        TraceArgs {
            common: CommonArgs::parse(f, "mlp"),
            requests: f.usize("requests", 8),
            out: f.get("out", "trace.json"),
            metrics: f.opt("metrics").map(str::to_string),
        }
    }
}

/// `spa swap` flags: a live re-prune request against a running server.
struct SwapArgs {
    addr: String,
    req: serve::SwapRequest,
}

impl SwapArgs {
    fn parse(f: &Flags) -> anyhow::Result<SwapArgs> {
        let model = f.get("model", "");
        anyhow::ensure!(!model.is_empty(), "swap needs --model");
        Ok(SwapArgs {
            addr: f.get("addr", "127.0.0.1:7878"),
            req: serve::SwapRequest {
                model,
                target_rf: f.f64("target-rf", 2.0),
                criterion: f.get("criterion", "l1"),
                shadow: f.usize("shadow-requests", 0) as u32,
                max_divergence: f.f64("max-divergence", 0.0),
            },
        })
    }
}

/// `spa lint` flags: which models, at what [`CheckLevel`].
struct LintArgs {
    model: String,
    icfg: ImageCfg,
    seed: u64,
    level: CheckLevel,
}

impl LintArgs {
    fn parse(f: &Flags) -> anyhow::Result<LintArgs> {
        let common = CommonArgs::parse(f, "all");
        Ok(LintArgs {
            model: common.model,
            icfg: common.icfg,
            seed: common.seed,
            level: CheckLevel::parse(&f.get("level", "strict"))?,
        })
    }
}

struct BenchDiffArgs {
    base: String,
    fresh: String,
    warn_pct: f64,
    /// Write the fresh entries (normalized `{name, ns_per_iter}`) here
    /// after diffing, so CI can refresh the committed baseline.
    write_baseline: Option<String>,
    /// Write the full diff (per-row deltas + summary) as JSON here, for
    /// machine consumption alongside the human table.
    json: Option<String>,
}

impl BenchDiffArgs {
    fn parse(f: &Flags) -> anyhow::Result<BenchDiffArgs> {
        let base = f.get("base", "");
        let fresh = f.get("new", "");
        let write_baseline = f.opt("write-baseline").map(str::to_string);
        anyhow::ensure!(!fresh.is_empty(), "bench-diff needs --new");
        anyhow::ensure!(
            !base.is_empty() || write_baseline.is_some(),
            "bench-diff needs --base and/or --write-baseline"
        );
        Ok(BenchDiffArgs {
            base,
            fresh,
            warn_pct: f.f64("warn-pct", 25.0),
            write_baseline,
            json: f.opt("json").map(str::to_string),
        })
    }
}

const USAGE: &str = "spa — Structurally Prune Anything (Rust+JAX+Pallas reproduction)

USAGE: spa <command> [--flag value ...]

COMMANDS:
  info     --model <name>                      print params/FLOPs/groups
  train    --model <name> [--steps N --lr F]   train on SynthCIFAR
  prune    --model <name> [--time tpf|pt] [--criterion l1|snip|grasp|crop]
           [--target-rf F] [--iterations N]    full pipeline + report row
  obspa    --model <name> [--source id|ood|datafree] [--target-rf F]
  optimize --model <name> [--out <file>]       run the inference-time
           graph passes (dead nodes, identities, BN fold, const fold)
           and report the compiled-plan arena footprint
  serve    [--addr H:P --tick-ms N --max-batch N --cache-cap N]
           [--opt none|exact|fast --prune-rf F --criterion l1]
           [--queue-cap N --faults <spec> --obs on|off]
           batching inference server over compiled plans (spa::serve);
           SIGINT/SIGTERM drain gracefully, --faults injects chaos,
           --obs (or SPA_OBS=1) records trace events (spa::obs)
  swap     --addr H:P --model <name> --target-rf F [--criterion l1]
           [--shadow-requests N --max-divergence F]
           live re-prune a model on a running server: verify, shadow,
           atomic plan flip, automatic rollback (spa::serve swap verb)
  profile  --model <name> [--runs N --opt none|exact|fast --json <file>]
           per-step plan profile: wall time, bytes, GEMM dims, fusion
           attribution, hottest op first (spa::obs profiler)
  trace    [--model <name> --requests N --out <file> --metrics <file>]
           run a traced in-process serve demo and export the events as
           Chrome trace_event JSON (load in chrome://tracing or Perfetto)
  lint     [--model <name>|all] [--level off|debug|strict]
           run every static checker (spa::check) over the zoo: graph
           shape/coupling invariants, an audited prune, compiled plans;
           `all` also lints a patched-then-repruned surgery lineage
  bench-diff --new <json> [--base <json>] [--warn-pct F]
           [--write-baseline <json> --json <file>]
           compare two SPA_BENCH_JSON snapshots, warn on regressions and
           stale baselines, optionally refresh the committed baseline
  convert  --model <name> --dialect <torch|tf|jax|mxnet> --out <file>
  import   --file <dialect json> [--out <spa-ir json>]
  models                                       list zoo models
";

fn cmd_info(a: &CommonArgs) -> anyhow::Result<()> {
    let g = a.graph()?;
    // read-only inspection: grouping alone, no saliency pass
    let groups = crate::prune::build_groups(&g)?;
    println!("model   : {}", g.name);
    println!("ops     : {}", g.ops.len());
    println!("params  : {}", g.num_params());
    println!("flops   : {}", analysis::flops(&g));
    println!(
        "groups  : {} ({} prunable CCs)",
        groups.groups.len(),
        groups.num_prunable_ccs()
    );
    Ok(())
}

fn cmd_train(a: &TrainArgs) -> anyhow::Result<()> {
    let mut g = a.common.graph()?;
    let ds = a.common.dataset();
    let rep = crate::train::train(&mut g, &ds, &a.cfg)?;
    for e in &rep.history {
        println!("step {:>5}  loss {:.4}  lr {:.4}", e.step, e.loss, e.lr);
    }
    let acc = crate::train::evaluate(&g, &ds, 256)?;
    println!("test accuracy: {:.2}%", acc * 100.0);
    Ok(())
}

fn cmd_prune(a: PruneArgs) -> anyhow::Result<()> {
    let g = a.common.graph()?;
    let ds = a.common.dataset();
    let rep = match a.time {
        PruneTime::TrainPruneFinetune => train_prune_finetune(g, &ds, &a.cfg)?.1,
        PruneTime::PruneTrain => prune_train(g, &ds, &a.cfg)?.1,
    };
    let mut t = Table::new(
        "pipeline result",
        &["model", "ori acc.", "pruned acc.", "final acc.", "RF", "RP", "secs"],
    );
    t.row(&[
        a.common.model,
        format!("{:.2}%", rep.ori_acc * 100.0),
        format!("{:.2}%", rep.pruned_acc * 100.0),
        format!("{:.2}%", rep.final_acc * 100.0),
        format!("{:.2}x", rep.rf),
        format!("{:.2}x", rep.rp),
        format!("{:.1}", rep.seconds),
    ]);
    t.print();
    Ok(())
}

fn cmd_obspa(a: &ObspaArgs) -> anyhow::Result<()> {
    let g = a.common.graph()?;
    let ds = a.common.dataset();
    let ood = ImageDataset::synth_cifar(
        a.common.icfg.classes * 2,
        256,
        a.common.icfg.hw,
        a.common.icfg.channels,
        a.common.seed ^ 0xF00D,
    );
    let (_, rep) = train_prune(
        g,
        &ds,
        Some(&ood),
        NoFinetuneAlgo::Obspa(a.source),
        a.target_rf,
        &a.cfg,
    )?;
    println!(
        "OBSPA({}) {}: acc {:.2}% -> {:.2}% (drop {:.2}%), RF {:.2}x RP {:.2}x",
        a.source.name(),
        a.common.model,
        rep.ori_acc * 100.0,
        rep.final_acc * 100.0,
        (rep.ori_acc - rep.final_acc) * 100.0,
        rep.rf,
        rep.rp
    );
    Ok(())
}

fn cmd_optimize(a: &OptimizeArgs) -> anyhow::Result<()> {
    let mut g = a.common.graph()?;
    let ops_before = g.ops.len();
    let params_before = g.num_params();
    let rep = crate::ir::passes::optimize(&mut g)?;
    println!("model      : {}", a.common.model);
    println!("ops        : {} -> {}", ops_before, g.ops.len());
    println!("params     : {} -> {}", params_before, g.num_params());
    println!(
        "passes     : {} dead ops, {} identities, {} BN folded, {} const folded",
        rep.dead_ops, rep.identities_removed, rep.bn_folded, rep.constants_folded
    );
    let plan = crate::exec::Plan::compile(&g, crate::exec::PlanOpts::default())?;
    let pr = plan.report();
    println!(
        "exec plan  : {} steps ({} fused, {} aliased), {} arena slots",
        pr.steps, pr.fused_ops, pr.aliased_ops, pr.arena_slots
    );
    println!(
        "activations: {} arena bytes vs {} interpreted bytes (+{} wt cache)",
        pr.peak_arena_bytes, pr.interp_intermediate_bytes, pr.gemm_wt_bytes
    );
    if let Some(out) = &a.out {
        ir_serde::save_graph(&g, out, true)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Arm SIGINT/SIGTERM to flip a flag `cmd_serve` polls, so Ctrl-C and
/// orchestrator stops drain the server instead of killing it mid-batch.
#[cfg(unix)]
fn install_stop_signals() -> &'static AtomicBool {
    static STOP: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_stop(_signum: i32) {
        STOP.store(true, Ordering::SeqCst);
    }
    extern "C" {
        // libc signal(2); the return (previous handler) is unused
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: the handler only stores to a static atomic, which is
    // async-signal-safe; no allocation, locking, or panicking.
    unsafe {
        signal(SIGINT, on_stop);
        signal(SIGTERM, on_stop);
    }
    &STOP
}

#[cfg(not(unix))]
fn install_stop_signals() -> &'static AtomicBool {
    // no signal(2) here; the flag simply never flips and the loop runs
    // until the process is killed (same as the pre-drain behavior)
    static STOP: AtomicBool = AtomicBool::new(false);
    &STOP
}

fn cmd_serve(a: ServeArgs) -> anyhow::Result<()> {
    let tick = a.cfg.tick;
    let server = serve::Server::spawn(a.cfg)?;
    println!(
        "serving on {} (tick {:?}; length-prefixed TCP, see README \"Serving\")",
        server.local_addr(),
        tick
    );
    if let Some(f) = server.fault_plan() {
        println!("fault injection armed: {f:?}");
    }
    let stop = install_stop_signals();
    let stats = server.stats();
    let mut last_report = std::time::Instant::now();
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(200));
        if last_report.elapsed() >= Duration::from_secs(10) {
            last_report = std::time::Instant::now();
            println!(
                "served {:>8} ({} errors, {} batches, {} shed, {} expired, {} panics)  \
                 p50 {:>7}us  p99 {:>7}us",
                stats.served(),
                stats.errors(),
                stats.batches(),
                stats.shed(),
                stats.expired(),
                stats.panics(),
                stats.latency_percentile_us(50.0).unwrap_or(0),
                stats.latency_percentile_us(99.0).unwrap_or(0),
            );
        }
    }
    let depth = server.health().queue_depth;
    println!("stop signal received: draining ({depth} queued request(s))");
    server.drain();
    println!("drained cleanly");
    Ok(())
}

fn cmd_swap(a: &SwapArgs) -> anyhow::Result<()> {
    let mut client = serve::Client::connect(a.addr.as_str())?;
    let rep = client.swap(&a.req)?;
    println!("key        : {}", rep.key);
    println!("generation : {} -> {}", rep.from_generation, rep.to_generation);
    println!("outcome    : {:?}", rep.outcome);
    println!(
        "recompiled : {} region(s), {} of {} steps reused",
        rep.recompiled_regions, rep.reused_steps, rep.steps
    );
    println!(
        "shadow     : {} request(s) checked, worst divergence {:.3e}",
        rep.shadow_checked, rep.divergence
    );
    println!("message    : {}", rep.message);
    // a rollback is a correct server outcome but a failed operator
    // intent — exit nonzero so scripts notice
    anyhow::ensure!(
        rep.outcome == serve::SwapOutcome::Committed,
        "swap did not commit: {}",
        rep.message
    );
    Ok(())
}

/// A deterministic input tensor shaped for `g`'s single graph input.
fn demo_input(g: &crate::ir::Graph, seed: u64) -> Tensor {
    let shape = g.data(g.inputs[0]).shape.clone();
    let n: usize = shape.iter().product();
    let mut rng = Rng::new(seed);
    Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0))
}

fn cmd_profile(a: &ProfileArgs) -> anyhow::Result<()> {
    let g = a.common.graph()?;
    let plan = Plan::compile(
        &g,
        PlanOpts {
            level: a.level,
            ..Default::default()
        },
    )?;
    let x = demo_input(&g, a.common.seed);
    let mut runner = Runner::new(&plan);
    // one unprofiled warm-up so first-touch page faults and the lazy
    // GEMM weight cache don't land on the measured runs
    runner.predict(&x)?;
    let mut prof = Profiler::new();
    for _ in 0..a.runs {
        runner.predict_profiled(&x, &mut prof)?;
    }
    let rep = prof.report(&plan);
    print!("{}", rep.render(&format!("spa profile {}", a.common.model)));
    // a gate, not just a report: if the per-step rows stop accounting
    // for the end-to-end plan time the profiler (or the schedule's
    // instrumentation) is broken, and CI should fail loudly rather
    // than upload a misleading per-op baseline
    anyhow::ensure!(
        rep.coverage() > 0.5,
        "profiled steps account for only {:.1}% of end-to-end time",
        rep.coverage() * 100.0
    );
    if let Some(path) = &a.json {
        std::fs::write(path, format!("{}\n", rep.to_json()))
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_trace(a: &TraceArgs) -> anyhow::Result<()> {
    ObsCfg::tracing().apply();
    // start from empty rings so the export holds only this demo run
    let _ = obs::trace::drain();
    let common = &a.common;
    let server = serve::Server::spawn(ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        image: common.icfg,
        seed: common.seed,
        obs: ObsCfg::tracing(),
        ..Default::default()
    })?;
    let g = common.graph()?;
    let x = demo_input(&g, common.seed);
    let mut client = serve::Client::connect(server.local_addr())?;
    for _ in 0..a.requests {
        client.predict(&common.model, &x)?;
    }
    let report = client.metrics()?;
    drop(client);
    server.drain();
    let buf = obs::trace::drain();
    ObsCfg::default().apply();
    let json = obs::chrome_json(&buf);
    std::fs::write(&a.out, format!("{json}\n"))
        .map_err(|e| anyhow::anyhow!("write {}: {e}", a.out))?;
    println!(
        "traced {} request(s) against {}: {} event(s) ({} dropped) -> {}",
        a.requests,
        common.model,
        buf.events.len(),
        buf.dropped,
        a.out
    );
    if let Some(path) = &a.metrics {
        std::fs::write(path, report.render_prometheus())
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("wrote metrics snapshot to {path}");
    }
    Ok(())
}

fn cmd_convert(a: &ConvertArgs) -> anyhow::Result<()> {
    let g = a.common.graph()?;
    let out = a
        .out
        .clone()
        .unwrap_or_else(|| format!("{}.{}.json", a.common.model, a.dialect.name()));
    std::fs::write(&out, frontends::export_to_string(&g, a.dialect))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_import(a: &ImportArgs) -> anyhow::Result<()> {
    let g = frontends::import_from_string(&std::fs::read_to_string(&a.file)?)?;
    println!(
        "imported `{}`: {} ops, {} params, {} flops",
        g.name,
        g.ops.len(),
        g.num_params(),
        analysis::flops(&g)
    );
    if let Some(out) = &a.out {
        ir_serde::save_graph(&g, out, true)?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Load a `SPA_BENCH_JSON` array as `(name, ns_per_iter)` pairs; later
/// entries for the same name win (the recorder appends).
fn load_bench(path: &str) -> anyhow::Result<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
    let Json::Arr(entries) = crate::util::parse_json(&text)? else {
        anyhow::bail!("{path}: expected a JSON array of bench entries");
    };
    let mut out: Vec<(String, f64)> = Vec::new();
    for e in &entries {
        let Json::Obj(o) = e else { continue };
        let (Some(Json::Str(name)), Some(Json::Num(ns))) =
            (o.get("name"), o.get("ns_per_iter"))
        else {
            continue;
        };
        match out.iter_mut().find(|(n, _)| n == name) {
            Some(slot) => slot.1 = *ns,
            None => out.push((name.clone(), *ns)),
        }
    }
    Ok(out)
}

/// Run every static checker over one model: graph, a strict-audited
/// prune, and compiled plans (baseline + pruned) at `level`. Returns a
/// short summary for the report table.
fn lint_one(name: &str, icfg: ImageCfg, seed: u64, level: CheckLevel) -> anyhow::Result<String> {
    let g = if name == "distilbert" {
        zoo::distilbert(zoo::TextCfg::default(), seed)
    } else {
        zoo::by_name(name, icfg, seed)?
    };
    crate::check::check_graph(&g).map_err(|e| anyhow::anyhow!("graph: {e}"))?;
    let plan = crate::Session::on(&g)
        .criterion(Criterion::L1)
        .target(crate::Target::FlopsRf(1.3))
        .check(level)
        .plan()
        .map_err(|e| anyhow::anyhow!("prune: {e}"))?;
    let pruned = plan.apply().map_err(|e| anyhow::anyhow!("prune: {e}"))?;
    let opts = crate::exec::PlanOpts {
        check: level,
        ..Default::default()
    };
    let base = crate::exec::Plan::compile(&g, opts.clone())
        .map_err(|e| anyhow::anyhow!("plan(base): {e}"))?;
    let fast = crate::exec::Plan::compile(&pruned.graph, opts)
        .map_err(|e| anyhow::anyhow!("plan(pruned): {e}"))?;
    Ok(format!(
        "{} ops, {} groups, {}+{} steps",
        g.ops.len(),
        plan.num_groups(),
        base.report().steps,
        fast.report().steps
    ))
}

/// Lint the surgery lineage a live `spa swap` produces: run the
/// optimize passes as verified patches, re-prune the patched graph
/// through a session patch, and check the graph plus its compiled plan
/// after the second surgery.
fn lint_patched(
    name: &str,
    icfg: ImageCfg,
    seed: u64,
    level: CheckLevel,
) -> anyhow::Result<String> {
    let mut g = zoo::by_name(name, icfg, seed)?;
    let reports = crate::ir::patch::optimize_as_patches(&mut g, level)
        .map_err(|e| anyhow::anyhow!("patch(optimize): {e}"))?;
    let sess = crate::Session::on(&g)
        .criterion(Criterion::L1)
        .target(crate::Target::FlopsRf(1.3))
        .check(level)
        .plan()
        .map_err(|e| anyhow::anyhow!("prune: {e}"))?;
    let patch = sess
        .as_patch(&g)
        .map_err(|e| anyhow::anyhow!("patch(prune): {e}"))?;
    let mut repatched = g.clone();
    let prep = patch
        .apply_checked(&mut repatched, level)
        .map_err(|e| anyhow::anyhow!("patch(apply): {e}"))?;
    crate::check::check_graph(&repatched).map_err(|e| anyhow::anyhow!("graph: {e}"))?;
    let opts = crate::exec::PlanOpts {
        check: level,
        ..Default::default()
    };
    let plan = crate::exec::Plan::compile(&repatched, opts)
        .map_err(|e| anyhow::anyhow!("plan(repatched): {e}"))?;
    Ok(format!(
        "{} patch(es), {} param edit(s), {} steps",
        reports.len() + 1,
        prep.param_edits,
        plan.report().steps
    ))
}

fn cmd_lint(a: &LintArgs) -> anyhow::Result<()> {
    let names: Vec<String> = if a.model == "all" {
        zoo::IMAGE_MODELS
            .iter()
            .chain(zoo::EXTRA_MODELS)
            .map(|s| s.to_string())
            .chain(std::iter::once("distilbert".to_string()))
            .collect()
    } else {
        vec![a.model.clone()]
    };
    let mut t = Table::new(
        &format!("spa lint (level {})", a.level.name()),
        &["model", "summary", "status"],
    );
    let mut failures: Vec<(String, String)> = Vec::new();
    let mut total = names.len();
    for name in &names {
        match lint_one(name, a.icfg, a.seed, a.level) {
            Ok(summary) => t.row(&[name.clone(), summary, "ok".to_string()]),
            Err(e) => {
                t.row(&[name.clone(), "-".to_string(), "FAIL".to_string()]);
                failures.push((name.clone(), e.to_string()));
            }
        }
    }
    if a.model == "all" {
        // the graph lineage a live `spa swap` serves: optimize patches
        // followed by a session re-prune patch, verified at `level`
        total += 1;
        let label = "resnet18+patch".to_string();
        match lint_patched("resnet18", a.icfg, a.seed, a.level) {
            Ok(summary) => t.row(&[label, summary, "ok".to_string()]),
            Err(e) => {
                t.row(&[label.clone(), "-".to_string(), "FAIL".to_string()]);
                failures.push((label, e.to_string()));
            }
        }
    }
    t.print();
    if !failures.is_empty() {
        for (name, e) in &failures {
            println!("lint: {name}: {e}");
        }
        anyhow::bail!(
            "lint failed for {} of {} model(s) at level {}",
            failures.len(),
            total,
            a.level.name()
        );
    }
    println!("lint: {} model(s) clean at level {}", total, a.level.name());
    Ok(())
}

/// Percent delta of `new_ns` against a baseline measurement, or `None`
/// when the baseline is missing or non-positive (an empty smoke-lane
/// snapshot records no usable time — treat as "no baseline", never as a
/// division by zero).
fn bench_delta(base_ns: Option<f64>, new_ns: f64) -> Option<f64> {
    base_ns.filter(|&b| b > 0.0).map(|b| (new_ns - b) / b * 100.0)
}

/// Write bench entries as a normalized `[{name, ns_per_iter}]` snapshot
/// (the shape `load_bench` reads back), for refreshing a committed
/// baseline from a smoke-lane run.
fn write_bench_baseline(path: &str, entries: &[(String, f64)]) -> anyhow::Result<()> {
    let arr = Json::Arr(
        entries
            .iter()
            .map(|(name, ns)| {
                let mut o = JsonObj::new();
                o.insert("name", name.as_str());
                o.insert("ns_per_iter", *ns);
                Json::Obj(o)
            })
            .collect(),
    );
    std::fs::write(path, format!("{arr}\n"))
        .map_err(|e| anyhow::anyhow!("write {path}: {e}"))
}

fn cmd_bench_diff(a: &BenchDiffArgs) -> anyhow::Result<()> {
    let fresh = load_bench(&a.fresh)?;
    anyhow::ensure!(!fresh.is_empty(), "{}: no bench entries", a.fresh);
    let base = match load_bench(&a.base) {
        Ok(v) if !v.is_empty() => v,
        // tolerate a missing/empty baseline: the diff is advisory, and
        // the first PR that commits a snapshot bootstraps it
        _ => {
            if !a.base.is_empty() {
                println!(
                    "bench-diff: no baseline entries at {} — commit the smoke-lane \
                     SPA_BENCH_JSON output to enable regression diffs",
                    a.base
                );
            }
            Vec::new()
        }
    };
    // a baseline where *every* row is a zero-time placeholder came from
    // an empty smoke run: say so out loud instead of quietly labelling
    // each row "no baseline" and reporting a clean diff
    let stale = !base.is_empty() && base.iter().all(|(_, ns)| *ns <= 0.0);
    if stale {
        println!(
            "::warning::bench-diff: stale baseline at {} — every entry is a zero-time \
             placeholder; refresh it from a real smoke run (--write-baseline)",
            a.base
        );
    }
    let mut t = Table::new("bench-diff (ns/iter)", &["bench", "base", "new", "delta"]);
    let mut json_rows: Vec<Json> = Vec::new();
    let mut regressions = 0usize;
    let mut compared = 0usize;
    for (name, new_ns) in &fresh {
        let base_ns = base.iter().find(|(n, _)| n == name).map(|(_, b)| *b);
        let mut row = JsonObj::new();
        row.insert("name", name.as_str());
        row.insert("new_ns", *new_ns);
        match bench_delta(base_ns, *new_ns) {
            Some(pct) => {
                compared += 1;
                let b = base_ns.expect("delta implies baseline");
                t.row(&[
                    name.clone(),
                    format!("{b:.0}"),
                    format!("{new_ns:.0}"),
                    format!("{pct:+.1}%"),
                ]);
                row.insert("base_ns", b);
                row.insert("delta_pct", pct);
                row.insert("regressed", pct > a.warn_pct);
                if pct > a.warn_pct {
                    regressions += 1;
                    println!(
                        "::warning::bench `{name}` regressed {pct:+.1}% \
                         ({b:.0} -> {new_ns:.0} ns/iter)"
                    );
                }
            }
            None => {
                // missing entry or a zero-time record (empty snapshot):
                // notice only, never part of the regression gate
                let label = if base_ns.is_some() { "no baseline" } else { "new" };
                t.row(&[
                    name.clone(),
                    "-".to_string(),
                    format!("{new_ns:.0}"),
                    label.to_string(),
                ]);
                row.insert("status", label);
            }
        }
        json_rows.push(Json::Obj(row));
    }
    t.print();
    println!(
        "bench-diff: {compared} of {} benches compared, {} regression(s) beyond {:.0}%",
        fresh.len(),
        regressions,
        a.warn_pct
    );
    if let Some(path) = &a.json {
        let mut o = JsonObj::new();
        o.insert("compared", compared);
        o.insert("regressions", regressions);
        o.insert("warn_pct", a.warn_pct);
        o.insert("stale_baseline", stale);
        o.insert("rows", json_rows);
        std::fs::write(path, format!("{}\n", Json::Obj(o)))
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("bench-diff: wrote diff json to {path}");
    }
    if let Some(path) = &a.write_baseline {
        write_bench_baseline(path, &fresh)?;
        println!("bench-diff: wrote {} entries to {path}", fresh.len());
    }
    Ok(())
}

/// CLI entrypoint (used by `rust/src/main.rs`).
pub fn run(args: Vec<String>) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "models" => {
            for m in zoo::IMAGE_MODELS {
                println!("{m}");
            }
            println!("{} (also available)", zoo::EXTRA_MODELS.join(" "));
            Ok(())
        }
        "info" => cmd_info(&CommonArgs::parse(&flags, "resnet18")),
        "train" => cmd_train(&TrainArgs::parse(&flags)),
        "prune" => cmd_prune(PruneArgs::parse(&flags)?),
        "obspa" => cmd_obspa(&ObspaArgs::parse(&flags)?),
        "optimize" => cmd_optimize(&OptimizeArgs::parse(&flags)),
        "serve" => cmd_serve(ServeArgs::parse(&flags)?),
        "swap" => cmd_swap(&SwapArgs::parse(&flags)?),
        "profile" => cmd_profile(&ProfileArgs::parse(&flags)?),
        "trace" => cmd_trace(&TraceArgs::parse(&flags)),
        "lint" => cmd_lint(&LintArgs::parse(&flags)?),
        "bench-diff" => cmd_bench_diff(&BenchDiffArgs::parse(&flags)?),
        "convert" => cmd_convert(&ConvertArgs::parse(&flags)?),
        "import" => cmd_import(&ImportArgs::parse(&flags)?),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            anyhow::bail!("unknown command `{other}`\n{USAGE}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(pairs: &[(&str, &str)]) -> Flags {
        let args: Vec<String> = pairs
            .iter()
            .flat_map(|(k, v)| [format!("--{k}"), v.to_string()])
            .collect();
        Flags::parse(&args).unwrap()
    }

    #[test]
    fn flags_parse() {
        let f = Flags::parse(&[
            "--model".into(),
            "vgg16".into(),
            "--target-rf".into(),
            "2.5".into(),
        ])
        .unwrap();
        assert_eq!(f.get("model", "x"), "vgg16");
        assert_eq!(f.f64("target-rf", 1.0), 2.5);
        assert_eq!(f.usize("missing", 7), 7);
    }

    #[test]
    fn flags_reject_bad_syntax() {
        assert!(Flags::parse(&["model".into()]).is_err());
        assert!(Flags::parse(&["--model".into()]).is_err());
    }

    #[test]
    fn info_command_runs() {
        run(vec![
            "info".into(),
            "--model".into(),
            "mlp".into(),
            "--hw".into(),
            "8".into(),
        ])
        .unwrap();
    }

    #[test]
    fn usage_on_no_args() {
        run(vec![]).unwrap();
    }

    #[test]
    fn optimize_command_runs() {
        run(vec![
            "optimize".into(),
            "--model".into(),
            "vgg16".into(),
            "--hw".into(),
            "8".into(),
        ])
        .unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["frobnicate".into()]).is_err());
    }

    #[test]
    fn common_args_share_flag_defaults() {
        let f = flags(&[("hw", "8"), ("seed", "9")]);
        let a = CommonArgs::parse(&f, "resnet18");
        assert_eq!(a.model, "resnet18");
        assert_eq!(a.icfg.hw, 8);
        assert_eq!(a.seed, 9);
        let b = CommonArgs::parse(&f, "resnet50");
        assert_eq!(b.model, "resnet50");
    }

    #[test]
    fn prune_args_reject_unknown_time() {
        let f = flags(&[("time", "sideways")]);
        let err = PruneArgs::parse(&f).unwrap_err();
        assert_eq!(err.to_string(), "unknown --time `sideways` (tpf|pt)");
    }

    #[test]
    fn serve_args_resolve_typed_config() {
        let f = flags(&[
            ("addr", "127.0.0.1:0"),
            ("tick-ms", "5"),
            ("max-batch", "16"),
            ("opt", "fast"),
            ("prune-rf", "1.5"),
        ]);
        let a = ServeArgs::parse(&f).unwrap();
        assert_eq!(a.cfg.addr, "127.0.0.1:0");
        assert_eq!(a.cfg.tick, Duration::from_millis(5));
        assert_eq!(a.cfg.max_batch, 16);
        assert_eq!(a.cfg.level, OptLevel::Fast);
        assert_eq!(a.cfg.prune_rf, Some(1.5));
        let bad = flags(&[("opt", "warp")]);
        let err = ServeArgs::parse(&bad).unwrap_err();
        assert_eq!(err.to_string(), "unknown --opt `warp` (none|exact|fast)");
    }

    #[test]
    fn serve_args_parse_queue_cap_and_faults() {
        let f = flags(&[
            ("queue-cap", "32"),
            ("faults", "seed=7;group.panic=0.5;frame.torn=0.25"),
        ]);
        let a = ServeArgs::parse(&f).unwrap();
        assert_eq!(a.cfg.queue_cap, 32);
        assert_eq!(a.cfg.faults.as_ref().unwrap().seed(), 7);
        // defaults: bounded queue, no faults armed, observability off
        let d = ServeArgs::parse(&flags(&[])).unwrap();
        assert_eq!(d.cfg.queue_cap, 1024);
        assert!(d.cfg.faults.is_none());
        assert!(!d.cfg.obs.trace);
        let o = ServeArgs::parse(&flags(&[("obs", "on")])).unwrap();
        assert!(o.cfg.obs.trace);
        // a malformed spec is a parse error, not a silently inert plan
        let bad = flags(&[("faults", "group.meteor=0.5")]);
        let err = ServeArgs::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown fault kind"), "got: {err}");
    }

    #[test]
    fn swap_args_resolve_typed_request() {
        let f = flags(&[
            ("addr", "127.0.0.1:9999"),
            ("model", "mlp"),
            ("target-rf", "1.4"),
            ("criterion", "l1"),
            ("shadow-requests", "6"),
            ("max-divergence", "0.5"),
        ]);
        let a = SwapArgs::parse(&f).unwrap();
        assert_eq!(a.addr, "127.0.0.1:9999");
        assert_eq!(a.req.model, "mlp");
        assert_eq!(a.req.target_rf, 1.4);
        assert_eq!(a.req.shadow, 6);
        assert_eq!(a.req.max_divergence, 0.5);
        // defaults: bit-exact shadow gate, no shadow requests
        let d = SwapArgs::parse(&flags(&[("model", "mlp")])).unwrap();
        assert_eq!(d.req.shadow, 0);
        assert_eq!(d.req.max_divergence, 0.0);
        // --model is mandatory — there is no default model to re-prune
        assert!(SwapArgs::parse(&flags(&[])).is_err());
    }

    #[test]
    fn lint_patched_lineage_is_clean_at_strict() {
        let icfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let summary = lint_patched("resnet18", icfg, 1, CheckLevel::Strict).unwrap();
        assert!(summary.contains("patch(es)"), "got: {summary}");
    }

    #[test]
    fn bench_diff_tolerates_missing_baseline_and_warns_on_regression() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let base = dir.join(format!("spa_cli_bd_base_{pid}.json"));
        let fresh = dir.join(format!("spa_cli_bd_new_{pid}.json"));
        std::fs::write(&fresh, r#"[{"name":"a","ns_per_iter":130.0,"iters":3}]"#).unwrap();
        // missing baseline: advisory notice, still Ok
        run(vec![
            "bench-diff".into(),
            "--base".into(),
            base.to_str().unwrap().into(),
            "--new".into(),
            fresh.to_str().unwrap().into(),
        ])
        .unwrap();
        // present baseline: diff runs (warn path is print-only, still Ok)
        std::fs::write(&base, r#"[{"name":"a","ns_per_iter":100.0,"iters":3}]"#).unwrap();
        run(vec![
            "bench-diff".into(),
            "--base".into(),
            base.to_str().unwrap().into(),
            "--new".into(),
            fresh.to_str().unwrap().into(),
        ])
        .unwrap();
        let loaded = load_bench(base.to_str().unwrap()).unwrap();
        assert_eq!(loaded, vec![("a".to_string(), 100.0)]);
        std::fs::remove_file(&base).ok();
        std::fs::remove_file(&fresh).ok();
    }

    #[test]
    fn bench_diff_requires_both_paths() {
        let f = flags(&[("base", "x.json")]);
        assert!(BenchDiffArgs::parse(&f).is_err());
        // --new alone is not enough either: there must be a baseline to
        // diff against or a --write-baseline to produce
        let f = flags(&[("new", "y.json")]);
        assert!(BenchDiffArgs::parse(&f).is_err());
        let f = flags(&[("new", "y.json"), ("write-baseline", "b.json")]);
        assert!(BenchDiffArgs::parse(&f).is_ok());
    }

    #[test]
    fn bench_diff_write_baseline_round_trips() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let fresh = dir.join(format!("spa_cli_bd_wb_new_{pid}.json"));
        let written = dir.join(format!("spa_cli_bd_wb_out_{pid}.json"));
        // duplicate names collapse (later wins) and extra fields drop
        std::fs::write(
            &fresh,
            r#"[{"name":"a","ns_per_iter":120.0,"iters":3},
                {"name":"b","ns_per_iter":7.5,"iters":9},
                {"name":"a","ns_per_iter":130.0,"iters":3}]"#,
        )
        .unwrap();
        run(vec![
            "bench-diff".into(),
            "--new".into(),
            fresh.to_str().unwrap().into(),
            "--write-baseline".into(),
            written.to_str().unwrap().into(),
        ])
        .unwrap();
        let loaded = load_bench(written.to_str().unwrap()).unwrap();
        assert_eq!(
            loaded,
            vec![("a".to_string(), 130.0), ("b".to_string(), 7.5)]
        );
        std::fs::remove_file(&fresh).ok();
        std::fs::remove_file(&written).ok();
    }

    #[test]
    fn bench_delta_treats_zero_or_missing_baseline_as_no_baseline() {
        // the regression gate must never divide by a zero-time record
        assert_eq!(bench_delta(None, 130.0), None);
        assert_eq!(bench_delta(Some(0.0), 130.0), None);
        assert_eq!(bench_delta(Some(-5.0), 130.0), None);
        let pct = bench_delta(Some(100.0), 130.0).unwrap();
        assert!((pct - 30.0).abs() < 1e-9, "got {pct}");
    }

    #[test]
    fn bench_diff_zero_time_baseline_is_notice_only() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let base = dir.join(format!("spa_cli_bd_zero_{pid}.json"));
        let fresh = dir.join(format!("spa_cli_bd_zero_new_{pid}.json"));
        std::fs::write(&base, r#"[{"name":"a","ns_per_iter":0.0,"iters":0}]"#).unwrap();
        std::fs::write(&fresh, r#"[{"name":"a","ns_per_iter":130.0,"iters":3}]"#).unwrap();
        run(vec![
            "bench-diff".into(),
            "--base".into(),
            base.to_str().unwrap().into(),
            "--new".into(),
            fresh.to_str().unwrap().into(),
        ])
        .unwrap();
        std::fs::remove_file(&base).ok();
        std::fs::remove_file(&fresh).ok();
    }

    #[test]
    fn bench_diff_json_reports_stale_zero_time_baseline() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let base = dir.join(format!("spa_cli_bd_stale_{pid}.json"));
        let fresh = dir.join(format!("spa_cli_bd_stale_new_{pid}.json"));
        let out = dir.join(format!("spa_cli_bd_stale_out_{pid}.json"));
        std::fs::write(&base, r#"[{"name":"a","ns_per_iter":0.0,"iters":0}]"#).unwrap();
        std::fs::write(&fresh, r#"[{"name":"a","ns_per_iter":130.0,"iters":3}]"#).unwrap();
        run(vec![
            "bench-diff".into(),
            "--base".into(),
            base.to_str().unwrap().into(),
            "--new".into(),
            fresh.to_str().unwrap().into(),
            "--json".into(),
            out.to_str().unwrap().into(),
        ])
        .unwrap();
        let j = crate::util::parse_json(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.field("stale_baseline").unwrap().as_bool(), Some(true));
        assert_eq!(j.field("compared").unwrap().as_usize(), Some(0));
        let rows = j.field("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].field("status").unwrap().as_str(), Some("no baseline"));
        std::fs::remove_file(&base).ok();
        std::fs::remove_file(&fresh).ok();
        std::fs::remove_file(&out).ok();
    }

    #[test]
    fn profile_command_writes_a_json_report() {
        let dir = std::env::temp_dir();
        let out = dir.join(format!("spa_cli_profile_{}.json", std::process::id()));
        run(vec![
            "profile".into(),
            "--model".into(),
            "mlp".into(),
            "--hw".into(),
            "8".into(),
            "--runs".into(),
            "2".into(),
            "--json".into(),
            out.to_str().unwrap().into(),
        ])
        .unwrap();
        let j = crate::util::parse_json(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.field("runs").unwrap().as_usize(), Some(2));
        assert!(!j.field("rows").unwrap().as_arr().unwrap().is_empty());
        std::fs::remove_file(&out).ok();
        // --runs 0 is a parse error, not a silent empty report
        assert!(ProfileArgs::parse(&flags(&[("runs", "0")])).is_err());
    }

    #[test]
    fn trace_command_writes_chrome_json_and_metrics() {
        // toggles the global trace flag: serialize with other obs tests
        let _guard = crate::util::par::test_lock();
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let out = dir.join(format!("spa_cli_trace_{pid}.json"));
        let prom = dir.join(format!("spa_cli_trace_{pid}.prom"));
        run(vec![
            "trace".into(),
            "--model".into(),
            "mlp".into(),
            "--hw".into(),
            "8".into(),
            "--requests".into(),
            "2".into(),
            "--out".into(),
            out.to_str().unwrap().into(),
            "--metrics".into(),
            prom.to_str().unwrap().into(),
        ])
        .unwrap();
        let j = crate::util::parse_json(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let events = j.field("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty(), "demo run must record trace events");
        let text = std::fs::read_to_string(&prom).unwrap();
        assert!(text.contains("spa_requests_total"), "got: {text}");
        std::fs::remove_file(&out).ok();
        std::fs::remove_file(&prom).ok();
    }

    #[test]
    fn lint_command_passes_on_a_small_model() {
        run(vec![
            "lint".into(),
            "--model".into(),
            "mlp".into(),
            "--hw".into(),
            "8".into(),
            "--level".into(),
            "strict".into(),
        ])
        .unwrap();
    }

    #[test]
    fn lint_rejects_unknown_level_and_model() {
        let f = flags(&[("level", "paranoid")]);
        assert!(LintArgs::parse(&f).is_err());
        assert!(run(vec!["lint".into(), "--model".into(), "nope".into()]).is_err());
    }
}
