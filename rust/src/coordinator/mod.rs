//! Prune-any-time coordinator (paper §3.3): the three pipelines the paper
//! defines, each a composition of the same primitives —
//!
//! * **prune-train**: score at initialization (SNIP/CroP/GraSP family),
//!   structurally prune, then train the sparse model to convergence;
//! * **train-prune-finetune**: train dense, prune (L1/Taylor family,
//!   one-shot or iterative), fine-tune;
//! * **train-prune**: train dense, prune with OBSPA (ID/OOD/DataFree) or
//!   the DFPC baseline, **no** fine-tuning.
//!
//! All structural pruning inside the pipelines goes through the one
//! [`crate::session::Session`] entry point; this module adds the
//! training/evaluation choreography around it. Every pipeline returns a
//! [`PipelineReport`] with the paper's metrics (ori/pruned acc, RF, RP,
//! wallclock) so benches print tables directly.

pub mod cli;

use crate::analysis;
use crate::baselines;
use crate::criteria::{Criterion, Saliency, SaliencyRef};
use crate::data::ImageDataset;
use crate::ir::Graph;
use crate::obspa::{self, CalibSource, ObspaCfg};
use crate::prune::{Agg, Norm, Scope};
use crate::session::{Session, Target};
use crate::train::{self, TrainCfg};
use crate::util::Rng;

/// When pruning happens relative to training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruneTime {
    PruneTrain,
    TrainPruneFinetune,
    TrainPrune,
}

/// Full pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineCfg {
    /// Saliency criterion — a built-in `Criterion` converts via
    /// `.into()`, and `Criterion::parse` / user-registered criteria
    /// resolve to the same handle type.
    pub criterion: SaliencyRef,
    pub scope: Scope,
    pub agg: Agg,
    pub norm: Norm,
    pub target_rf: f64,
    pub min_keep: usize,
    /// Iterative pruning: number of prune→tune rounds (1 = one-shot).
    pub iterations: usize,
    pub train: TrainCfg,
    pub finetune: TrainCfg,
    pub seed: u64,
}

impl Default for PipelineCfg {
    fn default() -> Self {
        PipelineCfg {
            criterion: Criterion::L1.into(),
            scope: Scope::FullCc,
            agg: Agg::Sum,
            norm: Norm::Mean,
            target_rf: 2.0,
            min_keep: 1,
            iterations: 1,
            train: TrainCfg {
                steps: 150,
                ..Default::default()
            },
            finetune: TrainCfg {
                steps: 80,
                lr: 0.02,
                ..Default::default()
            },
            seed: 0xAB5,
        }
    }
}

/// The paper's per-experiment row.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub model: String,
    pub ori_acc: f32,
    pub pruned_acc: f32,
    pub final_acc: f32,
    pub rf: f64,
    pub rp: f64,
    pub seconds: f64,
    pub loss_history: Vec<train::LogEntry>,
}

/// One structural pruning round to an RF target, through the session
/// API (drawing a calibration batch when the criterion needs one).
fn prune_round(
    g: &mut Graph,
    ds: &ImageDataset,
    cfg: &PipelineCfg,
    round_rf: f64,
) -> anyhow::Result<()> {
    let mut session = Session::on(&*g)
        .criterion(cfg.criterion.clone())
        .scope(cfg.scope)
        .agg(cfg.agg)
        .norm(cfg.norm)
        .min_keep(cfg.min_keep)
        .target(Target::FlopsRf(round_rf));
    if cfg.criterion.needs_data() {
        let (x, labels) = ds.train_batch_seeded(cfg.seed, 32);
        session = session.batch(x, labels);
    }
    let pruned = session.plan()?.apply()?;
    *g = pruned.graph;
    Ok(())
}

/// train-prune-finetune (optionally iterative, paper's "it" variants).
pub fn train_prune_finetune(
    mut g: Graph,
    ds: &ImageDataset,
    cfg: &PipelineCfg,
) -> anyhow::Result<(Graph, PipelineReport)> {
    let t0 = std::time::Instant::now();
    let mut history = Vec::new();
    let dense = {
        let rep = train::train(&mut g, ds, &cfg.train)?;
        history.extend(rep.history);
        g.clone()
    };
    let ori_acc = train::evaluate(&g, ds, 256)?;
    let per_round_rf = cfg.target_rf.powf(1.0 / cfg.iterations as f64);
    let mut cumulative = 1.0f64;
    for round in 0..cfg.iterations {
        cumulative *= per_round_rf;
        // target is cumulative w.r.t. the dense model
        let cur = analysis::flops(&dense) as f64 / analysis::flops(&g) as f64;
        let need = (cumulative / cur).max(1.0);
        prune_round(&mut g, ds, cfg, need)?;
        if cfg.iterations > 1 && round + 1 < cfg.iterations {
            // short inter-round tuning (paper: 5 epochs between steps)
            let mut inter = cfg.finetune.clone();
            inter.steps = (cfg.finetune.steps / cfg.iterations).max(10);
            let rep = train::train(&mut g, ds, &inter)?;
            history.extend(rep.history);
        }
    }
    let pruned_acc = train::evaluate(&g, ds, 256)?;
    let rep = train::train(&mut g, ds, &cfg.finetune)?;
    history.extend(rep.history);
    let final_acc = train::evaluate(&g, ds, 256)?;
    let r = analysis::reduction(&dense, &g);
    Ok((
        g.clone(),
        PipelineReport {
            model: g.name.clone(),
            ori_acc,
            pruned_acc,
            final_acc,
            rf: r.rf,
            rp: r.rp,
            seconds: t0.elapsed().as_secs_f64(),
            loss_history: history,
        },
    ))
}

/// prune-train: prune at initialization, then train to convergence.
pub fn prune_train(
    mut g: Graph,
    ds: &ImageDataset,
    cfg: &PipelineCfg,
) -> anyhow::Result<(Graph, PipelineReport)> {
    let t0 = std::time::Instant::now();
    let dense = g.clone();
    prune_round(&mut g, ds, cfg, cfg.target_rf)?;
    let pruned_acc = train::evaluate(&g, ds, 256)?; // chance level
    let rep = train::train(&mut g, ds, &cfg.train)?;
    let final_acc = train::evaluate(&g, ds, 256)?;
    let r = analysis::reduction(&dense, &g);
    Ok((
        g.clone(),
        PipelineReport {
            model: g.name.clone(),
            ori_acc: f32::NAN, // no dense training in this setting
            pruned_acc,
            final_acc,
            rf: r.rf,
            rp: r.rp,
            seconds: t0.elapsed().as_secs_f64(),
            loss_history: rep.history,
        },
    ))
}

/// Which train-prune (no fine-tune) algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoFinetuneAlgo {
    Obspa(CalibSource),
    Dfpc,
}

/// train-prune: prune a trained model with no recovery training.
pub fn train_prune(
    mut g: Graph,
    ds: &ImageDataset,
    ood: Option<&ImageDataset>,
    algo: NoFinetuneAlgo,
    target_rf: f64,
    cfg: &PipelineCfg,
) -> anyhow::Result<(Graph, PipelineReport)> {
    let t0 = std::time::Instant::now();
    train::train(&mut g, ds, &cfg.train)?;
    let dense = g.clone();
    let ori_acc = train::evaluate(&g, ds, 256)?;
    match algo {
        NoFinetuneAlgo::Obspa(source) => {
            let calib = match source {
                CalibSource::InDistribution => ds.train_batch_seeded(cfg.seed, 128).0,
                CalibSource::OutOfDistribution => ood
                    .ok_or_else(|| anyhow::anyhow!("OOD source requires an OOD dataset"))?
                    .train_batch_seeded(cfg.seed, 128)
                    .0,
                CalibSource::DataFree => {
                    let mut rng = Rng::new(cfg.seed);
                    obspa::datafree_calib(&g, 128, &mut rng)
                }
            };
            obspa::obspa_prune(
                &mut g,
                &calib,
                &ObspaCfg {
                    target_rf,
                    min_keep: cfg.min_keep,
                    bn_recalibrate: source != CalibSource::DataFree,
                    agg: cfg.agg,
                    norm: cfg.norm,
                    ..Default::default()
                },
            )?;
        }
        NoFinetuneAlgo::Dfpc => {
            baselines::dfpc_prune(&mut g, target_rf, cfg.min_keep)?;
        }
    }
    let final_acc = train::evaluate(&g, ds, 256)?;
    let r = analysis::reduction(&dense, &g);
    Ok((
        g.clone(),
        PipelineReport {
            model: g.name.clone(),
            ori_acc,
            pruned_acc: final_acc,
            final_acc,
            rf: r.rf,
            rp: r.rp,
            seconds: t0.elapsed().as_secs_f64(),
            loss_history: Vec::new(),
        },
    ))
}

/// Early pruning (paper §2, Rachwan et al. 2022 / You et al. 2020):
/// train briefly, prune once, then train to convergence — between
/// prune-train and train-prune-finetune on the pruning-time axis.
pub fn early_prune(
    mut g: Graph,
    ds: &ImageDataset,
    cfg: &PipelineCfg,
    warmup_steps: usize,
) -> anyhow::Result<(Graph, PipelineReport)> {
    let t0 = std::time::Instant::now();
    let dense = g.clone();
    let mut warm = cfg.train.clone();
    warm.steps = warmup_steps;
    train::train(&mut g, ds, &warm)?;
    prune_round(&mut g, ds, cfg, cfg.target_rf)?;
    let pruned_acc = train::evaluate(&g, ds, 256)?;
    let rep = train::train(&mut g, ds, &cfg.train)?;
    let final_acc = train::evaluate(&g, ds, 256)?;
    let r = analysis::reduction(&dense, &g);
    Ok((
        g.clone(),
        PipelineReport {
            model: g.name.clone(),
            ori_acc: f32::NAN,
            pruned_acc,
            final_acc,
            rf: r.rf,
            rp: r.rp,
            seconds: t0.elapsed().as_secs_f64(),
            loss_history: rep.history,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::{self, ImageCfg};

    fn tiny_cfg() -> PipelineCfg {
        PipelineCfg {
            target_rf: 1.4,
            train: TrainCfg {
                steps: 60,
                lr: 0.05,
                ..Default::default()
            },
            finetune: TrainCfg {
                steps: 30,
                lr: 0.02,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn tpf_pipeline_end_to_end() {
        let icfg = ImageCfg {
            hw: 8,
            classes: 4,
            ..Default::default()
        };
        let ds = ImageDataset::synth_cifar(4, 384, 8, 3, 21);
        let g = zoo::resnet18(icfg, 1);
        let (pruned, rep) = train_prune_finetune(g, &ds, &tiny_cfg()).unwrap();
        pruned.validate().unwrap();
        assert!(rep.rf >= 1.4, "rf {}", rep.rf);
        assert!(rep.ori_acc > 0.4, "ori {}", rep.ori_acc);
        assert!(rep.final_acc > rep.ori_acc - 0.3);
    }

    #[test]
    fn prune_train_pipeline() {
        let icfg = ImageCfg {
            hw: 8,
            classes: 4,
            ..Default::default()
        };
        let ds = ImageDataset::synth_cifar(4, 384, 8, 3, 22);
        let g = zoo::vgg16(icfg, 2);
        let mut cfg = tiny_cfg();
        cfg.criterion = Criterion::Snip.into();
        let (pruned, rep) = prune_train(g, &ds, &cfg).unwrap();
        pruned.validate().unwrap();
        assert!(rep.rf >= 1.4);
        assert!(rep.final_acc > 0.4, "final {}", rep.final_acc);
    }

    #[test]
    fn early_prune_pipeline() {
        let icfg = ImageCfg {
            hw: 8,
            classes: 4,
            ..Default::default()
        };
        let ds = ImageDataset::synth_cifar(4, 384, 8, 3, 24);
        let mut cfg = tiny_cfg();
        cfg.criterion = Criterion::Crop.into(); // the early-pruning criterion
        let (pruned, rep) = early_prune(zoo::resnet18(icfg, 4), &ds, &cfg, 20).unwrap();
        pruned.validate().unwrap();
        assert!(rep.rf >= 1.4);
        assert!(rep.final_acc > 0.4, "final {}", rep.final_acc);
    }

    #[test]
    fn train_prune_obspa_vs_dfpc_ordering() {
        let icfg = ImageCfg {
            hw: 8,
            classes: 4,
            ..Default::default()
        };
        let ds = ImageDataset::synth_cifar(4, 384, 8, 3, 23);
        let cfg = tiny_cfg();
        let (_, obspa_rep) = train_prune(
            zoo::resnet18(icfg, 3),
            &ds,
            None,
            NoFinetuneAlgo::Obspa(CalibSource::InDistribution),
            1.3,
            &cfg,
        )
        .unwrap();
        let (_, dfpc_rep) = train_prune(
            zoo::resnet18(icfg, 3),
            &ds,
            None,
            NoFinetuneAlgo::Dfpc,
            1.3,
            &cfg,
        )
        .unwrap();
        // the Tab. 4 shape: OBSPA's drop is smaller (allow small slack)
        let obspa_drop = obspa_rep.ori_acc - obspa_rep.final_acc;
        let dfpc_drop = dfpc_rep.ori_acc - dfpc_rep.final_acc;
        assert!(
            obspa_drop <= dfpc_drop + 0.05,
            "obspa drop {obspa_drop} vs dfpc {dfpc_drop}"
        );
    }
}
