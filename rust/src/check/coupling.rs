//! Prune-coupling invariants (the paper's §3.2 made checkable).
//!
//! Structured pruning is only sound when every coupled channel set is
//! pruned *identically* across the operators it ties together: both
//! branches of a residual add keep the same channels, concat offsets are
//! re-based after upstream deletions, grouped convolutions keep a channel
//! count divisible by `groups`. [`check_widths`] verifies those
//! cross-operator width agreements directly on declared shapes — with
//! group-flavored messages, so an inconsistently pruned residual reads as
//! a coupling violation rather than a generic shape error.
//! [`check_coupling`] re-derives the dependency groups with
//! [`crate::prune::build_groups`] and validates their global invariant
//! (every prunable source channel belongs to exactly one coupled set);
//! [`check_pruned`] audits an applied prune against the selection that
//! produced it.

use crate::ir::shape::broadcast_ok;
use crate::ir::{Graph, OpKind};
use crate::prune::{build_groups, prunable_source, Groups, Loc};
use std::collections::{HashMap, HashSet};

/// Cross-operator channel-width agreement on declared shapes. Runs before
/// shape re-derivation in [`super::check_graph`] so coupling violations
/// get coupling-flavored diagnostics.
pub fn check_widths(g: &Graph) -> anyhow::Result<()> {
    for op in &g.ops {
        // Rewrite passes neutralize dead operators by emptying their
        // endpoints; those carry no constraints.
        if op.inputs.is_empty() || op.outputs.is_empty() {
            continue;
        }
        let shape = |i: usize| &g.datas[op.inputs[i]].shape;
        let iname = |i: usize| g.datas[op.inputs[i]].name.as_str();
        match &op.kind {
            OpKind::Add | OpKind::Mul => {
                if op.inputs.len() != 2 {
                    continue;
                }
                let (a, b) = (shape(0), shape(1));
                if a == b || broadcast_ok(a, b) {
                    continue;
                }
                if a.len() == b.len() {
                    let d = a.iter().zip(b).position(|(x, y)| x != y).unwrap_or(0);
                    anyhow::bail!(
                        "residual group at `{}`: coupled inputs disagree on dim {d} — \
                         `{}` has {} where `{}` has {} (inconsistently pruned group?)",
                        op.name,
                        iname(0),
                        a[d],
                        iname(1),
                        b[d]
                    );
                }
                anyhow::bail!(
                    "residual group at `{}`: inputs `{}` {:?} and `{}` {:?} are not \
                     shape-compatible",
                    op.name,
                    iname(0),
                    a,
                    iname(1),
                    b
                );
            }
            OpKind::Concat { axis } => {
                let rank = shape(0).len();
                anyhow::ensure!(
                    *axis < rank,
                    "concat `{}`: axis {axis} out of rank {rank}",
                    op.name
                );
                let mut sum = 0usize;
                for i in 0..op.inputs.len() {
                    let s = shape(i);
                    anyhow::ensure!(
                        s.len() == rank,
                        "concat group at `{}`: input `{}` has rank {} where `{}` has {}",
                        op.name,
                        iname(i),
                        s.len(),
                        iname(0),
                        rank
                    );
                    for d in 0..rank {
                        if d == *axis {
                            continue;
                        }
                        anyhow::ensure!(
                            s[d] == shape(0)[d],
                            "concat group at `{}`: input `{}` has {} on dim {d} where `{}` \
                             has {} (inconsistently pruned group?)",
                            op.name,
                            iname(i),
                            s[d],
                            iname(0),
                            shape(0)[d]
                        );
                    }
                    sum += s[*axis];
                }
                let out = &g.datas[op.outputs[0]].shape;
                anyhow::ensure!(
                    out.len() == rank && out[*axis] == sum,
                    "concat group at `{}`: output `{}` declares {} on axis {axis} but the \
                     inputs sum to {sum} (stale concat offsets?)",
                    op.name,
                    g.datas[op.outputs[0]].name,
                    out.get(*axis).copied().unwrap_or(0)
                );
            }
            OpKind::Conv2d { groups, .. } => {
                if op.inputs.len() < 2 {
                    continue;
                }
                let (x, w) = (shape(0), shape(1));
                if x.len() != 4 || w.len() != 4 {
                    continue; // rank errors belong to the shape checker
                }
                anyhow::ensure!(
                    w[0] % groups == 0,
                    "group-conv `{}`: {} output channels not divisible by groups={} \
                     (channels pruned without respecting conv groups?)",
                    op.name,
                    w[0],
                    groups
                );
                anyhow::ensure!(
                    x[1] == w[1] * groups,
                    "conv group at `{}`: input `{}` carries {} channels but weight `{}` \
                     expects {}×{} (inconsistently pruned group?)",
                    op.name,
                    iname(0),
                    x[1],
                    iname(1),
                    w[1],
                    groups
                );
                if op.inputs.len() > 2 {
                    anyhow::ensure!(
                        shape(2) == &vec![w[0]],
                        "conv group at `{}`: bias `{}` has {:?} entries but weight keeps \
                         {} output channels (inconsistently pruned group?)",
                        op.name,
                        iname(2),
                        shape(2),
                        w[0]
                    );
                }
            }
            OpKind::Gemm => {
                if op.inputs.len() < 2 {
                    continue;
                }
                let (x, w) = (shape(0), shape(1));
                if w.len() != 2 || x.is_empty() {
                    continue;
                }
                anyhow::ensure!(
                    x.last() == Some(&w[1]),
                    "gemm group at `{}`: input `{}` ends in {} features but weight `{}` \
                     expects {} (inconsistently pruned group?)",
                    op.name,
                    iname(0),
                    x.last().unwrap(),
                    iname(1),
                    w[1]
                );
                if op.inputs.len() > 2 {
                    anyhow::ensure!(
                        shape(2) == &vec![w[0]],
                        "gemm group at `{}`: bias `{}` has {:?} entries but weight keeps \
                         {} output features (inconsistently pruned group?)",
                        op.name,
                        iname(2),
                        shape(2),
                        w[0]
                    );
                }
            }
            OpKind::BatchNorm { .. } => {
                if op.inputs.len() != 5 || shape(0).len() < 2 {
                    continue;
                }
                let c = shape(0)[1];
                for i in 1..5 {
                    anyhow::ensure!(
                        shape(i) == &vec![c],
                        "norm group at `{}`: param `{}` has {:?} channels but the input \
                         carries {c} (inconsistently pruned group?)",
                        op.name,
                        iname(i),
                        shape(i)
                    );
                }
            }
            OpKind::LayerNorm { .. } => {
                if op.inputs.len() != 3 || shape(0).is_empty() {
                    continue;
                }
                let d = *shape(0).last().unwrap();
                for i in 1..3 {
                    anyhow::ensure!(
                        shape(i) == &vec![d],
                        "norm group at `{}`: param `{}` has {:?} features but the input \
                         ends in {d} (inconsistently pruned group?)",
                        op.name,
                        iname(i),
                        shape(i)
                    );
                }
            }
            OpKind::SplitHeads { heads } => {
                let x = shape(0);
                if x.len() != 3 {
                    continue;
                }
                anyhow::ensure!(
                    x[2] % heads == 0,
                    "attention group at `{}`: hidden dim {} not divisible by heads={} \
                     (pruned unevenly across heads?)",
                    op.name,
                    x[2],
                    heads
                );
            }
            _ => {}
        }
    }
    Ok(())
}

/// Re-derive the dependency groups and verify their global invariant:
/// every channel of every prunable source parameter (conv/gemm weight
/// out-dim) belongs to *exactly one* coupled channel set, and every
/// recorded location is in range. A violation means the mask propagation
/// double-counted or dropped channels — pruning on such groups would
/// delete the wrong slices.
pub fn check_coupling(g: &Graph) -> anyhow::Result<()> {
    let groups = build_groups(g)?;
    // (source param, out dim) universe the partition must cover
    let mut sources: HashMap<usize, (usize, String)> = HashMap::new();
    for op in &g.ops {
        if let Some((src, dim)) = prunable_source(g, op.id) {
            let d = g.data(src);
            anyhow::ensure!(
                dim < d.shape.len(),
                "op `{}`: prunable dim {dim} out of rank for `{}`",
                op.name,
                d.name
            );
            sources.insert(src, (d.shape[dim], d.name.clone()));
        }
    }
    let mut owner: HashMap<Loc, usize> = HashMap::new();
    for gr in &groups.groups {
        let src_name = &g.op(gr.source_op).name;
        for cc in &gr.ccs {
            for l in cc.locs.iter().chain(&cc.acts) {
                anyhow::ensure!(
                    l.data < g.datas.len(),
                    "group {} (source `{src_name}`): location references data id {} out \
                     of range",
                    gr.id,
                    l.data
                );
                let d = g.data(l.data);
                anyhow::ensure!(
                    l.dim < d.shape.len() && l.idx < d.shape[l.dim],
                    "group {} (source `{src_name}`): channel {} of `{}` dim {} is out of \
                     range for shape {:?}",
                    gr.id,
                    l.idx,
                    d.name,
                    l.dim,
                    d.shape
                );
            }
            for l in &cc.locs {
                if l.dim != 0 || !sources.contains_key(&l.data) {
                    continue;
                }
                if let Some(&prev) = owner.get(l) {
                    if prev != gr.id {
                        anyhow::bail!(
                            "channel {} of `{}` is claimed by both group {prev} (source \
                             `{}`) and group {} (source `{src_name}`)",
                            l.idx,
                            g.data(l.data).name,
                            g.op(groups.groups[prev].source_op).name,
                            gr.id
                        );
                    }
                } else {
                    owner.insert(*l, gr.id);
                }
            }
        }
    }
    for (&src, &(channels, ref name)) in &sources {
        for c in 0..channels {
            anyhow::ensure!(
                owner.contains_key(&Loc {
                    data: src,
                    dim: 0,
                    idx: c
                }),
                "channel {c} of `{name}` is not covered by any dependency group",
            );
        }
    }
    Ok(())
}

/// Audit an applied prune: for every parameter a selected coupled channel
/// set touches, the pruned graph must have removed *exactly* those
/// channels — no more, no fewer. Activations are not audited here;
/// [`super::check_graph`] on the pruned graph re-derives them.
pub fn check_pruned(
    original: &Graph,
    groups: &Groups,
    selected: &[(usize, usize)],
    pruned: &Graph,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        original.datas.len() == pruned.datas.len(),
        "pruned graph has {} data nodes, original had {} (pruning must keep ids stable)",
        pruned.datas.len(),
        original.datas.len()
    );
    // per (data, dim): deleted channel set + the group that owns it
    let mut deleted: HashMap<(usize, usize), HashSet<usize>> = HashMap::new();
    let mut blame: HashMap<(usize, usize), usize> = HashMap::new();
    for &(gi, ci) in selected {
        anyhow::ensure!(
            gi < groups.groups.len(),
            "selection references group {gi} but only {} groups exist",
            groups.groups.len()
        );
        let gr = &groups.groups[gi];
        anyhow::ensure!(
            gr.prunable,
            "selection prunes group {gi} (source `{}`) which is marked un-prunable",
            original.op(gr.source_op).name
        );
        anyhow::ensure!(
            ci < gr.ccs.len(),
            "selection references coupled set {ci} of group {gi} but it has only {}",
            gr.ccs.len()
        );
        for l in &gr.ccs[ci].locs {
            let d = original.data(l.data);
            anyhow::ensure!(
                l.dim < d.shape.len() && l.idx < d.shape[l.dim],
                "group {gi}: channel {} of `{}` dim {} out of range for {:?}",
                l.idx,
                d.name,
                l.dim,
                d.shape
            );
            deleted.entry((l.data, l.dim)).or_default().insert(l.idx);
            blame.entry((l.data, l.dim)).or_insert(gi);
        }
    }
    for (&(data, dim), idxs) in &deleted {
        let orig = &original.data(data).shape;
        let now = &pruned.data(data).shape;
        let expect = orig[dim] - idxs.len();
        anyhow::ensure!(
            now.len() == orig.len(),
            "after pruning, `{}` changed rank ({} → {})",
            original.data(data).name,
            orig.len(),
            now.len()
        );
        let gi = blame[&(data, dim)];
        anyhow::ensure!(
            now[dim] == expect,
            "after pruning, `{}` kept {} channels on dim {dim} but group {gi} (source \
             `{}`) expected {expect} ({} of {} deleted)",
            original.data(data).name,
            now[dim],
            original.op(groups.groups[gi].source_op).name,
            idxs.len(),
            orig[dim]
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::tests::{corrupt_residual_branch, resnet_like};
    use crate::ir::GraphBuilder;

    #[test]
    fn widths_flag_corrupt_residual_as_group_violation() {
        let mut g = resnet_like();
        corrupt_residual_branch(&mut g);
        let err = check_widths(&g).unwrap_err().to_string();
        assert!(err.contains("residual group at `add`"), "got: {err}");
        assert!(err.contains('7') && err.contains('8'), "got: {err}");
    }

    #[test]
    fn widths_flag_stale_concat_offsets() {
        let mut b = GraphBuilder::new("cat", 1);
        let x = b.input("x", vec![1, 4, 6, 6]);
        let c1 = b.conv2d("c1", x, 4, 3, 1, 1, 1, false);
        let cat = b.concat("cat", &[x, c1], 1);
        let c2 = b.conv2d("c2", cat, 6, 3, 1, 1, 1, false);
        let gp = b.global_avgpool("gap", c2);
        let fc = b.gemm("fc", gp, 2, false);
        b.output(fc);
        let mut g = b.finish().unwrap();
        check_widths(&g).unwrap();
        // pretend an upstream prune shrank the concat without re-basing
        let cat_out = g.op_by_name("cat").unwrap().outputs[0];
        g.datas[cat_out].shape[1] = 7;
        let err = check_widths(&g).unwrap_err().to_string();
        assert!(err.contains("stale concat offsets"), "got: {err}");
        assert!(err.contains("cat"), "got: {err}");
    }

    #[test]
    fn widths_flag_group_conv_divisibility() {
        let mut b = GraphBuilder::new("grp", 2);
        let x = b.input("x", vec![1, 4, 6, 6]);
        let c0 = b.conv2d("c0", x, 8, 1, 1, 0, 1, false);
        let c1 = b.conv2d("c1", c0, 8, 3, 1, 1, 4, false);
        let gp = b.global_avgpool("gap", c1);
        let fc = b.gemm("fc", gp, 2, false);
        b.output(fc);
        let mut g = b.finish().unwrap();
        check_widths(&g).unwrap();
        // shrink c1's out-channels to 7: 7 % 4 != 0
        let w = g.data_by_name("c1.w").unwrap().id;
        g.datas[w].shape[0] = 7;
        let t = g.datas[w].param_mut().unwrap();
        let inner: usize = t.shape[1..].iter().product();
        t.shape[0] = 7;
        t.data.truncate(7 * inner);
        let err = check_widths(&g).unwrap_err().to_string();
        assert!(err.contains("group-conv `c1`"), "got: {err}");
        assert!(err.contains("groups=4"), "got: {err}");
    }

    #[test]
    fn coupling_passes_on_clean_graphs() {
        check_coupling(&resnet_like()).unwrap();
    }

    #[test]
    fn pruned_audit_accepts_a_real_prune() {
        let g = resnet_like();
        let groups = build_groups(&g).unwrap();
        // prune two coupled sets from the residual group, one from c1's
        let selected = vec![(0usize, 0usize), (0, 3), (1, 5)];
        let mut pruned = g.clone();
        crate::prune::apply_pruning(&mut pruned, &groups, &selected).unwrap();
        check_pruned(&g, &groups, &selected, &pruned).unwrap();
        crate::check::check_graph(&pruned).unwrap();
    }

    #[test]
    fn pruned_audit_rejects_a_tampered_result() {
        let g = resnet_like();
        let groups = build_groups(&g).unwrap();
        let selected = vec![(0usize, 0usize)];
        let mut pruned = g.clone();
        crate::prune::apply_pruning(&mut pruned, &groups, &selected).unwrap();
        // tamper: delete one extra channel from c2.w behind the audit's back
        let w = pruned.data_by_name("c2.w").unwrap().id;
        pruned.datas[w].shape[0] -= 1;
        let err = check_pruned(&g, &groups, &selected, &pruned)
            .unwrap_err()
            .to_string();
        assert!(err.contains("c2.w"), "got: {err}");
        assert!(err.contains("group"), "got: {err}");
    }

    #[test]
    fn pruned_audit_rejects_unprunable_selection() {
        let g = resnet_like();
        let groups = build_groups(&g).unwrap();
        let fc_group = groups
            .groups
            .iter()
            .position(|gr| !gr.prunable)
            .expect("classifier group must be un-prunable");
        let err = check_pruned(&g, &groups, &[(fc_group, 0)], &g)
            .unwrap_err()
            .to_string();
        assert!(err.contains("un-prunable"), "got: {err}");
    }
}
