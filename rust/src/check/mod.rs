//! `spa::check` — static verification of graphs and compiled plans.
//!
//! The paper's "any architecture" claim rests on invariants that are easy
//! to state and easy to silently break: every operator's declared output
//! shape must follow from its inputs, every dependency group must prune
//! its coupled producers/consumers identically (residual adds, re-based
//! concat offsets, group-conv divisibility), and a compiled
//! [`crate::exec::Plan`] must never let two simultaneously-live
//! intermediates share an arena slot. Numeric parity tests catch
//! violations only probabilistically; this module checks them
//! *statically*, so a broken rewrite pass or a corrupted checkpoint fails
//! at check time with a message naming the offending node — not at kernel
//! time with a slice panic, and not at serve time with wrong logits.
//!
//! Three verifiers:
//!
//! * [`check_graph`] — shape/dtype abstract interpretation over the IR
//!   ([`shape`]) plus prune-coupling invariants ([`coupling`]): declared
//!   metadata is diffed against re-derived shapes, coupled channel widths
//!   are cross-checked at every residual add / concat / group conv, and
//!   the dependency groups from [`crate::prune::build_groups`] are
//!   validated (source channels partition exactly into coupled sets).
//! * [`check_pruned`] — provenance check after [`crate::session`]
//!   applies a plan: every selected coupled-channel set must have removed
//!   exactly its channels from every parameter it touches.
//! * [`check_plan`] — verifies a compiled [`crate::exec::Plan`] before
//!   its first run ([`plan`]): the schedule is a valid topological order,
//!   fused post-op chains are well-formed, reshape aliases point at live
//!   buffers, and the arena assignment never overwrites a slot whose
//!   current value is still needed.
//!
//! Wiring: [`CheckLevel`] gates the checks in
//! [`crate::exec::PlanOpts::check`] and [`crate::session::Session::check`]
//! (default [`CheckLevel::Debug`] under `debug_assertions`, `Off` in
//! release). [`crate::ir::passes::optimize_checked`] re-runs
//! [`check_graph`] after every rewrite pass, checkpoint loading
//! ([`crate::ir::serde::load_graph`]) always verifies, the serve-layer
//! plan cache refuses to cache a plan that fails [`check_plan`], and
//! `spa lint <model>` runs every checker across the zoo from the CLI.

pub mod coupling;
pub mod plan;
pub mod shape;

pub use coupling::{check_coupling, check_pruned};
pub use plan::check_plan;
pub use shape::check_shapes;

use crate::ir::Graph;

/// How much static checking to run at the wired-in sites.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CheckLevel {
    /// No static checks (release-build default).
    Off,
    /// Run every checker at its wiring point (debug-build default): after
    /// `Session` applies a prune, after every `ir::passes` pass inside
    /// [`crate::ir::passes::optimize_checked`], and on every compiled
    /// plan.
    Debug,
    /// Everything `Debug` runs, plus a full graph re-check inside
    /// [`crate::exec::Plan::compile`] — the explicit opt-in for CI lint
    /// lanes and serving fleets that want checkpoints and plans verified
    /// in release builds too.
    Strict,
}

impl Default for CheckLevel {
    /// `Debug` when compiled with `debug_assertions`, `Off` otherwise.
    fn default() -> CheckLevel {
        if cfg!(debug_assertions) {
            CheckLevel::Debug
        } else {
            CheckLevel::Off
        }
    }
}

impl CheckLevel {
    /// Whether any checking runs at this level.
    pub fn enabled(self) -> bool {
        !matches!(self, CheckLevel::Off)
    }

    /// Stable lowercase name (CLI flags, reports).
    pub fn name(self) -> &'static str {
        match self {
            CheckLevel::Off => "off",
            CheckLevel::Debug => "debug",
            CheckLevel::Strict => "strict",
        }
    }

    /// Parse a CLI-style level name.
    pub fn parse(s: &str) -> anyhow::Result<CheckLevel> {
        match s {
            "off" => Ok(CheckLevel::Off),
            "debug" => Ok(CheckLevel::Debug),
            "strict" => Ok(CheckLevel::Strict),
            other => anyhow::bail!("unknown check level `{other}` (want off|debug|strict)"),
        }
    }
}

/// Run the full static graph analysis: structural sanity, coupled-width
/// consistency, shape/dtype re-derivation, and dependency-group
/// invariants — in that order, so a coupling violation (an
/// inconsistently pruned residual, a stale concat offset) is reported
/// with its group context rather than as a generic shape error.
pub fn check_graph(g: &Graph) -> anyhow::Result<()> {
    structural(g)?;
    coupling::check_widths(g)?;
    shape::check_shapes(g)?;
    coupling::check_coupling(g)?;
    Ok(())
}

/// Cheap structural sanity that every later checker relies on: ids match
/// positions, references are in range, producer/consumer links are
/// symmetric, and parameter tensors physically match their declared
/// shapes (the gap `Graph::validate` does not cover — a checkpoint whose
/// weight payload disagrees with its metadata).
fn structural(g: &Graph) -> anyhow::Result<()> {
    for (i, d) in g.datas.iter().enumerate() {
        anyhow::ensure!(d.id == i, "data id mismatch at index {i} (recorded {})", d.id);
        if let Some(p) = d.producer {
            anyhow::ensure!(
                p < g.ops.len() && g.ops[p].outputs.contains(&i),
                "data `{}` claims a producer which does not output it",
                d.name
            );
        }
        for &c in &d.consumers {
            anyhow::ensure!(
                c < g.ops.len() && g.ops[c].inputs.contains(&i),
                "data `{}` claims a consumer which does not input it",
                d.name
            );
        }
        if let Some(t) = d.param() {
            anyhow::ensure!(
                t.shape == d.shape,
                "param `{}`: tensor storage has shape {:?} but the node declares {:?}",
                d.name,
                t.shape,
                d.shape
            );
        }
    }
    for (i, op) in g.ops.iter().enumerate() {
        anyhow::ensure!(op.id == i, "op id mismatch at index {i} (recorded {})", op.id);
        for &d in op.inputs.iter().chain(&op.outputs) {
            anyhow::ensure!(
                d < g.datas.len(),
                "op `{}` references data id {d} out of range ({} data nodes)",
                op.name,
                g.datas.len()
            );
        }
        for &o in &op.outputs {
            anyhow::ensure!(
                g.datas[o].producer == Some(i),
                "output `{}` of op `{}` records the wrong producer",
                g.datas[o].name,
                op.name
            );
        }
    }
    for &i in g.inputs.iter().chain(&g.outputs) {
        anyhow::ensure!(
            i < g.datas.len(),
            "graph io references data id {i} out of range ({} data nodes)",
            g.datas.len()
        );
    }
    Ok(())
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::ir::{DataKind, GraphBuilder};

    /// The grouping module's residual exemplar: c0/c2 coupled via `add`.
    pub(crate) fn resnet_like() -> Graph {
        let mut b = GraphBuilder::new("resnetish", 1);
        let x = b.input("x", vec![1, 3, 8, 8]);
        let c0 = b.conv2d("c0", x, 8, 3, 1, 1, 1, false);
        let n0 = b.batchnorm("bn0", c0);
        let r0 = b.relu("r0", n0);
        let c1 = b.conv2d("c1", r0, 8, 3, 1, 1, 1, false);
        let n1 = b.batchnorm("bn1", c1);
        let r1 = b.relu("r1", n1);
        let c2 = b.conv2d("c2", r1, 8, 3, 1, 1, 1, false);
        let n2 = b.batchnorm("bn2", c2);
        let s = b.add("add", n2, r0);
        let r2 = b.relu("r2", s);
        let gp = b.global_avgpool("gap", r2);
        let fc = b.gemm("fc", gp, 4, true);
        b.output(fc);
        b.finish().unwrap()
    }

    /// Shrink the `c2`/`bn2` branch of [`resnet_like`] to 7 channels
    /// while the residual `r0` branch keeps 8 — the canonical
    /// inconsistently-pruned group.
    pub(crate) fn corrupt_residual_branch(g: &mut Graph) {
        let keep = 7usize;
        for d in &mut g.datas {
            let name = d.name.clone();
            if name == "c2.w" {
                d.shape[0] = keep;
                let t = d.param_mut().unwrap();
                let inner: usize = t.shape[1..].iter().product();
                t.shape[0] = keep;
                t.data.truncate(keep * inner);
            } else if name.starts_with("bn2.") {
                d.shape = vec![keep];
                let t = d.param_mut().unwrap();
                t.shape = vec![keep];
                t.data.truncate(keep);
            }
        }
        let c2 = g.op_by_name("c2").unwrap().outputs[0];
        let bn2 = g.op_by_name("bn2").unwrap().outputs[0];
        g.datas[c2].shape[1] = keep;
        g.datas[bn2].shape[1] = keep;
    }

    #[test]
    fn clean_graph_passes_all_checks() {
        let g = resnet_like();
        check_graph(&g).unwrap();
    }

    #[test]
    fn rejects_inconsistently_pruned_residual_group() {
        let mut g = resnet_like();
        corrupt_residual_branch(&mut g);
        let err = check_graph(&g).unwrap_err().to_string();
        assert!(err.contains("residual group"), "got: {err}");
        assert!(err.contains("add"), "must name the coupling op: {err}");
        assert!(err.contains('7') && err.contains('8'), "got: {err}");
    }

    #[test]
    fn rejects_param_storage_shape_mismatch() {
        let mut g = resnet_like();
        let w = g.data_by_name("c1.w").unwrap().id;
        // corrupt the payload only: metadata still claims 8 channels
        let t = g.datas[w].param_mut().unwrap();
        let inner: usize = t.shape[1..].iter().product();
        t.shape[0] = 6;
        t.data.truncate(6 * inner);
        let err = check_graph(&g).unwrap_err().to_string();
        assert!(err.contains("c1.w"), "must name the param: {err}");
        assert!(err.contains("declares"), "got: {err}");
    }

    #[test]
    fn rejects_declared_shape_drift() {
        let mut g = resnet_like();
        // declared activation shape no longer follows from the inputs
        let gap = g.op_by_name("gap").unwrap().outputs[0];
        g.datas[gap].shape = vec![1, 5];
        let err = check_graph(&g).unwrap_err().to_string();
        assert!(err.contains("gap"), "must name the node: {err}");
    }

    #[test]
    fn rejects_embedding_fed_by_non_input() {
        let mut b = GraphBuilder::new("embgraph", 2);
        let ids = b.input("ids", vec![1, 6]);
        let e = b.embedding("emb", ids, 10, 8);
        let ln = b.layernorm("ln", e);
        let pooled = b.reduce_mean("pool", ln, 1);
        let out = b.gemm("head", pooled, 3, true);
        b.output(out);
        let mut g = b.finish().unwrap();
        check_graph(&g).unwrap();
        // corrupt: the ids tensor is no longer an integer-typed graph
        // input — embeddings must not gather with float indices
        let ids_id = g.inputs[0];
        g.datas[ids_id].kind = DataKind::Activation;
        let err = check_graph(&g).unwrap_err().to_string();
        assert!(err.contains("emb"), "must name the op: {err}");
        assert!(err.contains("ids"), "must mention the dtype: {err}");
    }

    #[test]
    fn level_semantics() {
        assert!(!CheckLevel::Off.enabled());
        assert!(CheckLevel::Debug.enabled());
        assert!(CheckLevel::Strict.enabled());
        assert_eq!(CheckLevel::parse("strict").unwrap(), CheckLevel::Strict);
        assert_eq!(CheckLevel::parse("off").unwrap(), CheckLevel::Off);
        assert!(CheckLevel::parse("bogus").is_err());
        if cfg!(debug_assertions) {
            assert_eq!(CheckLevel::default(), CheckLevel::Debug);
        } else {
            assert_eq!(CheckLevel::default(), CheckLevel::Off);
        }
    }

    #[test]
    fn every_zoo_model_passes_at_nominal_shapes() {
        use crate::zoo::{self, ImageCfg};
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        for name in zoo::IMAGE_MODELS.iter().chain(zoo::EXTRA_MODELS) {
            let g = zoo::by_name(name, cfg, 2).unwrap();
            check_graph(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        let t = zoo::distilbert(zoo::TextCfg::default(), 3);
        check_graph(&t).unwrap();
    }
}
