//! Static verification of compiled execution plans.
//!
//! [`crate::exec::Plan::compile`] turns a graph into a schedule over a
//! liveness-analyzed buffer arena with fused in-place post-ops — exactly
//! the transformations where a bug corrupts values silently: two
//! simultaneously-live intermediates mapped to one slot, a fused
//! activation mutating a buffer a reshape alias still exposes, a
//! schedule that reads before it writes. Numeric parity tests catch such
//! bugs only when a random input happens to excite them; [`check_plan`]
//! proves their absence for a given plan by replaying the schedule
//! abstractly: it re-derives liveness from the schedule itself, walks
//! the steps simulating slot ownership, and verifies every invariant the
//! executor relies on.

use crate::exec::{act_of, Item, Loc, Plan, PostOp};
use crate::ir::{DataId, OpKind};
use std::collections::{HashMap, HashSet};

/// Verify a compiled plan before its first run. Checks, in order:
///
/// 1. every `Alias` wraps a reshape-only op and its output's location
///    equals its input's (reshape aliases share storage, never copy);
/// 2. the schedule is a valid topological order — every read (following
///    reshape aliases) sees a previously produced value;
/// 3. fused post-op chains are well-formed: each hidden intermediate has
///    exactly one consumer, is not readable after the run, and the chain's
///    recorded end matches the graph;
/// 4. the location table is consistent (slots in range, step outputs
///    mapped to their slots, feeds/params pointing at real inputs/params);
/// 5. every live op is covered exactly once (as a step, a fused post-op,
///    or an alias) and no data is written twice;
/// 6. **arena safety**: replaying the schedule with re-derived liveness,
///    no step overwrites a slot whose current value is still needed —
///    including values pinned by graph outputs, retained ids, and reshape
///    aliases of any of those.
pub fn check_plan(plan: &Plan) -> anyhow::Result<()> {
    let g = &plan.graph;
    let nd = g.datas.len();

    // ---- alias map + rule 1 (well-formed aliases) ----
    let mut alias_src: HashMap<DataId, DataId> = HashMap::new();
    for item in &plan.schedule {
        if let Item::Alias { op } = item {
            let o = &g.ops[*op];
            anyhow::ensure!(
                matches!(o.kind, OpKind::Identity | OpKind::Flatten),
                "plan aliases op `{}` ({}) which is not reshape-only",
                o.name,
                o.kind.name()
            );
            anyhow::ensure!(
                !o.inputs.is_empty() && !o.outputs.is_empty(),
                "plan aliases neutralized op `{}`",
                o.name
            );
            alias_src.insert(o.outputs[0], o.inputs[0]);
        }
    }
    let resolve = |mut d: DataId| -> anyhow::Result<DataId> {
        let mut hops = 0usize;
        while let Some(&s) = alias_src.get(&d) {
            d = s;
            hops += 1;
            anyhow::ensure!(hops <= nd, "alias cycle at data `{}`", g.datas[d].name);
        }
        Ok(d)
    };

    // ---- rule 4: location table sanity ----
    for (id, l) in plan.loc.iter().enumerate() {
        match l {
            Some(Loc::Slot(s)) => anyhow::ensure!(
                *s < plan.slot_count,
                "data `{}` mapped to arena slot {s} but the plan has {} slots",
                g.datas[id].name,
                plan.slot_count
            ),
            Some(Loc::Feed(k)) => anyhow::ensure!(
                *k < g.inputs.len(),
                "data `{}` mapped to feed {k} but the graph has {} inputs",
                g.datas[id].name,
                g.inputs.len()
            ),
            Some(Loc::Param(p)) => anyhow::ensure!(
                g.datas.get(*p).is_some_and(|d| d.is_param()),
                "data `{}` mapped to param {p} which is not a parameter",
                g.datas[id].name
            ),
            None => {}
        }
    }

    // ---- re-derive liveness from the schedule itself (mirror of the
    // compiler's phase B, but from first principles: a slot's value is
    // needed until the last step that reads it, or forever if a readable
    // id — graph output or retained — resolves to it) ----
    let mut write_at: HashMap<DataId, usize> = HashMap::new();
    let mut last_read: HashMap<DataId, usize> = HashMap::new();
    for (si, item) in plan.schedule.iter().enumerate() {
        if let Item::Step { op, out_data, .. } = item {
            for &i in &g.ops[*op].inputs {
                let r = resolve(i)?;
                if write_at.contains_key(&r) {
                    last_read.insert(r, si);
                }
            }
            write_at.insert(*out_data, si);
        }
    }
    for &d in &plan.readable {
        let r = resolve(d)?;
        if write_at.contains_key(&r) {
            last_read.insert(r, usize::MAX);
        }
    }

    // ---- rules 2, 3, 5, 6: replay the schedule ----
    let mut available: HashSet<DataId> = g.inputs.iter().copied().collect();
    for d in &g.datas {
        if d.is_param() {
            available.insert(d.id);
        }
    }
    let mut steps_seen: HashSet<usize> = HashSet::new();
    let mut written: HashSet<DataId> = HashSet::new();
    let mut slot_owner: Vec<Option<DataId>> = vec![None; plan.slot_count];
    let mut fused_count = 0usize;
    let mut alias_count = 0usize;
    for (si, item) in plan.schedule.iter().enumerate() {
        match item {
            Item::Alias { op } => {
                let o = &g.ops[*op];
                let (inp, out) = (o.inputs[0], o.outputs[0]);
                let r = resolve(inp)?;
                anyhow::ensure!(
                    available.contains(&r),
                    "schedule is not a topological order: alias `{}` reads `{}` before \
                     it is produced",
                    o.name,
                    g.datas[r].name
                );
                anyhow::ensure!(
                    plan.loc[r].is_some(),
                    "alias `{}`: source `{}` has no run-time location",
                    o.name,
                    g.datas[r].name
                );
                anyhow::ensure!(
                    plan.loc[out] == plan.loc[inp],
                    "alias `{}`: output `{}` does not share its input's location",
                    o.name,
                    g.datas[out].name
                );
                available.insert(out);
                alias_count += 1;
            }
            Item::Step {
                op,
                out_data,
                out_slot,
                post,
            } => {
                let o = &g.ops[*op];
                anyhow::ensure!(
                    !o.outputs.is_empty(),
                    "plan schedules neutralized op `{}`",
                    o.name
                );
                anyhow::ensure!(steps_seen.insert(*op), "op `{}` is scheduled twice", o.name);
                for &i in &o.inputs {
                    let r = resolve(i)?;
                    anyhow::ensure!(
                        available.contains(&r),
                        "schedule is not a topological order: step {si} (`{}`) reads \
                         `{}` before it is produced",
                        o.name,
                        g.datas[r].name
                    );
                }
                // fused post-op chain must mirror the graph exactly
                let mut cur = o.outputs[0];
                for p in post {
                    let d = &g.datas[cur];
                    anyhow::ensure!(
                        d.consumers.len() == 1,
                        "fused chain at `{}`: hidden intermediate `{}` has {} consumers",
                        o.name,
                        d.name,
                        d.consumers.len()
                    );
                    anyhow::ensure!(
                        !plan.readable.contains(&cur),
                        "fused chain at `{}` hides `{}` which must stay readable",
                        o.name,
                        d.name
                    );
                    let cop = &g.ops[d.consumers[0]];
                    match p {
                        PostOp::Bn { .. } => anyhow::ensure!(
                            matches!(cop.kind, OpKind::BatchNorm { .. })
                                && cop.inputs.first() == Some(&cur),
                            "fused chain at `{}`: BN post-op does not match consumer `{}`",
                            o.name,
                            cop.name
                        ),
                        PostOp::Act(a) => anyhow::ensure!(
                            act_of(&cop.kind) == Some(*a),
                            "fused chain at `{}`: activation post-op does not match \
                             consumer `{}`",
                            o.name,
                            cop.name
                        ),
                    }
                    cur = cop.outputs[0];
                }
                anyhow::ensure!(
                    cur == *out_data,
                    "step for `{}` records out data `{}` but its fused chain ends at `{}`",
                    o.name,
                    g.datas[*out_data].name,
                    g.datas[cur].name
                );
                fused_count += post.len();
                anyhow::ensure!(
                    *out_slot < plan.slot_count,
                    "step for `{}` writes slot {} but the plan has {} slots",
                    o.name,
                    out_slot,
                    plan.slot_count
                );
                anyhow::ensure!(
                    plan.loc[*out_data] == Some(Loc::Slot(*out_slot)),
                    "step output `{}`: location table disagrees with the scheduled \
                     slot {}",
                    g.datas[*out_data].name,
                    out_slot
                );
                anyhow::ensure!(
                    written.insert(*out_data),
                    "data `{}` is written by two schedule steps",
                    g.datas[*out_data].name
                );
                // rule 6: the slot's current value must be dead (its last
                // reader strictly before this step)
                if let Some(prev) = slot_owner[*out_slot] {
                    if prev != *out_data {
                        let live = last_read.get(&prev).copied().unwrap_or(0);
                        if live >= si {
                            let until = if live == usize::MAX {
                                "it must stay readable after the run".to_string()
                            } else {
                                format!("its last read is at step {live}")
                            };
                            anyhow::bail!(
                                "arena hazard: step {si} (`{}`) overwrites slot {} while \
                                 `{}` is still live ({until})",
                                o.name,
                                out_slot,
                                g.datas[prev].name
                            );
                        }
                    }
                }
                slot_owner[*out_slot] = Some(*out_data);
                available.insert(*out_data);
            }
        }
    }

    // ---- rule 5: every live op covered exactly once ----
    let covered = steps_seen.len() + fused_count + alias_count;
    let expected = g.ops.iter().filter(|o| !o.outputs.is_empty()).count();
    anyhow::ensure!(
        covered == expected,
        "plan schedule covers {covered} ops (steps + fused + aliases) but the graph has \
         {expected} live ops"
    );
    for &out in &g.outputs {
        let r = resolve(out)?;
        anyhow::ensure!(
            available.contains(&r),
            "graph output `{}` is never produced by the schedule",
            g.datas[out].name
        );
        anyhow::ensure!(
            plan.loc[out].is_some(),
            "graph output `{}` has no run-time location",
            g.datas[out].name
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{OptLevel, Plan, PlanOpts};
    use crate::ir::GraphBuilder;
    use crate::zoo::{self, ImageCfg};

    fn cfg() -> ImageCfg {
        ImageCfg {
            hw: 8,
            ..Default::default()
        }
    }

    /// x → fc1 → relu → add(relu, fc1): fc1.out has two consumers, so no
    /// fusion and two simultaneously-live intermediates — the minimal
    /// graph where slot sharing would corrupt the residual.
    fn residual_gemm() -> crate::ir::Graph {
        let mut b = GraphBuilder::new("resgemm", 1);
        let x = b.input("x", vec![1, 8]);
        let f = b.gemm("fc1", x, 8, false);
        let r = b.relu("relu", f);
        let s = b.add("res", r, f);
        b.output(s);
        b.finish().unwrap()
    }

    fn compile(g: &crate::ir::Graph, level: OptLevel) -> Plan {
        Plan::compile(
            g,
            PlanOpts {
                level,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn compiled_zoo_plans_verify_at_every_level() {
        for name in ["resnet18", "mobilenetv2", "densenet", "mlp", "vit"] {
            let g = zoo::by_name(name, cfg(), 2).unwrap();
            for level in [OptLevel::None, OptLevel::Exact, OptLevel::Fast] {
                let plan = compile(&g, level);
                check_plan(&plan).unwrap_or_else(|e| panic!("{name}/{level:?}: {e}"));
            }
        }
        let t = zoo::distilbert(zoo::TextCfg::default(), 3);
        check_plan(&compile(&t, OptLevel::Exact)).unwrap();
    }

    #[test]
    fn rejects_overlapping_live_arena_slots() {
        let g = residual_gemm();
        let mut plan = compile(&g, OptLevel::None);
        check_plan(&plan).unwrap();
        // force relu's output into fc1's slot — fc1.out is still read by
        // the later add, so the two values are simultaneously live
        let (fc1_slot, fc1_out) = plan
            .schedule
            .iter()
            .find_map(|it| match it {
                Item::Step {
                    op,
                    out_slot,
                    out_data,
                    ..
                } if plan.graph.ops[*op].name == "fc1" => Some((*out_slot, *out_data)),
                _ => None,
            })
            .unwrap();
        let relu_out = {
            let it = plan
                .schedule
                .iter_mut()
                .find(|it| {
                    matches!(it, Item::Step { op, .. } if plan.graph.ops[*op].name == "relu")
                })
                .unwrap();
            match it {
                Item::Step {
                    out_slot, out_data, ..
                } => {
                    assert_ne!(*out_slot, fc1_slot, "compiler must separate live values");
                    *out_slot = fc1_slot;
                    *out_data
                }
                _ => unreachable!(),
            }
        };
        plan.loc[relu_out] = plan.loc[fc1_out];
        let err = check_plan(&plan).unwrap_err().to_string();
        assert!(err.contains("arena hazard"), "got: {err}");
        assert!(err.contains("fc1.out"), "must name the clobbered value: {err}");
    }

    #[test]
    fn rejects_non_topological_schedule() {
        let g = residual_gemm();
        let mut plan = compile(&g, OptLevel::None);
        plan.schedule.swap(0, 1); // relu now runs before fc1
        let err = check_plan(&plan).unwrap_err().to_string();
        assert!(err.contains("not a topological order"), "got: {err}");
    }

    #[test]
    fn rejects_corrupt_location_table() {
        let g = residual_gemm();
        let mut plan = compile(&g, OptLevel::None);
        let out = plan.graph.outputs[0];
        plan.loc[out] = Some(Loc::Slot(plan.slot_count + 3));
        let err = check_plan(&plan).unwrap_err().to_string();
        assert!(err.contains("slot"), "got: {err}");
    }

    #[test]
    fn rejects_double_write() {
        let g = residual_gemm();
        let mut plan = compile(&g, OptLevel::None);
        let first = plan.schedule[0].clone();
        plan.schedule.push(first);
        let err = check_plan(&plan).unwrap_err().to_string();
        assert!(err.contains("scheduled twice"), "got: {err}");
    }
}
