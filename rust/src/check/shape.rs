//! Shape/dtype abstract interpretation over SPA-IR.
//!
//! Walks the graph in topological order re-deriving every operator's
//! output shape from its *inputs'* declared shapes (the same per-operator
//! semantics as [`crate::ir::shape::infer_op_output_shapes`]) and diffs
//! the result against the declared metadata. A rewrite pass that edits a
//! weight without fixing downstream shapes, or a checkpoint whose
//! metadata drifted from its payload, fails here with the node named —
//! before any kernel indexes out of bounds.
//!
//! SPA-IR carries a deliberately tiny dtype universe: everything is f32
//! except [`crate::ir::OpKind::Embedding`] indices, which are integer
//! ids stored in a float tensor. The dtype pass enforces the two rules
//! that keep that sound: embedding indices must come from a graph input,
//! and no tensor may be consumed both as ids and as float arithmetic.

use crate::ir::shape::{infer_op_output_shapes, infer_shapes};
use crate::ir::{DataKind, Graph, OpKind};

/// Re-derive every data node's shape and diff against declared metadata;
/// enforce the ids/float dtype split. Assumes the structural sanity of
/// [`super::check_graph`]'s first stage.
pub fn check_shapes(g: &Graph) -> anyhow::Result<()> {
    check_dtypes(g)?;
    // Abstract interpretation: `infer_shapes` seeds producer-less nodes
    // (inputs/params) from declared shapes and folds
    // `infer_op_output_shapes` over the topological order, so one call
    // re-derives the whole graph from first principles.
    let derived = infer_shapes(g)?;
    for d in &g.datas {
        if let Some(s) = derived.get(&d.id) {
            anyhow::ensure!(
                s == &d.shape,
                "shape drift on `{}`: declared {:?} but re-derived {:?} from its producer's inputs",
                d.name,
                d.shape,
                s
            );
        } else {
            // Unreached by inference means no producer seeded it — a
            // dangling activation is only a defect if something reads it.
            anyhow::ensure!(
                d.consumers.is_empty() && !g.outputs.contains(&d.id),
                "activation `{}` has no producer but is consumed",
                d.name
            );
        }
    }
    Ok(())
}

/// The ids/float dtype rules (see module docs).
fn check_dtypes(g: &Graph) -> anyhow::Result<()> {
    for op in &g.ops {
        if !matches!(op.kind, OpKind::Embedding) || op.inputs.is_empty() {
            continue;
        }
        let ids = &g.datas[op.inputs[0]];
        anyhow::ensure!(
            matches!(ids.kind, DataKind::Input),
            "op `{}`: embedding ids input `{}` must be an integer-typed graph input, \
             not a float {}",
            op.name,
            ids.name,
            match ids.kind {
                DataKind::Param(_) => "parameter",
                _ => "activation",
            }
        );
        // ids must never double as float data elsewhere
        for &c in &ids.consumers {
            let cop = &g.ops[c];
            let float_use = !matches!(cop.kind, OpKind::Embedding)
                || cop.inputs.first() != Some(&ids.id);
            anyhow::ensure!(
                !float_use,
                "data `{}` is consumed both as integer ids (op `{}`) and as floats (op `{}`)",
                ids.name,
                op.name,
                cop.name
            );
        }
    }
    Ok(())
}

/// Standalone single-op re-derivation, shared with the plan checker:
/// derive `kind`'s output shape from input shapes, with the op name
/// attached to errors.
pub(crate) fn derive_output(
    name: &str,
    kind: &OpKind,
    ins: &[Vec<usize>],
) -> anyhow::Result<Vec<usize>> {
    let mut outs =
        infer_op_output_shapes(kind, ins).map_err(|e| anyhow::anyhow!("op `{name}`: {e}"))?;
    anyhow::ensure!(!outs.is_empty(), "op `{name}` derives no outputs");
    Ok(outs.swap_remove(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn detects_stale_downstream_shape() {
        let mut b = GraphBuilder::new("stale", 1);
        let x = b.input("x", vec![1, 3, 8, 8]);
        let c = b.conv2d("c", x, 4, 3, 1, 1, 1, false);
        let gp = b.global_avgpool("gap", c);
        let out = b.gemm("fc", gp, 2, false);
        b.output(out);
        let mut g = b.finish().unwrap();
        check_shapes(&g).unwrap();
        // "prune" the conv weight without re-inferring anything downstream
        let w = g.data_by_name("c.w").unwrap().id;
        g.datas[w].shape[0] = 3;
        let t = g.datas[w].param_mut().unwrap();
        let inner: usize = t.shape[1..].iter().product();
        t.shape[0] = 3;
        t.data.truncate(3 * inner);
        let err = check_shapes(&g).unwrap_err().to_string();
        // the conv output is the first place declaration and derivation
        // disagree
        assert!(err.contains("shape drift") || err.contains("op `"), "got: {err}");
    }

    #[test]
    fn derive_output_names_the_op() {
        let err = derive_output(
            "badconv",
            &OpKind::Conv2d {
                stride: 1,
                pad: 0,
                groups: 1,
            },
            &[vec![1, 4, 8, 8], vec![8, 3, 3, 3]],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("badconv"), "got: {err}");
    }
}
