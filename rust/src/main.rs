//! SPA command-line interface — see `spa --help`.

fn main() -> anyhow::Result<()> {
    spa::coordinator::cli::run(std::env::args().skip(1).collect())
}
