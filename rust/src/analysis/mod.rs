//! Model cost analysis: FLOPs and parameter counting over SPA-IR, and the
//! paper's efficiency metrics RF = FLOPs_before / FLOPs_after and
//! RP = params_before / params_after (App. B.2, Eqs. 15-16).

use crate::ir::{Graph, OpKind};

/// Multiply-accumulate-style FLOP count of one forward pass at the
/// graph's nominal batch size 1 (batch dim normalized out).
pub fn flops(g: &Graph) -> usize {
    let mut total = 0usize;
    for op in &g.ops {
        let out_shape = &g.data(op.outputs[0]).shape;
        let batch = out_shape.first().copied().unwrap_or(1).max(1);
        let out_elems: usize = out_shape.iter().product::<usize>() / batch;
        total += match &op.kind {
            OpKind::Conv2d { groups, .. } => {
                let w = &g.data(op.inputs[1]).shape; // [Co, Ci/g, kh, kw]
                let _ = groups;
                // per output element: Ci/g * kh * kw MACs (×2 flops)
                2 * out_elems * w[1] * w[2] * w[3]
            }
            OpKind::Gemm => {
                let w = &g.data(op.inputs[1]).shape; // [Co, K]
                2 * out_elems * w[1]
            }
            OpKind::MatMul => {
                let a = &g.data(op.inputs[0]).shape;
                2 * out_elems * a[a.len() - 1]
            }
            OpKind::BatchNorm { .. } | OpKind::LayerNorm { .. } => 4 * out_elems,
            OpKind::Relu | OpKind::Identity | OpKind::Scale { .. } => out_elems,
            OpKind::Gelu | OpKind::Silu | OpKind::Sigmoid | OpKind::Tanh => 4 * out_elems,
            OpKind::Add | OpKind::Mul => out_elems,
            OpKind::MaxPool2d { k, .. } | OpKind::AvgPool2d { k, .. } => out_elems * k * k,
            OpKind::GlobalAvgPool => {
                let x = &g.data(op.inputs[0]).shape;
                x.iter().product::<usize>() / batch
            }
            OpKind::Softmax => 5 * out_elems,
            OpKind::Flatten
            | OpKind::Concat { .. }
            | OpKind::Transpose { .. }
            | OpKind::SplitHeads { .. }
            | OpKind::MergeHeads
            | OpKind::Embedding
            | OpKind::NchwToTokens
            | OpKind::ReduceMean { .. } => 0,
        };
    }
    total
}

/// Total parameter count.
pub fn params(g: &Graph) -> usize {
    g.num_params()
}

/// RF/RP pair for a (dense, pruned) model pair.
#[derive(Debug, Clone, Copy)]
pub struct Reduction {
    pub rf: f64,
    pub rp: f64,
}

pub fn reduction(before: &Graph, after: &Graph) -> Reduction {
    Reduction {
        rf: flops(before) as f64 / flops(after).max(1) as f64,
        rp: params(before) as f64 / params(after).max(1) as f64,
    }
}

impl std::fmt::Display for Reduction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RF {:.2}x RP {:.2}x", self.rf, self.rp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn conv_flops_formula() {
        let mut b = GraphBuilder::new("f", 1);
        let x = b.input("x", vec![1, 3, 8, 8]);
        let c = b.conv2d("c", x, 4, 3, 1, 1, 1, false);
        b.output(c);
        let g = b.finish().unwrap();
        // out 4x8x8, each elem = 3*3*3 macs * 2
        assert_eq!(flops(&g), 2 * 4 * 64 * 27);
    }

    #[test]
    fn gemm_flops() {
        let mut b = GraphBuilder::new("f", 1);
        let x = b.input("x", vec![1, 16]);
        let y = b.gemm("fc", x, 8, false);
        b.output(y);
        let g = b.finish().unwrap();
        assert_eq!(flops(&g), 2 * 8 * 16);
    }

    #[test]
    fn reduction_ratio() {
        let mut b = GraphBuilder::new("a", 1);
        let x = b.input("x", vec![1, 16]);
        let y = b.gemm("fc", x, 8, false);
        b.output(y);
        let big = b.finish().unwrap();
        let mut b = GraphBuilder::new("b", 1);
        let x = b.input("x", vec![1, 16]);
        let y = b.gemm("fc", x, 4, false);
        b.output(y);
        let small = b.finish().unwrap();
        let r = reduction(&big, &small);
        assert!((r.rf - 2.0).abs() < 1e-9);
        assert!((r.rp - 2.0).abs() < 1e-9);
    }
}
