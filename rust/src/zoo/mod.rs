//! Model zoo — the architectures of the paper's Tab. 2, scaled to the
//! synthetic-data regime (≈0.05–1 M params) while keeping every coupling
//! pattern that makes structured pruning hard:
//!
//! | model | coupling pattern exercised |
//! |---|---|
//! | `mlp`             | plain GEMM chains |
//! | `alexnet`         | conv → flatten → fc feature blocks |
//! | `vgg16` / `vgg19` | deep conv chains + maxpool + classifier head |
//! | `resnet18/50/101` | residual Add coupling (+ bottlenecks, downsample) |
//! | `wideresnet`      | wide residual blocks |
//! | `resnext`         | grouped convolutions (cross-group position ties) |
//! | `densenet`        | concat growth (offset mapping) |
//! | `mobilenetv2`     | depthwise + inverted residual |
//! | `efficientnet`    | depthwise + squeeze-excite gates (Mul coupling) |
//! | `regnet`          | group conv + residual |
//! | `vit`             | attention head sub-position ties + LayerNorm |
//! | `distilbert`      | text transformer: embeddings + attention + GELU MLP |
//!
//! Builders are deterministic in `seed`; `by_name` is the single lookup
//! the CLI / benches / examples use.

use crate::ir::{DataId, Graph, GraphBuilder};

/// Configuration shared by image models.
#[derive(Debug, Clone, Copy)]
pub struct ImageCfg {
    pub channels: usize,
    pub hw: usize,
    pub classes: usize,
    pub batch: usize,
}

impl Default for ImageCfg {
    fn default() -> Self {
        ImageCfg {
            channels: 3,
            hw: 16,
            classes: 10,
            batch: 8,
        }
    }
}

/// Configuration for text models.
#[derive(Debug, Clone, Copy)]
pub struct TextCfg {
    pub vocab: usize,
    pub seq: usize,
    pub dim: usize,
    pub heads: usize,
    pub layers: usize,
    pub classes: usize,
    pub batch: usize,
}

impl Default for TextCfg {
    fn default() -> Self {
        TextCfg {
            vocab: 64,
            seq: 12,
            dim: 32,
            heads: 4,
            layers: 2,
            classes: 2,
            batch: 8,
        }
    }
}

/// conv + bn + relu convenience.
fn cbr(
    b: &mut GraphBuilder,
    name: &str,
    x: DataId,
    co: usize,
    k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
) -> DataId {
    let c = b.conv2d(&format!("{name}.conv"), x, co, k, stride, pad, groups, false);
    let n = b.batchnorm(&format!("{name}.bn"), c);
    b.relu(&format!("{name}.relu"), n)
}

/// Plain MLP on flattened images.
pub fn mlp(cfg: ImageCfg, widths: &[usize], seed: u64) -> Graph {
    let mut b = GraphBuilder::new("mlp", seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    let mut h = b.flatten("flat", x);
    for (i, &w) in widths.iter().enumerate() {
        h = b.gemm(&format!("fc{i}"), h, w, true);
        h = b.relu(&format!("relu{i}"), h);
    }
    let out = b.gemm("head", h, cfg.classes, true);
    b.output(out);
    b.finish().expect("mlp")
}

/// AlexNet-mini: conv stack then large fc layers through a flatten.
pub fn alexnet(cfg: ImageCfg, seed: u64) -> Graph {
    let mut b = GraphBuilder::new("alexnet", seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    let c1 = b.conv2d("c1", x, 16, 3, 1, 1, 1, true);
    let r1 = b.relu("r1", c1);
    let p1 = b.maxpool2d("p1", r1, 2, 2, 0);
    let c2 = b.conv2d("c2", p1, 32, 3, 1, 1, 1, true);
    let r2 = b.relu("r2", c2);
    let p2 = b.maxpool2d("p2", r2, 2, 2, 0);
    let c3 = b.conv2d("c3", p2, 48, 3, 1, 1, 1, true);
    let r3 = b.relu("r3", c3);
    let c4 = b.conv2d("c4", r3, 32, 3, 1, 1, 1, true);
    let r4 = b.relu("r4", c4);
    let f = b.flatten("flat", r4);
    let fc1 = b.gemm("fc1", f, 64, true);
    let fr1 = b.relu("fr1", fc1);
    let fc2 = b.gemm("fc2", fr1, 64, true);
    let fr2 = b.relu("fr2", fc2);
    let out = b.gemm("head", fr2, cfg.classes, true);
    b.output(out);
    b.finish().expect("alexnet")
}

/// VGG-style plain conv stack. `plan` gives channels per stage.
fn vgg(name: &str, cfg: ImageCfg, plan: &[&[usize]], seed: u64) -> Graph {
    let mut b = GraphBuilder::new(name, seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    let mut h = x;
    for (si, stage) in plan.iter().enumerate() {
        for (ci, &co) in stage.iter().enumerate() {
            h = cbr(&mut b, &format!("s{si}b{ci}"), h, co, 3, 1, 1, 1);
        }
        if si + 1 < plan.len() {
            h = b.maxpool2d(&format!("pool{si}"), h, 2, 2, 0);
        }
    }
    let g = b.global_avgpool("gap", h);
    let fc = b.gemm("fc1", g, 64, true);
    let fr = b.relu("fr", fc);
    let out = b.gemm("head", fr, cfg.classes, true);
    b.output(out);
    b.finish().expect("vgg")
}

pub fn vgg16(cfg: ImageCfg, seed: u64) -> Graph {
    vgg(
        "vgg16",
        cfg,
        &[&[16, 16], &[32, 32], &[48, 48, 48], &[64, 64, 64]],
        seed,
    )
}

pub fn vgg19(cfg: ImageCfg, seed: u64) -> Graph {
    vgg(
        "vgg19",
        cfg,
        &[&[16, 16], &[32, 32], &[48, 48, 48, 48], &[64, 64, 64, 64]],
        seed,
    )
}

/// Basic residual block (ResNet-18 style).
fn basic_block(
    b: &mut GraphBuilder,
    name: &str,
    x: DataId,
    co: usize,
    stride: usize,
    in_ch: usize,
) -> DataId {
    let c1 = b.conv2d(&format!("{name}.c1"), x, co, 3, stride, 1, 1, false);
    let n1 = b.batchnorm(&format!("{name}.bn1"), c1);
    let r1 = b.relu(&format!("{name}.r1"), n1);
    let c2 = b.conv2d(&format!("{name}.c2"), r1, co, 3, 1, 1, 1, false);
    let n2 = b.batchnorm(&format!("{name}.bn2"), c2);
    let short = if stride != 1 || in_ch != co {
        let sc = b.conv2d(&format!("{name}.down"), x, co, 1, stride, 0, 1, false);
        b.batchnorm(&format!("{name}.downbn"), sc)
    } else {
        x
    };
    let s = b.add(&format!("{name}.add"), n2, short);
    b.relu(&format!("{name}.out"), s)
}

/// Bottleneck residual block (ResNet-50/101 style), expansion 2.
fn bottleneck(
    b: &mut GraphBuilder,
    name: &str,
    x: DataId,
    mid: usize,
    stride: usize,
    in_ch: usize,
    groups: usize,
) -> DataId {
    let out_ch = mid * 2;
    let c1 = b.conv2d(&format!("{name}.c1"), x, mid, 1, 1, 0, 1, false);
    let n1 = b.batchnorm(&format!("{name}.bn1"), c1);
    let r1 = b.relu(&format!("{name}.r1"), n1);
    let c2 = b.conv2d(&format!("{name}.c2"), r1, mid, 3, stride, 1, groups, false);
    let n2 = b.batchnorm(&format!("{name}.bn2"), c2);
    let r2 = b.relu(&format!("{name}.r2"), n2);
    let c3 = b.conv2d(&format!("{name}.c3"), r2, out_ch, 1, 1, 0, 1, false);
    let n3 = b.batchnorm(&format!("{name}.bn3"), c3);
    let short = if stride != 1 || in_ch != out_ch {
        let sc = b.conv2d(&format!("{name}.down"), x, out_ch, 1, stride, 0, 1, false);
        b.batchnorm(&format!("{name}.downbn"), sc)
    } else {
        x
    };
    let s = b.add(&format!("{name}.add"), n3, short);
    b.relu(&format!("{name}.out"), s)
}

fn resnet_basic(name: &str, cfg: ImageCfg, widths: &[usize], blocks: &[usize], seed: u64) -> Graph {
    let mut b = GraphBuilder::new(name, seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    let mut h = cbr(&mut b, "stem", x, widths[0], 3, 1, 1, 1);
    let mut in_ch = widths[0];
    for (si, (&w, &n)) in widths.iter().zip(blocks).enumerate() {
        for bi in 0..n {
            let stride = if bi == 0 && si > 0 { 2 } else { 1 };
            h = basic_block(&mut b, &format!("s{si}b{bi}"), h, w, stride, in_ch);
            in_ch = w;
        }
    }
    let g = b.global_avgpool("gap", h);
    let out = b.gemm("head", g, cfg.classes, true);
    b.output(out);
    b.finish().expect("resnet")
}

fn resnet_bottleneck(
    name: &str,
    cfg: ImageCfg,
    mids: &[usize],
    blocks: &[usize],
    groups: usize,
    seed: u64,
) -> Graph {
    let mut b = GraphBuilder::new(name, seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    let mut h = cbr(&mut b, "stem", x, mids[0], 3, 1, 1, 1);
    let mut in_ch = mids[0];
    for (si, (&m, &n)) in mids.iter().zip(blocks).enumerate() {
        for bi in 0..n {
            let stride = if bi == 0 && si > 0 { 2 } else { 1 };
            h = bottleneck(&mut b, &format!("s{si}b{bi}"), h, m, stride, in_ch, groups);
            in_ch = m * 2;
        }
    }
    let g = b.global_avgpool("gap", h);
    let out = b.gemm("head", g, cfg.classes, true);
    b.output(out);
    b.finish().expect("resnet-bottleneck")
}

pub fn resnet18(cfg: ImageCfg, seed: u64) -> Graph {
    resnet_basic("resnet18", cfg, &[16, 32, 64], &[2, 2, 2], seed)
}

pub fn resnet50(cfg: ImageCfg, seed: u64) -> Graph {
    resnet_bottleneck("resnet50", cfg, &[16, 32, 64], &[3, 4, 3], 1, seed)
}

pub fn resnet101(cfg: ImageCfg, seed: u64) -> Graph {
    resnet_bottleneck("resnet101", cfg, &[16, 32, 48], &[3, 8, 3], 1, seed)
}

pub fn wideresnet(cfg: ImageCfg, seed: u64) -> Graph {
    resnet_basic("wideresnet", cfg, &[32, 64, 128], &[2, 2, 2], seed)
}

pub fn resnext(cfg: ImageCfg, seed: u64) -> Graph {
    resnet_bottleneck("resnext", cfg, &[16, 32, 64], &[2, 2, 2], 4, seed)
}

pub fn regnet(cfg: ImageCfg, seed: u64) -> Graph {
    resnet_bottleneck("regnet", cfg, &[16, 24, 48], &[1, 2, 3], 2, seed)
}

/// DenseNet-mini: concat growth inside dense blocks, 1×1 transitions.
pub fn densenet(cfg: ImageCfg, seed: u64) -> Graph {
    let mut b = GraphBuilder::new("densenet", seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    let growth = 8;
    let mut h = cbr(&mut b, "stem", x, 16, 3, 1, 1, 1);
    for blk in 0..2 {
        for layer in 0..3 {
            let name = format!("d{blk}l{layer}");
            let c = cbr(&mut b, &name, h, growth, 3, 1, 1, 1);
            h = b.concat(&format!("{name}.cat"), &[h, c], 1);
        }
        let tname = format!("t{blk}");
        h = cbr(&mut b, &tname, h, 24, 1, 1, 0, 1);
        if blk == 0 {
            h = b.avgpool2d(&format!("{tname}.pool"), h, 2, 2, 0);
        }
    }
    let g = b.global_avgpool("gap", h);
    let out = b.gemm("head", g, cfg.classes, true);
    b.output(out);
    b.finish().expect("densenet")
}

/// Inverted residual block (MobileNet-v2).
fn inverted_residual(
    b: &mut GraphBuilder,
    name: &str,
    x: DataId,
    in_ch: usize,
    out_ch: usize,
    expand: usize,
    stride: usize,
) -> DataId {
    let mid = in_ch * expand;
    let e = cbr(b, &format!("{name}.expand"), x, mid, 1, 1, 0, 1);
    let dwc = b.conv2d(&format!("{name}.dw.conv"), e, mid, 3, stride, 1, mid, false);
    let dwn = b.batchnorm(&format!("{name}.dw.bn"), dwc);
    let dwr = b.relu(&format!("{name}.dw.relu"), dwn);
    let pc = b.conv2d(&format!("{name}.proj.conv"), dwr, out_ch, 1, 1, 0, 1, false);
    let pn = b.batchnorm(&format!("{name}.proj.bn"), pc);
    if stride == 1 && in_ch == out_ch {
        b.add(&format!("{name}.add"), pn, x)
    } else {
        pn
    }
}

pub fn mobilenetv2(cfg: ImageCfg, seed: u64) -> Graph {
    let mut b = GraphBuilder::new("mobilenetv2", seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    let mut h = cbr(&mut b, "stem", x, 16, 3, 1, 1, 1);
    h = inverted_residual(&mut b, "ir0", h, 16, 16, 2, 1);
    h = inverted_residual(&mut b, "ir1", h, 16, 24, 2, 2);
    h = inverted_residual(&mut b, "ir2", h, 24, 24, 2, 1);
    h = inverted_residual(&mut b, "ir3", h, 24, 32, 2, 2);
    h = cbr(&mut b, "headconv", h, 64, 1, 1, 0, 1);
    let g = b.global_avgpool("gap", h);
    let out = b.gemm("head", g, cfg.classes, true);
    b.output(out);
    b.finish().expect("mobilenetv2")
}

/// Squeeze-and-excitation gate: GAP → fc → relu → fc → sigmoid → Mul.
/// The [N,C] gate broadcasts over the spatial dims; the Mul ties the gate
/// channels to the trunk channels (a coupling pattern unique to SE nets).
fn se_gate(b: &mut GraphBuilder, name: &str, x: DataId, ch: usize, r: usize) -> DataId {
    let g = b.global_avgpool(&format!("{name}.gap"), x);
    let d = b.gemm(&format!("{name}.down"), g, (ch / r).max(1), true);
    let dr = b.relu(&format!("{name}.relu"), d);
    let u = b.gemm(&format!("{name}.up"), dr, ch, true);
    let s = b.sigmoid(&format!("{name}.sig"), u);
    b.mul(&format!("{name}.mul"), x, s)
}

pub fn efficientnet(cfg: ImageCfg, seed: u64) -> Graph {
    let mut b = GraphBuilder::new("efficientnet", seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    let mut h = cbr(&mut b, "stem", x, 16, 3, 1, 1, 1);
    for (i, (out_ch, stride)) in [(16usize, 1usize), (24, 2), (24, 1)].iter().enumerate() {
        let name = format!("mb{i}");
        let in_ch = b.peek_shape(h)[1];
        let mid = in_ch * 2;
        let e = cbr(&mut b, &format!("{name}.expand"), h, mid, 1, 1, 0, 1);
        let dwc = b.conv2d(&format!("{name}.dw.conv"), e, mid, 3, *stride, 1, mid, false);
        let dwn = b.batchnorm(&format!("{name}.dw.bn"), dwc);
        let dwr = b.silu(&format!("{name}.dw.act"), dwn);
        let se = se_gate(&mut b, &format!("{name}.se"), dwr, mid, 4);
        let pc = b.conv2d(&format!("{name}.proj.conv"), se, *out_ch, 1, 1, 0, 1, false);
        let pn = b.batchnorm(&format!("{name}.proj.bn"), pc);
        h = if *stride == 1 && in_ch == *out_ch {
            b.add(&format!("{name}.add"), pn, h)
        } else {
            pn
        };
    }
    let hc = cbr(&mut b, "headconv", h, 48, 1, 1, 0, 1);
    let g = b.global_avgpool("gap", hc);
    let out = b.gemm("head", g, cfg.classes, true);
    b.output(out);
    b.finish().expect("efficientnet")
}

/// One pre-norm transformer encoder block.
#[allow(clippy::too_many_arguments)]
fn transformer_block(
    b: &mut GraphBuilder,
    name: &str,
    x: DataId,
    dim: usize,
    heads: usize,
    mlp_mult: usize,
) -> DataId {
    let scale = 1.0 / ((dim / heads) as f32).sqrt();
    let ln1 = b.layernorm(&format!("{name}.ln1"), x);
    let q = b.gemm(&format!("{name}.q"), ln1, dim, true);
    let k = b.gemm(&format!("{name}.k"), ln1, dim, true);
    let v = b.gemm(&format!("{name}.v"), ln1, dim, true);
    let qh = b.split_heads(&format!("{name}.qh"), q, heads);
    let kh = b.split_heads(&format!("{name}.kh"), k, heads);
    let vh = b.split_heads(&format!("{name}.vh"), v, heads);
    let kt = b.transpose(&format!("{name}.kt"), kh, vec![0, 1, 3, 2]);
    let sc = b.matmul(&format!("{name}.qk"), qh, kt);
    let scl = b.scale(&format!("{name}.scale"), sc, scale);
    let sm = b.softmax(&format!("{name}.sm"), scl);
    let ctx = b.matmul(&format!("{name}.av"), sm, vh);
    let mh = b.merge_heads(&format!("{name}.mh"), ctx);
    let proj = b.gemm(&format!("{name}.proj"), mh, dim, true);
    let res1 = b.add(&format!("{name}.res1"), proj, x);
    let ln2 = b.layernorm(&format!("{name}.ln2"), res1);
    let up = b.gemm(&format!("{name}.up"), ln2, dim * mlp_mult, true);
    let act = b.gelu(&format!("{name}.gelu"), up);
    let down = b.gemm(&format!("{name}.down"), act, dim, true);
    b.add(&format!("{name}.res2"), down, res1)
}

/// ViT-mini: patchify conv → transformer blocks → mean-pool → head.
pub fn vit(cfg: ImageCfg, seed: u64) -> Graph {
    let dim = 32;
    let heads = 4;
    let patch = 4;
    let mut b = GraphBuilder::new("vit", seed);
    let x = b.input("x", vec![cfg.batch, cfg.channels, cfg.hw, cfg.hw]);
    // patch embedding: conv stride=patch then flatten spatial to tokens
    let pe = b.conv2d("patch", x, dim, patch, patch, 0, 1, true);
    let tokens = b.nchw_to_tokens("tok", pe);
    let mut h = tokens;
    for i in 0..2 {
        h = transformer_block(&mut b, &format!("blk{i}"), h, dim, heads, 2);
    }
    let ln = b.layernorm("final_ln", h);
    let pooled = b.reduce_mean("pool", ln, 1);
    let out = b.gemm("head", pooled, cfg.classes, true);
    b.output(out);
    b.finish().expect("vit")
}

/// DistilBERT-mini: token embedding + transformer + mean-pool classifier.
pub fn distilbert(cfg: TextCfg, seed: u64) -> Graph {
    let mut b = GraphBuilder::new("distilbert", seed);
    let ids = b.input("ids", vec![cfg.batch, cfg.seq]);
    let emb = b.embedding("emb", ids, cfg.vocab, cfg.dim);
    let pos = {
        let t = crate::tensor::Tensor::kaiming(&[1, cfg.seq, cfg.dim], cfg.dim, b.rng());
        b.param("pos", t)
    };
    let mut h = b.add("posadd", emb, pos);
    for i in 0..cfg.layers {
        h = transformer_block(&mut b, &format!("blk{i}"), h, cfg.dim, cfg.heads, 2);
    }
    let ln = b.layernorm("final_ln", h);
    let pooled = b.reduce_mean("pool", ln, 1);
    let out = b.gemm("head", pooled, cfg.classes, true);
    b.output(out);
    b.finish().expect("distilbert")
}

/// All image-model names (Tab. 2 order).
pub const IMAGE_MODELS: &[&str] = &[
    "alexnet",
    "densenet",
    "efficientnet",
    "mobilenetv2",
    "regnet",
    "resnet50",
    "resnext",
    "vgg16",
    "wideresnet",
    "vit",
];

/// Additional model names [`by_name`] accepts beyond [`IMAGE_MODELS`]
/// (kept in sync with the match arms below).
pub const EXTRA_MODELS: &[&str] = &["mlp", "vgg19", "resnet18", "resnet101"];

/// Build an image model by name.
pub fn by_name(name: &str, cfg: ImageCfg, seed: u64) -> anyhow::Result<Graph> {
    Ok(match name {
        "mlp" => mlp(cfg, &[64, 64], seed),
        "alexnet" => alexnet(cfg, seed),
        "vgg16" => vgg16(cfg, seed),
        "vgg19" => vgg19(cfg, seed),
        "resnet18" => resnet18(cfg, seed),
        "resnet50" => resnet50(cfg, seed),
        "resnet101" => resnet101(cfg, seed),
        "wideresnet" => wideresnet(cfg, seed),
        "resnext" => resnext(cfg, seed),
        "regnet" => regnet(cfg, seed),
        "densenet" => densenet(cfg, seed),
        "mobilenetv2" => mobilenetv2(cfg, seed),
        "efficientnet" => efficientnet(cfg, seed),
        "vit" => vit(cfg, seed),
        other => anyhow::bail!(
            "unknown model `{other}` — valid names: {}, {}",
            IMAGE_MODELS.join(", "),
            EXTRA_MODELS.join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine;
    use crate::prune::{self, build_groups, score_groups, Agg, Norm};
    use crate::tensor::Tensor;
    use crate::util::Rng;
    use std::collections::HashMap;

    fn all_models() -> Vec<Graph> {
        let cfg = ImageCfg::default();
        let mut v: Vec<Graph> = IMAGE_MODELS
            .iter()
            .map(|m| by_name(m, cfg, 1).unwrap())
            .collect();
        v.push(by_name("mlp", cfg, 1).unwrap());
        v.push(by_name("resnet18", cfg, 1).unwrap());
        v.push(by_name("resnet101", cfg, 1).unwrap());
        v.push(by_name("vgg19", cfg, 1).unwrap());
        v.push(distilbert(TextCfg::default(), 1));
        v
    }

    #[test]
    fn by_name_error_lists_valid_models() {
        let err = by_name("resnet9000", ImageCfg::default(), 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("resnet9000"), "{err}");
        for name in ["resnet50", "mobilenetv2", "mlp", "vgg19"] {
            assert!(err.contains(name), "`{name}` missing from: {err}");
        }
    }

    #[test]
    fn all_models_validate() {
        for g in all_models() {
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
            assert!(g.num_params() > 500, "{} too small", g.name);
        }
    }

    #[test]
    fn all_image_models_run_forward() {
        let cfg = ImageCfg::default();
        let mut rng = Rng::new(2);
        for name in IMAGE_MODELS {
            let g = by_name(name, cfg, 1).unwrap();
            let x = Tensor::new(
                vec![2, cfg.channels, cfg.hw, cfg.hw],
                rng.uniform_vec(2 * cfg.channels * cfg.hw * cfg.hw, -1.0, 1.0),
            );
            let y = engine::predict(&g, x).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(y.shape, vec![2, cfg.classes], "{name}");
            assert!(y.data.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn distilbert_runs_forward() {
        let cfg = TextCfg::default();
        let g = distilbert(cfg, 3);
        let mut rng = Rng::new(4);
        let ids = Tensor::new(
            vec![2, cfg.seq],
            (0..2 * cfg.seq)
                .map(|_| rng.below(cfg.vocab) as f32)
                .collect(),
        );
        let y = engine::predict(&g, ids).unwrap();
        assert_eq!(y.shape, vec![2, cfg.classes]);
    }

    #[test]
    fn every_model_is_prunable_2x() {
        // the Tab. 2 experiment in miniature: every architecture must
        // survive grouping + ~2x FLOPs pruning + forward execution
        let cfg = ImageCfg::default();
        let mut rng = Rng::new(5);
        for name in IMAGE_MODELS {
            let mut g = by_name(name, cfg, 1).unwrap();
            let groups = build_groups(&g).unwrap();
            assert!(
                groups.num_prunable_ccs() > 4,
                "{name}: too few prunable CCs"
            );
            let mut scores = HashMap::new();
            for pid in g.param_ids() {
                scores.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
            }
            let ranked = score_groups(&g, &groups, &scores, Agg::Sum, Norm::Mean);
            let sel =
                prune::select_by_flops_target(&g, &groups, &ranked, 1.5, 1).unwrap();
            assert!(!sel.is_empty(), "{name}: empty selection");
            prune::apply_pruning(&mut g, &groups, &sel)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            let x = Tensor::new(
                vec![1, cfg.channels, cfg.hw, cfg.hw],
                rng.uniform_vec(cfg.channels * cfg.hw * cfg.hw, -1.0, 1.0),
            );
            let y = engine::predict(&g, x).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(y.data.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn distilbert_prunable() {
        let cfg = TextCfg::default();
        let mut g = distilbert(cfg, 7);
        let groups = build_groups(&g).unwrap();
        let mut scores = HashMap::new();
        for pid in g.param_ids() {
            scores.insert(pid, g.data(pid).param().unwrap().map(f32::abs));
        }
        let ranked = score_groups(&g, &groups, &scores, Agg::Sum, Norm::Mean);
        let sel = prune::select_lowest(&groups, &ranked, 0.3, 2);
        assert!(!sel.is_empty());
        prune::apply_pruning(&mut g, &groups, &sel).unwrap();
        let mut rng = Rng::new(8);
        let ids = Tensor::new(
            vec![1, cfg.seq],
            (0..cfg.seq).map(|_| rng.below(cfg.vocab) as f32).collect(),
        );
        let y = engine::predict(&g, ids).unwrap();
        assert_eq!(y.shape, vec![1, cfg.classes]);
    }
}
