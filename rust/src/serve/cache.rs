//! Process-global plan cache keyed by `(model, prune config, OptLevel)`.
//!
//! Compiling a [`crate::exec::Plan`] is the expensive step of serving a
//! model; the cache makes it a once-per-key cost. Keys are
//! [`crate::session::PlanKey`]s — the prune component derives from
//! [`crate::session::PruneReport::cache_tag`], so two identically
//! configured prunes of the same model share one compiled plan while
//! different targets, criteria, or [`crate::exec::OptLevel`]s do not.
//!
//! Eviction is warm/cold: every access stamps the entry with a logical
//! clock tick, and when the cache exceeds capacity the coldest entry
//! (smallest stamp) is dropped. Each entry also carries the warmed
//! [`Workspace`] pool the serve batch loop persists across ticks, so an
//! eviction sheds the arena memory along with the plan.

use crate::exec::{Plan, Workspace};
use crate::session::PlanKey;
use crate::util::relock;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A compiled plan plus the warmed workspace pool that serves it.
pub struct CachedPlan {
    pub plan: Plan,
    /// Workspaces recycled across batch-loop ticks ([`crate::exec::Batcher::with_pool`]).
    pub pool: Mutex<Vec<Workspace>>,
}

struct Entry {
    plan: Arc<CachedPlan>,
    last_use: u64,
}

struct Inner {
    clock: u64,
    map: HashMap<PlanKey, Entry>,
}

/// Bounded plan cache with warm/cold eviction — see the module docs.
pub struct PlanCache {
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
}

impl PlanCache {
    /// A cache holding at most `cap` compiled plans (min 1).
    pub fn with_capacity(cap: usize) -> PlanCache {
        PlanCache {
            cap: cap.max(1),
            inner: Mutex::new(Inner {
                clock: 0,
                map: HashMap::new(),
            }),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    /// The process-global cache every [`crate::serve::Server`] shares by
    /// default. Capacity comes from `SPA_PLAN_CACHE_CAP` (default 8),
    /// read once on first use.
    pub fn global() -> Arc<PlanCache> {
        static GLOBAL: OnceLock<Arc<PlanCache>> = OnceLock::new();
        GLOBAL
            .get_or_init(|| {
                let cap = std::env::var("SPA_PLAN_CACHE_CAP")
                    .ok()
                    .and_then(|v| v.trim().parse().ok())
                    .unwrap_or(8);
                Arc::new(PlanCache::with_capacity(cap))
            })
            .clone()
    }

    /// Look up `key`, compiling via `build` on a miss. The returned
    /// entry is shared: concurrent holders keep an evicted plan alive
    /// until they drop it.
    pub fn get_or_compile(
        &self,
        key: &PlanKey,
        build: impl FnOnce() -> anyhow::Result<Plan>,
    ) -> anyhow::Result<Arc<CachedPlan>> {
        // relock: a panicked batch worker holding a workspace-pool or
        // cache lock must not wedge every later compile (see
        // `crate::util::relock`)
        let mut inner = relock(&self.inner);
        inner.clock += 1;
        let now = inner.clock;
        if let Some(e) = inner.map.get_mut(key) {
            e.last_use = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(&e.plan));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = build()?;
        // Never serve a plan that fails static verification, regardless of
        // the CheckLevel it was compiled at: a bad arena assignment here
        // corrupts every request batched onto the shared workspace pool.
        crate::check::check_plan(&built)
            .map_err(|e| anyhow::anyhow!("refusing to cache plan for {key}: {e}"))?;
        let plan = Arc::new(CachedPlan {
            plan: built,
            pool: Mutex::new(Vec::new()),
        });
        inner.map.insert(
            key.clone(),
            Entry {
                plan: Arc::clone(&plan),
                last_use: now,
            },
        );
        while inner.map.len() > self.cap {
            let coldest = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(k, _)| k.clone())
                .expect("nonempty over-capacity cache");
            inner.map.remove(&coldest);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok(plan)
    }

    /// Cached plans currently resident.
    pub fn len(&self) -> usize {
        relock(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{OptLevel, PlanOpts};
    use crate::zoo::{self, ImageCfg};

    fn key(model: &str) -> PlanKey {
        PlanKey::baseline(model, OptLevel::Exact)
    }

    fn compile(model: &str) -> anyhow::Result<Plan> {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let g = zoo::by_name(model, cfg, 1)?;
        Plan::compile(&g, PlanOpts::default())
    }

    #[test]
    fn hit_returns_the_same_plan() {
        let cache = PlanCache::with_capacity(4);
        let a = cache
            .get_or_compile(&key("mlp"), || compile("mlp"))
            .unwrap();
        let b = cache
            .get_or_compile(&key("mlp"), || panic!("must not rebuild on a hit"))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn cold_entries_are_evicted_first() {
        let cache = PlanCache::with_capacity(2);
        cache
            .get_or_compile(&key("mlp"), || compile("mlp"))
            .unwrap();
        cache
            .get_or_compile(&key("alexnet"), || compile("alexnet"))
            .unwrap();
        // warm mlp so alexnet is the cold one
        cache
            .get_or_compile(&key("mlp"), || panic!("hit expected"))
            .unwrap();
        cache
            .get_or_compile(&key("resnet18"), || compile("resnet18"))
            .unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        // mlp survived; alexnet was evicted and recompiles
        cache
            .get_or_compile(&key("mlp"), || panic!("mlp must be warm"))
            .unwrap();
        let mut rebuilt = false;
        cache
            .get_or_compile(&key("alexnet"), || {
                rebuilt = true;
                compile("alexnet")
            })
            .unwrap();
        assert!(rebuilt, "cold alexnet must have been evicted");
    }

    #[test]
    fn refuses_to_cache_a_plan_that_fails_verification() {
        use crate::exec::Loc;
        let cache = PlanCache::with_capacity(2);
        let err = cache
            .get_or_compile(&key("mlp"), || {
                let mut plan = compile("mlp")?;
                // sabotage the location table so check_plan must reject it
                let bad = plan.slot_count + 5;
                if let Some(slot) = plan.loc.iter_mut().find(|l| matches!(l, Some(Loc::Slot(_))))
                {
                    *slot = Some(Loc::Slot(bad));
                }
                Ok(plan)
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("refusing to cache"), "got: {err}");
        assert_eq!(cache.len(), 0, "rejected plan must not be cached");
    }

    #[test]
    fn a_poisoned_lock_does_not_wedge_the_cache() {
        let cache = Arc::new(PlanCache::with_capacity(2));
        let c2 = Arc::clone(&cache);
        let _ = std::thread::spawn(move || {
            let _g = c2.inner.lock().unwrap();
            panic!("poison the cache lock");
        })
        .join();
        assert!(cache.inner.is_poisoned());
        cache
            .get_or_compile(&key("mlp"), || compile("mlp"))
            .unwrap();
        assert_eq!(cache.len(), 1, "cache must keep working after a poison");
    }

    #[test]
    fn build_errors_do_not_poison_the_cache() {
        let cache = PlanCache::with_capacity(2);
        let err = cache.get_or_compile(&key("nope"), || compile("nope"));
        assert!(err.is_err());
        assert_eq!(cache.len(), 0);
    }
}
