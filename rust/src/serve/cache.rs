//! Process-global plan cache keyed by `(model, prune config, OptLevel)`.
//!
//! Compiling a [`crate::exec::Plan`] is the expensive step of serving a
//! model; the cache makes it a once-per-key cost. Keys are
//! [`crate::session::PlanKey`]s — the prune component derives from
//! [`crate::session::PruneReport::cache_tag`], so two identically
//! configured prunes of the same model share one compiled plan while
//! different targets, criteria, or [`crate::exec::OptLevel`]s do not.
//!
//! Eviction is warm/cold: every access stamps the entry with a logical
//! clock tick, and when the cache exceeds capacity the coldest entry
//! (smallest stamp) is dropped. Each entry also carries the warmed
//! [`Workspace`] pool the serve batch loop persists across ticks, so an
//! eviction sheds the arena memory along with the plan.

use crate::exec::{Plan, Workspace};
use crate::session::PlanKey;
use crate::util::relock;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A compiled plan plus the warmed workspace pool that serves it.
pub struct CachedPlan {
    pub plan: Plan,
    /// Workspaces recycled across batch-loop ticks ([`crate::exec::Batcher::with_pool`]).
    pub pool: Mutex<Vec<Workspace>>,
}

/// Stage of the live-swap pipeline at which a candidate was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapStage {
    /// Static verification (`check_graph` + `check_plan` at Strict).
    Verify,
    /// Shadow-parity gate against live requests.
    Shadow,
    /// Post-flip error/panic-rate monitor.
    PostFlip,
}

/// Outcome of the most recent swap attempt for a key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SwapOutcome {
    /// No swap has ever been attempted for this key.
    #[default]
    None,
    /// The last swap committed; its generation is serving.
    Committed,
    /// The last swap was rejected or rolled back at this stage; the
    /// previous generation kept serving throughout.
    RolledBack(SwapStage),
}

struct Entry {
    plan: Arc<CachedPlan>,
    last_use: u64,
}

/// Per-key swap bookkeeping. Kept in a side map that eviction never
/// touches, so health reporting survives a plan being shed and
/// recompiled.
#[derive(Clone, Copy)]
struct SwapMeta {
    generation: u64,
    outcome: SwapOutcome,
}

struct Inner {
    clock: u64,
    map: HashMap<PlanKey, Entry>,
    meta: HashMap<PlanKey, SwapMeta>,
}

/// Bounded plan cache with warm/cold eviction — see the module docs.
pub struct PlanCache {
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
}

impl PlanCache {
    /// A cache holding at most `cap` compiled plans (min 1).
    pub fn with_capacity(cap: usize) -> PlanCache {
        PlanCache {
            cap: cap.max(1),
            inner: Mutex::new(Inner {
                clock: 0,
                map: HashMap::new(),
                meta: HashMap::new(),
            }),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
        }
    }

    /// The process-global cache every [`crate::serve::Server`] shares by
    /// default. Capacity comes from `SPA_PLAN_CACHE_CAP` (default 8),
    /// read once on first use.
    pub fn global() -> Arc<PlanCache> {
        static GLOBAL: OnceLock<Arc<PlanCache>> = OnceLock::new();
        GLOBAL
            .get_or_init(|| {
                let cap = std::env::var("SPA_PLAN_CACHE_CAP")
                    .ok()
                    .and_then(|v| v.trim().parse().ok())
                    .unwrap_or(8);
                Arc::new(PlanCache::with_capacity(cap))
            })
            .clone()
    }

    /// Look up `key`, compiling via `build` on a miss. The returned
    /// entry is shared: concurrent holders keep an evicted plan alive
    /// until they drop it.
    pub fn get_or_compile(
        &self,
        key: &PlanKey,
        build: impl FnOnce() -> anyhow::Result<Plan>,
    ) -> anyhow::Result<Arc<CachedPlan>> {
        // relock: a panicked batch worker holding a workspace-pool or
        // cache lock must not wedge every later compile (see
        // `crate::util::relock`)
        let mut inner = relock(&self.inner);
        inner.clock += 1;
        let now = inner.clock;
        if let Some(e) = inner.map.get_mut(key) {
            e.last_use = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            crate::obs::trace::instant_with("cache.hit", || key.to_string());
            return Ok(Arc::clone(&e.plan));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        crate::obs::trace::instant_with("cache.miss", || key.to_string());
        let built = build()?;
        // Never serve a plan that fails static verification, regardless of
        // the CheckLevel it was compiled at: a bad arena assignment here
        // corrupts every request batched onto the shared workspace pool.
        crate::check::check_plan(&built)
            .map_err(|e| anyhow::anyhow!("refusing to cache plan for {key}: {e}"))?;
        let plan = Arc::new(CachedPlan {
            plan: built,
            pool: Mutex::new(Vec::new()),
        });
        inner.map.insert(
            key.clone(),
            Entry {
                plan: Arc::clone(&plan),
                last_use: now,
            },
        );
        // an evicted-then-recompiled key keeps its swap history
        inner.meta.entry(key.clone()).or_insert(SwapMeta {
            generation: 1,
            outcome: SwapOutcome::None,
        });
        self.evict_over_cap(&mut inner);
        Ok(plan)
    }

    fn evict_over_cap(&self, inner: &mut Inner) {
        while inner.map.len() > self.cap {
            let coldest = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(k, _)| k.clone())
                .expect("nonempty over-capacity cache");
            inner.map.remove(&coldest);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            crate::obs::trace::instant_with("cache.evict", || coldest.to_string());
        }
    }

    /// The active plan generation for `key`: 1 for an entry that has
    /// never been swapped, 0 when the key has never been resident.
    pub fn generation(&self, key: &PlanKey) -> u64 {
        relock(&self.inner).meta.get(key).map_or(0, |m| m.generation)
    }

    /// Generation + last-swap outcome for `key`, when it has ever been
    /// resident.
    pub fn swap_meta(&self, key: &PlanKey) -> Option<(u64, SwapOutcome)> {
        relock(&self.inner)
            .meta
            .get(key)
            .map(|m| (m.generation, m.outcome))
    }

    /// Every key's generation and last-swap outcome, sorted for a
    /// deterministic wire order (the health verb reports this).
    pub fn snapshot_meta(&self) -> Vec<(PlanKey, u64, SwapOutcome)> {
        let inner = relock(&self.inner);
        let mut v: Vec<(PlanKey, u64, SwapOutcome)> = inner
            .meta
            .iter()
            .map(|(k, m)| (k.clone(), m.generation, m.outcome))
            .collect();
        v.sort_by(|a, b| {
            a.0.model
                .cmp(&b.0.model)
                .then_with(|| a.0.prune.cmp(&b.0.prune))
        });
        v
    }

    /// Record the outcome of a swap attempt that never flipped (a
    /// verify or shadow failure): the serving plan and its generation
    /// stay untouched.
    pub fn record_outcome(&self, key: &PlanKey, outcome: SwapOutcome) {
        let mut inner = relock(&self.inner);
        let m = inner.meta.entry(key.clone()).or_insert(SwapMeta {
            generation: 1,
            outcome: SwapOutcome::None,
        });
        m.outcome = outcome;
    }

    /// Atomically install a new generation for `key`: verify `built`,
    /// swap it into the map, bump the generation, and return
    /// `(from_gen, to_gen, old)` — `old` being the displaced entry,
    /// which in-flight batches keep alive (its workspace pool is freed
    /// only when the last holder drops it). The outcome is recorded as
    /// [`SwapOutcome::Committed`]; a post-flip monitor that decides
    /// otherwise rolls back with [`PlanCache::restore`].
    pub fn flip(
        &self,
        key: &PlanKey,
        built: Plan,
    ) -> anyhow::Result<(u64, u64, Option<Arc<CachedPlan>>)> {
        // same refusal as get_or_compile: an unverifiable plan must
        // never become an admission target
        crate::check::check_plan(&built)
            .map_err(|e| anyhow::anyhow!("refusing to flip plan for {key}: {e}"))?;
        let plan = Arc::new(CachedPlan {
            plan: built,
            pool: Mutex::new(Vec::new()),
        });
        let mut inner = relock(&self.inner);
        inner.clock += 1;
        let now = inner.clock;
        let old = inner
            .map
            .insert(
                key.clone(),
                Entry {
                    plan,
                    last_use: now,
                },
            )
            .map(|e| e.plan);
        let m = inner.meta.entry(key.clone()).or_insert(SwapMeta {
            generation: 0,
            outcome: SwapOutcome::None,
        });
        let from = m.generation;
        m.generation += 1;
        m.outcome = SwapOutcome::Committed;
        let to = m.generation;
        self.evict_over_cap(&mut inner);
        Ok((from, to, old))
    }

    /// Roll back a committed flip: re-install `old` as the serving
    /// entry, restore the generation to `gen`, and record the rollback
    /// outcome. New admissions land back on the old plan as soon as
    /// this returns.
    pub fn restore(&self, key: &PlanKey, old: Arc<CachedPlan>, gen: u64, outcome: SwapOutcome) {
        let mut inner = relock(&self.inner);
        inner.clock += 1;
        let now = inner.clock;
        inner.map.insert(
            key.clone(),
            Entry {
                plan: old,
                last_use: now,
            },
        );
        let m = inner.meta.entry(key.clone()).or_insert(SwapMeta {
            generation: gen,
            outcome: SwapOutcome::None,
        });
        m.generation = gen;
        m.outcome = outcome;
        self.evict_over_cap(&mut inner);
    }

    /// Cached plans currently resident.
    pub fn len(&self) -> usize {
        relock(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{OptLevel, PlanOpts};
    use crate::zoo::{self, ImageCfg};

    fn key(model: &str) -> PlanKey {
        PlanKey::baseline(model, OptLevel::Exact)
    }

    fn compile(model: &str) -> anyhow::Result<Plan> {
        let cfg = ImageCfg {
            hw: 8,
            ..Default::default()
        };
        let g = zoo::by_name(model, cfg, 1)?;
        Plan::compile(&g, PlanOpts::default())
    }

    #[test]
    fn hit_returns_the_same_plan() {
        let cache = PlanCache::with_capacity(4);
        let a = cache
            .get_or_compile(&key("mlp"), || compile("mlp"))
            .unwrap();
        let b = cache
            .get_or_compile(&key("mlp"), || panic!("must not rebuild on a hit"))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn cold_entries_are_evicted_first() {
        let cache = PlanCache::with_capacity(2);
        cache
            .get_or_compile(&key("mlp"), || compile("mlp"))
            .unwrap();
        cache
            .get_or_compile(&key("alexnet"), || compile("alexnet"))
            .unwrap();
        // warm mlp so alexnet is the cold one
        cache
            .get_or_compile(&key("mlp"), || panic!("hit expected"))
            .unwrap();
        cache
            .get_or_compile(&key("resnet18"), || compile("resnet18"))
            .unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        // mlp survived; alexnet was evicted and recompiles
        cache
            .get_or_compile(&key("mlp"), || panic!("mlp must be warm"))
            .unwrap();
        let mut rebuilt = false;
        cache
            .get_or_compile(&key("alexnet"), || {
                rebuilt = true;
                compile("alexnet")
            })
            .unwrap();
        assert!(rebuilt, "cold alexnet must have been evicted");
    }

    #[test]
    fn refuses_to_cache_a_plan_that_fails_verification() {
        use crate::exec::Loc;
        let cache = PlanCache::with_capacity(2);
        let err = cache
            .get_or_compile(&key("mlp"), || {
                let mut plan = compile("mlp")?;
                // sabotage the location table so check_plan must reject it
                let bad = plan.slot_count + 5;
                if let Some(slot) = plan.loc.iter_mut().find(|l| matches!(l, Some(Loc::Slot(_))))
                {
                    *slot = Some(Loc::Slot(bad));
                }
                Ok(plan)
            })
            .unwrap_err()
            .to_string();
        assert!(err.contains("refusing to cache"), "got: {err}");
        assert_eq!(cache.len(), 0, "rejected plan must not be cached");
    }

    #[test]
    fn a_poisoned_lock_does_not_wedge_the_cache() {
        let cache = Arc::new(PlanCache::with_capacity(2));
        let c2 = Arc::clone(&cache);
        let _ = std::thread::spawn(move || {
            let _g = c2.inner.lock().unwrap();
            panic!("poison the cache lock");
        })
        .join();
        assert!(cache.inner.is_poisoned());
        cache
            .get_or_compile(&key("mlp"), || compile("mlp"))
            .unwrap();
        assert_eq!(cache.len(), 1, "cache must keep working after a poison");
    }

    #[test]
    fn build_errors_do_not_poison_the_cache() {
        let cache = PlanCache::with_capacity(2);
        let err = cache.get_or_compile(&key("nope"), || compile("nope"));
        assert!(err.is_err());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn flip_swaps_atomically_and_tracks_generations() {
        let cache = PlanCache::with_capacity(4);
        let k = key("mlp");
        assert_eq!(cache.generation(&k), 0, "unseen key has no generation");
        let first = cache.get_or_compile(&k, || compile("mlp")).unwrap();
        assert_eq!(cache.generation(&k), 1);
        assert_eq!(cache.swap_meta(&k), Some((1, SwapOutcome::None)));
        let (from, to, old) = cache.flip(&k, compile("mlp").unwrap()).unwrap();
        assert_eq!((from, to), (1, 2));
        assert!(Arc::ptr_eq(old.as_ref().unwrap(), &first));
        let now = cache
            .get_or_compile(&k, || panic!("flipped entry must be a hit"))
            .unwrap();
        assert!(!Arc::ptr_eq(&now, &first), "admissions land on the new generation");
        let snap = cache.snapshot_meta();
        assert!(snap
            .iter()
            .any(|(sk, g, o)| sk == &k && *g == 2 && *o == SwapOutcome::Committed));
    }

    #[test]
    fn restore_rolls_back_to_the_old_generation() {
        let cache = PlanCache::with_capacity(4);
        let k = key("mlp");
        let first = cache.get_or_compile(&k, || compile("mlp")).unwrap();
        let (from, _, old) = cache.flip(&k, compile("mlp").unwrap()).unwrap();
        cache.restore(
            &k,
            old.unwrap(),
            from,
            SwapOutcome::RolledBack(SwapStage::PostFlip),
        );
        let serving = cache
            .get_or_compile(&k, || panic!("restored entry must be a hit"))
            .unwrap();
        assert!(Arc::ptr_eq(&serving, &first), "old generation serves again");
        assert_eq!(
            cache.swap_meta(&k),
            Some((1, SwapOutcome::RolledBack(SwapStage::PostFlip)))
        );
    }

    #[test]
    fn flip_refuses_an_unverifiable_plan() {
        use crate::exec::Loc;
        let cache = PlanCache::with_capacity(2);
        let k = key("mlp");
        let first = cache.get_or_compile(&k, || compile("mlp")).unwrap();
        let mut bad = compile("mlp").unwrap();
        let slot = bad.slot_count + 5;
        if let Some(l) = bad.loc.iter_mut().find(|l| matches!(l, Some(Loc::Slot(_)))) {
            *l = Some(Loc::Slot(slot));
        }
        let err = cache.flip(&k, bad).unwrap_err().to_string();
        assert!(err.contains("refusing to flip"), "got: {err}");
        let serving = cache.get_or_compile(&k, || panic!("must be a hit")).unwrap();
        assert!(Arc::ptr_eq(&serving, &first), "old plan must keep serving");
        assert_eq!(cache.generation(&k), 1, "generation must not advance");
    }

    #[test]
    fn swap_meta_survives_eviction() {
        let cache = PlanCache::with_capacity(1);
        let k = key("mlp");
        cache.get_or_compile(&k, || compile("mlp")).unwrap();
        cache.flip(&k, compile("mlp").unwrap()).unwrap();
        // evict mlp by inserting another model into the 1-slot cache
        cache
            .get_or_compile(&key("alexnet"), || compile("alexnet"))
            .unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(
            cache.swap_meta(&k),
            Some((2, SwapOutcome::Committed)),
            "history must survive the plan being shed"
        );
        // recompiling after eviction keeps the generation counter
        cache.get_or_compile(&k, || compile("mlp")).unwrap();
        assert_eq!(cache.generation(&k), 2);
    }

    #[test]
    fn old_generation_pool_is_released_after_last_holder_drops() {
        let cache = PlanCache::with_capacity(2);
        let k = key("mlp");
        let held = cache.get_or_compile(&k, || compile("mlp")).unwrap();
        relock(&held.pool).push(held.plan.workspace());
        let weak = Arc::downgrade(&held);
        let (_, _, old) = cache.flip(&k, compile("mlp").unwrap()).unwrap();
        drop(old);
        // an in-flight batch still holds the old generation alive
        assert!(weak.upgrade().is_some(), "in-flight holder keeps it alive");
        drop(held);
        assert!(
            weak.upgrade().is_none(),
            "pool must be freed with the last holder, not leaked"
        );
    }

    #[test]
    fn concurrent_flips_race_eviction_and_in_flight_batches() {
        use crate::exec::Batcher;
        use crate::tensor::Tensor;
        use crate::util::par;
        use std::sync::atomic::AtomicBool;
        let _serial = par::test_lock();
        for width in [1usize, 8] {
            par::with_threads(width, || {
                let cache = Arc::new(PlanCache::with_capacity(2));
                let k = key("mlp");
                let old = cache.get_or_compile(&k, || compile("mlp")).unwrap();
                let weak = Arc::downgrade(&old);
                let stop = Arc::new(AtomicBool::new(false));
                let (c2, s2) = (Arc::clone(&cache), Arc::clone(&stop));
                let flipper = std::thread::spawn(move || {
                    let mut flips = 0usize;
                    while !s2.load(Ordering::Relaxed) {
                        c2.flip(&key("mlp"), compile("mlp").unwrap()).unwrap();
                        flips += 1;
                    }
                    flips
                });
                let (c3, s3) = (Arc::clone(&cache), Arc::clone(&stop));
                let evictor = std::thread::spawn(move || {
                    while !s3.load(Ordering::Relaxed) {
                        c3.get_or_compile(&key("alexnet"), || compile("alexnet"))
                            .unwrap();
                        c3.get_or_compile(&key("resnet18"), || compile("resnet18"))
                            .unwrap();
                    }
                });
                // in-flight batches on the pre-flip generation keep
                // producing that generation's exact bits throughout
                let x = Tensor::zeros(&[2, 3, 8, 8]);
                let want = old.plan.predict(&x).unwrap();
                let pool = std::mem::take(&mut *relock(&old.pool));
                let batcher = Batcher::with_pool(&old.plan, pool);
                for _ in 0..10 {
                    for out in batcher.run_batch(&[x.clone(), x.clone()]).unwrap() {
                        assert_eq!(out.shape, want.shape);
                        for (a, b) in out.data.iter().zip(&want.data) {
                            assert_eq!(a.to_bits(), b.to_bits());
                        }
                    }
                }
                *relock(&old.pool) = batcher.into_pool();
                stop.store(true, Ordering::Relaxed);
                let flips = flipper.join().unwrap();
                evictor.join().unwrap();
                assert!(flips > 0, "flipper must have flipped");
                assert!(cache.len() <= 2, "eviction must hold the cap");
                assert!(cache.generation(&key("mlp")) > 1);
                drop(old);
                assert!(
                    weak.upgrade().is_none(),
                    "old generations must be released once batches finish"
                );
            });
        }
    }
}
