//! `spa::serve` — a batching inference server over compiled plans.
//!
//! The paper's "any time" pruning story only pays off when the pruned
//! model's smaller FLOPs become user-visible throughput; this module is
//! the front-end that cashes that in. It is hermetic (std-net,
//! length-prefixed TCP — see [`protocol`]) and long-running, exposed as
//! the `spa serve` CLI subcommand:
//!
//! * **Admission**: each connection gets a handler thread that decodes
//!   requests and parks them on a [`queue::Queue`], blocking per
//!   request until the batch loop responds.
//! * **Dynamic batching**: a single batch-loop thread drains the queue
//!   once per tick, stacks same-shape requests into batched tensors,
//!   and dispatches one [`crate::exec::Batcher`] call per tick per
//!   plan. Per-sample kernels are bit-identical at any batch size, so
//!   responses match [`crate::exec::Plan::predict`] exactly.
//! * **Deadlines**: a request's soft deadline can only *accelerate* its
//!   batch's dispatch (the batch leaves at
//!   `min(oldest admission + tick, earliest deadline)`); requests are
//!   never dropped.
//! * **Plan cache**: compiled plans live in a process-global
//!   [`cache::PlanCache`] keyed by [`crate::session::PlanKey`] —
//!   `(model, prune config, OptLevel)` — with warm/cold eviction, so
//!   heterogeneous traffic shares compilations.
//! * **Latency**: every response carries the server-measured
//!   admission→response latency; [`Stats`] aggregates p50/p99 for the
//!   CLI and the `micro_serve` bench.
//!
//! ```no_run
//! use spa::serve::{Client, ServeCfg, Server};
//! # fn main() -> anyhow::Result<()> {
//! let server = Server::spawn(ServeCfg::default())?;
//! let mut client = Client::connect(server.local_addr())?;
//! let x = spa::tensor::Tensor::zeros(&[1, 3, 16, 16]);
//! let (logits, latency_us) = client.predict("resnet18", &x)?;
//! println!("{:?} in {latency_us}us", logits.shape);
//! # Ok(()) }
//! ```

pub mod cache;
pub mod protocol;
pub mod queue;

pub use cache::{CachedPlan, PlanCache};
pub use protocol::{Client, Request, Response};
pub use queue::{Pending, Queue};

use crate::criteria::Criterion;
use crate::exec::{Batcher, OptLevel, Plan, PlanOpts};
use crate::ir::Graph;
use crate::session::{PlanKey, Session, Target};
use crate::tensor::Tensor;
use crate::zoo::{self, ImageCfg};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration for [`Server::spawn`].
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Bind address; port 0 picks a free port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Batching tick: a batch dispatches once its oldest request has
    /// waited this long (deadlines can only shorten the wait).
    pub tick: Duration,
    /// Maximum stacked rows per dispatched chunk, and maximum requests
    /// drained per tick.
    pub max_batch: usize,
    /// Plan-cache capacity; 0 uses the process-global
    /// [`PlanCache::global`] (capacity `SPA_PLAN_CACHE_CAP`, default 8).
    pub cache_cap: usize,
    /// Optimization level plans are compiled at.
    pub level: OptLevel,
    /// Zoo instantiation config for requested models.
    pub image: ImageCfg,
    /// Zoo weight seed.
    pub seed: u64,
    /// When set, serve every model pruned toward this FLOPs RF.
    pub prune_rf: Option<f64>,
    /// Saliency criterion for `prune_rf` (data-free criteria only).
    pub criterion: String,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            addr: "127.0.0.1:0".to_string(),
            tick: Duration::from_millis(2),
            max_batch: 64,
            cache_cap: 0,
            level: OptLevel::Exact,
            image: ImageCfg::default(),
            seed: 1,
            prune_rf: None,
            criterion: "l1".to_string(),
        }
    }
}

/// Serving counters plus a latency ring for percentile reporting.
pub struct Stats {
    served: AtomicUsize,
    errors: AtomicUsize,
    batches: AtomicUsize,
    lat_us: Mutex<Vec<u32>>,
}

/// Latency samples kept for percentiles (oldest dropped first).
const LAT_RING: usize = 8192;

impl Stats {
    fn new() -> Stats {
        Stats {
            served: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            lat_us: Mutex::new(Vec::new()),
        }
    }

    /// Requests answered (ok or error).
    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    /// Requests answered with an error response.
    pub fn errors(&self) -> usize {
        self.errors.load(Ordering::Relaxed)
    }

    /// Non-empty batch-loop ticks dispatched.
    pub fn batches(&self) -> usize {
        self.batches.load(Ordering::Relaxed)
    }

    /// The `p`-th latency percentile (0-100) over the recent ring, in
    /// microseconds. `None` before any request completed.
    pub fn latency_percentile_us(&self, p: f64) -> Option<u32> {
        let lat = self.lat_us.lock().unwrap();
        if lat.is_empty() {
            return None;
        }
        let mut v = lat.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        Some(v[idx.min(v.len() - 1)])
    }

    fn record(&self, latency_us: u32, ok: bool) {
        self.served.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let mut lat = self.lat_us.lock().unwrap();
        if lat.len() >= LAT_RING {
            lat.remove(0);
        }
        lat.push(latency_us);
    }
}

/// Resolves model names to cached compiled plans. Lives on the batch-
/// loop thread; `keys` memoizes the model → [`PlanKey`] derivation
/// (pruning must run once before the prune tag is known).
struct Resolver {
    image: ImageCfg,
    seed: u64,
    level: OptLevel,
    prune_rf: Option<f64>,
    criterion: String,
    cache: Arc<PlanCache>,
    keys: HashMap<String, PlanKey>,
}

impl Resolver {
    /// Build the (optionally pruned) graph and derive its cache key.
    fn build_model(&self, model: &str) -> anyhow::Result<(Graph, PlanKey)> {
        let g = zoo::by_name(model, self.image, self.seed)?;
        match self.prune_rf {
            Some(rf) => {
                let pruned = Session::on(&g)
                    .criterion(Criterion::parse(&self.criterion)?)
                    .target(Target::FlopsRf(rf))
                    .plan()?
                    .apply()?;
                let key = PlanKey::pruned(model, &pruned.report, self.level);
                Ok((pruned.graph, key))
            }
            None => Ok((g, PlanKey::baseline(model, self.level))),
        }
    }

    fn plan_for(&mut self, model: &str) -> anyhow::Result<Arc<CachedPlan>> {
        let (key, prebuilt) = match self.keys.get(model) {
            Some(k) => (k.clone(), None),
            None => {
                let (g, key) = self.build_model(model)?;
                self.keys.insert(model.to_string(), key.clone());
                (key, Some(g))
            }
        };
        let cache = Arc::clone(&self.cache);
        let level = self.level;
        cache.get_or_compile(&key, || {
            let g = match prebuilt {
                Some(g) => g,
                // evicted since the key was derived: rebuild from source
                None => self.build_model(model)?.0,
            };
            Plan::compile(
                &g,
                PlanOpts {
                    level,
                    ..Default::default()
                },
            )
        })
    }
}

/// Pack request tensors into stacked chunks: consecutive tensors with
/// equal tail shapes concatenate along dim 0, up to `max_rows` rows per
/// chunk. Returns `(chunks, members)` where `members[c]` lists the
/// indices stacked into `chunks[c]`, in order.
fn pack_chunks(tensors: &[&Tensor], max_rows: usize) -> (Vec<Tensor>, Vec<Vec<usize>>) {
    let mut chunks: Vec<Tensor> = Vec::new();
    let mut members: Vec<Vec<usize>> = Vec::new();
    for (i, t) in tensors.iter().enumerate() {
        let rows = t.shape[0];
        let fits = chunks
            .last()
            .is_some_and(|c| c.shape[1..] == t.shape[1..] && c.shape[0] + rows <= max_rows.max(1));
        if fits {
            let c = chunks.last_mut().expect("fits implies a chunk");
            c.shape[0] += rows;
            c.data.extend_from_slice(&t.data);
            members.last_mut().expect("fits implies members").push(i);
        } else {
            chunks.push((*t).clone());
            members.push(vec![i]);
        }
    }
    (chunks, members)
}

/// Split a stacked chunk's output back into per-request tensors by each
/// member's leading dim, and respond.
fn send_split(reqs: &[Pending], valid: &[usize], mem: &[usize], out: &Tensor) {
    let rows_total: usize = mem.iter().map(|&m| reqs[valid[m]].tensor.shape[0]).sum();
    if rows_total == 0 || out.shape.first().copied().unwrap_or(0) != rows_total {
        for &m in mem {
            let _ = reqs[valid[m]].resp.send(Err(anyhow::anyhow!(
                "model output rows {:?} do not match the {rows_total} stacked request rows",
                out.shape.first()
            )));
        }
        return;
    }
    let per_row = out.numel() / rows_total;
    let mut off = 0usize;
    for &m in mem {
        let rows = reqs[valid[m]].tensor.shape[0];
        let mut shape = out.shape.clone();
        shape[0] = rows;
        let data = out.data[off * per_row..(off + rows) * per_row].to_vec();
        off += rows;
        let _ = reqs[valid[m]].resp.send(Ok(Tensor::new(shape, data)));
    }
}

/// Serve one model's share of a tick: stack, dispatch through a
/// [`Batcher`] whose workspace pool persists on the cache entry, split,
/// respond. A failed combined dispatch falls back to per-chunk
/// dispatch so one malformed request cannot poison co-batched ones.
fn process_group(cached: &CachedPlan, reqs: &[Pending], max_rows: usize) {
    let mut valid: Vec<usize> = Vec::new();
    for (i, p) in reqs.iter().enumerate() {
        if p.tensor.shape.first().copied().unwrap_or(0) == 0 {
            let _ = p.resp.send(Err(anyhow::anyhow!(
                "request tensor needs a leading batch dim of at least 1"
            )));
        } else {
            valid.push(i);
        }
    }
    let tensors: Vec<&Tensor> = valid.iter().map(|&i| &reqs[i].tensor).collect();
    let (chunks, members) = pack_chunks(&tensors, max_rows);
    let pool = std::mem::take(&mut *cached.pool.lock().unwrap());
    let batcher = Batcher::with_pool(&cached.plan, pool);
    match batcher.run_batch(&chunks) {
        Ok(outs) => {
            for (out, mem) in outs.iter().zip(&members) {
                send_split(reqs, &valid, mem, out);
            }
        }
        Err(_) => {
            for (chunk, mem) in chunks.iter().zip(&members) {
                match batcher.run_batch(std::slice::from_ref(chunk)) {
                    Ok(outs) => send_split(reqs, &valid, mem, &outs[0]),
                    Err(e) => {
                        let msg = e.to_string();
                        for &m in mem {
                            let _ = reqs[valid[m]].resp.send(Err(anyhow::anyhow!("{msg}")));
                        }
                    }
                }
            }
        }
    }
    *cached.pool.lock().unwrap() = batcher.into_pool();
}

fn process_batch(resolver: &mut Resolver, batch: Vec<Pending>, max_rows: usize) {
    // group by model, preserving admission order within each group
    let mut groups: Vec<(String, Vec<Pending>)> = Vec::new();
    for p in batch {
        match groups.iter_mut().find(|(m, _)| *m == p.model) {
            Some((_, v)) => v.push(p),
            None => {
                let m = p.model.clone();
                groups.push((m, vec![p]));
            }
        }
    }
    for (model, reqs) in &groups {
        match resolver.plan_for(model) {
            Ok(cached) => process_group(&cached, reqs, max_rows),
            Err(e) => {
                let msg = e.to_string();
                for p in reqs {
                    let _ = p.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

fn batch_loop(
    queue: Arc<Queue>,
    shutdown: Arc<AtomicBool>,
    mut resolver: Resolver,
    tick: Duration,
    max_batch: usize,
    stats: Arc<Stats>,
) {
    loop {
        let batch = queue.drain_tick(tick, max_batch);
        if batch.is_empty() {
            // flush-then-exit: handlers stop enqueuing once shutdown is
            // set, so an empty queue here means we are done
            if shutdown.load(Ordering::SeqCst) && queue.is_empty() {
                break;
            }
            continue;
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        process_batch(&mut resolver, batch, max_batch);
    }
}

fn handle_conn(
    mut stream: TcpStream,
    queue: Arc<Queue>,
    shutdown: Arc<AtomicBool>,
    stats: Arc<Stats>,
) {
    let _ = stream.set_nodelay(true);
    // short read timeout so idle handlers observe shutdown
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    loop {
        match protocol::read_frame(&mut stream) {
            Ok(protocol::FrameRead::Idle) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Ok(protocol::FrameRead::Eof) | Err(_) => break,
            Ok(protocol::FrameRead::Frame(body)) => {
                let t0 = Instant::now();
                let reply = match protocol::decode_request(&body) {
                    Ok(req) => {
                        let (tx, rx) = mpsc::channel();
                        queue.push(Pending {
                            model: req.model,
                            tensor: req.tensor,
                            admitted: t0,
                            deadline: (req.deadline_ms > 0)
                                .then(|| t0 + Duration::from_millis(u64::from(req.deadline_ms))),
                            resp: tx,
                        });
                        match rx.recv() {
                            Ok(Ok(t)) => Ok(t),
                            Ok(Err(e)) => Err(e.to_string()),
                            Err(_) => Err("server shut down before responding".to_string()),
                        }
                    }
                    Err(e) => Err(e.to_string()),
                };
                let latency_us = t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32;
                stats.record(latency_us, reply.is_ok());
                let resp = match reply {
                    Ok(tensor) => Response::Ok { latency_us, tensor },
                    Err(message) => Response::Err {
                        latency_us,
                        message,
                    },
                };
                let body = match protocol::encode_response(&resp) {
                    Ok(b) => b,
                    Err(_) => break,
                };
                if protocol::write_frame(&mut stream, &body).is_err() {
                    break;
                }
            }
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    queue: Arc<Queue>,
    shutdown: Arc<AtomicBool>,
    stats: Arc<Stats>,
) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let q = Arc::clone(&queue);
                let f = Arc::clone(&shutdown);
                let s = Arc::clone(&stats);
                if let Ok(h) = std::thread::Builder::new()
                    .name("spa-serve-conn".to_string())
                    .spawn(move || handle_conn(stream, q, f, s))
                {
                    handlers.push(h);
                }
            }
            Err(_) => continue,
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// A running serve instance: an accept thread (one handler thread per
/// connection) plus the batch-loop thread. Shuts down cleanly on
/// [`Server::shutdown`] or drop, flushing queued requests first.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    batch: Option<JoinHandle<()>>,
    stats: Arc<Stats>,
    cache: Arc<PlanCache>,
}

impl Server {
    /// Bind and start serving in background threads.
    pub fn spawn(cfg: ServeCfg) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(Queue::new());
        let stats = Arc::new(Stats::new());
        let cache = match cfg.cache_cap {
            0 => PlanCache::global(),
            n => Arc::new(PlanCache::with_capacity(n)),
        };
        let resolver = Resolver {
            image: cfg.image,
            seed: cfg.seed,
            level: cfg.level,
            prune_rf: cfg.prune_rf,
            criterion: cfg.criterion.clone(),
            cache: Arc::clone(&cache),
            keys: HashMap::new(),
        };
        let batch = {
            let queue = Arc::clone(&queue);
            let shutdown = Arc::clone(&shutdown);
            let stats = Arc::clone(&stats);
            let (tick, max_batch) = (cfg.tick, cfg.max_batch.max(1));
            std::thread::Builder::new()
                .name("spa-serve-batch".to_string())
                .spawn(move || batch_loop(queue, shutdown, resolver, tick, max_batch, stats))?
        };
        let accept = {
            let queue = Arc::clone(&queue);
            let shutdown = Arc::clone(&shutdown);
            let stats = Arc::clone(&stats);
            std::thread::Builder::new()
                .name("spa-serve-accept".to_string())
                .spawn(move || accept_loop(listener, queue, shutdown, stats))?
        };
        Ok(Server {
            addr,
            shutdown,
            accept: Some(accept),
            batch: Some(batch),
            stats,
            cache,
        })
    }

    /// The bound address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live serving counters and latency percentiles.
    pub fn stats(&self) -> Arc<Stats> {
        Arc::clone(&self.stats)
    }

    /// The plan cache this server compiles into.
    pub fn cache(&self) -> Arc<PlanCache> {
        Arc::clone(&self.cache)
    }

    /// Stop accepting, flush queued requests, and join all threads.
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.batch.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_chunks_stacks_same_tail_shapes() {
        let a = Tensor::zeros(&[1, 3, 4, 4]);
        let b = Tensor::zeros(&[2, 3, 4, 4]);
        let c = Tensor::zeros(&[1, 8]);
        let d = Tensor::zeros(&[1, 3, 4, 4]);
        let tensors = vec![&a, &b, &c, &d];
        let (chunks, members) = pack_chunks(&tensors, 64);
        // a+b stack; c breaks the run; d starts a new image chunk
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].shape, vec![3, 3, 4, 4]);
        assert_eq!(members[0], vec![0, 1]);
        assert_eq!(chunks[1].shape, vec![1, 8]);
        assert_eq!(chunks[2].shape, vec![1, 3, 4, 4]);
    }

    #[test]
    fn pack_chunks_respects_max_rows() {
        let ts: Vec<Tensor> = (0..5).map(|_| Tensor::zeros(&[1, 4])).collect();
        let refs: Vec<&Tensor> = ts.iter().collect();
        let (chunks, members) = pack_chunks(&refs, 2);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].shape, vec![2, 4]);
        assert_eq!(chunks[2].shape, vec![1, 4]);
        assert_eq!(members.concat(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn server_round_trips_one_request() {
        let cfg = ServeCfg {
            tick: Duration::from_millis(1),
            cache_cap: 2,
            image: ImageCfg {
                hw: 8,
                ..Default::default()
            },
            ..Default::default()
        };
        let (image, seed, level) = (cfg.image, cfg.seed, cfg.level);
        let server = Server::spawn(cfg).unwrap();
        let mut client = Client::connect(server.local_addr()).unwrap();
        let x = Tensor::zeros(&[1, image.channels, image.hw, image.hw]);
        let (logits, _lat) = client.predict("mlp", &x).unwrap();
        // bit-identical to a local Plan::predict on the same zoo build
        let g = zoo::by_name("mlp", image, seed).unwrap();
        let plan = Plan::compile(
            &g,
            PlanOpts {
                level,
                ..Default::default()
            },
        )
        .unwrap();
        let want = plan.predict(&x).unwrap();
        assert_eq!(logits.shape, want.shape);
        for (a, b) in logits.data.iter().zip(&want.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // unknown models error without killing the connection
        assert!(client.predict("definitely-not-a-model", &x).is_err());
        let (again, _) = client.predict("mlp", &x).unwrap();
        assert_eq!(again.shape, want.shape);
        assert_eq!(server.stats().served(), 3);
        assert_eq!(server.stats().errors(), 1);
        server.shutdown();
    }
}
