//! `spa::serve` — a batching, fault-tolerant inference server over
//! compiled plans.
//!
//! The paper's "any time" pruning story only pays off when the pruned
//! model's smaller FLOPs become user-visible throughput; this module is
//! the front-end that cashes that in. It is hermetic (std-net,
//! length-prefixed TCP — see [`protocol`]) and long-running, exposed as
//! the `spa serve` CLI subcommand:
//!
//! * **Admission**: each connection gets a handler thread that decodes
//!   requests and parks them on a bounded [`queue::Queue`], blocking
//!   per request until the batch loop responds. A full queue rejects
//!   with [`ErrorCode::Overloaded`] at admission (load shedding at the
//!   cheapest point), never by growing without bound.
//! * **Dynamic batching**: a single batch-loop thread drains the queue
//!   once per tick, stacks same-shape requests into batched tensors,
//!   and dispatches one [`crate::exec::Batcher`] call per tick per
//!   plan. Per-sample kernels are bit-identical at any batch size, so
//!   responses match [`crate::exec::Plan::predict`] exactly.
//! * **Deadlines**: a request's soft deadline accelerates its batch's
//!   dispatch (the batch leaves at
//!   `min(oldest admission + tick, earliest deadline)`). A request
//!   still queued one full tick *past* its deadline is shed with
//!   [`ErrorCode::DeadlineExceeded`] instead of computed late — the
//!   one-tick grace means deadlines only shed under real backlog.
//! * **Plan cache**: compiled plans live in a process-global
//!   [`cache::PlanCache`] keyed by [`crate::session::PlanKey`] —
//!   `(model, prune config, OptLevel)` — with warm/cold eviction, so
//!   heterogeneous traffic shares compilations.
//! * **Latency**: every response carries the server-measured
//!   admission→response latency; [`Stats`] aggregates p50/p99 for the
//!   CLI and the `micro_serve` bench.
//!
//! # Failure semantics
//!
//! Every error response carries a typed [`ErrorCode`], and the server
//! is built so no single failure takes it down:
//!
//! * **Panic isolation** — each model group of a batch runs inside
//!   `catch_unwind`; a panicking plan answers its own requests with
//!   [`ErrorCode::Panic`] and the batch loop keeps serving everyone
//!   else. Every serve-path mutex is taken through
//!   [`crate::util::relock`], so a poisoned lock cannot cascade.
//! * **Overload** — bounded queue + [`ErrorCode::Overloaded`];
//!   [`Client::predict_retry`] implements capped jittered backoff.
//! * **Health & drain** — the `health` verb ([`Client::health`])
//!   reports queue depth, counters, and cache state without touching
//!   the batch loop; [`Server::begin_drain`] stops admission
//!   ([`ErrorCode::ShuttingDown`]) while queued work still completes,
//!   and [`Server::drain`]/[`Server::shutdown`] flush then join.
//! * **Fault injection** — a seeded [`faults::FaultPlan`]
//!   (`ServeCfg::faults` or `SPA_FAULTS`) deterministically injects
//!   panics, slow batches, and torn frames at named sites; the
//!   `serve_chaos` integration suite drives it.
//! * **Live re-pruning** — [`Server::swap`] (and the `swap` wire verb)
//!   re-prunes a serving plan toward a tighter FLOPs target without
//!   dropping a request: the candidate compiles off the hot path via
//!   [`crate::exec::Plan::recompile`], passes `check_graph` +
//!   `check_plan` at [`CheckLevel::Strict`], optionally shadow-executes
//!   recent live requests against both plans, and only then atomically
//!   flips the cache entry's generation — in-flight batches finish on
//!   the old plan, new admissions land on the new one. A failure at any
//!   stage (verification, shadow divergence, a post-flip panic spike)
//!   rolls back automatically; the health verb reports each key's
//!   generation and last-swap outcome.
//!
//! ```no_run
//! use spa::serve::{Client, ServeCfg, Server};
//! # fn main() -> anyhow::Result<()> {
//! let server = Server::spawn(ServeCfg::default())?;
//! let mut client = Client::connect(server.local_addr())?;
//! let x = spa::tensor::Tensor::zeros(&[1, 3, 16, 16]);
//! let (logits, latency_us) = client.predict("resnet18", &x)?;
//! println!("{:?} in {latency_us}us", logits.shape);
//! # Ok(()) }
//! ```

pub mod cache;
pub mod faults;
pub mod protocol;
pub mod queue;

pub use cache::{CachedPlan, PlanCache, SwapOutcome, SwapStage};
pub use faults::{Fault, FaultPlan, Site};
pub use protocol::{
    Client, ErrorCode, HealthReport, Request, RequestMsg, Response, RetryCfg, ServeError,
    SwapHealth, SwapReport, SwapRequest,
};
pub use queue::{Pending, Queue};

use crate::check::{self, CheckLevel};
use crate::criteria::Criterion;
use crate::exec::{Batcher, OptLevel, Plan, PlanOpts};
use crate::ir::Graph;
use crate::obs::{trace, Histogram, MetricsReport, ObsCfg};
use crate::session::{PlanKey, PrunedModel, Session, Target};
use crate::tensor::Tensor;
use crate::util::{relock, Rng};
use crate::zoo::{self, ImageCfg};
use std::collections::{HashMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration for [`Server::spawn`].
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Bind address; port 0 picks a free port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// Batching tick: a batch dispatches once its oldest request has
    /// waited this long (deadlines can only shorten the wait).
    pub tick: Duration,
    /// Maximum stacked rows per dispatched chunk, and maximum requests
    /// drained per tick.
    pub max_batch: usize,
    /// Plan-cache capacity; 0 uses the process-global
    /// [`PlanCache::global`] (capacity `SPA_PLAN_CACHE_CAP`, default 8).
    pub cache_cap: usize,
    /// Optimization level plans are compiled at.
    pub level: OptLevel,
    /// Zoo instantiation config for requested models.
    pub image: ImageCfg,
    /// Zoo weight seed.
    pub seed: u64,
    /// When set, serve every model pruned toward this FLOPs RF.
    pub prune_rf: Option<f64>,
    /// Saliency criterion for `prune_rf` (data-free criteria only).
    pub criterion: String,
    /// Admission-queue depth cap; requests past it are rejected with
    /// [`ErrorCode::Overloaded`]. 0 = unbounded.
    pub queue_cap: usize,
    /// Deterministic fault injection (chaos testing); `None` also
    /// consults the `SPA_FAULTS` environment variable at spawn.
    pub faults: Option<Arc<FaultPlan>>,
    /// Observability switches ([`crate::obs::ObsCfg`]). Enable-only:
    /// spawning with tracing off never turns off tracing another
    /// component already switched on; the `SPA_OBS` environment
    /// variable is also consulted at spawn.
    pub obs: ObsCfg,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            addr: "127.0.0.1:0".to_string(),
            tick: Duration::from_millis(2),
            max_batch: 64,
            cache_cap: 0,
            level: OptLevel::Exact,
            image: ImageCfg::default(),
            seed: 1,
            prune_rf: None,
            criterion: "l1".to_string(),
            queue_cap: 1024,
            faults: None,
            obs: ObsCfg::default(),
        }
    }
}

/// Serving counters, a log-linear latency histogram (every request is
/// counted, nothing is sampled away — see [`crate::obs::Histogram`]),
/// and cumulative per-stage wall time.
pub struct Stats {
    served: AtomicUsize,
    errors: AtomicUsize,
    batches: AtomicUsize,
    shed: AtomicUsize,
    expired: AtomicUsize,
    panics: AtomicUsize,
    lat: Mutex<Histogram>,
    queue_wait_ns: AtomicU64,
    exec_ns: AtomicU64,
    batch_ns: AtomicU64,
    swap_ns: AtomicU64,
}

impl Stats {
    fn new() -> Stats {
        Stats {
            served: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            expired: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
            lat: Mutex::new(Histogram::new()),
            queue_wait_ns: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            batch_ns: AtomicU64::new(0),
            swap_ns: AtomicU64::new(0),
        }
    }

    /// Requests answered (ok or error).
    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    /// Requests answered with an error response.
    pub fn errors(&self) -> usize {
        self.errors.load(Ordering::Relaxed)
    }

    /// Non-empty batch-loop ticks dispatched.
    pub fn batches(&self) -> usize {
        self.batches.load(Ordering::Relaxed)
    }

    /// Requests rejected at admission with [`ErrorCode::Overloaded`].
    pub fn shed(&self) -> usize {
        self.shed.load(Ordering::Relaxed)
    }

    /// Requests shed at dispatch with [`ErrorCode::DeadlineExceeded`].
    pub fn expired(&self) -> usize {
        self.expired.load(Ordering::Relaxed)
    }

    /// Batch dispatches that panicked and were isolated.
    pub fn panics(&self) -> usize {
        self.panics.load(Ordering::Relaxed)
    }

    /// The `p`-th latency percentile (0-100) over *every* recorded
    /// request, in microseconds, by the nearest-rank method — exact for
    /// sub-64 µs values, within 1/64 above (the histogram's bucket
    /// resolution). `None` before any request completed.
    pub fn latency_percentile_us(&self, p: f64) -> Option<u32> {
        relock(&self.lat).percentile(p).map(|v| v.min(u64::from(u32::MAX)) as u32)
    }

    /// A snapshot of the full latency histogram.
    pub fn latency_histogram(&self) -> Histogram {
        relock(&self.lat).clone()
    }

    /// Cumulative time dispatched requests spent queued between
    /// admission and batch dispatch, nanoseconds.
    pub fn queue_wait_ns(&self) -> u64 {
        self.queue_wait_ns.load(Ordering::Relaxed)
    }

    /// Cumulative time inside batch-group plan execution, nanoseconds.
    pub fn exec_ns(&self) -> u64 {
        self.exec_ns.load(Ordering::Relaxed)
    }

    /// Cumulative batch-loop tick time (shedding, grouping, dispatch —
    /// a superset of [`Stats::exec_ns`]), nanoseconds.
    pub fn batch_ns(&self) -> u64 {
        self.batch_ns.load(Ordering::Relaxed)
    }

    /// Cumulative time inside swap pipelines, nanoseconds.
    pub fn swap_ns(&self) -> u64 {
        self.swap_ns.load(Ordering::Relaxed)
    }

    fn record(&self, latency_us: u32, ok: bool) {
        self.served.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        relock(&self.lat).record(u64::from(latency_us));
    }
}

/// How the server instantiates and (optionally pre-)prunes models:
/// the `ServeCfg` slice both the batch-loop [`Resolver`] and the swap
/// pipeline need.
#[derive(Clone)]
struct ModelCfg {
    image: ImageCfg,
    seed: u64,
    level: OptLevel,
    prune_rf: Option<f64>,
    criterion: String,
}

impl ModelCfg {
    /// Build the (optionally pruned) graph and derive its cache key.
    /// An unknown model name is the one admission-time user error here,
    /// so it gets its own [`ErrorCode::ModelNotFound`].
    fn build_model(&self, model: &str) -> Result<(Graph, PlanKey), ServeError> {
        let g = zoo::by_name(model, self.image, self.seed)
            .map_err(|e| ServeError::new(ErrorCode::ModelNotFound, e.to_string()))?;
        match self.prune_rf {
            Some(rf) => {
                let pruned = (|| -> anyhow::Result<PrunedModel> {
                    Session::on(&g)
                        .criterion(Criterion::parse(&self.criterion)?)
                        .target(Target::FlopsRf(rf))
                        .plan()?
                        .apply()
                })()
                .map_err(|e| ServeError::internal(format!("pruning `{model}` failed: {e}")))?;
                let key = PlanKey::pruned(model, &pruned.report, self.level);
                Ok((pruned.graph, key))
            }
            None => Ok((g, PlanKey::baseline(model, self.level))),
        }
    }
}

/// Live request tensors retained per model as shadow-gate samples.
const SHADOW_RING: usize = 8;

/// Everything the accept loop, connection handlers, and batch loop
/// share. Lives behind one `Arc` so a handler outliving the `Server`
/// handle (client still connected during teardown) keeps valid state.
struct Shared {
    queue: Queue,
    stats: Arc<Stats>,
    cache: Arc<PlanCache>,
    shutdown: AtomicBool,
    draining: AtomicBool,
    faults: Option<Arc<FaultPlan>>,
    model: ModelCfg,
    tick: Duration,
    /// Models under a post-flip watch window: [`Site::SwapPostFlip`]
    /// fires only for groups serving these.
    monitor: Mutex<HashSet<String>>,
    /// First few live request tensors per model, retained as shadow
    /// samples for the swap gate.
    recent: Mutex<HashMap<String, Vec<Tensor>>>,
    /// Serializes swap pipelines — one candidate compile at a time.
    swap_lock: Mutex<()>,
}

impl Shared {
    fn health_report(&self) -> HealthReport {
        HealthReport {
            queue_depth: self.queue.len() as u64,
            served: self.stats.served() as u64,
            errors: self.stats.errors() as u64,
            batches: self.stats.batches() as u64,
            shed: self.stats.shed() as u64,
            expired: self.stats.expired() as u64,
            panics: self.stats.panics() as u64,
            cache_plans: self.cache.len() as u64,
            cache_hits: self.cache.hits() as u64,
            cache_misses: self.cache.misses() as u64,
            p50_us: self.stats.latency_percentile_us(50.0).map_or(0, u64::from),
            p99_us: self.stats.latency_percentile_us(99.0).map_or(0, u64::from),
            p999_us: self.stats.latency_percentile_us(99.9).map_or(0, u64::from),
            queue_wait_ns: self.stats.queue_wait_ns(),
            exec_ns: self.stats.exec_ns(),
            draining: self.draining.load(Ordering::SeqCst) || self.shutdown.load(Ordering::SeqCst),
            swaps: self
                .cache
                .snapshot_meta()
                .into_iter()
                .map(|(k, generation, outcome)| SwapHealth {
                    key: k.to_string(),
                    generation,
                    outcome,
                })
                .collect(),
        }
    }

    /// The full observability snapshot behind the protocol-v4 `metrics`
    /// verb and [`Server::metrics`]. Every counter here reconciles with
    /// [`Shared::health_report`]: both read the same atomics and the
    /// same latency histogram.
    fn metrics_report(&self) -> MetricsReport {
        let lat = self.stats.latency_histogram();
        let mut swaps_committed = 0u64;
        let mut swaps_rolled_back = 0u64;
        let mut generation = 0u64;
        for (_, g, outcome) in self.cache.snapshot_meta() {
            generation = generation.max(g);
            match outcome {
                SwapOutcome::Committed => swaps_committed += 1,
                SwapOutcome::RolledBack(_) => swaps_rolled_back += 1,
                SwapOutcome::None => {}
            }
        }
        MetricsReport {
            served: self.stats.served() as u64,
            errors: self.stats.errors() as u64,
            batches: self.stats.batches() as u64,
            shed: self.stats.shed() as u64,
            expired: self.stats.expired() as u64,
            panics: self.stats.panics() as u64,
            cache_hits: self.cache.hits() as u64,
            cache_misses: self.cache.misses() as u64,
            cache_evictions: self.cache.evictions() as u64,
            swaps_committed,
            swaps_rolled_back,
            generation,
            draining: self.draining.load(Ordering::SeqCst) || self.shutdown.load(Ordering::SeqCst),
            lat_count: lat.count(),
            lat_sum_us: lat.sum(),
            lat_max_us: lat.max(),
            p50_us: lat.percentile(50.0).unwrap_or(0),
            p99_us: lat.percentile(99.0).unwrap_or(0),
            p999_us: lat.percentile(99.9).unwrap_or(0),
            queue_wait_ns: self.stats.queue_wait_ns(),
            exec_ns: self.stats.exec_ns(),
            batch_ns: self.stats.batch_ns(),
            swap_ns: self.stats.swap_ns(),
        }
    }

    /// Shadow-gate inputs for `model`: up to `want` retained live
    /// request tensors, topped up with seeded synthetic tensors shaped
    /// like the graph's input (batch 1) when traffic has not filled the
    /// ring yet.
    fn shadow_inputs(&self, model: &str, want: usize, g: &Graph) -> Vec<Tensor> {
        let mut xs: Vec<Tensor> = relock(&self.recent)
            .get(model)
            .map(|ring| ring.iter().take(want).cloned().collect())
            .unwrap_or_default();
        let mut rng = Rng::new(0x5AAB ^ self.model.seed);
        while xs.len() < want {
            let mut shape = g.data(g.inputs[0]).shape.clone();
            if !shape.is_empty() {
                shape[0] = 1;
            }
            let n = shape.iter().product();
            xs.push(Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0)));
        }
        xs
    }

    /// The live re-prune pipeline behind [`Server::swap`] and the wire
    /// `swap` verb: re-prune the serving graph toward `req.target_rf`,
    /// recompile incrementally off the hot path, gate through static
    /// verification and an optional shadow-parity check, flip the cache
    /// generation, then watch for a post-flip panic spike. Returns `Ok`
    /// for commits *and* rollbacks — the report carries the outcome;
    /// `Err` only for request-level mistakes (unknown model, bad
    /// criterion).
    fn swap(&self, req: &SwapRequest) -> Result<SwapReport, ServeError> {
        let _span = trace::span_with("serve.swap", || req.model.clone());
        let t0 = Instant::now();
        let result = self.swap_inner(req);
        self.stats
            .swap_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        result
    }

    fn swap_inner(&self, req: &SwapRequest) -> Result<SwapReport, ServeError> {
        // one candidate compile at a time; predicts keep flowing
        let _one_at_a_time = relock(&self.swap_lock);
        Criterion::parse(&req.criterion)
            .map_err(|e| ServeError::new(ErrorCode::BadRequest, e.to_string()))?;
        // resolve the serving key, compiling a plan if none is resident
        let (source, key) = self.model.build_model(&req.model)?;
        let level = self.model.level;
        let old = self
            .cache
            .get_or_compile(&key, || {
                Plan::compile(
                    &source,
                    PlanOpts {
                        level,
                        ..Default::default()
                    },
                )
            })
            .map_err(|e| ServeError::internal(e.to_string()))?;
        let from_generation = self.cache.generation(&key);
        let mut report = SwapReport {
            key: key.to_string(),
            from_generation,
            to_generation: from_generation,
            outcome: SwapOutcome::None,
            recompiled_regions: 0,
            reused_steps: 0,
            steps: 0,
            shadow_checked: 0,
            divergence: 0.0,
            message: String::new(),
        };
        // Stage 1 — build and verify the candidate, entirely off the
        // hot path: derive the re-prune as a patch against the graph
        // that is *actually serving*, recompile only the dirty schedule
        // regions, and gate through the full static analysis at Strict.
        let base = old.plan.graph().clone();
        let verify_span = trace::span_with("swap.verify", || key.to_string());
        let built = (|| -> anyhow::Result<Plan> {
            let sess = Session::on(&base)
                .criterion(Criterion::parse(&req.criterion)?)
                .target(Target::FlopsRf(req.target_rf))
                .check(CheckLevel::Strict)
                .plan()?;
            let patch = sess.as_patch(&base)?;
            let mut patched = base.clone();
            let prep = patch.apply_checked(&mut patched, CheckLevel::Strict)?;
            let candidate = old.plan.recompile(
                &patched,
                &prep,
                PlanOpts {
                    level,
                    ..Default::default()
                },
            )?;
            if let Some(f) = &self.faults {
                if f.fire(Site::SwapVerify) {
                    anyhow::bail!("injected swap verification failure");
                }
            }
            check::check_graph(&patched)?;
            check::check_plan(&candidate)?;
            Ok(candidate)
        })();
        drop(verify_span);
        let candidate = match built {
            Ok(c) => c,
            Err(e) => {
                report.outcome = SwapOutcome::RolledBack(SwapStage::Verify);
                report.message = format!("verification failed: {e:#}");
                self.cache.record_outcome(&key, report.outcome);
                return Ok(report);
            }
        };
        report.recompiled_regions = candidate.report().recompiled_regions as u64;
        report.reused_steps = candidate.report().reused_steps as u64;
        report.steps = candidate.report().steps as u64;
        // Stage 2 — shadow parity: run retained live requests through
        // both plans and bound their divergence (0.0 demands bit-equal)
        if req.shadow > 0 {
            let _shadow_span =
                trace::span_with("swap.shadow", || format!("{} request(s)", req.shadow));
            let shadow = (|| -> anyhow::Result<(u64, f64)> {
                let xs = self.shadow_inputs(&req.model, req.shadow as usize, &base);
                let mut worst = 0.0f64;
                let mut bit_equal = true;
                for x in &xs {
                    let a = old.plan.predict(x)?;
                    let b = candidate.predict(x)?;
                    anyhow::ensure!(
                        a.shape == b.shape,
                        "shadow output shapes diverged: {:?} vs {:?}",
                        a.shape,
                        b.shape
                    );
                    for (u, v) in a.data.iter().zip(&b.data) {
                        if u.to_bits() != v.to_bits() {
                            bit_equal = false;
                        }
                        worst = worst.max((f64::from(*u) - f64::from(*v)).abs());
                    }
                }
                if let Some(f) = &self.faults {
                    if f.fire(Site::SwapShadow) {
                        anyhow::bail!("injected shadow divergence on {} request(s)", xs.len());
                    }
                }
                if req.max_divergence == 0.0 {
                    anyhow::ensure!(
                        bit_equal,
                        "shadow outputs are not bit-equal (worst |delta| = {worst:e})"
                    );
                } else {
                    anyhow::ensure!(
                        worst <= req.max_divergence,
                        "shadow divergence {worst:e} exceeds the {:e} bound",
                        req.max_divergence
                    );
                }
                Ok((xs.len() as u64, worst))
            })();
            match shadow {
                Ok((checked, worst)) => {
                    report.shadow_checked = checked;
                    report.divergence = worst;
                }
                Err(e) => {
                    report.outcome = SwapOutcome::RolledBack(SwapStage::Shadow);
                    report.message = format!("shadow gate failed: {e:#}");
                    self.cache.record_outcome(&key, report.outcome);
                    return Ok(report);
                }
            }
        }
        // Stage 3 — the flip: atomic under the cache lock. In-flight
        // batches hold the old Arc and finish on the old plan; every
        // admission from here on resolves to the new generation.
        let (from, to, displaced) = match self.cache.flip(&key, candidate) {
            Ok(v) => v,
            Err(e) => {
                report.outcome = SwapOutcome::RolledBack(SwapStage::Verify);
                report.message = format!("flip refused: {e:#}");
                self.cache.record_outcome(&key, report.outcome);
                return Ok(report);
            }
        };
        report.from_generation = from;
        report.to_generation = to;
        report.outcome = SwapOutcome::Committed;
        report.message = "committed".to_string();
        trace::instant_with("swap.flip", || format!("{key}: generation {from} -> {to}"));
        // Stage 4 — post-flip watch: keep the displaced generation in
        // hand for a few ticks; a panic spike while the new generation
        // serves rolls it straight back.
        let _watch_span = trace::span_with("swap.watch", || key.to_string());
        let window = (self.tick * 16).max(Duration::from_millis(40));
        let poll = (self.tick / 2).max(Duration::from_millis(1));
        let panics_before = self.stats.panics();
        relock(&self.monitor).insert(req.model.clone());
        let deadline = Instant::now() + window;
        let mut spiked = false;
        while Instant::now() < deadline {
            std::thread::sleep(poll);
            if self.stats.panics() > panics_before {
                spiked = true;
                break;
            }
        }
        relock(&self.monitor).remove(&req.model);
        if spiked {
            report.outcome = SwapOutcome::RolledBack(SwapStage::PostFlip);
            report.to_generation = from;
            report.message =
                format!("rolled back: panic rate spiked within the {window:?} post-flip window");
            // `displaced` is the plan the flip removed; it can only be
            // None if eviction raced the key out, in which case the Arc
            // we resolved at the start is the same generation
            let prev = displaced.unwrap_or_else(|| Arc::clone(&old));
            self.cache.restore(&key, prev, from, report.outcome);
        }
        Ok(report)
    }
}

/// Resolves model names to cached compiled plans. Lives on the batch-
/// loop thread; `keys` memoizes the model → [`PlanKey`] derivation
/// (pruning must run once before the prune tag is known).
struct Resolver {
    model: ModelCfg,
    cache: Arc<PlanCache>,
    keys: HashMap<String, PlanKey>,
    faults: Option<Arc<FaultPlan>>,
}

impl Resolver {
    fn plan_for(&mut self, model: &str) -> Result<Arc<CachedPlan>, ServeError> {
        if let Some(f) = &self.faults {
            // Site::Resolve may panic; plan_for always runs inside the
            // batch loop's per-group catch_unwind
            f.fire(Site::Resolve);
        }
        let (key, prebuilt) = match self.keys.get(model) {
            Some(k) => (k.clone(), None),
            None => {
                let (g, key) = self.model.build_model(model)?;
                self.keys.insert(model.to_string(), key.clone());
                (key, Some(g))
            }
        };
        let cache = Arc::clone(&self.cache);
        let level = self.model.level;
        cache
            .get_or_compile(&key, || {
                let g = match prebuilt {
                    Some(g) => g,
                    // evicted since the key was derived: rebuild from source
                    None => self.model.build_model(model)?.0,
                };
                Plan::compile(
                    &g,
                    PlanOpts {
                        level,
                        ..Default::default()
                    },
                )
            })
            .map_err(|e| ServeError::internal(e.to_string()))
    }
}

/// Pack request tensors into stacked chunks: consecutive tensors with
/// equal tail shapes concatenate along dim 0, up to `max_rows` rows per
/// chunk. Returns `(chunks, members)` where `members[c]` lists the
/// indices stacked into `chunks[c]`, in order.
fn pack_chunks(tensors: &[&Tensor], max_rows: usize) -> (Vec<Tensor>, Vec<Vec<usize>>) {
    let mut chunks: Vec<Tensor> = Vec::new();
    let mut members: Vec<Vec<usize>> = Vec::new();
    for (i, t) in tensors.iter().enumerate() {
        let rows = t.shape[0];
        let fits = chunks
            .last()
            .is_some_and(|c| c.shape[1..] == t.shape[1..] && c.shape[0] + rows <= max_rows.max(1));
        if fits {
            let c = chunks.last_mut().expect("fits implies a chunk");
            c.shape[0] += rows;
            c.data.extend_from_slice(&t.data);
            members.last_mut().expect("fits implies members").push(i);
        } else {
            chunks.push((*t).clone());
            members.push(vec![i]);
        }
    }
    (chunks, members)
}

/// Split a stacked chunk's output back into per-request tensors by each
/// member's leading dim, and respond.
fn send_split(reqs: &[Pending], valid: &[usize], mem: &[usize], out: &Tensor) {
    let rows_total: usize = mem.iter().map(|&m| reqs[valid[m]].tensor.shape[0]).sum();
    if rows_total == 0 || out.shape.first().copied().unwrap_or(0) != rows_total {
        for &m in mem {
            let _ = reqs[valid[m]].resp.send(Err(ServeError::internal(format!(
                "model output rows {:?} do not match the {rows_total} stacked request rows",
                out.shape.first()
            ))));
        }
        return;
    }
    let per_row = out.numel() / rows_total;
    let mut off = 0usize;
    for &m in mem {
        let rows = reqs[valid[m]].tensor.shape[0];
        let mut shape = out.shape.clone();
        shape[0] = rows;
        let data = out.data[off * per_row..(off + rows) * per_row].to_vec();
        off += rows;
        let _ = reqs[valid[m]].resp.send(Ok(Tensor::new(shape, data)));
    }
}

/// Serve one model's share of a tick: stack, dispatch through a
/// [`Batcher`] whose workspace pool persists on the cache entry, split,
/// respond. A failed combined dispatch falls back to per-chunk
/// dispatch so one malformed request cannot poison co-batched ones.
fn process_group(
    cached: &CachedPlan,
    reqs: &[Pending],
    max_rows: usize,
    faults: Option<&FaultPlan>,
) {
    if let Some(f) = faults {
        // Site::Group may panic or sleep; the caller's catch_unwind
        // turns a panic into per-request `ErrorCode::Panic` replies
        f.fire(Site::Group);
    }
    let mut valid: Vec<usize> = Vec::new();
    for (i, p) in reqs.iter().enumerate() {
        if p.tensor.shape.first().copied().unwrap_or(0) == 0 {
            let _ = p.resp.send(Err(ServeError::new(
                ErrorCode::BadRequest,
                "request tensor needs a leading batch dim of at least 1",
            )));
        } else {
            valid.push(i);
        }
    }
    let tensors: Vec<&Tensor> = valid.iter().map(|&i| &reqs[i].tensor).collect();
    let (chunks, members) = pack_chunks(&tensors, max_rows);
    let pool = std::mem::take(&mut *relock(&cached.pool));
    let batcher = Batcher::with_pool(&cached.plan, pool);
    match batcher.run_batch(&chunks) {
        Ok(outs) => {
            for (out, mem) in outs.iter().zip(&members) {
                send_split(reqs, &valid, mem, out);
            }
        }
        Err(_) => {
            for (chunk, mem) in chunks.iter().zip(&members) {
                match batcher.run_batch(std::slice::from_ref(chunk)) {
                    Ok(outs) => send_split(reqs, &valid, mem, &outs[0]),
                    Err(e) => {
                        let err = ServeError::internal(e.to_string());
                        for &m in mem {
                            let _ = reqs[valid[m]].resp.send(Err(err.clone()));
                        }
                    }
                }
            }
        }
    }
    *relock(&cached.pool) = batcher.into_pool();
}

/// Best-effort text from a caught panic payload (`panic!` with a string
/// or format args covers everything this crate throws).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "batch worker panicked".to_string()
    }
}

fn process_batch(
    resolver: &mut Resolver,
    batch: Vec<Pending>,
    max_rows: usize,
    tick: Duration,
    shared: &Shared,
) {
    let stats = &*shared.stats;
    // Shed requests whose deadline has long passed instead of computing
    // results nobody is waiting on. One-tick grace: a deadline's primary
    // job is to *accelerate* dispatch, so a request only sheds once it
    // is a full tick past due — i.e. only under real backlog (a slow or
    // panicking batch ahead of it), never on the fast path.
    let now = Instant::now();
    let mut live: Vec<Pending> = Vec::with_capacity(batch.len());
    let mut queue_wait_ns = 0u64;
    for p in batch {
        match p.deadline {
            Some(d) if d + tick < now => {
                stats.expired.fetch_add(1, Ordering::Relaxed);
                let _ = p.resp.send(Err(ServeError::new(
                    ErrorCode::DeadlineExceeded,
                    format!(
                        "request expired {:?} before dispatch under backlog",
                        now.duration_since(d)
                    ),
                )));
            }
            _ => {
                queue_wait_ns += now.saturating_duration_since(p.admitted).as_nanos() as u64;
                live.push(p);
            }
        }
    }
    stats.queue_wait_ns.fetch_add(queue_wait_ns, Ordering::Relaxed);
    // group by model, preserving admission order within each group
    let mut groups: Vec<(String, Vec<Pending>)> = Vec::new();
    for p in live {
        match groups.iter_mut().find(|(m, _)| *m == p.model) {
            Some((_, v)) => v.push(p),
            None => {
                let m = p.model.clone();
                groups.push((m, vec![p]));
            }
        }
    }
    for (model, reqs) in &groups {
        // A model under a post-flip watch window runs its injected
        // `Site::SwapPostFlip` panic inside the same catch_unwind the
        // real serving path uses — the monitor must observe the spike
        // through the ordinary panic counter, not a side channel.
        let monitored = relock(&shared.monitor).contains(model.as_str());
        // Panic isolation: one group's unwind (a plan bug, a poisoned
        // workspace, an injected fault) answers its own requests with
        // `ErrorCode::Panic` and leaves every other group — and the
        // batch loop itself — serving.
        let t_exec = Instant::now();
        let unwound = catch_unwind(AssertUnwindSafe(|| {
            if monitored {
                if let Some(f) = &resolver.faults {
                    f.fire(Site::SwapPostFlip);
                }
            }
            match resolver.plan_for(model) {
                Ok(cached) => process_group(&cached, reqs, max_rows, resolver.faults.as_deref()),
                Err(e) => {
                    for p in reqs {
                        let _ = p.resp.send(Err(e.clone()));
                    }
                }
            }
        }));
        stats.exec_ns.fetch_add(t_exec.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if let Err(payload) = unwound {
            stats.panics.fetch_add(1, Ordering::Relaxed);
            let err = ServeError::new(
                ErrorCode::Panic,
                format!(
                    "batch worker panicked while serving `{model}`: {}",
                    panic_message(payload.as_ref())
                ),
            );
            // requests answered before the unwind dropped their
            // receivers already; this send is a no-op for them
            for p in reqs {
                let _ = p.resp.send(Err(err.clone()));
            }
        }
    }
}

fn batch_loop(shared: Arc<Shared>, mut resolver: Resolver, tick: Duration, max_batch: usize) {
    loop {
        let batch = shared.queue.drain_tick(tick, max_batch);
        if batch.is_empty() {
            // flush-then-exit: a closed queue admits nothing new, so an
            // empty queue during shutdown/drain means we are done
            if (shared.shutdown.load(Ordering::SeqCst) || shared.queue.is_closed())
                && shared.queue.is_empty()
            {
                break;
            }
            continue;
        }
        if let Some(f) = &shared.faults {
            // Site::Batch allows only non-unwinding faults (slow ticks):
            // this runs outside the per-group catch_unwind
            f.fire(Site::Batch);
        }
        // retain the first few live tensors per model as shadow-gate
        // samples (cheap: only while a model's ring is still filling)
        {
            let mut recent = relock(&shared.recent);
            for p in &batch {
                let ring = recent.entry(p.model.clone()).or_default();
                if ring.len() < SHADOW_RING {
                    ring.push(p.tensor.clone());
                }
            }
        }
        shared.stats.batches.fetch_add(1, Ordering::Relaxed);
        let _tick_span = trace::span_with("batch.tick", || format!("{} request(s)", batch.len()));
        let t_tick = Instant::now();
        process_batch(&mut resolver, batch, max_batch, tick, &shared);
        let tick_ns = t_tick.elapsed().as_nanos() as u64;
        shared.stats.batch_ns.fetch_add(tick_ns, Ordering::Relaxed);
    }
}

/// Generous in-frame budget: a slow client may dribble one frame in for
/// this long, while the 50 ms socket timeout still ends waits *between*
/// frames promptly (see [`protocol::read_frame_budget`]).
const FRAME_BUDGET: Duration = Duration::from_secs(5);

/// Admit one decoded request and block until the batch loop answers.
fn admit_and_wait(shared: &Shared, req: Request, t0: Instant) -> Result<Tensor, ServeError> {
    let (tx, rx) = mpsc::channel();
    let pending = Pending {
        model: req.model,
        tensor: req.tensor,
        admitted: t0,
        deadline: (req.deadline_ms > 0)
            .then(|| t0 + Duration::from_millis(u64::from(req.deadline_ms))),
        resp: tx,
    };
    if let Err(e) = shared.queue.try_push(pending) {
        if e.code == ErrorCode::Overloaded {
            shared.stats.shed.fetch_add(1, Ordering::Relaxed);
        }
        return Err(e);
    }
    match rx.recv() {
        Ok(r) => r,
        // the queue was flushed during teardown and the sender dropped
        Err(_) => Err(ServeError::new(
            ErrorCode::ShuttingDown,
            "server shut down before responding",
        )),
    }
}

fn handle_conn(mut stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    // short read timeout so idle handlers observe shutdown between
    // frames; FRAME_BUDGET governs stalls *inside* a frame
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    loop {
        match protocol::read_frame_budget(&mut stream, FRAME_BUDGET) {
            Ok(protocol::FrameRead::Idle) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Ok(protocol::FrameRead::Eof) | Err(_) => break,
            Ok(protocol::FrameRead::Frame(body)) => {
                let t0 = Instant::now();
                let resp = match protocol::decode_request(&body) {
                    Ok(RequestMsg::Health) => Response::Health {
                        latency_us: t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32,
                        report: shared.health_report(),
                    },
                    Ok(RequestMsg::Metrics) => Response::Metrics {
                        latency_us: t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32,
                        report: shared.metrics_report(),
                    },
                    Ok(RequestMsg::Swap(req)) => {
                        // runs inline on this handler thread — the whole
                        // pipeline stays off the batch loop's hot path
                        let result = shared.swap(&req);
                        let latency_us =
                            t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32;
                        match result {
                            Ok(report) => Response::Swap { latency_us, report },
                            Err(e) => Response::Err {
                                latency_us,
                                code: e.code,
                                message: e.message,
                            },
                        }
                    }
                    Ok(RequestMsg::Predict(req)) => {
                        let reply = admit_and_wait(&shared, req, t0);
                        let latency_us =
                            t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32;
                        shared.stats.record(latency_us, reply.is_ok());
                        match reply {
                            Ok(tensor) => Response::Ok { latency_us, tensor },
                            Err(e) => Response::Err {
                                latency_us,
                                code: e.code,
                                message: e.message,
                            },
                        }
                    }
                    Err(e) => {
                        let latency_us =
                            t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32;
                        shared.stats.record(latency_us, false);
                        Response::Err {
                            latency_us,
                            code: ErrorCode::BadRequest,
                            message: e.to_string(),
                        }
                    }
                };
                let body = match protocol::encode_response(&resp) {
                    Ok(b) => b,
                    Err(_) => break,
                };
                if let Some(f) = &shared.faults {
                    if f.fire(Site::Frame) {
                        // torn frame: deliver half, sever, and stop —
                        // the client must see EOF, never a hang
                        let _ = protocol::write_frame_torn(&mut stream, &body);
                        break;
                    }
                }
                if protocol::write_frame(&mut stream, &body).is_err() {
                    break;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let s = Arc::clone(&shared);
                if let Ok(h) = std::thread::Builder::new()
                    .name("spa-serve-conn".to_string())
                    .spawn(move || handle_conn(stream, s))
                {
                    handlers.push(h);
                }
            }
            Err(_) => continue,
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// A running serve instance: an accept thread (one handler thread per
/// connection) plus the batch-loop thread. Shuts down cleanly on
/// [`Server::shutdown`], [`Server::drain`], or drop, flushing queued
/// requests first — every admitted request is answered, with a typed
/// [`ErrorCode::ShuttingDown`] if it can no longer be computed.
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    batch: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving in background threads.
    pub fn spawn(cfg: ServeCfg) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(cfg.addr.as_str())?;
        let addr = listener.local_addr()?;
        let cache = match cfg.cache_cap {
            0 => PlanCache::global(),
            n => Arc::new(PlanCache::with_capacity(n)),
        };
        let faults = match cfg.faults.clone() {
            Some(f) => Some(f),
            None => FaultPlan::from_env()?.map(Arc::new),
        };
        // enable-only: spawning with tracing off must not switch off
        // tracing another component (a test, the CLI) already enabled
        if cfg.obs.trace || ObsCfg::from_env().trace {
            ObsCfg::tracing().apply();
        }
        let model = ModelCfg {
            image: cfg.image,
            seed: cfg.seed,
            level: cfg.level,
            prune_rf: cfg.prune_rf,
            criterion: cfg.criterion.clone(),
        };
        let shared = Arc::new(Shared {
            queue: Queue::bounded(cfg.queue_cap),
            stats: Arc::new(Stats::new()),
            cache: Arc::clone(&cache),
            shutdown: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            faults,
            model: model.clone(),
            tick: cfg.tick,
            monitor: Mutex::new(HashSet::new()),
            recent: Mutex::new(HashMap::new()),
            swap_lock: Mutex::new(()),
        });
        let resolver = Resolver {
            model,
            cache,
            keys: HashMap::new(),
            faults: shared.faults.clone(),
        };
        let batch = {
            let shared = Arc::clone(&shared);
            let (tick, max_batch) = (cfg.tick, cfg.max_batch.max(1));
            std::thread::Builder::new()
                .name("spa-serve-batch".to_string())
                .spawn(move || batch_loop(shared, resolver, tick, max_batch))?
        };
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("spa-serve-accept".to_string())
                .spawn(move || accept_loop(listener, shared))?
        };
        Ok(Server {
            addr,
            shared,
            accept: Some(accept),
            batch: Some(batch),
        })
    }

    /// The bound address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live serving counters and latency percentiles.
    pub fn stats(&self) -> Arc<Stats> {
        Arc::clone(&self.shared.stats)
    }

    /// The plan cache this server compiles into.
    pub fn cache(&self) -> Arc<PlanCache> {
        Arc::clone(&self.shared.cache)
    }

    /// The fault plan this server runs under, if any.
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.shared.faults.clone()
    }

    /// A health snapshot without going through the wire (the `health`
    /// protocol verb reports the same data to remote clients).
    pub fn health(&self) -> HealthReport {
        self.shared.health_report()
    }

    /// A full metrics snapshot without going through the wire (the
    /// protocol-v4 `metrics` verb reports the same data): counters,
    /// exact-count latency percentiles, cumulative per-stage timings.
    /// Render with [`crate::obs::MetricsReport::render_prometheus`].
    pub fn metrics(&self) -> MetricsReport {
        self.shared.metrics_report()
    }

    /// Live re-prune `model`'s serving plan toward a tighter FLOPs
    /// target with zero dropped requests — the `swap` wire verb calls
    /// this same pipeline. The candidate compiles off the hot path
    /// (incremental [`crate::exec::Plan::recompile`] over the serving
    /// graph), is gated through static verification at
    /// [`CheckLevel::Strict`] and an optional shadow-parity check, and
    /// only then atomically replaces the cache entry, bumping its
    /// generation; a post-flip panic spike rolls the old generation
    /// back in. Rollbacks return `Ok` with the stage in the report's
    /// outcome; `Err` means the request itself was invalid.
    pub fn swap(&self, req: &SwapRequest) -> anyhow::Result<SwapReport> {
        self.shared.swap(req).map_err(anyhow::Error::from)
    }

    /// Stop admitting new requests while queued work still completes:
    /// every later predict is answered [`ErrorCode::ShuttingDown`],
    /// connections stay open, and `health` reports `draining: true`.
    /// Idempotent; follow with [`Server::drain`] (or drop) to flush and
    /// join.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.queue.close();
    }

    /// Graceful exit: stop admission, let the batch loop flush every
    /// already-admitted request, then tear down the listener and join
    /// all threads.
    pub fn drain(mut self) {
        self.begin_drain();
        self.halt();
    }

    /// Stop accepting, flush queued requests, and join all threads.
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        // ordering matters: close admission first so the batch loop's
        // flush-then-exit condition is reachable, then wake the accept
        // loop, join the batch loop (which drains the queue), answer
        // anything it could not (batch thread died), and only then join
        // accept — handler threads all unblock once every pending
        // request has been answered.
        self.shared.draining.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.batch.take() {
            let _ = h.join();
        }
        for p in self.shared.queue.drain_all() {
            let _ = p.resp.send(Err(ServeError::new(
                ErrorCode::ShuttingDown,
                "server shut down before dispatching this request",
            )));
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_chunks_stacks_same_tail_shapes() {
        let a = Tensor::zeros(&[1, 3, 4, 4]);
        let b = Tensor::zeros(&[2, 3, 4, 4]);
        let c = Tensor::zeros(&[1, 8]);
        let d = Tensor::zeros(&[1, 3, 4, 4]);
        let tensors = vec![&a, &b, &c, &d];
        let (chunks, members) = pack_chunks(&tensors, 64);
        // a+b stack; c breaks the run; d starts a new image chunk
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].shape, vec![3, 3, 4, 4]);
        assert_eq!(members[0], vec![0, 1]);
        assert_eq!(chunks[1].shape, vec![1, 8]);
        assert_eq!(chunks[2].shape, vec![1, 3, 4, 4]);
    }

    #[test]
    fn pack_chunks_respects_max_rows() {
        let ts: Vec<Tensor> = (0..5).map(|_| Tensor::zeros(&[1, 4])).collect();
        let refs: Vec<&Tensor> = ts.iter().collect();
        let (chunks, members) = pack_chunks(&refs, 2);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].shape, vec![2, 4]);
        assert_eq!(chunks[2].shape, vec![1, 4]);
        assert_eq!(members.concat(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn latency_percentiles_use_nearest_rank() {
        let stats = Stats::new();
        assert_eq!(stats.latency_percentile_us(50.0), None, "empty ring");
        stats.record(70, true);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(stats.latency_percentile_us(p), Some(70), "single sample");
        }
        // known distribution 1..=100 in scrambled insert order
        let stats = Stats::new();
        for v in (51..=100).chain(1..=50) {
            stats.record(v, true);
        }
        assert_eq!(stats.latency_percentile_us(50.0), Some(50));
        assert_eq!(stats.latency_percentile_us(99.0), Some(99));
        assert_eq!(stats.latency_percentile_us(100.0), Some(100));
        assert_eq!(stats.latency_percentile_us(1.0), Some(1));
        assert_eq!(stats.latency_percentile_us(0.0), Some(1), "p0 clamps to min");
    }

    #[test]
    fn latency_percentiles_recover_from_a_poisoned_lock() {
        let stats = Arc::new(Stats::new());
        stats.record(42, true);
        let s2 = Arc::clone(&stats);
        let _ = std::thread::spawn(move || {
            let _g = s2.lat.lock().unwrap();
            panic!("poison the latency histogram");
        })
        .join();
        assert!(stats.lat.is_poisoned());
        assert_eq!(stats.latency_percentile_us(50.0), Some(42));
        stats.record(43, true);
        assert_eq!(stats.latency_percentile_us(100.0), Some(43));
        assert_eq!(stats.latency_histogram().count(), 2);
    }

    #[test]
    fn server_round_trips_one_request() {
        let cfg = ServeCfg {
            tick: Duration::from_millis(1),
            cache_cap: 2,
            image: ImageCfg {
                hw: 8,
                ..Default::default()
            },
            ..Default::default()
        };
        let (image, seed, level) = (cfg.image, cfg.seed, cfg.level);
        let server = Server::spawn(cfg).unwrap();
        let mut client = Client::connect(server.local_addr()).unwrap();
        let x = Tensor::zeros(&[1, image.channels, image.hw, image.hw]);
        let (logits, _lat) = client.predict("mlp", &x).unwrap();
        // bit-identical to a local Plan::predict on the same zoo build
        let g = zoo::by_name("mlp", image, seed).unwrap();
        let plan = Plan::compile(
            &g,
            PlanOpts {
                level,
                ..Default::default()
            },
        )
        .unwrap();
        let want = plan.predict(&x).unwrap();
        assert_eq!(logits.shape, want.shape);
        for (a, b) in logits.data.iter().zip(&want.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // unknown models get a typed error without killing the connection
        let err = client
            .try_predict("definitely-not-a-model", &x, Duration::ZERO)
            .unwrap()
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::ModelNotFound);
        let (again, _) = client.predict("mlp", &x).unwrap();
        assert_eq!(again.shape, want.shape);
        assert_eq!(server.stats().served(), 3);
        assert_eq!(server.stats().errors(), 1);
        // in-process health snapshot agrees with the counters
        let health = server.health();
        assert_eq!(health.served, 3);
        assert_eq!(health.errors, 1);
        assert!(!health.draining);
        // the latency percentiles ride on health and reconcile with the
        // full metrics snapshot (in-process and over the wire alike)
        assert!(health.p50_us > 0 && health.p50_us <= health.p99_us);
        let metrics = server.metrics();
        assert_eq!(metrics.served, 3);
        assert_eq!(metrics.errors, 1);
        assert_eq!(metrics.lat_count, 3);
        assert_eq!(metrics.p50_us, health.p50_us);
        assert_eq!(metrics.queue_wait_ns, health.queue_wait_ns);
        let wire = client.metrics().unwrap();
        assert_eq!(wire.served, 3);
        assert_eq!(wire.lat_count, 3);
        assert!(wire
            .render_prometheus()
            .contains("spa_requests_total{outcome=\"ok\"} 3"));
        server.shutdown();
    }

    #[test]
    fn swap_commits_and_health_reports_the_generation() {
        let cfg = ServeCfg {
            tick: Duration::from_millis(1),
            cache_cap: 2,
            image: ImageCfg {
                hw: 8,
                ..Default::default()
            },
            ..Default::default()
        };
        let image = cfg.image;
        let server = Server::spawn(cfg).unwrap();
        let mut client = Client::connect(server.local_addr()).unwrap();
        let x = Tensor::zeros(&[1, image.channels, image.hw, image.hw]);
        // traffic before the swap fills the shadow ring
        client.predict("mlp", &x).unwrap();
        let report = server
            .swap(&SwapRequest {
                model: "mlp".into(),
                target_rf: 1.3,
                criterion: "l1".into(),
                shadow: 4,
                max_divergence: f64::INFINITY,
            })
            .unwrap();
        assert_eq!(report.outcome, SwapOutcome::Committed, "{}", report.message);
        assert_eq!(
            (report.from_generation, report.to_generation),
            (1, 2),
            "first swap flips generation 1 to 2"
        );
        assert!(report.steps > 0);
        assert_eq!(report.shadow_checked, 4);
        // the new generation serves (same wire key, re-pruned plan)
        let (y, _) = client.predict("mlp", &x).unwrap();
        assert_eq!(y.shape, vec![1, image.classes]);
        // the wire health verb reports the flip
        let health = client.health().unwrap();
        let entry = health
            .swaps
            .iter()
            .find(|s| s.key.contains("mlp"))
            .expect("swapped key in health");
        assert_eq!(entry.generation, 2);
        assert_eq!(entry.outcome, SwapOutcome::Committed);
        // the metrics snapshot counts the commit and the pipeline time
        let metrics = server.metrics();
        assert_eq!(metrics.swaps_committed, 1);
        assert_eq!(metrics.generation, 2);
        assert!(metrics.swap_ns > 0);
        // an unknown model is a request-level error, not a rollback
        let err = server
            .swap(&SwapRequest {
                model: "definitely-not-a-model".into(),
                target_rf: 1.3,
                criterion: "l1".into(),
                shadow: 0,
                max_divergence: 0.0,
            })
            .unwrap_err();
        assert!(err.to_string().contains("model-not-found"), "got: {err}");
        server.shutdown();
    }
}
