//! Length-prefixed wire protocol for `spa serve` (std-net only).
//!
//! Every message is one frame: a little-endian `u32` body length
//! followed by the body. Request bodies are
//!
//! ```text
//! u8  version (= 1)
//! u16 model-name length, then that many UTF-8 bytes
//! u32 deadline in milliseconds (0 = no deadline)
//! u8  ndim, then ndim × u32 dims
//! numel × f32 tensor data (row-major, little-endian)
//! ```
//!
//! and response bodies are
//!
//! ```text
//! u8  status (0 = ok, 1 = error)
//! u32 server-measured latency in microseconds (admission → response)
//! ok:    u8 ndim, ndim × u32 dims, numel × f32 data
//! error: u16 message length, then that many UTF-8 bytes
//! ```
//!
//! Frames are capped at 1 GiB; oversized lengths are rejected before
//! any allocation. Deadlines travel with the request so the server's
//! dynamic batcher can dispatch a batch early — see the deadline
//! semantics on [`crate::serve`].

use crate::tensor::Tensor;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Protocol version carried in every request.
pub const VERSION: u8 = 1;

/// Hard cap on one frame's body (1 GiB).
pub const MAX_FRAME: u32 = 1 << 30;

/// A decoded inference request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Zoo model name the request targets.
    pub model: String,
    /// Soft deadline in milliseconds from admission (0 = none).
    pub deadline_ms: u32,
    /// Input tensor; the leading dim is the request's own batch.
    pub tensor: Tensor,
}

/// A decoded inference response.
#[derive(Debug, Clone)]
pub enum Response {
    Ok { latency_us: u32, tensor: Tensor },
    Err { latency_us: u32, message: String },
}

/// Outcome of reading one frame from a stream that may carry a read
/// timeout (the server sets one so handler threads can observe
/// shutdown between requests).
pub enum FrameRead {
    /// A complete frame body.
    Frame(Vec<u8>),
    /// Clean EOF at a frame boundary — the peer is done.
    Eof,
    /// Read timeout with no bytes consumed — still at a frame boundary.
    Idle,
}

/// Read one length-prefixed frame. Timeouts that land *between* frames
/// surface as [`FrameRead::Idle`]; a timeout inside a frame keeps
/// reading (the rest of the frame is assumed to be in flight).
pub fn read_frame(stream: &mut TcpStream) -> std::io::Result<FrameRead> {
    let mut len4 = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match stream.read(&mut len4[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(FrameRead::Eof)
                } else {
                    Err(std::io::Error::new(
                        ErrorKind::UnexpectedEof,
                        "eof inside frame header",
                    ))
                };
            }
            Ok(n) => got += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if got == 0 {
                    return Ok(FrameRead::Idle);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(len4);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    // Grow the body as bytes actually arrive instead of trusting the
    // header: a hostile peer claiming a near-cap frame costs at most one
    // chunk of memory until it delivers the payload.
    const CHUNK: usize = 64 * 1024;
    let len = len as usize;
    let mut body: Vec<u8> = Vec::with_capacity(len.min(CHUNK));
    let mut off = 0usize;
    while off < len {
        if off == body.len() {
            let grow = (len - off).min(CHUNK);
            body.resize(off + grow, 0);
        }
        match stream.read(&mut body[off..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "eof inside frame body",
                ));
            }
            Ok(n) => off += n,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(FrameRead::Frame(body))
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> std::io::Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Byte cursor over a frame body.
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, off: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.off.checked_add(n).map_or(false, |e| e <= self.b.len()),
            "truncated frame: need {n} bytes at offset {}, have {}",
            self.off,
            self.b.len()
        );
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.off == self.b.len(),
            "{} trailing bytes after frame payload",
            self.b.len() - self.off
        );
        Ok(())
    }
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) -> anyhow::Result<()> {
    anyhow::ensure!(
        t.shape.len() <= u8::MAX as usize,
        "tensor rank {} exceeds the wire limit",
        t.shape.len()
    );
    out.push(t.shape.len() as u8);
    for &d in &t.shape {
        anyhow::ensure!(d <= u32::MAX as usize, "dim {d} exceeds the wire limit");
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in &t.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

fn get_tensor(c: &mut Cur<'_>) -> anyhow::Result<Tensor> {
    let ndim = c.u8()? as usize;
    let mut shape = Vec::with_capacity(ndim);
    let mut numel = 1usize;
    for _ in 0..ndim {
        let d = c.u32()? as usize;
        numel = numel
            .checked_mul(d)
            .ok_or_else(|| anyhow::anyhow!("tensor dims overflow"))?;
        shape.push(d);
    }
    let bytes = numel
        .checked_mul(4)
        .ok_or_else(|| anyhow::anyhow!("tensor dims overflow"))?;
    let raw = c.take(bytes)?;
    let data = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Tensor::new(shape, data))
}

/// Encode a request body (frame it with [`write_frame`]).
pub fn encode_request(model: &str, deadline_ms: u32, t: &Tensor) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(
        model.len() <= u16::MAX as usize,
        "model name of {} bytes exceeds the wire limit",
        model.len()
    );
    let mut out = Vec::with_capacity(16 + model.len() + t.numel() * 4);
    out.push(VERSION);
    out.extend_from_slice(&(model.len() as u16).to_le_bytes());
    out.extend_from_slice(model.as_bytes());
    out.extend_from_slice(&deadline_ms.to_le_bytes());
    put_tensor(&mut out, t)?;
    Ok(out)
}

/// Decode a request body.
pub fn decode_request(body: &[u8]) -> anyhow::Result<Request> {
    let mut c = Cur::new(body);
    let v = c.u8()?;
    anyhow::ensure!(v == VERSION, "unsupported protocol version {v} (want {VERSION})");
    let mlen = c.u16()? as usize;
    let model = std::str::from_utf8(c.take(mlen)?)
        .map_err(|e| anyhow::anyhow!("model name is not UTF-8: {e}"))?
        .to_string();
    let deadline_ms = c.u32()?;
    let tensor = get_tensor(&mut c)?;
    c.done()?;
    Ok(Request {
        model,
        deadline_ms,
        tensor,
    })
}

/// Encode a response body (frame it with [`write_frame`]).
pub fn encode_response(resp: &Response) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::new();
    match resp {
        Response::Ok { latency_us, tensor } => {
            out.push(0u8);
            out.extend_from_slice(&latency_us.to_le_bytes());
            put_tensor(&mut out, tensor)?;
        }
        Response::Err { latency_us, message } => {
            out.push(1u8);
            out.extend_from_slice(&latency_us.to_le_bytes());
            let msg = message.as_bytes();
            let take = msg.len().min(u16::MAX as usize);
            out.extend_from_slice(&(take as u16).to_le_bytes());
            out.extend_from_slice(&msg[..take]);
        }
    }
    Ok(out)
}

/// Decode a response body.
pub fn decode_response(body: &[u8]) -> anyhow::Result<Response> {
    let mut c = Cur::new(body);
    let status = c.u8()?;
    let latency_us = c.u32()?;
    let resp = match status {
        0 => Response::Ok {
            latency_us,
            tensor: get_tensor(&mut c)?,
        },
        1 => {
            let mlen = c.u16()? as usize;
            let message = String::from_utf8_lossy(c.take(mlen)?).into_owned();
            Response::Err {
                latency_us,
                message,
            }
        }
        other => anyhow::bail!("unknown response status {other}"),
    };
    c.done()?;
    Ok(resp)
}

/// A blocking client for the serve protocol. One request in flight per
/// connection; open several clients for concurrency.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a running `spa serve` instance.
    pub fn connect(addr: impl ToSocketAddrs) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    /// Infer `x` on `model` with no deadline. Returns the output tensor
    /// and the server-measured latency in microseconds.
    pub fn predict(&mut self, model: &str, x: &Tensor) -> anyhow::Result<(Tensor, u32)> {
        self.predict_deadline(model, x, Duration::ZERO)
    }

    /// Infer with a soft deadline: the server dispatches the batch
    /// containing this request no later than admission + `deadline`
    /// (requests are never dropped; `Duration::ZERO` means none).
    pub fn predict_deadline(
        &mut self,
        model: &str,
        x: &Tensor,
        deadline: Duration,
    ) -> anyhow::Result<(Tensor, u32)> {
        let deadline_ms = deadline.as_millis().min(u32::MAX as u128) as u32;
        let body = encode_request(model, deadline_ms, x)?;
        write_frame(&mut self.stream, &body)?;
        match read_frame(&mut self.stream)? {
            FrameRead::Frame(body) => match decode_response(&body)? {
                Response::Ok { latency_us, tensor } => Ok((tensor, latency_us)),
                Response::Err { message, .. } => anyhow::bail!("server error: {message}"),
            },
            FrameRead::Eof | FrameRead::Idle => {
                anyhow::bail!("server closed the connection")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let t = Tensor::new(vec![2, 3], vec![1.0, -2.5, 0.0, 3.25, f32::MIN, f32::MAX]);
        let body = encode_request("resnet18", 7, &t).unwrap();
        let req = decode_request(&body).unwrap();
        assert_eq!(req.model, "resnet18");
        assert_eq!(req.deadline_ms, 7);
        assert_eq!(req.tensor.shape, t.shape);
        for (a, b) in req.tensor.data.iter().zip(&t.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn response_round_trips() {
        let t = Tensor::new(vec![4], vec![0.5; 4]);
        let ok = Response::Ok {
            latency_us: 123,
            tensor: t.clone(),
        };
        match decode_response(&encode_response(&ok).unwrap()).unwrap() {
            Response::Ok { latency_us, tensor } => {
                assert_eq!(latency_us, 123);
                assert_eq!(tensor.shape, t.shape);
            }
            Response::Err { .. } => panic!("expected ok"),
        }
        let err = Response::Err {
            latency_us: 9,
            message: "no such model".into(),
        };
        match decode_response(&encode_response(&err).unwrap()).unwrap() {
            Response::Err { latency_us, message } => {
                assert_eq!(latency_us, 9);
                assert_eq!(message, "no such model");
            }
            Response::Ok { .. } => panic!("expected err"),
        }
    }

    fn pair() -> (TcpStream, TcpStream) {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn zero_length_frames_round_trip() {
        let (mut a, mut b) = pair();
        write_frame(&mut a, &[]).unwrap();
        match read_frame(&mut b).unwrap() {
            FrameRead::Frame(body) => assert!(body.is_empty()),
            _ => panic!("expected a frame"),
        }
        // an empty request body is a protocol error, not a crash
        assert!(decode_request(&[]).is_err());
        assert!(decode_response(&[]).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let (mut a, mut b) = pair();
        a.write_all(&(MAX_FRAME + 1).to_le_bytes()).unwrap();
        let err = read_frame(&mut b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
        assert!(err.to_string().contains("cap"), "got: {err}");
    }

    #[test]
    fn truncated_body_is_an_unexpected_eof() {
        let (mut a, mut b) = pair();
        a.write_all(&8u32.to_le_bytes()).unwrap();
        a.write_all(&[1, 2, 3]).unwrap();
        drop(a); // close mid-body
        let err = read_frame(&mut b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::UnexpectedEof);
    }

    #[test]
    fn truncated_header_is_an_unexpected_eof() {
        let (mut a, mut b) = pair();
        a.write_all(&[7u8, 7]).unwrap();
        drop(a);
        let err = read_frame(&mut b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::UnexpectedEof);
    }

    #[test]
    fn hostile_tensor_header_cannot_force_a_huge_allocation() {
        // a body whose dims promise ~64 EiB of f32s must die in the
        // cursor's bounds check, never in an allocation
        let mut body = vec![VERSION];
        body.extend_from_slice(&3u16.to_le_bytes());
        body.extend_from_slice(b"mlp");
        body.extend_from_slice(&0u32.to_le_bytes());
        body.push(2); // ndim
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_request(&body).unwrap_err().to_string();
        assert!(
            err.contains("truncated") || err.contains("overflow"),
            "got: {err}"
        );
    }

    #[test]
    fn malformed_frames_are_rejected() {
        assert!(decode_request(&[]).is_err());
        // bad version
        let t = Tensor::new(vec![1], vec![1.0]);
        let mut body = encode_request("mlp", 0, &t).unwrap();
        body[0] = 99;
        assert!(decode_request(&body).is_err());
        // trailing garbage
        let mut body = encode_request("mlp", 0, &t).unwrap();
        body.push(0);
        assert!(decode_request(&body).is_err());
        // truncated tensor data
        let body = encode_request("mlp", 0, &t).unwrap();
        assert!(decode_request(&body[..body.len() - 1]).is_err());
    }
}
