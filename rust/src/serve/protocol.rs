//! Length-prefixed wire protocol for `spa serve` (std-net only).
//!
//! Every message is one frame: a little-endian `u32` body length
//! followed by the body. Request bodies are
//!
//! ```text
//! u8  version (= 4)
//! u8  verb (0 = predict, 1 = health, 2 = swap, 3 = metrics)
//! predict: u16 model-name length, then that many UTF-8 bytes
//!          u32 deadline in milliseconds (0 = no deadline)
//!          u8 ndim, then ndim × u32 dims
//!          numel × f32 tensor data (row-major, little-endian)
//! health:  (no further payload)
//! swap:    u16 model-name length + bytes, f64 target FLOPs RF,
//!          u16 criterion length + bytes, u32 shadow-request count,
//!          f64 max divergence
//! metrics: (no further payload)
//! ```
//!
//! and response bodies are
//!
//! ```text
//! u8  status (0 = ok, 1 = error, 2 = health, 3 = swap, 4 = metrics)
//! u32 server-measured latency in microseconds (admission → response)
//! ok:      u8 ndim, ndim × u32 dims, numel × f32 data
//! error:   u8 error code (see [`ErrorCode`]), u16 message length, then
//!          that many UTF-8 bytes
//! health:  15 × u64 counters (queue depth, served, errors, batches,
//!          shed, expired, panics, cache plans/hits/misses,
//!          p50/p99/p999 latency µs, queue-wait ns, exec ns)
//!          + u8 draining + u16 swap-entry count, then per entry u16
//!          key length + bytes, u64 generation, u8 outcome (0 = none,
//!          1 = committed, 2/3/4 = rolled back at
//!          verify/shadow/post-flip)
//! swap:    u16 key length + bytes, u64 from/to generations, u8 outcome,
//!          u64 recompiled regions / reused steps / steps / shadow
//!          checked, f64 divergence, u16 message length + bytes
//! metrics: 22 × u64 in [`crate::obs::MetricsReport`] field order
//!          (served … swap_ns) + u8 draining
//! ```
//!
//! Version history: v4 added the `metrics` verb and the latency/stage
//! fields on the health payload; v1–v3 frames are rejected by version.
//!
//! Frames are capped at 1 GiB; oversized lengths are rejected before
//! any allocation. Deadlines travel with the request so the server's
//! dynamic batcher can dispatch a batch early — see the deadline and
//! failure semantics on [`crate::serve`].
//!
//! Reads are budgeted two ways: the short socket read timeout the
//! server installs only ever ends a read *between* frames (surfacing as
//! [`FrameRead::Idle`] so handlers can observe shutdown), while a
//! started frame gets a generous per-frame budget — a healthy-but-slow
//! peer can dribble a frame in without being dropped, but a peer that
//! stalls mid-frame past the budget is disconnected instead of pinning
//! the handler forever.

use crate::obs::MetricsReport;
use crate::serve::cache::{SwapOutcome, SwapStage};
use crate::tensor::Tensor;
use crate::util::Rng;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Protocol version carried in every request.
pub const VERSION: u8 = 4;

/// Hard cap on one frame's body (1 GiB).
pub const MAX_FRAME: u32 = 1 << 30;

/// Typed failure classes carried on error responses, so clients can
/// tell a shed request from a crashed batch without parsing prose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Unclassified server-side failure.
    Internal = 0,
    /// The batch dispatching this request panicked; the worker was
    /// isolated and the server keeps serving.
    Panic = 1,
    /// The requested model name is not in the zoo.
    ModelNotFound = 2,
    /// The request's soft deadline expired before its batch dispatched
    /// (only possible under backlog; see the shedding semantics on
    /// [`crate::serve`]).
    DeadlineExceeded = 3,
    /// Load shedding at admission: the bounded queue is full.
    Overloaded = 4,
    /// The server is draining or shutting down and admits no new work.
    ShuttingDown = 5,
    /// The request frame was malformed.
    BadRequest = 6,
}

impl ErrorCode {
    /// Stable lowercase name (used in `Display` and logs).
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::Internal => "internal",
            ErrorCode::Panic => "panic",
            ErrorCode::ModelNotFound => "model-not-found",
            ErrorCode::DeadlineExceeded => "deadline-exceeded",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::ShuttingDown => "shutting-down",
            ErrorCode::BadRequest => "bad-request",
        }
    }

    /// Decode a wire byte; unknown values degrade to [`ErrorCode::Internal`]
    /// (never a decode failure — the message still travels).
    pub fn from_u8(v: u8) -> ErrorCode {
        match v {
            1 => ErrorCode::Panic,
            2 => ErrorCode::ModelNotFound,
            3 => ErrorCode::DeadlineExceeded,
            4 => ErrorCode::Overloaded,
            5 => ErrorCode::ShuttingDown,
            6 => ErrorCode::BadRequest,
            _ => ErrorCode::Internal,
        }
    }
}

/// A typed serving error: an [`ErrorCode`] plus a human-readable
/// message. Implements [`std::error::Error`], so it converts into
/// `anyhow::Error` with `?` while keeping the code readable first.
#[derive(Debug, Clone)]
pub struct ServeError {
    pub code: ErrorCode,
    pub message: String,
}

impl ServeError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ServeError {
        ServeError {
            code,
            message: message.into(),
        }
    }

    /// Shorthand for an [`ErrorCode::Internal`] error.
    pub fn internal(message: impl Into<String>) -> ServeError {
        ServeError::new(ErrorCode::Internal, message)
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.name(), self.message)
    }
}

impl std::error::Error for ServeError {}

/// A decoded inference request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Zoo model name the request targets.
    pub model: String,
    /// Soft deadline in milliseconds from admission (0 = none).
    pub deadline_ms: u32,
    /// Input tensor; the leading dim is the request's own batch.
    pub tensor: Tensor,
}

/// A live re-prune request: swap the serving plan for `model` to one
/// pruned toward `target_rf`, verified and (optionally) shadow-checked
/// before the flip — see `crate::serve::Server::swap`.
#[derive(Debug, Clone, PartialEq)]
pub struct SwapRequest {
    /// Zoo model whose serving plan is re-pruned in place.
    pub model: String,
    /// FLOPs reduction factor the candidate is pruned toward.
    pub target_rf: f64,
    /// Saliency criterion name (data-free criteria only).
    pub criterion: String,
    /// Shadow requests executed against both plans before the flip
    /// (0 skips the shadow gate).
    pub shadow: u32,
    /// Largest element-wise |old − new| the shadow gate tolerates;
    /// exactly `0.0` demands bit-equal outputs.
    pub max_divergence: f64,
}

/// What a swap attempt did, as answered to the `swap` verb and returned
/// by `crate::serve::Server::swap`.
#[derive(Debug, Clone, PartialEq)]
pub struct SwapReport {
    /// Display form of the [`crate::session::PlanKey`] that was swapped.
    pub key: String,
    /// Generation serving when the swap began.
    pub from_generation: u64,
    /// Generation serving when the swap returned (equals
    /// `from_generation` unless the outcome is `Committed`).
    pub to_generation: u64,
    /// Committed, or rolled back at a named stage.
    pub outcome: SwapOutcome,
    /// Schedule regions the incremental recompile rebuilt.
    pub recompiled_regions: u64,
    /// Schedule steps carried over from the old plan untouched.
    pub reused_steps: u64,
    /// Total steps in the candidate plan.
    pub steps: u64,
    /// Shadow requests actually executed against both plans.
    pub shadow_checked: u64,
    /// Largest element-wise |old − new| the shadow gate observed.
    pub divergence: f64,
    /// Human-readable detail (the failure, for rollbacks).
    pub message: String,
}

/// A decoded request frame: inference, or a control verb.
#[derive(Debug, Clone)]
pub enum RequestMsg {
    Predict(Request),
    Health,
    Swap(SwapRequest),
    Metrics,
}

/// A server-state snapshot answered to the `health` verb.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HealthReport {
    /// Requests admitted but not yet dispatched.
    pub queue_depth: u64,
    /// Requests answered (ok or error).
    pub served: u64,
    /// Requests answered with an error response.
    pub errors: u64,
    /// Non-empty batch-loop ticks dispatched.
    pub batches: u64,
    /// Requests rejected at admission with [`ErrorCode::Overloaded`].
    pub shed: u64,
    /// Requests shed with [`ErrorCode::DeadlineExceeded`].
    pub expired: u64,
    /// Batch dispatches that panicked and were isolated.
    pub panics: u64,
    /// Compiled plans resident in the cache.
    pub cache_plans: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Nearest-rank request-latency percentiles over every answered
    /// request, microseconds (0 before the first response).
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    /// Cumulative time dispatched requests spent queued between
    /// admission and batch dispatch, nanoseconds.
    pub queue_wait_ns: u64,
    /// Cumulative time inside batch-group plan execution, nanoseconds.
    pub exec_ns: u64,
    /// Whether the server has stopped admitting new work.
    pub draining: bool,
    /// Per-key plan generation and last-swap outcome, sorted by model
    /// then prune tag (stable wire order).
    pub swaps: Vec<SwapHealth>,
}

/// One plan key's swap state inside a [`HealthReport`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SwapHealth {
    /// Display form of the [`crate::session::PlanKey`].
    pub key: String,
    /// Active plan generation (1 = never swapped).
    pub generation: u64,
    /// Outcome of the most recent swap attempt.
    pub outcome: SwapOutcome,
}

/// A decoded inference response.
#[derive(Debug, Clone)]
pub enum Response {
    Ok {
        latency_us: u32,
        tensor: Tensor,
    },
    Err {
        latency_us: u32,
        code: ErrorCode,
        message: String,
    },
    Health {
        latency_us: u32,
        report: HealthReport,
    },
    Swap {
        latency_us: u32,
        report: SwapReport,
    },
    Metrics {
        latency_us: u32,
        report: MetricsReport,
    },
}

/// Outcome of reading one frame from a stream that may carry a read
/// timeout (the server sets one so handler threads can observe
/// shutdown between requests).
pub enum FrameRead {
    /// A complete frame body.
    Frame(Vec<u8>),
    /// Clean EOF at a frame boundary — the peer is done.
    Eof,
    /// Read timeout with no bytes consumed — still at a frame boundary.
    Idle,
}

/// Default per-frame budget for [`read_frame`]: effectively unbounded
/// for blocking client sockets, a backstop for timeout sockets.
const DEFAULT_FRAME_BUDGET: Duration = Duration::from_secs(3600);

/// Read one length-prefixed frame with the default per-frame budget.
/// See [`read_frame_budget`].
pub fn read_frame(stream: &mut TcpStream) -> std::io::Result<FrameRead> {
    read_frame_budget(stream, DEFAULT_FRAME_BUDGET)
}

/// `Ok` while a started frame is within its budget, a `TimedOut` error
/// once the peer has stalled mid-frame past it.
fn check_stall(started: Option<Instant>, budget: Duration) -> std::io::Result<()> {
    match started {
        Some(t) if t.elapsed() > budget => Err(std::io::Error::new(
            ErrorKind::TimedOut,
            format!("peer stalled mid-frame beyond the {budget:?} frame budget"),
        )),
        _ => Ok(()),
    }
}

/// Read one length-prefixed frame. A socket read timeout that lands
/// *between* frames surfaces as [`FrameRead::Idle`]; once the first
/// byte of a frame has arrived, timeouts keep the read alive (the rest
/// is assumed in flight) until the frame has taken longer than
/// `frame_budget` in total — then the read fails with `TimedOut` so a
/// stalled peer cannot pin the handler forever.
pub fn read_frame_budget(
    stream: &mut TcpStream,
    frame_budget: Duration,
) -> std::io::Result<FrameRead> {
    let mut len4 = [0u8; 4];
    let mut got = 0usize;
    let mut started: Option<Instant> = None;
    while got < 4 {
        match stream.read(&mut len4[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(FrameRead::Eof)
                } else {
                    Err(std::io::Error::new(
                        ErrorKind::UnexpectedEof,
                        "eof inside frame header",
                    ))
                };
            }
            Ok(n) => {
                got += n;
                started.get_or_insert_with(Instant::now);
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if got == 0 {
                    return Ok(FrameRead::Idle);
                }
                check_stall(started, frame_budget)?;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(len4);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    // Grow the body as bytes actually arrive instead of trusting the
    // header: a hostile peer claiming a near-cap frame costs at most one
    // chunk of memory until it delivers the payload.
    const CHUNK: usize = 64 * 1024;
    let len = len as usize;
    let mut body: Vec<u8> = Vec::with_capacity(len.min(CHUNK));
    let mut off = 0usize;
    while off < len {
        if off == body.len() {
            let grow = (len - off).min(CHUNK);
            body.resize(off + grow, 0);
        }
        match stream.read(&mut body[off..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "eof inside frame body",
                ));
            }
            Ok(n) => off += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                check_stall(started, frame_budget)?;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(FrameRead::Frame(body))
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> std::io::Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Deliberately write a torn frame — the length prefix plus only half
/// the body — then shut the stream down. Fault injection only
/// ([`crate::serve::faults`]): the peer must observe an unexpected EOF,
/// never a hang or a decodable half-message.
pub fn write_frame_torn(stream: &mut TcpStream, body: &[u8]) -> std::io::Result<()> {
    stream.write_all(&(body.len() as u32).to_le_bytes())?;
    stream.write_all(&body[..body.len() / 2])?;
    stream.flush()?;
    stream.shutdown(std::net::Shutdown::Both)
}

/// Byte cursor over a frame body.
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, off: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.off.checked_add(n).map_or(false, |e| e <= self.b.len()),
            "truncated frame: need {n} bytes at offset {}, have {}",
            self.off,
            self.b.len()
        );
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.off == self.b.len(),
            "{} trailing bytes after frame payload",
            self.b.len() - self.off
        );
        Ok(())
    }
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) -> anyhow::Result<()> {
    anyhow::ensure!(
        t.shape.len() <= u8::MAX as usize,
        "tensor rank {} exceeds the wire limit",
        t.shape.len()
    );
    out.push(t.shape.len() as u8);
    for &d in &t.shape {
        anyhow::ensure!(d <= u32::MAX as usize, "dim {d} exceeds the wire limit");
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in &t.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(())
}

fn get_tensor(c: &mut Cur<'_>) -> anyhow::Result<Tensor> {
    let ndim = c.u8()? as usize;
    let mut shape = Vec::with_capacity(ndim);
    let mut numel = 1usize;
    for _ in 0..ndim {
        let d = c.u32()? as usize;
        numel = numel
            .checked_mul(d)
            .ok_or_else(|| anyhow::anyhow!("tensor dims overflow"))?;
        shape.push(d);
    }
    let bytes = numel
        .checked_mul(4)
        .ok_or_else(|| anyhow::anyhow!("tensor dims overflow"))?;
    let raw = c.take(bytes)?;
    let data = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Tensor::new(shape, data))
}

/// One-byte wire form of a [`SwapOutcome`].
fn outcome_to_u8(o: SwapOutcome) -> u8 {
    match o {
        SwapOutcome::None => 0,
        SwapOutcome::Committed => 1,
        SwapOutcome::RolledBack(SwapStage::Verify) => 2,
        SwapOutcome::RolledBack(SwapStage::Shadow) => 3,
        SwapOutcome::RolledBack(SwapStage::PostFlip) => 4,
    }
}

fn outcome_from_u8(v: u8) -> anyhow::Result<SwapOutcome> {
    Ok(match v {
        0 => SwapOutcome::None,
        1 => SwapOutcome::Committed,
        2 => SwapOutcome::RolledBack(SwapStage::Verify),
        3 => SwapOutcome::RolledBack(SwapStage::Shadow),
        4 => SwapOutcome::RolledBack(SwapStage::PostFlip),
        other => anyhow::bail!("unknown swap outcome {other} on the wire"),
    })
}

fn put_str(out: &mut Vec<u8>, what: &str, s: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        s.len() <= u16::MAX as usize,
        "{what} of {} bytes exceeds the wire limit",
        s.len()
    );
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn get_str(c: &mut Cur<'_>, what: &str) -> anyhow::Result<String> {
    let len = c.u16()? as usize;
    Ok(std::str::from_utf8(c.take(len)?)
        .map_err(|e| anyhow::anyhow!("{what} is not UTF-8: {e}"))?
        .to_string())
}

/// Request verbs on the wire.
const VERB_PREDICT: u8 = 0;
const VERB_HEALTH: u8 = 1;
const VERB_SWAP: u8 = 2;
const VERB_METRICS: u8 = 3;

/// Encode a predict-request body (frame it with [`write_frame`]).
pub fn encode_request(model: &str, deadline_ms: u32, t: &Tensor) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(
        model.len() <= u16::MAX as usize,
        "model name of {} bytes exceeds the wire limit",
        model.len()
    );
    let mut out = Vec::with_capacity(16 + model.len() + t.numel() * 4);
    out.push(VERSION);
    out.push(VERB_PREDICT);
    out.extend_from_slice(&(model.len() as u16).to_le_bytes());
    out.extend_from_slice(model.as_bytes());
    out.extend_from_slice(&deadline_ms.to_le_bytes());
    put_tensor(&mut out, t)?;
    Ok(out)
}

/// Encode a health-request body.
pub fn encode_health_request() -> Vec<u8> {
    vec![VERSION, VERB_HEALTH]
}

/// Encode a metrics-request body (protocol v4).
pub fn encode_metrics_request() -> Vec<u8> {
    vec![VERSION, VERB_METRICS]
}

/// Encode a swap-request body (frame it with [`write_frame`]).
pub fn encode_swap_request(req: &SwapRequest) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::with_capacity(32 + req.model.len() + req.criterion.len());
    out.push(VERSION);
    out.push(VERB_SWAP);
    put_str(&mut out, "model name", &req.model)?;
    out.extend_from_slice(&req.target_rf.to_bits().to_le_bytes());
    put_str(&mut out, "criterion name", &req.criterion)?;
    out.extend_from_slice(&req.shadow.to_le_bytes());
    out.extend_from_slice(&req.max_divergence.to_bits().to_le_bytes());
    Ok(out)
}

/// Decode a request body.
pub fn decode_request(body: &[u8]) -> anyhow::Result<RequestMsg> {
    let mut c = Cur::new(body);
    let v = c.u8()?;
    anyhow::ensure!(v == VERSION, "unsupported protocol version {v} (want {VERSION})");
    let verb = c.u8()?;
    match verb {
        VERB_PREDICT => {
            let mlen = c.u16()? as usize;
            let model = std::str::from_utf8(c.take(mlen)?)
                .map_err(|e| anyhow::anyhow!("model name is not UTF-8: {e}"))?
                .to_string();
            let deadline_ms = c.u32()?;
            let tensor = get_tensor(&mut c)?;
            c.done()?;
            Ok(RequestMsg::Predict(Request {
                model,
                deadline_ms,
                tensor,
            }))
        }
        VERB_HEALTH => {
            c.done()?;
            Ok(RequestMsg::Health)
        }
        VERB_METRICS => {
            c.done()?;
            Ok(RequestMsg::Metrics)
        }
        VERB_SWAP => {
            let model = get_str(&mut c, "model name")?;
            let target_rf = c.f64()?;
            let criterion = get_str(&mut c, "criterion name")?;
            let shadow = c.u32()?;
            let max_divergence = c.f64()?;
            c.done()?;
            Ok(RequestMsg::Swap(SwapRequest {
                model,
                target_rf,
                criterion,
                shadow,
                max_divergence,
            }))
        }
        other => anyhow::bail!("unknown request verb {other}"),
    }
}

/// Encode a response body (frame it with [`write_frame`]).
pub fn encode_response(resp: &Response) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::new();
    match resp {
        Response::Ok { latency_us, tensor } => {
            out.push(0u8);
            out.extend_from_slice(&latency_us.to_le_bytes());
            put_tensor(&mut out, tensor)?;
        }
        Response::Err {
            latency_us,
            code,
            message,
        } => {
            out.push(1u8);
            out.extend_from_slice(&latency_us.to_le_bytes());
            out.push(*code as u8);
            let msg = message.as_bytes();
            let take = msg.len().min(u16::MAX as usize);
            out.extend_from_slice(&(take as u16).to_le_bytes());
            out.extend_from_slice(&msg[..take]);
        }
        Response::Health { latency_us, report } => {
            out.push(2u8);
            out.extend_from_slice(&latency_us.to_le_bytes());
            for v in [
                report.queue_depth,
                report.served,
                report.errors,
                report.batches,
                report.shed,
                report.expired,
                report.panics,
                report.cache_plans,
                report.cache_hits,
                report.cache_misses,
                report.p50_us,
                report.p99_us,
                report.p999_us,
                report.queue_wait_ns,
                report.exec_ns,
            ] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.push(u8::from(report.draining));
            anyhow::ensure!(
                report.swaps.len() <= u16::MAX as usize,
                "{} swap entries exceed the wire limit",
                report.swaps.len()
            );
            out.extend_from_slice(&(report.swaps.len() as u16).to_le_bytes());
            for s in &report.swaps {
                put_str(&mut out, "plan key", &s.key)?;
                out.extend_from_slice(&s.generation.to_le_bytes());
                out.push(outcome_to_u8(s.outcome));
            }
        }
        Response::Swap { latency_us, report } => {
            out.push(3u8);
            out.extend_from_slice(&latency_us.to_le_bytes());
            put_str(&mut out, "plan key", &report.key)?;
            out.extend_from_slice(&report.from_generation.to_le_bytes());
            out.extend_from_slice(&report.to_generation.to_le_bytes());
            out.push(outcome_to_u8(report.outcome));
            for v in [
                report.recompiled_regions,
                report.reused_steps,
                report.steps,
                report.shadow_checked,
            ] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.extend_from_slice(&report.divergence.to_bits().to_le_bytes());
            let msg = report.message.as_bytes();
            let take = msg.len().min(u16::MAX as usize);
            out.extend_from_slice(&(take as u16).to_le_bytes());
            out.extend_from_slice(&msg[..take]);
        }
        Response::Metrics { latency_us, report } => {
            out.push(4u8);
            out.extend_from_slice(&latency_us.to_le_bytes());
            for v in [
                report.served,
                report.errors,
                report.batches,
                report.shed,
                report.expired,
                report.panics,
                report.cache_hits,
                report.cache_misses,
                report.cache_evictions,
                report.swaps_committed,
                report.swaps_rolled_back,
                report.generation,
                report.lat_count,
                report.lat_sum_us,
                report.lat_max_us,
                report.p50_us,
                report.p99_us,
                report.p999_us,
                report.queue_wait_ns,
                report.exec_ns,
                report.batch_ns,
                report.swap_ns,
            ] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.push(u8::from(report.draining));
        }
    }
    Ok(out)
}

/// Decode a response body.
pub fn decode_response(body: &[u8]) -> anyhow::Result<Response> {
    let mut c = Cur::new(body);
    let status = c.u8()?;
    let latency_us = c.u32()?;
    let resp = match status {
        0 => Response::Ok {
            latency_us,
            tensor: get_tensor(&mut c)?,
        },
        1 => {
            let code = ErrorCode::from_u8(c.u8()?);
            let mlen = c.u16()? as usize;
            let message = String::from_utf8_lossy(c.take(mlen)?).into_owned();
            Response::Err {
                latency_us,
                code,
                message,
            }
        }
        2 => {
            let mut report = HealthReport {
                queue_depth: c.u64()?,
                served: c.u64()?,
                errors: c.u64()?,
                batches: c.u64()?,
                shed: c.u64()?,
                expired: c.u64()?,
                panics: c.u64()?,
                cache_plans: c.u64()?,
                cache_hits: c.u64()?,
                cache_misses: c.u64()?,
                p50_us: c.u64()?,
                p99_us: c.u64()?,
                p999_us: c.u64()?,
                queue_wait_ns: c.u64()?,
                exec_ns: c.u64()?,
                draining: c.u8()? != 0,
                swaps: Vec::new(),
            };
            let n = c.u16()? as usize;
            for _ in 0..n {
                report.swaps.push(SwapHealth {
                    key: get_str(&mut c, "plan key")?,
                    generation: c.u64()?,
                    outcome: outcome_from_u8(c.u8()?)?,
                });
            }
            Response::Health { latency_us, report }
        }
        3 => {
            let key = get_str(&mut c, "plan key")?;
            let from_generation = c.u64()?;
            let to_generation = c.u64()?;
            let outcome = outcome_from_u8(c.u8()?)?;
            let recompiled_regions = c.u64()?;
            let reused_steps = c.u64()?;
            let steps = c.u64()?;
            let shadow_checked = c.u64()?;
            let divergence = c.f64()?;
            let mlen = c.u16()? as usize;
            let message = String::from_utf8_lossy(c.take(mlen)?).into_owned();
            let report = SwapReport {
                key,
                from_generation,
                to_generation,
                outcome,
                recompiled_regions,
                reused_steps,
                steps,
                shadow_checked,
                divergence,
                message,
            };
            Response::Swap { latency_us, report }
        }
        4 => {
            let report = MetricsReport {
                served: c.u64()?,
                errors: c.u64()?,
                batches: c.u64()?,
                shed: c.u64()?,
                expired: c.u64()?,
                panics: c.u64()?,
                cache_hits: c.u64()?,
                cache_misses: c.u64()?,
                cache_evictions: c.u64()?,
                swaps_committed: c.u64()?,
                swaps_rolled_back: c.u64()?,
                generation: c.u64()?,
                lat_count: c.u64()?,
                lat_sum_us: c.u64()?,
                lat_max_us: c.u64()?,
                p50_us: c.u64()?,
                p99_us: c.u64()?,
                p999_us: c.u64()?,
                queue_wait_ns: c.u64()?,
                exec_ns: c.u64()?,
                batch_ns: c.u64()?,
                swap_ns: c.u64()?,
                draining: c.u8()? != 0,
            };
            Response::Metrics { latency_us, report }
        }
        other => anyhow::bail!("unknown response status {other}"),
    };
    c.done()?;
    Ok(resp)
}

/// Client-side retry policy for [`Client::predict_retry`] and
/// [`Client::connect_retry`]: capped exponential backoff with
/// deterministic (seeded) jitter. Retries cover [`ErrorCode::Overloaded`]
/// rejections and transport failures (broken connection, torn frame);
/// other typed errors surface immediately.
#[derive(Debug, Clone)]
pub struct RetryCfg {
    /// Total tries, including the first (min 1).
    pub attempts: u32,
    /// Backoff before the second try; doubles per retry.
    pub backoff: Duration,
    /// Upper bound on any single backoff (before jitter).
    pub max_backoff: Duration,
    /// Seed for the jitter stream — same seed, same delays.
    pub seed: u64,
}

impl Default for RetryCfg {
    fn default() -> RetryCfg {
        RetryCfg {
            attempts: 5,
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(250),
            seed: 0x5EED,
        }
    }
}

/// The delay before retry `attempt` (1-based): `backoff * 2^(attempt-1)`
/// capped at `max_backoff`, scaled by a jitter factor in [0.5, 1.5).
fn backoff_delay(retry: &RetryCfg, attempt: u32, rng: &mut Rng) -> Duration {
    let shift = attempt.saturating_sub(1).min(16);
    let exp = retry.backoff.saturating_mul(1u32 << shift);
    let capped = exp.min(retry.max_backoff);
    capped.mul_f64(0.5 + f64::from(rng.uniform()))
}

/// A blocking client for the serve protocol. One request in flight per
/// connection; open several clients for concurrency.
pub struct Client {
    stream: TcpStream,
    addr: SocketAddr,
    /// The stream died (io error / torn frame); the next retrying call
    /// reconnects before sending.
    broken: bool,
}

impl Client {
    /// Connect to a running `spa serve` instance.
    pub fn connect(addr: impl ToSocketAddrs) -> anyhow::Result<Client> {
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("address resolved to nothing"))?;
        Ok(Client::connect_one(addr)?)
    }

    fn connect_one(addr: SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client {
            stream,
            addr,
            broken: false,
        })
    }

    /// Connect with capped jittered-backoff retries on failure (e.g.
    /// the server is restarting and the listener is briefly gone).
    pub fn connect_retry(addr: impl ToSocketAddrs, retry: &RetryCfg) -> anyhow::Result<Client> {
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("address resolved to nothing"))?;
        let mut rng = Rng::new(retry.seed);
        let attempts = retry.attempts.max(1);
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(backoff_delay(retry, attempt, &mut rng));
            }
            match Client::connect_one(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
        }
        Err(anyhow::anyhow!(
            "connect to {addr} failed after {attempts} attempt(s): {}",
            last.expect("attempts >= 1 implies an error")
        ))
    }

    /// Infer `x` on `model` with no deadline. Returns the output tensor
    /// and the server-measured latency in microseconds.
    pub fn predict(&mut self, model: &str, x: &Tensor) -> anyhow::Result<(Tensor, u32)> {
        self.predict_deadline(model, x, Duration::ZERO)
    }

    /// Infer with a soft deadline: the server dispatches the batch
    /// containing this request no later than admission + `deadline`
    /// (`Duration::ZERO` means none). A request still queued one full
    /// tick past its deadline is shed with
    /// [`ErrorCode::DeadlineExceeded`] instead of computed late.
    pub fn predict_deadline(
        &mut self,
        model: &str,
        x: &Tensor,
        deadline: Duration,
    ) -> anyhow::Result<(Tensor, u32)> {
        match self.try_predict(model, x, deadline)? {
            Ok(r) => Ok(r),
            Err(e) => Err(e.into()),
        }
    }

    /// Structured predict: the outer `Err` is a transport failure (the
    /// connection is unusable), the inner `Err` a typed server-side
    /// [`ServeError`] on a healthy connection.
    pub fn try_predict(
        &mut self,
        model: &str,
        x: &Tensor,
        deadline: Duration,
    ) -> std::io::Result<Result<(Tensor, u32), ServeError>> {
        let deadline_ms = deadline.as_millis().min(u32::MAX as u128) as u32;
        let body = encode_request(model, deadline_ms, x)
            .map_err(|e| std::io::Error::new(ErrorKind::InvalidInput, e.to_string()))?;
        match self.round_trip(&body)? {
            Response::Ok { latency_us, tensor } => Ok(Ok((tensor, latency_us))),
            Response::Err { code, message, .. } => Ok(Err(ServeError::new(code, message))),
            Response::Health { .. } | Response::Swap { .. } | Response::Metrics { .. } => {
                Err(std::io::Error::new(
                    ErrorKind::InvalidData,
                    "control response to a predict request",
                ))
            }
        }
    }

    /// Infer with capped jittered-backoff retries: [`ErrorCode::Overloaded`]
    /// rejections back off and retry on the same connection; transport
    /// failures (broken/torn connection) reconnect first. A single
    /// [`ErrorCode::ShuttingDown`] is treated as the brief window of a
    /// server restart or plan-generation flip: the client backs off,
    /// reconnects once, and retries — a second one surfaces immediately
    /// (the server really is going away). Other typed errors surface
    /// immediately — they are not transient.
    pub fn predict_retry(
        &mut self,
        model: &str,
        x: &Tensor,
        deadline: Duration,
        retry: &RetryCfg,
    ) -> anyhow::Result<(Tensor, u32)> {
        let mut rng = Rng::new(retry.seed);
        let attempts = retry.attempts.max(1);
        let mut last = anyhow::anyhow!("no attempts made");
        let mut reconnected_on_shutdown = false;
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(backoff_delay(retry, attempt, &mut rng));
            }
            if self.broken {
                match Client::connect_one(self.addr) {
                    Ok(c) => *self = c,
                    Err(e) => {
                        last = anyhow::anyhow!("reconnect to {}: {e}", self.addr);
                        continue;
                    }
                }
            }
            match self.try_predict(model, x, deadline) {
                Ok(Ok(r)) => return Ok(r),
                Ok(Err(e)) if e.code == ErrorCode::Overloaded => last = e.into(),
                Ok(Err(e)) if e.code == ErrorCode::ShuttingDown && !reconnected_on_shutdown => {
                    reconnected_on_shutdown = true;
                    self.broken = true;
                    last = e.into();
                }
                Ok(Err(e)) => return Err(e.into()),
                Err(io) => {
                    self.broken = true;
                    last = anyhow::anyhow!("transport: {io}");
                }
            }
        }
        Err(last)
    }

    /// Fetch the server's health snapshot (queue depth, served/error
    /// counters, cache state, latency percentiles, drain flag). Works
    /// during a drain.
    pub fn health(&mut self) -> anyhow::Result<HealthReport> {
        match self.round_trip(&encode_health_request())? {
            Response::Health { report, .. } => Ok(report),
            Response::Err { code, message, .. } => Err(ServeError::new(code, message).into()),
            _ => anyhow::bail!("mismatched response to a health request"),
        }
    }

    /// Fetch the server's full metrics snapshot (protocol v4): request
    /// and fault counters, plan-cache and swap activity, exact-count
    /// latency percentiles, and cumulative per-stage timings. Render
    /// with [`crate::obs::MetricsReport::render_prometheus`]. Works
    /// during a drain.
    pub fn metrics(&mut self) -> anyhow::Result<MetricsReport> {
        match self.round_trip(&encode_metrics_request())? {
            Response::Metrics { report, .. } => Ok(report),
            Response::Err { code, message, .. } => Err(ServeError::new(code, message).into()),
            _ => anyhow::bail!("mismatched response to a metrics request"),
        }
    }

    /// Ask the server to live re-prune `model`'s serving plan (see
    /// `crate::serve::Server::swap`). Blocks until the swap pipeline —
    /// recompile, verify, shadow, flip, post-flip monitor — has
    /// resolved; a rollback still returns `Ok` with the outcome in the
    /// report.
    pub fn swap(&mut self, req: &SwapRequest) -> anyhow::Result<SwapReport> {
        let body = encode_swap_request(req)?;
        match self.round_trip(&body)? {
            Response::Swap { report, .. } => Ok(report),
            Response::Err { code, message, .. } => Err(ServeError::new(code, message).into()),
            _ => anyhow::bail!("mismatched response to a swap request"),
        }
    }

    fn round_trip(&mut self, body: &[u8]) -> std::io::Result<Response> {
        if let Err(e) = write_frame(&mut self.stream, body) {
            self.broken = true;
            return Err(e);
        }
        match read_frame(&mut self.stream) {
            Ok(FrameRead::Frame(body)) => decode_response(&body)
                .map_err(|e| std::io::Error::new(ErrorKind::InvalidData, e.to_string())),
            Ok(FrameRead::Eof) | Ok(FrameRead::Idle) => {
                self.broken = true;
                Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "server closed the connection",
                ))
            }
            Err(e) => {
                self.broken = true;
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decode_predict(body: &[u8]) -> Request {
        match decode_request(body).unwrap() {
            RequestMsg::Predict(r) => r,
            other => panic!("expected a predict request, got {other:?}"),
        }
    }

    #[test]
    fn request_round_trips() {
        let t = Tensor::new(vec![2, 3], vec![1.0, -2.5, 0.0, 3.25, f32::MIN, f32::MAX]);
        let body = encode_request("resnet18", 7, &t).unwrap();
        let req = decode_predict(&body);
        assert_eq!(req.model, "resnet18");
        assert_eq!(req.deadline_ms, 7);
        assert_eq!(req.tensor.shape, t.shape);
        for (a, b) in req.tensor.data.iter().zip(&t.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn health_request_round_trips() {
        let body = encode_health_request();
        assert!(matches!(
            decode_request(&body).unwrap(),
            RequestMsg::Health
        ));
        // a health verb with trailing bytes is malformed
        let mut bad = encode_health_request();
        bad.push(0);
        assert!(decode_request(&bad).is_err());
    }

    #[test]
    fn response_round_trips() {
        let t = Tensor::new(vec![4], vec![0.5; 4]);
        let ok = Response::Ok {
            latency_us: 123,
            tensor: t.clone(),
        };
        match decode_response(&encode_response(&ok).unwrap()).unwrap() {
            Response::Ok { latency_us, tensor } => {
                assert_eq!(latency_us, 123);
                assert_eq!(tensor.shape, t.shape);
            }
            _ => panic!("expected ok"),
        }
        let err = Response::Err {
            latency_us: 9,
            code: ErrorCode::ModelNotFound,
            message: "no such model".into(),
        };
        match decode_response(&encode_response(&err).unwrap()).unwrap() {
            Response::Err {
                latency_us,
                code,
                message,
            } => {
                assert_eq!(latency_us, 9);
                assert_eq!(code, ErrorCode::ModelNotFound);
                assert_eq!(message, "no such model");
            }
            _ => panic!("expected err"),
        }
    }

    #[test]
    fn every_error_code_round_trips() {
        for code in [
            ErrorCode::Internal,
            ErrorCode::Panic,
            ErrorCode::ModelNotFound,
            ErrorCode::DeadlineExceeded,
            ErrorCode::Overloaded,
            ErrorCode::ShuttingDown,
            ErrorCode::BadRequest,
        ] {
            assert_eq!(ErrorCode::from_u8(code as u8), code);
            let resp = Response::Err {
                latency_us: 1,
                code,
                message: code.name().to_string(),
            };
            match decode_response(&encode_response(&resp).unwrap()).unwrap() {
                Response::Err { code: got, .. } => assert_eq!(got, code),
                _ => panic!("expected err"),
            }
        }
        // unknown wire bytes degrade to Internal, never a decode failure
        assert_eq!(ErrorCode::from_u8(250), ErrorCode::Internal);
    }

    #[test]
    fn swap_request_round_trips() {
        let req = SwapRequest {
            model: "resnet18".into(),
            target_rf: 2.5,
            criterion: "l1".into(),
            shadow: 16,
            max_divergence: 0.125,
        };
        let body = encode_swap_request(&req).unwrap();
        match decode_request(&body).unwrap() {
            RequestMsg::Swap(got) => assert_eq!(got, req),
            _ => panic!("expected a swap request"),
        }
        // trailing garbage and truncation are malformed, not a crash
        let mut bad = encode_swap_request(&req).unwrap();
        bad.push(0);
        assert!(decode_request(&bad).is_err());
        assert!(decode_request(&body[..body.len() - 1]).is_err());
    }

    #[test]
    fn swap_response_round_trips_every_outcome() {
        for outcome in [
            SwapOutcome::None,
            SwapOutcome::Committed,
            SwapOutcome::RolledBack(SwapStage::Verify),
            SwapOutcome::RolledBack(SwapStage::Shadow),
            SwapOutcome::RolledBack(SwapStage::PostFlip),
        ] {
            assert_eq!(outcome_from_u8(outcome_to_u8(outcome)).unwrap(), outcome);
            let report = SwapReport {
                key: "model `mlp` at Exact".into(),
                from_generation: 3,
                to_generation: 4,
                outcome,
                recompiled_regions: 2,
                reused_steps: 11,
                steps: 13,
                shadow_checked: 8,
                divergence: 0.5,
                message: "ok".into(),
            };
            let resp = Response::Swap {
                latency_us: 77,
                report: report.clone(),
            };
            match decode_response(&encode_response(&resp).unwrap()).unwrap() {
                Response::Swap {
                    latency_us,
                    report: got,
                } => {
                    assert_eq!(latency_us, 77);
                    assert_eq!(got, report);
                }
                _ => panic!("expected swap"),
            }
        }
        // an unknown outcome byte is a decode error, not a panic
        assert!(outcome_from_u8(9).is_err());
    }

    #[test]
    fn health_response_round_trips() {
        let report = HealthReport {
            queue_depth: 3,
            served: 100,
            errors: 7,
            batches: 42,
            shed: 5,
            expired: 2,
            panics: 1,
            cache_plans: 2,
            cache_hits: 90,
            cache_misses: 2,
            p50_us: 180,
            p99_us: 950,
            p999_us: 1200,
            queue_wait_ns: 123_456,
            exec_ns: 654_321,
            draining: true,
            swaps: vec![
                SwapHealth {
                    key: "model `mlp` at Exact".into(),
                    generation: 2,
                    outcome: SwapOutcome::Committed,
                },
                SwapHealth {
                    key: "model `resnet18` at Exact".into(),
                    generation: 1,
                    outcome: SwapOutcome::RolledBack(SwapStage::PostFlip),
                },
            ],
        };
        let resp = Response::Health {
            latency_us: 11,
            report: report.clone(),
        };
        match decode_response(&encode_response(&resp).unwrap()).unwrap() {
            Response::Health {
                latency_us,
                report: got,
            } => {
                assert_eq!(latency_us, 11);
                assert_eq!(got, report);
            }
            _ => panic!("expected health"),
        }
    }

    #[test]
    fn metrics_request_and_response_round_trip() {
        let body = encode_metrics_request();
        assert!(matches!(
            decode_request(&body).unwrap(),
            RequestMsg::Metrics
        ));
        // a metrics verb with trailing bytes is malformed
        let mut bad = encode_metrics_request();
        bad.push(0);
        assert!(decode_request(&bad).is_err());

        let report = MetricsReport {
            served: 100,
            errors: 7,
            batches: 42,
            shed: 5,
            expired: 2,
            panics: 1,
            cache_hits: 90,
            cache_misses: 2,
            cache_evictions: 1,
            swaps_committed: 3,
            swaps_rolled_back: 1,
            generation: 4,
            draining: true,
            lat_count: 100,
            lat_sum_us: 25_000,
            lat_max_us: 4_096,
            p50_us: 180,
            p99_us: 950,
            p999_us: 1200,
            queue_wait_ns: 123_456,
            exec_ns: 654_321,
            batch_ns: 700_000,
            swap_ns: 9_001,
        };
        let resp = Response::Metrics {
            latency_us: 21,
            report: report.clone(),
        };
        let wire = encode_response(&resp).unwrap();
        match decode_response(&wire).unwrap() {
            Response::Metrics {
                latency_us,
                report: got,
            } => {
                assert_eq!(latency_us, 21);
                assert_eq!(got, report);
            }
            _ => panic!("expected metrics"),
        }
        // trailing garbage and truncation are malformed, not a crash
        let mut bad = wire.clone();
        bad.push(0);
        assert!(decode_response(&bad).is_err());
        assert!(decode_response(&wire[..wire.len() - 1]).is_err());
    }

    fn pair() -> (TcpStream, TcpStream) {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn zero_length_frames_round_trip() {
        let (mut a, mut b) = pair();
        write_frame(&mut a, &[]).unwrap();
        match read_frame(&mut b).unwrap() {
            FrameRead::Frame(body) => assert!(body.is_empty()),
            _ => panic!("expected a frame"),
        }
        // an empty request body is a protocol error, not a crash
        assert!(decode_request(&[]).is_err());
        assert!(decode_response(&[]).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let (mut a, mut b) = pair();
        a.write_all(&(MAX_FRAME + 1).to_le_bytes()).unwrap();
        let err = read_frame(&mut b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
        assert!(err.to_string().contains("cap"), "got: {err}");
    }

    #[test]
    fn truncated_body_is_an_unexpected_eof() {
        let (mut a, mut b) = pair();
        a.write_all(&8u32.to_le_bytes()).unwrap();
        a.write_all(&[1, 2, 3]).unwrap();
        drop(a); // close mid-body
        let err = read_frame(&mut b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::UnexpectedEof);
    }

    #[test]
    fn truncated_header_is_an_unexpected_eof() {
        let (mut a, mut b) = pair();
        a.write_all(&[7u8, 7]).unwrap();
        drop(a);
        let err = read_frame(&mut b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::UnexpectedEof);
    }

    #[test]
    fn torn_frame_is_an_unexpected_eof_not_a_hang() {
        let (mut a, mut b) = pair();
        let body = encode_response(&Response::Err {
            latency_us: 1,
            code: ErrorCode::Internal,
            message: "torn on purpose".into(),
        })
        .unwrap();
        write_frame_torn(&mut a, &body).unwrap();
        let err = read_frame(&mut b).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::UnexpectedEof);
    }

    #[test]
    fn slow_peer_within_frame_budget_is_not_dropped() {
        let (mut a, mut b) = pair();
        // server-style short inter-frame timeout: it must NOT truncate a
        // frame whose body dribbles in across several timeouts
        b.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        let body: Vec<u8> = (0..32u8).collect();
        let writer = std::thread::spawn(move || {
            a.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
            std::thread::sleep(Duration::from_millis(60));
            a.write_all(&body[..16]).unwrap();
            std::thread::sleep(Duration::from_millis(60));
            a.write_all(&body[16..]).unwrap();
            a.flush().unwrap();
            a // keep the stream alive until the reader is done
        });
        match read_frame_budget(&mut b, Duration::from_secs(2)).unwrap() {
            FrameRead::Frame(got) => assert_eq!(got, (0..32u8).collect::<Vec<u8>>()),
            _ => panic!("expected the dribbled frame"),
        }
        let _ = writer.join().unwrap();
    }

    #[test]
    fn stalled_peer_beyond_frame_budget_is_disconnected() {
        let (mut a, mut b) = pair();
        b.set_read_timeout(Some(Duration::from_millis(10))).unwrap();
        // promise 8 bytes, deliver 2, then stall (but keep the socket open)
        a.write_all(&8u32.to_le_bytes()).unwrap();
        a.write_all(&[1, 2]).unwrap();
        a.flush().unwrap();
        let t0 = Instant::now();
        let err = read_frame_budget(&mut b, Duration::from_millis(80)).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::TimedOut);
        assert!(err.to_string().contains("stalled"), "got: {err}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "budget must bound the stall"
        );
        drop(a);
    }

    #[test]
    fn hostile_tensor_header_cannot_force_a_huge_allocation() {
        // a body whose dims promise ~64 EiB of f32s must die in the
        // cursor's bounds check, never in an allocation
        let mut body = vec![VERSION, 0u8];
        body.extend_from_slice(&3u16.to_le_bytes());
        body.extend_from_slice(b"mlp");
        body.extend_from_slice(&0u32.to_le_bytes());
        body.push(2); // ndim
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_request(&body).unwrap_err().to_string();
        assert!(
            err.contains("truncated") || err.contains("overflow"),
            "got: {err}"
        );
    }

    #[test]
    fn malformed_frames_are_rejected() {
        assert!(decode_request(&[]).is_err());
        // bad version (including the retired v1, v2 and v3)
        let t = Tensor::new(vec![1], vec![1.0]);
        for v in [1u8, 2, 3, 99] {
            let mut body = encode_request("mlp", 0, &t).unwrap();
            body[0] = v;
            let err = decode_request(&body).unwrap_err().to_string();
            assert!(err.contains("version"), "got: {err}");
        }
        // bad verb
        let mut body = encode_request("mlp", 0, &t).unwrap();
        body[1] = 9;
        assert!(decode_request(&body).is_err());
        // trailing garbage
        let mut body = encode_request("mlp", 0, &t).unwrap();
        body.push(0);
        assert!(decode_request(&body).is_err());
        // truncated tensor data
        let body = encode_request("mlp", 0, &t).unwrap();
        assert!(decode_request(&body[..body.len() - 1]).is_err());
    }

    #[test]
    fn backoff_delays_are_deterministic_jittered_and_capped() {
        let retry = RetryCfg {
            attempts: 6,
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(50),
            seed: 42,
        };
        let delays: Vec<Duration> = {
            let mut rng = Rng::new(retry.seed);
            (1..retry.attempts)
                .map(|a| backoff_delay(&retry, a, &mut rng))
                .collect()
        };
        let again: Vec<Duration> = {
            let mut rng = Rng::new(retry.seed);
            (1..retry.attempts)
                .map(|a| backoff_delay(&retry, a, &mut rng))
                .collect()
        };
        assert_eq!(delays, again, "same seed must give the same delays");
        for (i, d) in delays.iter().enumerate() {
            // base doubles per attempt but never exceeds the cap; jitter
            // scales by [0.5, 1.5)
            let base = Duration::from_millis(10 * (1 << i)).min(Duration::from_millis(50));
            assert!(*d >= base.mul_f64(0.5), "attempt {i}: {d:?} below jitter floor");
            assert!(*d < base.mul_f64(1.5), "attempt {i}: {d:?} above jitter ceiling");
        }
    }

    #[test]
    fn connect_retry_reaches_a_live_listener() {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let c = Client::connect_retry(
            addr,
            &RetryCfg {
                attempts: 2,
                ..Default::default()
            },
        );
        assert!(c.is_ok());
    }

    #[test]
    fn serve_error_displays_code_first() {
        let e = ServeError::new(ErrorCode::Overloaded, "queue full (cap 4)");
        assert_eq!(e.to_string(), "overloaded: queue full (cap 4)");
        let any: anyhow::Error = e.into();
        assert!(any.to_string().starts_with("overloaded:"));
    }
}
