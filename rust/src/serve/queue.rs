//! Deadline-aware admission queue feeding the serve batch loop.
//!
//! Connection handlers [`Queue::push`] one [`Pending`] per request and
//! block on its response channel; the single batch-loop thread calls
//! [`Queue::drain_tick`] to collect one batch per tick. Coalescing is
//! bounded two ways:
//!
//! * the **tick**: a batch dispatches once its oldest request has
//!   waited one tick (letting concurrent requests pile in behind it);
//! * the **earliest deadline**: a pending request's soft deadline can
//!   only *accelerate* dispatch — requests are never dropped, a missed
//!   deadline just means the batch left as fast as the queue allowed.

use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted request, waiting for the batch loop.
pub struct Pending {
    /// Zoo model name the request targets.
    pub model: String,
    /// The request input (leading dim = the request's own batch).
    pub tensor: Tensor,
    /// When the connection handler admitted the request.
    pub admitted: Instant,
    /// Absolute soft deadline, if the request carried one.
    pub deadline: Option<Instant>,
    /// Where the batch loop sends the result; the handler blocks on the
    /// receiving end.
    pub resp: mpsc::Sender<anyhow::Result<Tensor>>,
}

/// MPSC admission queue with condvar wakeups (multiple handler
/// producers, one batch-loop consumer).
pub struct Queue {
    inner: Mutex<VecDeque<Pending>>,
    ready: Condvar,
}

impl Default for Queue {
    fn default() -> Queue {
        Queue::new()
    }
}

impl Queue {
    pub fn new() -> Queue {
        Queue {
            inner: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
        }
    }

    /// Admit one request and wake the batch loop.
    pub fn push(&self, p: Pending) {
        self.inner.lock().unwrap().push_back(p);
        self.ready.notify_one();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }

    /// Collect the next batch: block up to `tick` for a first request
    /// (returning empty on timeout so the caller can check shutdown),
    /// then coalesce until the oldest request has aged one tick or the
    /// earliest pending deadline arrives — whichever is sooner — and
    /// drain up to `max` requests in admission order.
    pub fn drain_tick(&self, tick: Duration, max: usize) -> Vec<Pending> {
        let mut q = self.inner.lock().unwrap();
        if q.is_empty() {
            let (guard, _) = self.ready.wait_timeout(q, tick).unwrap();
            q = guard;
            if q.is_empty() {
                return Vec::new();
            }
        }
        loop {
            let now = Instant::now();
            // front() is the oldest: pushes append and only this
            // consumer pops.
            let mut dispatch = q.front().expect("nonempty queue").admitted + tick;
            for p in q.iter() {
                if let Some(d) = p.deadline {
                    dispatch = dispatch.min(d);
                }
            }
            if dispatch <= now || q.len() >= max {
                break;
            }
            // woken early by a push: loop to recompute the dispatch
            // time (a new request may carry an earlier deadline)
            let (guard, _) = self.ready.wait_timeout(q, dispatch - now).unwrap();
            q = guard;
        }
        let take = q.len().min(max.max(1));
        q.drain(..take).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pending(
        model: &str,
        deadline: Option<Duration>,
    ) -> (Pending, mpsc::Receiver<anyhow::Result<Tensor>>) {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        (
            Pending {
                model: model.to_string(),
                tensor: Tensor::zeros(&[1]),
                admitted: now,
                deadline: deadline.map(|d| now + d),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn empty_queue_times_out_with_no_batch() {
        let q = Queue::new();
        let batch = q.drain_tick(Duration::from_millis(5), 8);
        assert!(batch.is_empty());
    }

    #[test]
    fn coalesces_requests_within_one_tick() {
        let q = Arc::new(Queue::new());
        let (p1, _r1) = pending("mlp", None);
        let (p2, _r2) = pending("mlp", None);
        q.push(p1);
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            q2.push(p2);
        });
        let batch = q.drain_tick(Duration::from_millis(100), 8);
        pusher.join().unwrap();
        assert_eq!(batch.len(), 2, "second request must join the first batch");
    }

    #[test]
    fn deadline_accelerates_dispatch_without_drops() {
        let q = Queue::new();
        let (p1, _r1) = pending("mlp", None);
        let (p2, _r2) = pending("mlp", Some(Duration::from_millis(2)));
        q.push(p1);
        q.push(p2);
        let t0 = Instant::now();
        // tick is a full second; the 2 ms deadline must cut the wait
        let batch = q.drain_tick(Duration::from_secs(1), 8);
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert_eq!(batch.len(), 2, "deadlines never drop requests");
    }

    #[test]
    fn max_batch_caps_one_drain() {
        let q = Queue::new();
        let mut rxs = Vec::new();
        for _ in 0..5 {
            let (p, r) = pending("mlp", Some(Duration::ZERO));
            q.push(p);
            rxs.push(r);
        }
        let batch = q.drain_tick(Duration::from_millis(50), 3);
        assert_eq!(batch.len(), 3);
        assert_eq!(q.len(), 2);
    }
}
