//! Deadline-aware bounded admission queue feeding the serve batch loop.
//!
//! Connection handlers [`Queue::try_push`] one [`Pending`] per request
//! and block on its response channel; the single batch-loop thread
//! calls [`Queue::drain_tick`] to collect one batch per tick.
//! Coalescing is bounded two ways:
//!
//! * the **tick**: a batch dispatches once its oldest request has
//!   waited one tick (letting concurrent requests pile in behind it);
//! * the **earliest deadline**: a pending request's soft deadline can
//!   only *accelerate* dispatch here — expiry shedding happens at
//!   dispatch time in the batch loop, never inside the queue.
//!
//! Robustness properties (see [`crate::serve`]'s failure semantics):
//!
//! * **bounded depth** — [`Queue::bounded`] caps pending requests;
//!   admission past the cap is rejected with
//!   [`ErrorCode::Overloaded`](crate::serve::ErrorCode::Overloaded)
//!   instead of growing without bound under backlog;
//! * **closable** — [`Queue::close`] flips the queue into a
//!   drain state where every new admission is rejected with
//!   [`ErrorCode::ShuttingDown`](crate::serve::ErrorCode::ShuttingDown)
//!   while already-admitted work still drains;
//! * **poison-proof** — all locking goes through
//!   [`relock`](crate::util::relock), so a panicked producer or
//!   consumer can't wedge admission for everyone else.

use super::protocol::{ErrorCode, ServeError};
use crate::tensor::Tensor;
use crate::util::relock;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted request, waiting for the batch loop.
pub struct Pending {
    /// Zoo model name the request targets.
    pub model: String,
    /// The request input (leading dim = the request's own batch).
    pub tensor: Tensor,
    /// When the connection handler admitted the request.
    pub admitted: Instant,
    /// Absolute soft deadline, if the request carried one.
    pub deadline: Option<Instant>,
    /// Where the batch loop sends the result; the handler blocks on the
    /// receiving end.
    pub resp: mpsc::Sender<Result<Tensor, ServeError>>,
}

/// MPSC admission queue with condvar wakeups (multiple handler
/// producers, one batch-loop consumer).
pub struct Queue {
    inner: Mutex<VecDeque<Pending>>,
    ready: Condvar,
    /// Admission cap; 0 = unbounded.
    cap: usize,
    /// Once set, every `try_push` is rejected with `ShuttingDown`.
    closed: AtomicBool,
}

impl Default for Queue {
    fn default() -> Queue {
        Queue::new()
    }
}

impl Queue {
    /// An unbounded queue (tests and trusted in-process callers).
    pub fn new() -> Queue {
        Queue::bounded(0)
    }

    /// A queue admitting at most `cap` pending requests (0 = unbounded).
    pub fn bounded(cap: usize) -> Queue {
        Queue {
            inner: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            cap,
            closed: AtomicBool::new(false),
        }
    }

    /// Admit one request and wake the batch loop. Rejects with
    /// `Overloaded` when the queue is at capacity (load shedding at
    /// admission — the cheapest possible point) and with
    /// `ShuttingDown` once the queue is closed.
    pub fn try_push(&self, p: Pending) -> Result<(), ServeError> {
        if self.closed.load(Ordering::Acquire) {
            return Err(ServeError::new(
                ErrorCode::ShuttingDown,
                "server is draining and admits no new requests",
            ));
        }
        let mut q = relock(&self.inner);
        // re-check under the lock so a close() racing with this push
        // can't admit work the drain will never dispatch
        if self.closed.load(Ordering::Acquire) {
            return Err(ServeError::new(
                ErrorCode::ShuttingDown,
                "server is draining and admits no new requests",
            ));
        }
        if self.cap > 0 && q.len() >= self.cap {
            crate::obs::trace::instant_with("queue.shed", || format!("{} pending", q.len()));
            return Err(ServeError::new(
                ErrorCode::Overloaded,
                format!("admission queue is full ({} pending, cap {})", q.len(), self.cap),
            ));
        }
        q.push_back(p);
        drop(q);
        self.ready.notify_one();
        crate::obs::trace::instant("queue.admit");
        Ok(())
    }

    /// Stop admitting: every later [`Queue::try_push`] fails with
    /// `ShuttingDown`. Already-queued requests still drain. Wakes the
    /// batch loop so it can observe the drain promptly.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.ready.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    pub fn len(&self) -> usize {
        relock(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        relock(&self.inner).is_empty()
    }

    /// Take every queued request at once (shutdown flush — the caller
    /// answers each with `ShuttingDown` so no handler blocks forever).
    pub fn drain_all(&self) -> Vec<Pending> {
        relock(&self.inner).drain(..).collect()
    }

    /// Collect the next batch: block up to `tick` for a first request
    /// (returning empty on timeout so the caller can check shutdown),
    /// then coalesce until the oldest request has aged one tick or the
    /// earliest pending deadline arrives — whichever is sooner — and
    /// drain up to `max` requests in admission order.
    pub fn drain_tick(&self, tick: Duration, max: usize) -> Vec<Pending> {
        let mut q = relock(&self.inner);
        if q.is_empty() {
            let (guard, _) = self
                .ready
                .wait_timeout(q, tick)
                .unwrap_or_else(|e| e.into_inner());
            q = guard;
            if q.is_empty() {
                return Vec::new();
            }
        }
        loop {
            let now = Instant::now();
            // front() is the oldest: pushes append and only this
            // consumer pops.
            let mut dispatch = q.front().expect("nonempty queue").admitted + tick;
            for p in q.iter() {
                if let Some(d) = p.deadline {
                    dispatch = dispatch.min(d);
                }
            }
            if dispatch <= now || q.len() >= max || self.is_closed() {
                break;
            }
            // woken early by a push: loop to recompute the dispatch
            // time (a new request may carry an earlier deadline)
            let (guard, _) = self
                .ready
                .wait_timeout(q, dispatch - now)
                .unwrap_or_else(|e| e.into_inner());
            q = guard;
        }
        let take = q.len().min(max.max(1));
        q.drain(..take).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn pending(
        model: &str,
        deadline: Option<Duration>,
    ) -> (Pending, mpsc::Receiver<Result<Tensor, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        (
            Pending {
                model: model.to_string(),
                tensor: Tensor::zeros(&[1]),
                admitted: now,
                deadline: deadline.map(|d| now + d),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn empty_queue_times_out_with_no_batch() {
        let q = Queue::new();
        let batch = q.drain_tick(Duration::from_millis(5), 8);
        assert!(batch.is_empty());
    }

    #[test]
    fn coalesces_requests_within_one_tick() {
        let q = Arc::new(Queue::new());
        let (p1, _r1) = pending("mlp", None);
        let (p2, _r2) = pending("mlp", None);
        q.try_push(p1).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            q2.try_push(p2).unwrap();
        });
        let batch = q.drain_tick(Duration::from_millis(100), 8);
        pusher.join().unwrap();
        assert_eq!(batch.len(), 2, "second request must join the first batch");
    }

    #[test]
    fn deadline_accelerates_dispatch_without_drops() {
        let q = Queue::new();
        let (p1, _r1) = pending("mlp", None);
        let (p2, _r2) = pending("mlp", Some(Duration::from_millis(2)));
        q.try_push(p1).unwrap();
        q.try_push(p2).unwrap();
        let t0 = Instant::now();
        // tick is a full second; the 2 ms deadline must cut the wait
        let batch = q.drain_tick(Duration::from_secs(1), 8);
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert_eq!(batch.len(), 2, "deadlines never drop requests");
    }

    #[test]
    fn max_batch_caps_one_drain() {
        let q = Queue::new();
        let mut rxs = Vec::new();
        for _ in 0..5 {
            let (p, r) = pending("mlp", Some(Duration::ZERO));
            q.try_push(p).unwrap();
            rxs.push(r);
        }
        let batch = q.drain_tick(Duration::from_millis(50), 3);
        assert_eq!(batch.len(), 3);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn bounded_queue_sheds_with_overloaded_at_capacity() {
        let q = Queue::bounded(2);
        let mut rxs = Vec::new();
        for _ in 0..2 {
            let (p, r) = pending("mlp", None);
            q.try_push(p).unwrap();
            rxs.push(r);
        }
        let (p3, _r3) = pending("mlp", None);
        let err = q.try_push(p3).unwrap_err();
        assert_eq!(err.code, ErrorCode::Overloaded);
        assert!(err.message.contains("cap 2"), "got: {}", err.message);
        // draining makes room again
        assert_eq!(q.drain_tick(Duration::ZERO, 8).len(), 2);
        let (p4, _r4) = pending("mlp", None);
        q.try_push(p4).unwrap();
    }

    #[test]
    fn closed_queue_rejects_with_shutting_down_but_still_drains() {
        let q = Queue::new();
        let (p1, _r1) = pending("mlp", None);
        q.try_push(p1).unwrap();
        q.close();
        assert!(q.is_closed());
        let (p2, _r2) = pending("mlp", None);
        let err = q.try_push(p2).unwrap_err();
        assert_eq!(err.code, ErrorCode::ShuttingDown);
        // admitted-before-close work is still dispatched
        assert_eq!(q.drain_tick(Duration::from_millis(50), 8).len(), 1);
        assert_eq!(q.drain_all().len(), 0);
    }

    #[test]
    fn close_wakes_a_parked_consumer() {
        let q = Arc::new(Queue::new());
        let q2 = Arc::clone(&q);
        let t0 = Instant::now();
        let consumer =
            std::thread::spawn(move || q2.drain_tick(Duration::from_secs(5), 8).len());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        // close() can't interrupt the initial empty-queue wait (there is
        // nothing to dispatch anyway) but an armed consumer must not
        // sleep a full tick past it; give it the whole tick as a bound
        assert_eq!(consumer.join().unwrap(), 0);
        assert!(t0.elapsed() < Duration::from_secs(6));
    }
}
