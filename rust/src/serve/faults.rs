//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] arms named sites inside the server with seeded
//! failure rules — panics while resolving or executing a batch, slow
//! batch ticks, torn response frames. Every decision comes from a
//! per-site PCG32 stream forked from one seed, so a chaos run is
//! exactly reproducible: same seed + same request order → same faults.
//! Production servers run with no plan armed; the hooks cost one
//! `Option` check per site.
//!
//! Specs are compact strings, e.g.
//!
//! ```text
//! seed=42;group.panic=0.5;batch.slow=0.25:30;frame.torn=0.5
//! ```
//!
//! `site.kind=prob` arms `kind` at `site` with probability `prob` per
//! visit; `slow` takes `prob:millis`. Sites accept only the faults that
//! make sense there: `panic` at `resolve`/`group` (both inside the
//! batch loop's `catch_unwind`), `slow` at `batch`/`group`, `torn` at
//! `frame` only. Configure via `ServeCfg::faults`, the `spa serve
//! --faults` flag, or the `SPA_FAULTS` environment variable.

use crate::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Marker embedded in every injected panic's message so test panic
/// hooks can tell deliberate chaos from a real bug.
pub const PANIC_TAG: &str = "spa-injected-fault";

/// Named injection points inside the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Model lookup + plan compilation for one batch group.
    Resolve,
    /// Execution of one model group's fused batch.
    Group,
    /// Top of one batch-loop tick (outside any `catch_unwind` — only
    /// non-unwinding faults are allowed here).
    Batch,
    /// Writing a response frame back to a client.
    Frame,
}

/// All sites, in the fixed order their PRNG streams are forked.
pub const SITES: [Site; 4] = [Site::Resolve, Site::Group, Site::Batch, Site::Frame];

impl Site {
    /// Stable name used in specs and panic messages.
    pub fn name(self) -> &'static str {
        match self {
            Site::Resolve => "resolve",
            Site::Group => "group",
            Site::Batch => "batch",
            Site::Frame => "frame",
        }
    }

    fn index(self) -> usize {
        match self {
            Site::Resolve => 0,
            Site::Group => 1,
            Site::Batch => 2,
            Site::Frame => 3,
        }
    }
}

/// What an armed site does when its probability roll hits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Unwind with a [`PANIC_TAG`]-marked message.
    Panic,
    /// Sleep this long before proceeding.
    Slow(Duration),
    /// Write a deliberately truncated frame and sever the connection.
    Torn,
}

#[derive(Debug, Clone, Copy)]
struct Rule {
    prob: f32,
    fault: Fault,
}

/// A seeded set of per-site failure rules. See the module docs for the
/// spec grammar.
pub struct FaultPlan {
    seed: u64,
    spec: String,
    rules: [Option<Rule>; 4],
    /// One independent stream per site, forked from `seed` in `SITES`
    /// order, so concurrency at one site never perturbs another's rolls.
    streams: [Mutex<Rng>; 4],
    injected: [AtomicUsize; 4],
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("seed", &self.seed)
            .field("spec", &self.spec)
            .finish()
    }
}

impl FaultPlan {
    /// Parse a spec string (grammar in the module docs).
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut seed = 0u64;
        let mut rules: [Option<Rule>; 4] = [None; 4];
        for token in spec.split(';').map(str::trim).filter(|t| !t.is_empty()) {
            let (key, value) = token
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault token `{token}` is not key=value"))?;
            if key == "seed" {
                seed = value
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad fault seed `{value}`: {e}"))?;
                continue;
            }
            let (site_name, kind) = key.split_once('.').ok_or_else(|| {
                anyhow::anyhow!("fault key `{key}` is not site.kind (or `seed`)")
            })?;
            let site = SITES
                .iter()
                .copied()
                .find(|s| s.name() == site_name)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown fault site `{site_name}` (resolve|group|batch|frame)"
                    )
                })?;
            let (prob_str, fault) = match kind {
                "panic" => {
                    anyhow::ensure!(
                        matches!(site, Site::Resolve | Site::Group),
                        "`panic` is only valid at resolve/group (inside the \
                         batch loop's catch_unwind), not `{site_name}`"
                    );
                    (value, Fault::Panic)
                }
                "slow" => {
                    anyhow::ensure!(
                        matches!(site, Site::Batch | Site::Group),
                        "`slow` is only valid at batch/group, not `{site_name}`"
                    );
                    let (p, ms) = value.split_once(':').ok_or_else(|| {
                        anyhow::anyhow!("`slow` takes prob:millis, got `{value}`")
                    })?;
                    let ms: u64 = ms
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad slow millis `{ms}`: {e}"))?;
                    (p, Fault::Slow(Duration::from_millis(ms)))
                }
                "torn" => {
                    anyhow::ensure!(
                        site == Site::Frame,
                        "`torn` is only valid at frame, not `{site_name}`"
                    );
                    (value, Fault::Torn)
                }
                other => anyhow::bail!("unknown fault kind `{other}` (panic|slow|torn)"),
            };
            let prob: f32 = prob_str
                .parse()
                .map_err(|e| anyhow::anyhow!("bad fault probability `{prob_str}`: {e}"))?;
            anyhow::ensure!(
                (0.0..=1.0).contains(&prob),
                "fault probability {prob} is outside [0, 1]"
            );
            anyhow::ensure!(
                rules[site.index()].is_none(),
                "site `{site_name}` is armed twice"
            );
            rules[site.index()] = Some(Rule { prob, fault });
        }
        let mut root = Rng::new(seed);
        let streams = [
            Mutex::new(root.fork(0)),
            Mutex::new(root.fork(1)),
            Mutex::new(root.fork(2)),
            Mutex::new(root.fork(3)),
        ];
        Ok(FaultPlan {
            seed,
            spec: spec.to_string(),
            rules,
            streams,
            injected: [
                AtomicUsize::new(0),
                AtomicUsize::new(0),
                AtomicUsize::new(0),
                AtomicUsize::new(0),
            ],
        })
    }

    /// Read a plan from the `SPA_FAULTS` environment variable, if set.
    pub fn from_env() -> anyhow::Result<Option<FaultPlan>> {
        match std::env::var("SPA_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlan::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    /// The seed the per-site streams were forked from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Roll `site`'s stream; `Some(fault)` when the site is armed and
    /// the roll hits. Rolls only happen on armed sites, so un-armed
    /// sites stay free and streams advance once per armed visit.
    pub fn check(&self, site: Site) -> Option<Fault> {
        let rule = self.rules[site.index()]?;
        let roll = crate::util::relock(&self.streams[site.index()]).uniform();
        (roll < rule.prob).then_some(rule.fault)
    }

    /// Roll `site` and act on the outcome: sleep through a `Slow`
    /// fault, unwind on `Panic` (message carries [`PANIC_TAG`]), and
    /// return `true` for `Torn` so the caller tears its frame.
    pub fn fire(&self, site: Site) -> bool {
        match self.check(site) {
            None => false,
            Some(fault) => {
                self.injected[site.index()].fetch_add(1, Ordering::Relaxed);
                match fault {
                    Fault::Panic => panic!("{PANIC_TAG}: injected panic at {}", site.name()),
                    Fault::Slow(d) => {
                        std::thread::sleep(d);
                        false
                    }
                    Fault::Torn => true,
                }
            }
        }
    }

    /// How many faults have fired at `site` so far.
    pub fn injected(&self, site: Site) -> usize {
        self.injected[site.index()].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_spec() {
        let plan =
            FaultPlan::parse("seed=42;group.panic=0.5;batch.slow=0.25:30;frame.torn=0.5").unwrap();
        assert_eq!(plan.seed(), 42);
        assert_eq!(plan.rules[Site::Group.index()].unwrap().fault, Fault::Panic);
        assert_eq!(
            plan.rules[Site::Batch.index()].unwrap().fault,
            Fault::Slow(Duration::from_millis(30))
        );
        assert_eq!(plan.rules[Site::Frame.index()].unwrap().fault, Fault::Torn);
        assert!(plan.rules[Site::Resolve.index()].is_none());
    }

    #[test]
    fn empty_and_whitespace_specs_are_inert() {
        for spec in ["", "  ", ";;"] {
            let plan = FaultPlan::parse(spec).unwrap();
            for site in SITES {
                assert!(plan.check(site).is_none(), "spec {spec:?} armed {site:?}");
            }
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for (spec, needle) in [
            ("group.panic", "key=value"),
            ("seed=banana", "bad fault seed"),
            ("turbine.panic=0.5", "unknown fault site"),
            ("group.meteor=0.5", "unknown fault kind"),
            ("group.panic=1.5", "outside [0, 1]"),
            ("group.panic=zebra", "bad fault probability"),
            ("batch.slow=0.5", "prob:millis"),
            ("batch.slow=0.5:fast", "bad slow millis"),
            ("group.panic=0.5;group.panic=0.2", "armed twice"),
            // kinds on sites that can't honor them
            ("batch.panic=0.5", "only valid at resolve/group"),
            ("frame.panic=0.5", "only valid at resolve/group"),
            ("frame.slow=0.5:10", "only valid at batch/group"),
            ("group.torn=0.5", "only valid at frame"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err().to_string();
            assert!(err.contains(needle), "spec {spec:?}: got `{err}`");
        }
    }

    #[test]
    fn rolls_are_deterministic_per_seed() {
        let roll = |seed: u64| {
            let plan = FaultPlan::parse(&format!("seed={seed};group.panic=0.5")).unwrap();
            (0..64)
                .map(|_| plan.check(Site::Group).is_some())
                .collect::<Vec<bool>>()
        };
        assert_eq!(roll(7), roll(7), "same seed must give the same faults");
        assert_ne!(roll(7), roll(8), "different seeds should diverge");
        // prob 0.5 over 64 rolls: both outcomes must appear
        let hits = roll(7).iter().filter(|h| **h).count();
        assert!(hits > 0 && hits < 64, "got {hits}/64 hits");
    }

    #[test]
    fn probability_bounds_always_and_never_fire() {
        let never = FaultPlan::parse("seed=1;group.panic=0.0").unwrap();
        let always = FaultPlan::parse("seed=1;frame.torn=1.0").unwrap();
        for _ in 0..32 {
            assert!(never.check(Site::Group).is_none());
            assert_eq!(always.check(Site::Frame), Some(Fault::Torn));
        }
    }

    #[test]
    fn fire_counts_and_tags_injected_panics() {
        let plan = FaultPlan::parse("seed=3;group.panic=1.0;frame.torn=1.0").unwrap();
        assert!(plan.fire(Site::Frame), "torn must ask the caller to tear");
        assert_eq!(plan.injected(Site::Frame), 1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.fire(Site::Group);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains(PANIC_TAG), "panic message `{msg}` lacks the tag");
        assert_eq!(plan.injected(Site::Group), 1);
        assert_eq!(plan.injected(Site::Batch), 0);
    }

    #[test]
    fn unarmed_sites_never_fire() {
        let plan = FaultPlan::parse("seed=9;frame.torn=1.0").unwrap();
        for _ in 0..16 {
            assert!(!plan.fire(Site::Batch));
            assert!(plan.check(Site::Resolve).is_none());
        }
    }

    #[test]
    fn from_env_is_none_when_unset() {
        // the test harness never sets SPA_FAULTS for unit tests; chaos
        // integration tests pass plans through ServeCfg instead
        if std::env::var("SPA_FAULTS").is_err() {
            assert!(FaultPlan::from_env().unwrap().is_none());
        }
    }
}
