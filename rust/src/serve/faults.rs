//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] arms named sites inside the server with seeded
//! failure rules — panics while resolving or executing a batch, slow
//! batch ticks, torn response frames. Every decision comes from a
//! per-site PCG32 stream forked from one seed, so a chaos run is
//! exactly reproducible: same seed + same request order → same faults.
//! Production servers run with no plan armed; the hooks cost one
//! `Option` check per site.
//!
//! Specs are compact strings, e.g.
//!
//! ```text
//! seed=42;group.panic=0.5;batch.slow=0.25:30;frame.torn=0.5
//! ```
//!
//! `site.kind=prob` arms `kind` at `site` with probability `prob` per
//! visit; `slow` takes `prob:millis`. Sites accept only the faults that
//! make sense there: `panic` at `resolve`/`group` (both inside the
//! batch loop's `catch_unwind`), `slow` at `batch`/`group`, `torn` at
//! `frame` only. The live-swap pipeline adds three gates of its own —
//! `swap.verify_fail` (static verification of the candidate plan
//! reports failure), `swap.shadow_diverge` (the shadow-parity gate
//! reports divergence), and `swap.post_flip_panic` (a batch group
//! panics inside the post-flip monitoring window) — each proving one
//! rollback path recovers. Configure via `ServeCfg::faults`, the
//! `spa serve --faults` flag, or the `SPA_FAULTS` environment variable.

use crate::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Marker embedded in every injected panic's message so test panic
/// hooks can tell deliberate chaos from a real bug.
pub const PANIC_TAG: &str = "spa-injected-fault";

/// Named injection points inside the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Model lookup + plan compilation for one batch group.
    Resolve,
    /// Execution of one model group's fused batch.
    Group,
    /// Top of one batch-loop tick (outside any `catch_unwind` — only
    /// non-unwinding faults are allowed here).
    Batch,
    /// Writing a response frame back to a client.
    Frame,
    /// Static verification of a swap candidate (`check_graph` +
    /// `check_plan` at Strict).
    SwapVerify,
    /// The shadow-parity gate comparing candidate outputs against the
    /// serving plan on live requests.
    SwapShadow,
    /// Batch-group execution inside the post-flip monitoring window
    /// (inside the batch loop's `catch_unwind`).
    SwapPostFlip,
}

/// All sites, in the fixed order their PRNG streams are forked.
pub const SITES: [Site; 7] = [
    Site::Resolve,
    Site::Group,
    Site::Batch,
    Site::Frame,
    Site::SwapVerify,
    Site::SwapShadow,
    Site::SwapPostFlip,
];

impl Site {
    /// Stable name used in specs and panic messages.
    pub fn name(self) -> &'static str {
        match self {
            Site::Resolve => "resolve",
            Site::Group => "group",
            Site::Batch => "batch",
            Site::Frame => "frame",
            Site::SwapVerify => "swap.verify_fail",
            Site::SwapShadow => "swap.shadow_diverge",
            Site::SwapPostFlip => "swap.post_flip_panic",
        }
    }

    fn index(self) -> usize {
        match self {
            Site::Resolve => 0,
            Site::Group => 1,
            Site::Batch => 2,
            Site::Frame => 3,
            Site::SwapVerify => 4,
            Site::SwapShadow => 5,
            Site::SwapPostFlip => 6,
        }
    }
}

/// What an armed site does when its probability roll hits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Unwind with a [`PANIC_TAG`]-marked message.
    Panic,
    /// Sleep this long before proceeding.
    Slow(Duration),
    /// Write a deliberately truncated frame and sever the connection.
    Torn,
    /// Report failure at a swap gate: [`FaultPlan::fire`] returns `true`
    /// and the swap pipeline converts it into a failed verification or
    /// parity check (no unwind, no sleep).
    Fail,
}

#[derive(Debug, Clone, Copy)]
struct Rule {
    prob: f32,
    fault: Fault,
}

/// A seeded set of per-site failure rules. See the module docs for the
/// spec grammar.
pub struct FaultPlan {
    seed: u64,
    spec: String,
    rules: [Option<Rule>; 7],
    /// One independent stream per site, forked from `seed` in `SITES`
    /// order, so concurrency at one site never perturbs another's rolls.
    streams: [Mutex<Rng>; 7],
    injected: [AtomicUsize; 7],
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("seed", &self.seed)
            .field("spec", &self.spec)
            .finish()
    }
}

impl FaultPlan {
    /// Parse a spec string (grammar in the module docs).
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut seed = 0u64;
        let mut rules: [Option<Rule>; 7] = [None; 7];
        for token in spec.split(';').map(str::trim).filter(|t| !t.is_empty()) {
            let (key, value) = token
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault token `{token}` is not key=value"))?;
            if key == "seed" {
                seed = value
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad fault seed `{value}`: {e}"))?;
                continue;
            }
            let (site_name, kind) = key.split_once('.').ok_or_else(|| {
                anyhow::anyhow!("fault key `{key}` is not site.kind (or `seed`)")
            })?;
            let (site, fault, prob_str) = if site_name == "swap" {
                // swap gates pair a fixed fault with each site; the
                // full spec token is the site's stable name
                let (site, fault) = match kind {
                    "verify_fail" => (Site::SwapVerify, Fault::Fail),
                    "shadow_diverge" => (Site::SwapShadow, Fault::Fail),
                    "post_flip_panic" => (Site::SwapPostFlip, Fault::Panic),
                    other => anyhow::bail!(
                        "unknown swap fault `{other}` \
                         (verify_fail|shadow_diverge|post_flip_panic)"
                    ),
                };
                (site, fault, value)
            } else {
                let site = SITES
                    .iter()
                    .copied()
                    .find(|s| s.name() == site_name)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown fault site `{site_name}` (resolve|group|batch|frame|swap)"
                        )
                    })?;
                let (prob_str, fault) = match kind {
                    "panic" => {
                        anyhow::ensure!(
                            matches!(site, Site::Resolve | Site::Group),
                            "`panic` is only valid at resolve/group (inside the \
                             batch loop's catch_unwind), not `{site_name}`"
                        );
                        (value, Fault::Panic)
                    }
                    "slow" => {
                        anyhow::ensure!(
                            matches!(site, Site::Batch | Site::Group),
                            "`slow` is only valid at batch/group, not `{site_name}`"
                        );
                        let (p, ms) = value.split_once(':').ok_or_else(|| {
                            anyhow::anyhow!("`slow` takes prob:millis, got `{value}`")
                        })?;
                        let ms: u64 = ms
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad slow millis `{ms}`: {e}"))?;
                        (p, Fault::Slow(Duration::from_millis(ms)))
                    }
                    "torn" => {
                        anyhow::ensure!(
                            site == Site::Frame,
                            "`torn` is only valid at frame, not `{site_name}`"
                        );
                        (value, Fault::Torn)
                    }
                    other => anyhow::bail!("unknown fault kind `{other}` (panic|slow|torn)"),
                };
                (site, fault, prob_str)
            };
            let prob: f32 = prob_str
                .parse()
                .map_err(|e| anyhow::anyhow!("bad fault probability `{prob_str}`: {e}"))?;
            anyhow::ensure!(
                (0.0..=1.0).contains(&prob),
                "fault probability {prob} is outside [0, 1]"
            );
            anyhow::ensure!(
                rules[site.index()].is_none(),
                "site `{}` is armed twice",
                site.name()
            );
            rules[site.index()] = Some(Rule { prob, fault });
        }
        let mut root = Rng::new(seed);
        let streams = [
            Mutex::new(root.fork(0)),
            Mutex::new(root.fork(1)),
            Mutex::new(root.fork(2)),
            Mutex::new(root.fork(3)),
            Mutex::new(root.fork(4)),
            Mutex::new(root.fork(5)),
            Mutex::new(root.fork(6)),
        ];
        Ok(FaultPlan {
            seed,
            spec: spec.to_string(),
            rules,
            streams,
            injected: std::array::from_fn(|_| AtomicUsize::new(0)),
        })
    }

    /// Read a plan from the `SPA_FAULTS` environment variable, if set.
    pub fn from_env() -> anyhow::Result<Option<FaultPlan>> {
        match std::env::var("SPA_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlan::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    /// The seed the per-site streams were forked from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Roll `site`'s stream; `Some(fault)` when the site is armed and
    /// the roll hits. Rolls only happen on armed sites, so un-armed
    /// sites stay free and streams advance once per armed visit.
    pub fn check(&self, site: Site) -> Option<Fault> {
        let rule = self.rules[site.index()]?;
        let roll = crate::util::relock(&self.streams[site.index()]).uniform();
        (roll < rule.prob).then_some(rule.fault)
    }

    /// Roll `site` and act on the outcome: sleep through a `Slow`
    /// fault, unwind on `Panic` (message carries [`PANIC_TAG`]), and
    /// return `true` for `Torn`/`Fail` so the caller tears its frame or
    /// fails its swap gate.
    pub fn fire(&self, site: Site) -> bool {
        match self.check(site) {
            None => false,
            Some(fault) => {
                self.injected[site.index()].fetch_add(1, Ordering::Relaxed);
                match fault {
                    Fault::Panic => panic!("{PANIC_TAG}: injected panic at {}", site.name()),
                    Fault::Slow(d) => {
                        std::thread::sleep(d);
                        false
                    }
                    Fault::Torn | Fault::Fail => true,
                }
            }
        }
    }

    /// How many faults have fired at `site` so far.
    pub fn injected(&self, site: Site) -> usize {
        self.injected[site.index()].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_spec() {
        let plan =
            FaultPlan::parse("seed=42;group.panic=0.5;batch.slow=0.25:30;frame.torn=0.5").unwrap();
        assert_eq!(plan.seed(), 42);
        assert_eq!(plan.rules[Site::Group.index()].unwrap().fault, Fault::Panic);
        assert_eq!(
            plan.rules[Site::Batch.index()].unwrap().fault,
            Fault::Slow(Duration::from_millis(30))
        );
        assert_eq!(plan.rules[Site::Frame.index()].unwrap().fault, Fault::Torn);
        assert!(plan.rules[Site::Resolve.index()].is_none());
    }

    #[test]
    fn empty_and_whitespace_specs_are_inert() {
        for spec in ["", "  ", ";;"] {
            let plan = FaultPlan::parse(spec).unwrap();
            for site in SITES {
                assert!(plan.check(site).is_none(), "spec {spec:?} armed {site:?}");
            }
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for (spec, needle) in [
            ("group.panic", "key=value"),
            ("seed=banana", "bad fault seed"),
            ("turbine.panic=0.5", "unknown fault site"),
            ("group.meteor=0.5", "unknown fault kind"),
            ("group.panic=1.5", "outside [0, 1]"),
            ("group.panic=zebra", "bad fault probability"),
            ("batch.slow=0.5", "prob:millis"),
            ("batch.slow=0.5:fast", "bad slow millis"),
            ("group.panic=0.5;group.panic=0.2", "armed twice"),
            // kinds on sites that can't honor them
            ("batch.panic=0.5", "only valid at resolve/group"),
            ("frame.panic=0.5", "only valid at resolve/group"),
            ("frame.slow=0.5:10", "only valid at batch/group"),
            ("group.torn=0.5", "only valid at frame"),
            // swap gate grammar
            ("swap.meteor=0.5", "unknown swap fault"),
            ("swap.verify_fail=1.5", "outside [0, 1]"),
            ("swap.verify_fail=zebra", "bad fault probability"),
            (
                "swap.shadow_diverge=0.5;swap.shadow_diverge=0.2",
                "armed twice",
            ),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err().to_string();
            assert!(err.contains(needle), "spec {spec:?}: got `{err}`");
        }
    }

    #[test]
    fn parses_swap_gates() {
        let plan = FaultPlan::parse(
            "seed=7;swap.verify_fail=1.0;swap.shadow_diverge=0.5;swap.post_flip_panic=0.25",
        )
        .unwrap();
        assert_eq!(
            plan.rules[Site::SwapVerify.index()].unwrap().fault,
            Fault::Fail
        );
        assert_eq!(
            plan.rules[Site::SwapShadow.index()].unwrap().fault,
            Fault::Fail
        );
        assert_eq!(
            plan.rules[Site::SwapPostFlip.index()].unwrap().fault,
            Fault::Panic
        );
        // swap gates never collide with the classic sites
        for site in [Site::Resolve, Site::Group, Site::Batch, Site::Frame] {
            assert!(plan.rules[site.index()].is_none());
        }
    }

    #[test]
    fn swap_fail_gates_fire_without_unwinding() {
        let plan = FaultPlan::parse("seed=5;swap.verify_fail=1.0;swap.shadow_diverge=1.0").unwrap();
        assert!(plan.fire(Site::SwapVerify), "armed gate must report failure");
        assert!(plan.fire(Site::SwapShadow));
        assert_eq!(plan.injected(Site::SwapVerify), 1);
        assert_eq!(plan.injected(Site::SwapShadow), 1);
        assert!(!plan.fire(Site::SwapPostFlip), "unarmed gate stays quiet");
    }

    #[test]
    fn swap_post_flip_panics_with_the_tag() {
        let plan = FaultPlan::parse("seed=5;swap.post_flip_panic=1.0").unwrap();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.fire(Site::SwapPostFlip);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains(PANIC_TAG), "panic `{msg}` lacks the tag");
        assert!(
            msg.contains("swap.post_flip_panic"),
            "panic `{msg}` lacks the site name"
        );
        assert_eq!(plan.injected(Site::SwapPostFlip), 1);
    }

    #[test]
    fn rolls_are_deterministic_per_seed() {
        let roll = |seed: u64| {
            let plan = FaultPlan::parse(&format!("seed={seed};group.panic=0.5")).unwrap();
            (0..64)
                .map(|_| plan.check(Site::Group).is_some())
                .collect::<Vec<bool>>()
        };
        assert_eq!(roll(7), roll(7), "same seed must give the same faults");
        assert_ne!(roll(7), roll(8), "different seeds should diverge");
        // prob 0.5 over 64 rolls: both outcomes must appear
        let hits = roll(7).iter().filter(|h| **h).count();
        assert!(hits > 0 && hits < 64, "got {hits}/64 hits");
    }

    #[test]
    fn probability_bounds_always_and_never_fire() {
        let never = FaultPlan::parse("seed=1;group.panic=0.0").unwrap();
        let always = FaultPlan::parse("seed=1;frame.torn=1.0").unwrap();
        for _ in 0..32 {
            assert!(never.check(Site::Group).is_none());
            assert_eq!(always.check(Site::Frame), Some(Fault::Torn));
        }
    }

    #[test]
    fn fire_counts_and_tags_injected_panics() {
        let plan = FaultPlan::parse("seed=3;group.panic=1.0;frame.torn=1.0").unwrap();
        assert!(plan.fire(Site::Frame), "torn must ask the caller to tear");
        assert_eq!(plan.injected(Site::Frame), 1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.fire(Site::Group);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains(PANIC_TAG), "panic message `{msg}` lacks the tag");
        assert_eq!(plan.injected(Site::Group), 1);
        assert_eq!(plan.injected(Site::Batch), 0);
    }

    #[test]
    fn unarmed_sites_never_fire() {
        let plan = FaultPlan::parse("seed=9;frame.torn=1.0").unwrap();
        for _ in 0..16 {
            assert!(!plan.fire(Site::Batch));
            assert!(plan.check(Site::Resolve).is_none());
        }
    }

    #[test]
    fn from_env_is_none_when_unset() {
        // the test harness never sets SPA_FAULTS for unit tests; chaos
        // integration tests pass plans through ServeCfg instead
        if std::env::var("SPA_FAULTS").is_err() {
            assert!(FaultPlan::from_env().unwrap().is_none());
        }
    }
}
