//! `spa::obs` — end-to-end observability: structured tracing, per-step
//! plan profiling, and histogram metrics.
//!
//! The serving stack runs the paper's "any time" story under live
//! traffic (plan-cache swaps, fault injection, dynamic batching); this
//! module makes that activity visible without perturbing it:
//!
//! * [`trace`] — per-thread bounded rings of typed span events
//!   (`exec.step`, `batch.tick`, `swap.*`, `cache.*`, `queue.*`),
//!   exported as Chrome `trace_event` JSON by `spa trace`. Off by
//!   default; the disabled path costs one relaxed atomic load per site.
//! * [`profile`] — an opt-in per-step profiler over `exec::Plan`
//!   (wall ns, bytes moved, GEMM dims, fusion attribution), surfaced by
//!   `spa profile` as the op-level baseline for kernel work.
//! * [`metrics`] — log-linear latency histograms (exact-count
//!   p50/p99/p999) and the [`MetricsReport`] snapshot served by the
//!   protocol-v4 `metrics` verb, renderable as Prometheus text.
//!
//! Everything is gated behind [`ObsCfg`] (`SPA_OBS` / `spa serve
//! --obs`), defaults off, and never changes computed outputs: traced
//! and untraced runs are bit-identical (asserted by the chaos suite).

pub mod metrics;
pub mod profile;
pub mod trace;

pub use metrics::{Histogram, MetricsReport};
pub use profile::{ProfileReport, ProfileRow, Profiler};
pub use trace::{chrome_json, Event, EventKind, Span, TraceBuf};

/// Runtime observability switches. `Default` is everything off — the
/// zero-overhead production posture.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ObsCfg {
    /// Record trace events into the per-thread rings.
    pub trace: bool,
}

impl ObsCfg {
    /// Tracing on.
    pub fn tracing() -> ObsCfg {
        ObsCfg { trace: true }
    }

    /// Read `SPA_OBS`: `1`/`true`/`on`/`trace` enable tracing; unset,
    /// empty, `0`, `false`, and `off` leave it disabled.
    pub fn from_env() -> ObsCfg {
        let v = std::env::var("SPA_OBS").unwrap_or_default();
        ObsCfg {
            trace: matches!(
                v.trim().to_ascii_lowercase().as_str(),
                "1" | "true" | "on" | "trace"
            ),
        }
    }

    /// Parse a CLI flag value (same grammar as `SPA_OBS`).
    pub fn from_flag(v: &str) -> ObsCfg {
        ObsCfg {
            trace: matches!(
                v.trim().to_ascii_lowercase().as_str(),
                "1" | "true" | "on" | "trace"
            ),
        }
    }

    /// Apply to the process-global trace switch.
    pub fn apply(&self) {
        trace::set_enabled(self.trace);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_cfg_flag_grammar() {
        for on in ["1", "true", "ON", "trace", " on "] {
            assert!(ObsCfg::from_flag(on).trace, "`{on}` should enable");
        }
        for off in ["", "0", "false", "off", "no"] {
            assert!(!ObsCfg::from_flag(off).trace, "`{off}` should disable");
        }
        assert_eq!(ObsCfg::default(), ObsCfg { trace: false });
        assert!(ObsCfg::tracing().trace);
    }
}
