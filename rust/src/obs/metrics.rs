//! Histogram metrics: log-linear latency histograms with exact-rank
//! percentiles, and the [`MetricsReport`] snapshot the serve layer's
//! protocol-v4 `metrics` verb ships to clients.
//!
//! [`Histogram`] replaces the old sampled percentile ring in
//! `serve::Stats`: every observation is counted (nothing is sampled
//! away), bucketed log-linearly — values below 64 land in exact
//! unit-width buckets, larger values in 64 sub-buckets per power-of-two
//! octave, bounding relative quantization error at 1/64 (~1.6%).
//! Percentiles are nearest-rank over the full count and return the
//! bucket's lower bound, so for small integer latencies (µs) they are
//! exact.
//!
//! [`MetricsReport`] is plain data (no serve dependencies): the serve
//! layer builds one from its live counters and histogram, the protocol
//! layer encodes it on the wire, and [`MetricsReport::render_prometheus`]
//! renders the Prometheus text exposition format for scraping or
//! snapshot artifacts.

use crate::util::{json::JsonObj, Json};

/// Unit-width buckets below this value (exact small-value resolution).
const LINEAR_MAX: u64 = 64;
/// Sub-buckets per power-of-two octave above [`LINEAR_MAX`].
const SUB: usize = 64;
/// Octaves tracked above the linear range. The last bucket's lower
/// bound is `(64 + 63) << 33` ≈ 1.09e12, far beyond any latency in µs
/// or stage time in ms this crate records; larger values clamp there.
const OCTAVES: usize = 34;
const BUCKETS: usize = LINEAR_MAX as usize + OCTAVES * SUB;

fn index_of(v: u64) -> usize {
    if v < LINEAR_MAX {
        v as usize
    } else {
        let p = 63 - v.leading_zeros() as usize; // >= 6
        let g = (p - 6).min(OCTAVES - 1);
        let sub = ((v >> (p - 6)) as usize - SUB).min(SUB - 1);
        LINEAR_MAX as usize + g * SUB + sub
    }
}

fn lower_bound(i: usize) -> u64 {
    if i < LINEAR_MAX as usize {
        i as u64
    } else {
        let g = (i - LINEAR_MAX as usize) / SUB;
        let sub = ((i - LINEAR_MAX as usize) % SUB) as u64;
        (LINEAR_MAX + sub) << g
    }
}

/// A log-linear histogram of `u64` observations. See the
/// [module docs](self) for the bucket scheme.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum: 0,
            max: 0,
        }
    }

    /// Count one observation. O(1), no allocation.
    pub fn record(&mut self, v: u64) {
        self.counts[index_of(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of all observations (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Largest observation recorded (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Nearest-rank percentile (`p` in 0..=100) over every recorded
    /// observation, returned as the matching bucket's lower bound —
    /// exact for values below 64, within 1/64 above. `None` when empty.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.total == 0 {
            return None;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0 * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(lower_bound(i));
            }
        }
        Some(self.max)
    }

    /// Non-empty buckets as `(upper_bound_exclusive, count)` pairs, in
    /// ascending value order — the shape Prometheus histogram series
    /// and JSON snapshots want.
    pub fn buckets(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (lower_bound(i + 1), c))
    }

    /// Merge another histogram's counts into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }
}

/// One self-contained snapshot of a server's observability state:
/// request/fault counters, plan-cache and swap activity, exact-count
/// latency percentiles, and cumulative per-stage time. Built by the
/// serve layer, shipped by the protocol-v4 `metrics` verb, rendered by
/// [`MetricsReport::render_prometheus`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsReport {
    /// Requests answered (ok or error).
    pub served: u64,
    /// Requests answered with a typed error (a subset of `served`).
    pub errors: u64,
    /// Batch-loop ticks that dispatched at least one request.
    pub batches: u64,
    /// Requests shed at admission (queue full / shutting down).
    pub shed: u64,
    /// Requests expired past their deadline before dispatch.
    pub expired: u64,
    /// Batch-group panics caught and converted to error responses.
    pub panics: u64,
    /// Plan-cache hits.
    pub cache_hits: u64,
    /// Plan-cache misses (compiles).
    pub cache_misses: u64,
    /// Plans evicted from the cache.
    pub cache_evictions: u64,
    /// Live swaps committed.
    pub swaps_committed: u64,
    /// Live swaps rolled back at any stage.
    pub swaps_rolled_back: u64,
    /// Current plan-cache generation.
    pub generation: u64,
    /// Whether the server is draining.
    pub draining: bool,
    /// Latency observations counted (served + error responses).
    pub lat_count: u64,
    /// Sum of all request latencies, µs.
    pub lat_sum_us: u64,
    /// Largest request latency, µs.
    pub lat_max_us: u64,
    /// Nearest-rank latency percentiles, µs (0 when no requests yet).
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    /// Cumulative time requests spent queued between admission and
    /// batch dispatch, ns.
    pub queue_wait_ns: u64,
    /// Cumulative time inside plan execution (batch-group inference), ns.
    pub exec_ns: u64,
    /// Cumulative batch-loop tick time (dispatch overhead incl. exec), ns.
    pub batch_ns: u64,
    /// Cumulative time inside swap pipelines, ns.
    pub swap_ns: u64,
}

impl MetricsReport {
    /// Render in the Prometheus text exposition format (counters,
    /// gauges, and a latency summary with exact-count quantiles).
    pub fn render_prometheus(&self) -> String {
        let mut s = String::with_capacity(1024);
        let mut counter = |name: &str, labels: &str, v: u64| {
            s.push_str(&format!("spa_{name}{labels} {v}\n"));
        };
        counter("requests_total", "{outcome=\"ok\"}", self.served);
        counter("requests_total", "{outcome=\"error\"}", self.errors);
        counter("batches_total", "", self.batches);
        counter("shed_total", "", self.shed);
        counter("expired_total", "", self.expired);
        counter("panics_total", "", self.panics);
        counter("cache_events_total", "{kind=\"hit\"}", self.cache_hits);
        counter("cache_events_total", "{kind=\"miss\"}", self.cache_misses);
        counter("cache_events_total", "{kind=\"evict\"}", self.cache_evictions);
        counter("swaps_total", "{outcome=\"committed\"}", self.swaps_committed);
        counter(
            "swaps_total",
            "{outcome=\"rolled_back\"}",
            self.swaps_rolled_back,
        );
        counter("generation", "", self.generation);
        counter("draining", "", self.draining as u64);
        counter("request_latency_us{quantile=\"0.5\"}", "", self.p50_us);
        counter("request_latency_us{quantile=\"0.99\"}", "", self.p99_us);
        counter("request_latency_us{quantile=\"0.999\"}", "", self.p999_us);
        counter("request_latency_us_sum", "", self.lat_sum_us);
        counter("request_latency_us_count", "", self.lat_count);
        counter("request_latency_us_max", "", self.lat_max_us);
        counter("stage_ns", "{stage=\"queue_wait\"}", self.queue_wait_ns);
        counter("stage_ns", "{stage=\"exec\"}", self.exec_ns);
        counter("stage_ns", "{stage=\"batch\"}", self.batch_ns);
        counter("stage_ns", "{stage=\"swap\"}", self.swap_ns);
        s
    }

    /// The same snapshot as a JSON object (artifact / `--json` form).
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("served", self.served as usize);
        o.insert("errors", self.errors as usize);
        o.insert("batches", self.batches as usize);
        o.insert("shed", self.shed as usize);
        o.insert("expired", self.expired as usize);
        o.insert("panics", self.panics as usize);
        o.insert("cache_hits", self.cache_hits as usize);
        o.insert("cache_misses", self.cache_misses as usize);
        o.insert("cache_evictions", self.cache_evictions as usize);
        o.insert("swaps_committed", self.swaps_committed as usize);
        o.insert("swaps_rolled_back", self.swaps_rolled_back as usize);
        o.insert("generation", self.generation as usize);
        o.insert("draining", self.draining);
        o.insert("lat_count", self.lat_count as usize);
        o.insert("lat_sum_us", self.lat_sum_us as usize);
        o.insert("lat_max_us", self.lat_max_us as usize);
        o.insert("p50_us", self.p50_us as usize);
        o.insert("p99_us", self.p99_us as usize);
        o.insert("p999_us", self.p999_us as usize);
        o.insert("queue_wait_ns", self.queue_wait_ns as usize);
        o.insert("exec_ns", self.exec_ns as usize);
        o.insert("batch_ns", self.batch_ns as usize);
        o.insert("swap_ns", self.swap_ns as usize);
        Json::from(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_have_exact_percentiles() {
        // the distribution the old sampled-ring test used: 1..=100 µs
        let mut h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.percentile(50.0), Some(50));
        assert_eq!(h.percentile(99.0), Some(99));
        assert_eq!(h.percentile(100.0), Some(100));
        assert_eq!(h.percentile(0.0), Some(1));
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 5050);
        assert_eq!(h.max(), 100);
    }

    #[test]
    fn empty_histogram_has_no_percentiles() {
        let h = Histogram::new();
        assert_eq!(h.percentile(50.0), None);
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn quantization_error_is_bounded() {
        // every value maps to a bucket whose lower bound is within 1/64
        for v in [
            1u64,
            63,
            64,
            65,
            127,
            128,
            1000,
            4097,
            1_000_000,
            123_456_789,
            u64::MAX / 2,
            u64::MAX,
        ] {
            let lb = lower_bound(index_of(v));
            assert!(lb <= v, "lower bound {lb} above value {v}");
            if index_of(v) < BUCKETS - 1 && v < (LINEAR_MAX + SUB as u64 - 1) << (OCTAVES - 1) {
                let err = (v - lb) as f64 / v.max(1) as f64;
                assert!(err <= 1.0 / 64.0 + 1e-9, "value {v}: error {err}");
            }
        }
    }

    #[test]
    fn buckets_are_monotone_and_contiguous() {
        for i in 1..BUCKETS {
            assert!(
                lower_bound(i) > lower_bound(i - 1),
                "bucket {i} not increasing"
            );
        }
        // index_of(lower_bound(i)) == i for every bucket
        for i in 0..BUCKETS {
            assert_eq!(index_of(lower_bound(i)), i, "bucket {i} round trip");
        }
    }

    #[test]
    fn heavy_tail_percentiles_rank_correctly() {
        let mut h = Histogram::new();
        for _ in 0..990 {
            h.record(10);
        }
        for _ in 0..10 {
            h.record(100_000);
        }
        assert_eq!(h.percentile(50.0), Some(10));
        assert_eq!(h.percentile(99.0), Some(10));
        let p999 = h.percentile(99.9).unwrap();
        assert!(
            (98_000..=100_000).contains(&p999),
            "p999 {p999} should land in the tail bucket"
        );
    }

    #[test]
    fn merge_combines_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for v in 1..=50u64 {
            a.record(v);
        }
        for v in 51..=100u64 {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert_eq!(a.percentile(50.0), Some(50));
        assert_eq!(a.percentile(100.0), Some(100));
    }

    #[test]
    fn prometheus_rendering_has_the_expected_series() {
        let r = MetricsReport {
            served: 12,
            errors: 3,
            p50_us: 40,
            p99_us: 90,
            p999_us: 95,
            lat_count: 15,
            queue_wait_ns: 1234,
            draining: true,
            ..Default::default()
        };
        let text = r.render_prometheus();
        for needle in [
            "spa_requests_total{outcome=\"ok\"} 12",
            "spa_requests_total{outcome=\"error\"} 3",
            "spa_request_latency_us{quantile=\"0.5\"} 40",
            "spa_request_latency_us{quantile=\"0.999\"} 95",
            "spa_request_latency_us_count 15",
            "spa_stage_ns{stage=\"queue_wait\"} 1234",
            "spa_draining 1",
        ] {
            assert!(text.contains(needle), "missing `{needle}` in:\n{text}");
        }
    }

    #[test]
    fn json_snapshot_round_trips() {
        let r = MetricsReport {
            served: 7,
            p99_us: 123,
            swap_ns: 456,
            ..Default::default()
        };
        let j = crate::util::parse_json(&r.to_json().to_string()).unwrap();
        assert_eq!(j.field("served").unwrap().as_usize(), Some(7));
        assert_eq!(j.field("p99_us").unwrap().as_usize(), Some(123));
        assert_eq!(j.field("swap_ns").unwrap().as_usize(), Some(456));
        assert_eq!(j.field("draining").unwrap().as_bool(), Some(false));
    }
}
