//! Low-overhead structured tracing: per-thread bounded ring buffers of
//! typed span events, exportable as Chrome `trace_event` JSON.
//!
//! Recording is gated on one process-global flag ([`enabled`]): when
//! tracing is off (the default), every hook costs a single relaxed
//! atomic load and allocates nothing — [`span`] returns an inert guard
//! and [`instant`] returns immediately. When on, events land in a
//! per-thread ring ([`RING_CAP`] entries; the oldest events are
//! overwritten and counted as dropped), so a misbehaving burst can
//! never grow memory without bound or block another thread.
//!
//! Event names are `&'static str` in dotted form and stable across PRs:
//! `exec.step`, `exec.compile`, `exec.recompile`, `session.prune`,
//! `batch.tick`, `queue.admit`, `queue.shed`, `cache.hit`,
//! `cache.miss`, `cache.evict`, `swap.verify`, `swap.shadow`,
//! `swap.flip`, `swap.watch`. [`drain`] collects and clears every
//! thread's ring; [`chrome_json`] renders the result in the Chrome
//! `trace_event` array format (load via `chrome://tracing` or Perfetto).

use crate::util::{json::JsonObj, relock, Json};
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Bounded capacity of each thread's event ring.
pub const RING_CAP: usize = 1 << 14;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static REGISTRY: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();

/// Whether tracing hooks record anything. The hot-path check every
/// instrumented site performs first.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn event recording on or off (process-global). Spans already in
/// flight when tracing turns off simply record nothing on drop.
pub fn set_enabled(on: bool) {
    if on {
        // pin the time base before the first event
        let _ = EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Nanoseconds since the process trace epoch (pinned on first use).
fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// How an [`Event`] renders: a duration slice or a point-in-time mark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A timed region (Chrome phase `X`).
    Span,
    /// An instantaneous mark, e.g. `cache.hit` (Chrome phase `i`).
    Instant,
}

/// One recorded trace event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Stable dotted event name (`exec.step`, `batch.tick`, ...).
    pub name: &'static str,
    /// Optional free-form detail (op name, model, plan key, ...).
    pub detail: Option<String>,
    pub kind: EventKind,
    /// Start time, nanoseconds since the trace epoch.
    pub t0_ns: u64,
    /// Duration (0 for [`EventKind::Instant`]).
    pub dur_ns: u64,
    /// Small stable id of the recording thread.
    pub tid: u64,
}

struct Ring {
    buf: Vec<Event>,
    /// Next write position once `buf` reaches [`RING_CAP`].
    next: usize,
    dropped: u64,
    tid: u64,
}

impl Ring {
    fn push(&mut self, e: Event) {
        if self.buf.len() < RING_CAP {
            self.buf.push(e);
        } else {
            self.buf[self.next] = e;
            self.next = (self.next + 1) % RING_CAP;
            self.dropped += 1;
        }
    }

    /// Events in chronological order, clearing the ring.
    fn take(&mut self) -> Vec<Event> {
        let mut out = self.buf.split_off(self.next);
        out.append(&mut self.buf);
        self.next = 0;
        out
    }
}

fn registry() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static RING: RefCell<Option<Arc<Mutex<Ring>>>> = const { RefCell::new(None) };
}

fn with_ring(f: impl FnOnce(&mut Ring)) {
    RING.with(|slot| {
        let mut slot = slot.borrow_mut();
        let arc = slot.get_or_insert_with(|| {
            let ring = Arc::new(Mutex::new(Ring {
                buf: Vec::new(),
                next: 0,
                dropped: 0,
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            }));
            relock(registry()).push(ring.clone());
            ring
        });
        f(&mut relock(arc));
    });
}

/// RAII guard for a timed region: records one [`EventKind::Span`] event
/// on drop. Inert (no clock read, no allocation) when tracing is off.
pub struct Span {
    name: &'static str,
    detail: Option<String>,
    t0: Option<u64>,
}

impl Span {
    /// Attach detail to an already-open span (only when it records).
    pub fn detail(&mut self, f: impl FnOnce() -> String) {
        if self.t0.is_some() {
            self.detail = Some(f());
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.t0 {
            let dur_ns = now_ns().saturating_sub(t0);
            let detail = self.detail.take();
            let name = self.name;
            with_ring(|r| {
                let tid = r.tid;
                r.push(Event {
                    name,
                    detail,
                    kind: EventKind::Span,
                    t0_ns: t0,
                    dur_ns,
                    tid,
                });
            });
        }
    }
}

/// Open a timed span; the event records when the guard drops.
#[inline]
pub fn span(name: &'static str) -> Span {
    Span {
        name,
        detail: None,
        t0: enabled().then(now_ns),
    }
}

/// [`span`] with a lazily-built detail string (only evaluated when
/// tracing is on).
#[inline]
pub fn span_with(name: &'static str, detail: impl FnOnce() -> String) -> Span {
    let t0 = enabled().then(now_ns);
    Span {
        name,
        detail: t0.is_some().then(detail),
        t0,
    }
}

/// Record an instantaneous mark (`cache.hit`, `queue.shed`, ...).
#[inline]
pub fn instant(name: &'static str) {
    if enabled() {
        instant_slow(name, None);
    }
}

/// [`instant`] with a lazily-built detail string.
#[inline]
pub fn instant_with(name: &'static str, detail: impl FnOnce() -> String) {
    if enabled() {
        instant_slow(name, Some(detail()));
    }
}

#[cold]
fn instant_slow(name: &'static str, detail: Option<String>) {
    let t0 = now_ns();
    with_ring(|r| {
        let tid = r.tid;
        r.push(Event {
            name,
            detail,
            kind: EventKind::Instant,
            t0_ns: t0,
            dur_ns: 0,
            tid,
        });
    });
}

/// Everything [`drain`] collected: the merged event stream plus how
/// many events ring-overflow discarded since the last drain.
#[derive(Debug, Default)]
pub struct TraceBuf {
    /// All threads' events, sorted by start time.
    pub events: Vec<Event>,
    /// Events overwritten by ring overflow (oldest-first policy).
    pub dropped: u64,
}

/// Collect and clear every thread's ring. Threads keep recording into
/// their (now empty) rings; events racing a drain land in the next one.
pub fn drain() -> TraceBuf {
    let mut buf = TraceBuf::default();
    for ring in relock(registry()).iter() {
        let mut r = relock(ring);
        buf.events.append(&mut r.take());
        buf.dropped += std::mem::take(&mut r.dropped);
    }
    buf.events.sort_by_key(|e| e.t0_ns);
    buf
}

/// Render a drained trace in Chrome `trace_event` JSON (the "JSON array
/// format" object variant: `{"traceEvents": [...]}`), loadable in
/// `chrome://tracing` and Perfetto. Timestamps are microseconds with
/// fractional nanosecond precision.
pub fn chrome_json(buf: &TraceBuf) -> Json {
    let mut events = Vec::with_capacity(buf.events.len());
    for e in &buf.events {
        let mut o = JsonObj::new();
        o.insert("name", e.name);
        o.insert("cat", "spa");
        match e.kind {
            EventKind::Span => {
                o.insert("ph", "X");
                o.insert("ts", e.t0_ns as f64 / 1000.0);
                o.insert("dur", e.dur_ns as f64 / 1000.0);
            }
            EventKind::Instant => {
                o.insert("ph", "i");
                o.insert("ts", e.t0_ns as f64 / 1000.0);
                o.insert("s", "t");
            }
        }
        o.insert("pid", 1usize);
        o.insert("tid", e.tid as usize);
        if let Some(d) = &e.detail {
            let mut args = JsonObj::new();
            args.insert("detail", d.as_str());
            o.insert("args", args);
        }
        events.push(Json::from(o));
    }
    let mut root = JsonObj::new();
    root.insert("traceEvents", events);
    root.insert("displayTimeUnit", "ns");
    if buf.dropped > 0 {
        root.insert("droppedEvents", buf.dropped as usize);
    }
    Json::from(root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::par;

    /// Trace tests share the process-global enable flag, so they hold
    /// the same lock the thread-width tests use.
    fn with_tracing<T>(f: impl FnOnce() -> T) -> T {
        let _g = par::test_lock();
        drain(); // discard anything a prior test left behind
        set_enabled(true);
        let r = f();
        set_enabled(false);
        drain();
        r
    }

    #[test]
    fn disabled_tracing_records_nothing() {
        let _g = par::test_lock();
        set_enabled(false);
        drain();
        {
            let _s = span("exec.step");
            instant("cache.hit");
        }
        assert!(drain().events.is_empty());
    }

    #[test]
    fn spans_and_instants_record_in_order() {
        let buf = with_tracing(|| {
            {
                let mut s = span("batch.tick");
                s.detail(|| "tick 7".to_string());
                instant_with("cache.miss", || "mlp".to_string());
            }
            instant("queue.admit");
            drain()
        });
        assert_eq!(buf.dropped, 0);
        let names: Vec<&str> = buf.events.iter().map(|e| e.name).collect();
        // the span records when its guard drops, after the instant inside
        assert_eq!(names, ["cache.miss", "batch.tick", "queue.admit"]);
        let tick = &buf.events[1];
        assert_eq!(tick.kind, EventKind::Span);
        assert_eq!(tick.detail.as_deref(), Some("tick 7"));
        assert!(buf.events[2].t0_ns >= tick.t0_ns);
        assert_eq!(buf.events[0].kind, EventKind::Instant);
        assert_eq!(buf.events[0].dur_ns, 0);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let buf = with_tracing(|| {
            for _ in 0..RING_CAP + 10 {
                instant("queue.admit");
            }
            drain()
        });
        assert_eq!(buf.events.len(), RING_CAP);
        assert_eq!(buf.dropped, 10);
        // chronological despite the wrap
        for w in buf.events.windows(2) {
            assert!(w[0].t0_ns <= w[1].t0_ns);
        }
    }

    #[test]
    fn chrome_json_is_well_formed() {
        let buf = with_tracing(|| {
            {
                let _s = span_with("exec.step", || "conv1".to_string());
            }
            instant("cache.hit");
            drain()
        });
        let j = chrome_json(&buf);
        // must round-trip through the crate's own JSON parser
        let parsed = crate::util::parse_json(&j.to_string()).unwrap();
        let events = parsed.field("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let step = &events[0];
        assert_eq!(step.field("name").unwrap().as_str(), Some("exec.step"));
        assert_eq!(step.field("ph").unwrap().as_str(), Some("X"));
        assert!(step.field("dur").unwrap().as_f64().is_some());
        assert_eq!(
            step.field("args").unwrap().field("detail").unwrap().as_str(),
            Some("conv1")
        );
        let mark = &events[1];
        assert_eq!(mark.field("ph").unwrap().as_str(), Some("i"));
        assert!(mark.field("ts").unwrap().as_f64().is_some());
    }
}
