//! Per-step execution profiler for compiled [`crate::exec::Plan`]s.
//!
//! A [`Profiler`] rides along `Plan::execute` (via
//! `Runner::predict_profiled` / `Plan::execute_profiled`) and
//! accumulates, per schedule step: wall nanoseconds, bytes moved
//! (inputs read + output written), GEMM dimensions for Gemm/Conv2d
//! dispatches, and the fused post-op chain — the op-level baseline the
//! ROADMAP's packed-GEMM work needs before it can claim a speedup.
//! [`Profiler::report`] aggregates everything into a [`ProfileReport`]
//! whose table ranks ops by total time; the summed per-step time is
//! checked against the end-to-end plan time, so the table provably
//! accounts for (almost) the whole run.
//!
//! Profiling is explicit opt-in per call — the plain `Plan::execute`
//! path carries no profiling state and no per-step clock reads.

use crate::exec::{Item, Plan, PostOp};
use crate::util::{json::JsonObj, Json, Table};

/// Accumulated measurements for one schedule step across runs.
#[derive(Debug, Clone, Default)]
struct StepAcc {
    calls: u64,
    wall_ns: u64,
    bytes: u64,
    gemm: Option<[usize; 3]>,
}

/// Accumulates per-step timings across one or more profiled runs of a
/// single plan. Reuse the same profiler across runs to average noise;
/// do not share one across different plans.
#[derive(Debug, Default)]
pub struct Profiler {
    steps: Vec<StepAcc>,
    runs: u64,
    total_ns: u64,
}

impl Profiler {
    pub fn new() -> Profiler {
        Profiler::default()
    }

    /// Profiled runs recorded so far.
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// End-to-end wall time across all profiled runs.
    pub fn total_ns(&self) -> u64 {
        self.total_ns
    }

    pub(crate) fn ensure(&mut self, schedule_len: usize) {
        if self.steps.len() < schedule_len {
            self.steps.resize(schedule_len, StepAcc::default());
        }
    }

    pub(crate) fn record_step(
        &mut self,
        idx: usize,
        wall_ns: u64,
        bytes: u64,
        gemm: Option<[usize; 3]>,
    ) {
        let s = &mut self.steps[idx];
        s.calls += 1;
        s.wall_ns += wall_ns;
        s.bytes += bytes;
        if gemm.is_some() {
            s.gemm = gemm;
        }
    }

    pub(crate) fn record_run(&mut self, total_ns: u64) {
        self.runs += 1;
        self.total_ns += total_ns;
    }

    /// Aggregate into a report. `plan` must be the plan the profiled
    /// runs executed (step labels come from its schedule).
    pub fn report(&self, plan: &Plan) -> ProfileReport {
        let step_ns: u64 = self.steps.iter().map(|s| s.wall_ns).sum();
        let mut rows = Vec::new();
        for (idx, item) in plan.schedule.iter().enumerate() {
            let Item::Step { op, post, .. } = item else {
                continue;
            };
            let Some(acc) = self.steps.get(idx) else {
                continue;
            };
            if acc.calls == 0 {
                continue;
            }
            let o = &plan.graph.ops[*op];
            let fused = post
                .iter()
                .map(|p| match p {
                    PostOp::Bn { .. } => "bn",
                    PostOp::Act(_) => "act",
                })
                .collect::<Vec<_>>()
                .join("+");
            rows.push(ProfileRow {
                name: o.name.clone(),
                kind: kind_label(&format!("{:?}", o.kind)),
                fused,
                calls: acc.calls,
                wall_ns: acc.wall_ns,
                pct: if step_ns > 0 {
                    acc.wall_ns as f64 * 100.0 / step_ns as f64
                } else {
                    0.0
                },
                bytes: acc.bytes,
                gemm: acc.gemm,
            });
        }
        rows.sort_by(|a, b| b.wall_ns.cmp(&a.wall_ns).then(a.name.cmp(&b.name)));
        ProfileReport {
            rows,
            runs: self.runs,
            total_ns: self.total_ns,
            step_ns,
        }
    }
}

/// `"Conv2d { stride: 2, .. }"` → `"Conv2d"`.
fn kind_label(debug: &str) -> String {
    debug.split([' ', '{']).next().unwrap_or(debug).to_string()
}

/// One aggregated table row: a schedule step (base op plus everything
/// fused into it) summed across profiled runs.
#[derive(Debug, Clone)]
pub struct ProfileRow {
    /// Base op name from the plan's graph.
    pub name: String,
    /// Base op kind (`Conv2d`, `Gemm`, ...).
    pub kind: String,
    /// Fused post-op chain (`"bn+act"`, empty when nothing fused).
    pub fused: String,
    /// Times this step executed.
    pub calls: u64,
    /// Total wall nanoseconds across all calls.
    pub wall_ns: u64,
    /// Share of the summed per-step time, percent.
    pub pct: f64,
    /// Bytes moved (inputs read + output written) across all calls.
    pub bytes: u64,
    /// GEMM dimensions `[M, K, N]` for Gemm / im2col'd Conv2d dispatches.
    pub gemm: Option<[usize; 3]>,
}

/// The aggregated profile: rows ranked by total time, plus the
/// end-to-end vs summed-step accounting.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// Per-step rows, hottest first.
    pub rows: Vec<ProfileRow>,
    /// Profiled runs aggregated.
    pub runs: u64,
    /// End-to-end plan time across all runs (includes shape inference
    /// and dispatch overhead between steps).
    pub total_ns: u64,
    /// Sum of per-step wall time across all runs.
    pub step_ns: u64,
}

impl ProfileReport {
    /// Fraction of end-to-end time the per-step rows account for
    /// (1.0 when steps explain everything; 0.0 with no runs).
    pub fn coverage(&self) -> f64 {
        if self.total_ns == 0 {
            0.0
        } else {
            self.step_ns as f64 / self.total_ns as f64
        }
    }

    /// Render as an ASCII table plus the accounting summary line.
    pub fn render(&self, title: &str) -> String {
        let mut t = Table::new(
            title,
            &["op", "kind", "fused", "calls", "us/call", "%", "KB/call", "gemm MxKxN"],
        );
        for r in &self.rows {
            let per_call_us = r.wall_ns as f64 / r.calls.max(1) as f64 / 1e3;
            let kb_per_call = r.bytes as f64 / r.calls.max(1) as f64 / 1024.0;
            t.row(&[
                r.name.clone(),
                r.kind.clone(),
                r.fused.clone(),
                r.calls.to_string(),
                format!("{per_call_us:.2}"),
                format!("{:.1}", r.pct),
                format!("{kb_per_call:.1}"),
                r.gemm
                    .map(|[m, k, n]| format!("{m}x{k}x{n}"))
                    .unwrap_or_default(),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "steps {:.3} ms / end-to-end {:.3} ms over {} run(s) — {:.1}% accounted\n",
            self.step_ns as f64 / 1e6,
            self.total_ns as f64 / 1e6,
            self.runs,
            self.coverage() * 100.0
        ));
        out
    }

    /// Machine-readable form (the `spa profile --json` artifact).
    pub fn to_json(&self) -> Json {
        let mut rows = Vec::with_capacity(self.rows.len());
        for r in &self.rows {
            let mut o = JsonObj::new();
            o.insert("op", r.name.as_str());
            o.insert("kind", r.kind.as_str());
            o.insert("fused", r.fused.as_str());
            o.insert("calls", r.calls as usize);
            o.insert("wall_ns", r.wall_ns as usize);
            o.insert("pct", r.pct);
            o.insert("bytes", r.bytes as usize);
            if let Some([m, k, n]) = r.gemm {
                o.insert("gemm", &[m, k, n][..]);
            }
            rows.push(Json::from(o));
        }
        let mut root = JsonObj::new();
        root.insert("runs", self.runs as usize);
        root.insert("total_ns", self.total_ns as usize);
        root.insert("step_ns", self.step_ns as usize);
        root.insert("coverage", self.coverage());
        root.insert("rows", rows);
        Json::from(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{PlanOpts, Runner};
    use crate::tensor::Tensor;
    use crate::util::Rng;
    use crate::zoo::{self, ImageCfg};

    fn mini() -> crate::ir::Graph {
        zoo::resnet18(
            ImageCfg {
                hw: 8,
                ..Default::default()
            },
            3,
        )
    }

    #[test]
    fn profiled_run_matches_unprofiled_bit_for_bit() {
        let g = mini();
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let mut rng = Rng::new(5);
        let shape = g.data(g.inputs[0]).shape.clone();
        let n: usize = shape.iter().product();
        let x = Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0));
        let want = plan.predict(&x).unwrap();
        let mut prof = Profiler::new();
        let mut runner = Runner::new(&plan);
        let got = runner.predict_profiled(&x, &mut prof).unwrap();
        assert_eq!(want.shape, got.shape);
        for (a, b) in want.data.iter().zip(&got.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn report_accounts_for_nearly_all_plan_time() {
        let g = mini();
        let plan = Plan::compile(&g, PlanOpts::default()).unwrap();
        let mut rng = Rng::new(6);
        let shape = g.data(g.inputs[0]).shape.clone();
        let n: usize = shape.iter().product();
        let x = Tensor::new(shape, rng.uniform_vec(n, -1.0, 1.0));
        let mut prof = Profiler::new();
        let mut runner = Runner::new(&plan);
        for _ in 0..3 {
            runner.predict_profiled(&x, &mut prof).unwrap();
        }
        let rep = prof.report(&plan);
        assert_eq!(rep.runs, 3);
        assert_eq!(rep.rows.len(), plan.report().steps);
        assert!(rep.step_ns > 0 && rep.step_ns <= rep.total_ns);
        // the per-step sum must be ≈ the end-to-end time: dispatch
        // bookkeeping between steps is a thin slice of the run
        assert!(
            rep.coverage() > 0.5,
            "steps account for only {:.1}% of the run",
            rep.coverage() * 100.0
        );
        // resnet18 must attribute GEMM dims to conv and gemm steps and
        // show bn/act fusion on at least one row
        assert!(rep.rows.iter().any(|r| r.gemm.is_some()));
        assert!(rep.rows.iter().any(|r| r.fused.contains("bn")));
        assert!(rep.rows.iter().all(|r| r.calls == 3));
        let table = rep.render("profile resnet18");
        assert!(table.contains("gemm MxKxN"));
        assert!(table.contains("accounted"));
        let j = crate::util::parse_json(&rep.to_json().to_string()).unwrap();
        assert_eq!(j.field("runs").unwrap().as_usize(), Some(3));
        assert!(!j.field("rows").unwrap().as_arr().unwrap().is_empty());
    }
}
