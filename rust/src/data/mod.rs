//! Synthetic datasets — the sandbox substitution for CIFAR-10/100,
//! ImageNette/ImageNet-1k, and SST-2 (DESIGN.md §Substitutions).
//!
//! Images are class-conditional smooth random fields (a few random
//! sinusoids per class) plus per-sample noise and amplitude jitter: real
//! learnable signal with intra-class variation, so accuracy-vs-pruning
//! trade-offs behave qualitatively like natural-image benchmarks. Text
//! is class-conditional token distributions (sentiment-bearing vocab
//! halves) — enough for a DistilBERT-mini to learn a nontrivial
//! classifier. Different seeds/class-counts give mutually-OOD datasets,
//! mirroring the paper's CIFAR-10 ↔ CIFAR-100 OOD protocol.

use crate::tensor::Tensor;
use crate::util::Rng;

/// A labelled image dataset with train/test split.
pub struct ImageDataset {
    pub classes: usize,
    pub channels: usize,
    pub hw: usize,
    train_x: Vec<f32>,
    train_y: Vec<usize>,
    test_x: Vec<f32>,
    test_y: Vec<usize>,
}

impl ImageDataset {
    /// Class-conditional synthetic dataset. `n` = train samples; a
    /// further `n/4` test samples are drawn from the same generator.
    pub fn synth_cifar(classes: usize, n: usize, hw: usize, channels: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
        // per-class template: sum of K random sinusoids per channel
        let kfreq = 3;
        let mut templates = vec![0.0f32; classes * channels * hw * hw];
        for cls in 0..classes {
            for ch in 0..channels {
                for _ in 0..kfreq {
                    let fx = rng.range(0.5, 3.0);
                    let fy = rng.range(0.5, 3.0);
                    let px = rng.range(0.0, std::f32::consts::TAU);
                    let py = rng.range(0.0, std::f32::consts::TAU);
                    let amp = rng.range(0.4, 1.0);
                    for y in 0..hw {
                        for x in 0..hw {
                            let v = amp
                                * ((fx * x as f32 / hw as f32 * std::f32::consts::TAU + px).sin()
                                    + (fy * y as f32 / hw as f32 * std::f32::consts::TAU + py)
                                        .cos());
                            templates[((cls * channels + ch) * hw + y) * hw + x] += v * 0.5;
                        }
                    }
                }
            }
        }
        let img = channels * hw * hw;
        let gen = |rng: &mut Rng, count: usize| -> (Vec<f32>, Vec<usize>) {
            let mut xs = Vec::with_capacity(count * img);
            let mut ys = Vec::with_capacity(count);
            for _ in 0..count {
                let cls = rng.below(classes);
                // strong per-sample variation keeps the task non-trivial:
                // amplitude jitter, a random spatial shift of the template,
                // and heavy pixel noise
                let alpha = rng.range(0.5, 1.4);
                let (dx, dy) = (rng.below(3), rng.below(3));
                let base = cls * img;
                for ch in 0..channels {
                    for y in 0..hw {
                        for x in 0..hw {
                            let sy = (y + dy) % hw;
                            let sx = (x + dx) % hw;
                            let v = templates[base + (ch * hw + sy) * hw + sx];
                            xs.push(v * alpha + rng.normal() * 0.8);
                        }
                    }
                }
                ys.push(cls);
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen(&mut rng, n);
        let (test_x, test_y) = gen(&mut rng, (n / 4).max(32));
        ImageDataset {
            classes,
            channels,
            hw,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }

    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    fn img(&self) -> usize {
        self.channels * self.hw * self.hw
    }

    /// Random training batch.
    pub fn train_batch(&self, rng: &mut Rng, bs: usize) -> (Tensor, Vec<usize>) {
        let img = self.img();
        let mut xs = Vec::with_capacity(bs * img);
        let mut ys = Vec::with_capacity(bs);
        for _ in 0..bs {
            let i = rng.below(self.train_len());
            xs.extend_from_slice(&self.train_x[i * img..(i + 1) * img]);
            ys.push(self.train_y[i]);
        }
        (
            Tensor::new(vec![bs, self.channels, self.hw, self.hw], xs),
            ys,
        )
    }

    /// Deterministic batch (for calibration sets).
    pub fn train_batch_seeded(&self, seed: u64, bs: usize) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(seed ^ 0xBA7C4);
        self.train_batch(&mut rng, bs)
    }

    /// Sequential test batch starting at `offset`.
    pub fn test_batch(&self, offset: usize, bs: usize) -> (Tensor, Vec<usize>) {
        let img = self.img();
        let bs = bs.min(self.test_len().saturating_sub(offset)).max(1);
        let mut xs = Vec::with_capacity(bs * img);
        let mut ys = Vec::with_capacity(bs);
        for i in offset..offset + bs {
            xs.extend_from_slice(&self.test_x[i * img..(i + 1) * img]);
            ys.push(self.test_y[i]);
        }
        (
            Tensor::new(vec![bs, self.channels, self.hw, self.hw], xs),
            ys,
        )
    }
}

/// A labelled token-sequence dataset (synthetic SST-2).
pub struct TextDataset {
    pub classes: usize,
    pub vocab: usize,
    pub seq: usize,
    train_x: Vec<f32>,
    train_y: Vec<usize>,
    test_x: Vec<f32>,
    test_y: Vec<usize>,
}

impl TextDataset {
    /// Sentiment-style task: class k draws `signal_frac` of its tokens
    /// from the k-th vocab stripe, the rest uniformly.
    pub fn synth_sst(classes: usize, n: usize, seq: usize, vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x7E47);
        let stripe = vocab / classes;
        let gen = |rng: &mut Rng, count: usize| -> (Vec<f32>, Vec<usize>) {
            let mut xs = Vec::with_capacity(count * seq);
            let mut ys = Vec::with_capacity(count);
            for _ in 0..count {
                let cls = rng.below(classes);
                for _ in 0..seq {
                    let tok = if rng.uniform() < 0.6 {
                        cls * stripe + rng.below(stripe)
                    } else {
                        rng.below(vocab)
                    };
                    xs.push(tok as f32);
                }
                ys.push(cls);
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen(&mut rng, n);
        let (test_x, test_y) = gen(&mut rng, (n / 4).max(32));
        TextDataset {
            classes,
            vocab,
            seq,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }

    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    pub fn train_batch(&self, rng: &mut Rng, bs: usize) -> (Tensor, Vec<usize>) {
        let mut xs = Vec::with_capacity(bs * self.seq);
        let mut ys = Vec::with_capacity(bs);
        for _ in 0..bs {
            let i = rng.below(self.train_len());
            xs.extend_from_slice(&self.train_x[i * self.seq..(i + 1) * self.seq]);
            ys.push(self.train_y[i]);
        }
        (Tensor::new(vec![bs, self.seq], xs), ys)
    }

    pub fn train_batch_seeded(&self, seed: u64, bs: usize) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(seed ^ 0x5E9);
        self.train_batch(&mut rng, bs)
    }

    pub fn test_batch(&self, offset: usize, bs: usize) -> (Tensor, Vec<usize>) {
        let bs = bs.min(self.test_len().saturating_sub(offset)).max(1);
        let mut xs = Vec::with_capacity(bs * self.seq);
        let mut ys = Vec::with_capacity(bs);
        for i in offset..offset + bs {
            xs.extend_from_slice(&self.test_x[i * self.seq..(i + 1) * self.seq]);
            ys.push(self.test_y[i]);
        }
        (Tensor::new(vec![bs, self.seq], xs), ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_batches_shaped() {
        let ds = ImageDataset::synth_cifar(10, 256, 8, 3, 1);
        let mut rng = Rng::new(2);
        let (x, y) = ds.train_batch(&mut rng, 16);
        assert_eq!(x.shape, vec![16, 3, 8, 8]);
        assert_eq!(y.len(), 16);
        assert!(y.iter().all(|&c| c < 10));
        let (tx, ty) = ds.test_batch(0, 32);
        assert_eq!(tx.shape[0], 32);
        assert_eq!(ty.len(), 32);
    }

    #[test]
    fn classes_are_separable() {
        // nearest-template classification on clean data should beat chance
        // by a wide margin — the dataset carries real signal
        let ds = ImageDataset::synth_cifar(4, 400, 8, 3, 3);
        let img = 3 * 8 * 8;
        // estimate class means from train data
        let mut means = vec![vec![0.0f32; img]; 4];
        let mut counts = vec![0usize; 4];
        for i in 0..ds.train_len() {
            let c = ds.train_y[i];
            counts[c] += 1;
            for j in 0..img {
                means[c][j] += ds.train_x[i * img + j];
            }
        }
        for c in 0..4 {
            for v in &mut means[c] {
                *v /= counts[c].max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..ds.test_len() {
            let x = &ds.test_x[i * img..(i + 1) * img];
            let mut best = (0usize, f32::INFINITY);
            for c in 0..4 {
                let d: f32 = x
                    .iter()
                    .zip(&means[c])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best.1 {
                    best = (c, d);
                }
            }
            if best.0 == ds.test_y[i] {
                correct += 1;
            }
        }
        let acc = correct as f32 / ds.test_len() as f32;
        assert!(acc > 0.7, "template accuracy only {acc}");
    }

    #[test]
    fn different_seeds_are_different_distributions() {
        let a = ImageDataset::synth_cifar(10, 64, 8, 3, 1);
        let b = ImageDataset::synth_cifar(10, 64, 8, 3, 2);
        let d: f32 = a
            .train_x
            .iter()
            .zip(&b.train_x)
            .map(|(x, y)| (x - y).abs())
            .sum::<f32>()
            / a.train_x.len() as f32;
        assert!(d > 0.1, "seeds produced near-identical data");
    }

    #[test]
    fn text_batches_valid_tokens() {
        let ds = TextDataset::synth_sst(2, 128, 12, 64, 5);
        let mut rng = Rng::new(6);
        let (x, y) = ds.train_batch(&mut rng, 8);
        assert_eq!(x.shape, vec![8, 12]);
        assert!(x.data.iter().all(|&t| t >= 0.0 && t < 64.0));
        assert!(y.iter().all(|&c| c < 2));
    }

    #[test]
    fn text_classes_statistically_distinct() {
        let ds = TextDataset::synth_sst(2, 512, 12, 64, 7);
        // class-0 samples should use tokens < 32 more often
        let mut frac0 = [0.0f32; 2];
        let mut counts = [0usize; 2];
        for i in 0..ds.train_len() {
            let c = ds.train_y[i];
            counts[c] += 1;
            let low = ds.train_x[i * 12..(i + 1) * 12]
                .iter()
                .filter(|&&t| t < 32.0)
                .count();
            frac0[c] += low as f32 / 12.0;
        }
        for c in 0..2 {
            frac0[c] /= counts[c].max(1) as f32;
        }
        assert!(frac0[0] > frac0[1] + 0.2, "{frac0:?}");
    }
}
