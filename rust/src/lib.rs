//! # SPA — Structurally Prune Anything
//!
//! A Rust + JAX + Pallas reproduction of *"Structurally Prune Anything:
//! Any Architecture, Any Framework, Any Time"* (2024).
//!
//! * **Any architecture** — [`ir`] is a standardized computational graph
//!   (operator / data / parameter nodes, the paper's ONNX analog);
//!   [`prune`] discovers coupled channels by mask propagation with per-
//!   operator rules, groups them, and structurally deletes them for any
//!   topology (residual, concat/dense, group/depthwise conv, attention).
//! * **Any framework** — [`frontends`] normalizes heterogeneous framework
//!   dialect exports (torch-like NCHW, tf-like NHWC-fused, jax-like,
//!   mxnet-like) into SPA-IR, mirroring the paper's ONNX funnel.
//! * **Any speed** — [`exec`] compiles a (pruned) graph once into a
//!   reusable execution plan: topologically scheduled kernels over a
//!   liveness-managed buffer arena, fused Conv→BN→Act chains, and
//!   deterministic batched inference — bit-identical to the [`engine`]
//!   interpreter, which remains the autodiff/training substrate.
//! * **Any traffic** — [`serve`] is a batching inference server over
//!   compiled plans: length-prefixed TCP, a deadline-aware dynamic
//!   batcher that coalesces concurrent requests into one dispatch per
//!   tick, and a process-global plan cache keyed by
//!   `(model, prune config, OptLevel)`.
//! * **Any confidence** — [`check`] statically verifies all of the above:
//!   shape/dtype abstract interpretation over the IR, prune-coupling
//!   invariants (every coupled group keeps one channel set), and
//!   compiled-plan arena/alias safety — gated by [`CheckLevel`] and
//!   surfaced as the `spa lint` CLI subcommand.
//! * **Any visibility** — [`obs`] watches all of it run: structured
//!   trace spans across exec/serve (Chrome `trace_event` export), an
//!   opt-in per-step plan profiler (`spa profile`), and log-linear
//!   latency histograms behind the serve protocol's `metrics` verb —
//!   all off by default with a one-atomic-load disabled path.
//! * **Any time** — [`session`] is the single user-facing entry point:
//!   a staged builder over the four-step algorithm, with pluggable
//!   [`criteria::Saliency`] scores; [`coordinator`] drives prune-train,
//!   train-prune-finetune, and train-prune pipelines through it;
//!   [`criteria`] transfers magnitude / SNIP / GraSP / CroP scores into
//!   grouped structured form (Eq. 1); [`obspa`] implements the paper's
//!   OBSPA data-free reconstruction, whose hot kernels are AOT-compiled
//!   Pallas programs executed through [`runtime`] (PJRT).

pub mod analysis;
pub mod baselines;
pub mod check;
pub mod coordinator;
pub mod criteria;
pub mod data;
pub mod engine;
pub mod exec;
pub mod frontends;
pub mod ir;
pub mod obs;
pub mod obspa;
pub mod prune;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod tensor;
pub mod train;
pub mod util;
pub mod zoo;

pub use check::CheckLevel;
pub use session::{Plan, PlanKey, PruneReport, PrunedModel, Session, Target};
