//! Paper Tab. 4 — pruning WITHOUT fine-tuning: ResNet-50 and VGG-19 on
//! CIFAR-10/100, DFPC vs OBSPA (ID / OOD / DataFree). The headline
//! train-prune result of the paper.

#[path = "common.rs"]
mod common;

use spa::coordinator::NoFinetuneAlgo;
use spa::train;
use spa::util::Table;
use spa::zoo;

fn main() {
    let mut t = Table::new(
        "Tab. 4 — no-finetune pruning (mini models / SynthCIFAR)",
        &["dataset", "model", "method", "acc. drop", "RF", "RP", "paper drop / RF"],
    );
    let paper: &[(&str, &str, &[(&str, &str)])] = &[
        ("CIFAR-10", "resnet50", &[
            ("DFPC", "-4.74% / 1.46x"),
            ("OBSPA (ID)", "-0.95% / 1.48x"),
            ("OBSPA (OOD)", "-1.13% / 1.48x"),
            ("OBSPA (DataFree)", "-1.34% / 1.48x"),
        ]),
        ("CIFAR-10", "vgg19", &[
            ("DFPC", "-3.38% / 1.68x"),
            ("OBSPA (ID)", "-0.99% / 1.71x"),
            ("OBSPA (OOD)", "-1.67% / 1.73x"),
            ("OBSPA (DataFree)", "-1.64% / 1.80x"),
        ]),
        ("CIFAR-100", "resnet50", &[
            ("DFPC", "-8.53% / 1.27x"),
            ("OBSPA (ID)", "-3.73% / 1.46x"),
            ("OBSPA (OOD)", "-3.70% / 1.47x"),
            ("OBSPA (DataFree)", "-5.24% / 1.37x"),
        ]),
        ("CIFAR-100", "vgg19", &[
            ("DFPC", "-1.92% / 1.26x"),
            ("OBSPA (ID)", "-0.80% / 1.54x"),
            ("OBSPA (OOD)", "-1.13% / 1.54x"),
            ("OBSPA (DataFree)", "-1.59% / 1.47x"),
        ]),
    ];
    for (dsname, model, rows) in common::take_smoke(paper.to_vec()) {
        let (ds, ood) = if dsname == "CIFAR-10" {
            (common::synth_cifar10(81), common::synth_cifar100(82))
        } else {
            (common::synth_cifar100(83), common::synth_cifar10(84))
        };
        let g0 = zoo::by_name(model, common::cifar_cfg(ds.classes), 9).unwrap();
        let base = common::train_base(g0, &ds, 220);
        let base_acc = train::evaluate(&base, &ds, 256).unwrap();
        let target_rf = 1.5f64;
        let algos: [(&str, NoFinetuneAlgo); 4] = [
            ("DFPC", common::DFPC),
            ("OBSPA (ID)", common::OBSPA_ID),
            ("OBSPA (OOD)", common::OBSPA_OOD),
            ("OBSPA (DataFree)", common::OBSPA_DF),
        ];
        for (i, (name, algo)) in algos.into_iter().enumerate() {
            let rep = common::no_finetune(base.clone(), &ds, Some(&ood), algo, target_rf);
            t.row(&[
                dsname.to_string(),
                model.to_string(),
                name.to_string(),
                format!("{:+.2}%", (rep.final_acc - base_acc) * 100.0),
                common::ratio(rep.rf),
                common::ratio(rep.rp),
                rows[i].1.to_string(),
            ]);
        }
    }
    t.print();
    println!("shape to check (paper Tab. 4): OBSPA drop ≪ DFPC drop at matched RF;");
    println!("ID ≤ OOD ≤ DataFree drops.");
}
