//! Microbench: GraphPatch surgery and the live re-prune path — the
//! optimize passes as patches, deriving and applying a session re-prune
//! patch, incremental plan recompile vs a full compile (with a
//! bit-identity parity gate), and an end-to-end `Server::swap`.

#[path = "common.rs"]
mod common;

use spa::criteria::Criterion;
use spa::exec::{Plan, PlanOpts};
use spa::ir::patch::optimize_as_patches;
use spa::serve::{ServeCfg, Server, SwapOutcome, SwapRequest};
use spa::tensor::Tensor;
use spa::util::{bench, Rng, Table};
use spa::zoo;
use spa::{CheckLevel, Session, Target};
use std::time::Duration;

const SEED: u64 = 1;

fn main() {
    let image = common::cifar_cfg(10);
    let g = zoo::by_name("resnet18", image, SEED).unwrap();
    let iters = common::iters(20);
    let warmup = common::warmup(2);

    // the optimize passes, re-expressed as localized patches
    bench("patch/optimize_as_patches", warmup, iters, || {
        let mut gg = g.clone();
        let reps = optimize_as_patches(&mut gg, CheckLevel::Off).unwrap();
        assert!(!reps.is_empty(), "resnet18 must yield optimize patches");
    });

    // a session re-prune, derived and applied as a patch
    let sess = Session::on(&g)
        .criterion(Criterion::L1)
        .target(Target::FlopsRf(1.3))
        .check(CheckLevel::Off)
        .plan()
        .unwrap();
    bench("patch/derive_apply", warmup, iters, || {
        let patch = sess.as_patch(&g).unwrap();
        let mut patched = g.clone();
        patch.apply_checked(&mut patched, CheckLevel::Off).unwrap();
    });

    let patch = sess.as_patch(&g).unwrap();
    let mut patched = g.clone();
    let prep = patch.apply_checked(&mut patched, CheckLevel::Off).unwrap();
    let old = Plan::compile(&g, PlanOpts::default()).unwrap();

    // incremental recompile of the serving plan vs compiling from scratch
    let mut incremental = None;
    bench("patch/recompile", warmup, iters, || {
        incremental = Some(old.recompile(&patched, &prep, PlanOpts::default()).unwrap());
    });
    let mut scratch = None;
    bench("patch/full_compile", warmup, iters, || {
        scratch = Some(Plan::compile(&patched, PlanOpts::default()).unwrap());
    });
    let (inc, full) = (incremental.unwrap(), scratch.unwrap());

    // parity gate: the incremental plan must be bit-identical
    let mut rng = Rng::new(7);
    let numel = image.channels * image.hw * image.hw;
    let x = Tensor::new(
        vec![1, image.channels, image.hw, image.hw],
        rng.uniform_vec(numel, -1.0, 1.0),
    );
    let a = inc.predict(&x).unwrap();
    let b = full.predict(&x).unwrap();
    assert_eq!(a.shape, b.shape, "recompile shape drift");
    for (u, v) in a.data.iter().zip(&b.data) {
        assert_eq!(u.to_bits(), v.to_bits(), "recompile must be bit-identical");
    }

    let pr = inc.report();
    let mut t = Table::new(
        "micro — patch: incremental recompile reuse (resnet18, rf 1.3)",
        &["steps", "reused", "regions", "reuse %"],
    );
    t.row(&[
        pr.steps.to_string(),
        pr.reused_steps.to_string(),
        pr.recompiled_regions.to_string(),
        format!("{:.0}%", pr.reuse_ratio() * 100.0),
    ]);
    t.print();

    // the live path end-to-end: verified zero-downtime swaps on a
    // quiet server (each round re-prunes the current serving graph)
    let server = Server::spawn(ServeCfg {
        tick: Duration::from_millis(1),
        image,
        seed: SEED,
        ..Default::default()
    })
    .expect("server spawn");
    let mut rf = 1.2;
    bench("swap/live", 0, common::iters(4), || {
        rf += 0.05;
        let rep = server
            .swap(&SwapRequest {
                model: "mlp".to_string(),
                target_rf: rf,
                criterion: "l1".to_string(),
                shadow: 2,
                max_divergence: f64::INFINITY,
            })
            .expect("swap");
        assert_eq!(rep.outcome, SwapOutcome::Committed, "{}", rep.message);
    });
    server.shutdown();
}
