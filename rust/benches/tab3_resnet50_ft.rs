//! Paper Tab. 3 — ResNet-50 on ImageNet *with* fine-tuning: SPA-L1 at two
//! compression points and OBSPA(+finetune) against DFPC and an
//! ungrouped-structured proxy for DepGraph/OTO-v2.

#[path = "common.rs"]
mod common;

use spa::criteria::Criterion;
use spa::obspa::{self, ObspaCfg};
use spa::prune::Scope;
use spa::train::{self, TrainCfg};
use spa::util::Table;
use spa::zoo;
use spa::{Session, Target};

fn finetune(g: &mut spa::ir::Graph, ds: &spa::data::ImageDataset) {
    train::train(
        g,
        ds,
        &TrainCfg {
            steps: common::steps(80),
            lr: 0.02,
            log_every: 0,
            ..Default::default()
        },
    )
    .unwrap();
}

fn main() {
    let ds = common::synth_imagenet(61);
    let base = common::train_base(zoo::resnet50(common::cifar_cfg(20), 9), &ds, 200);
    let base_acc = train::evaluate(&base, &ds, 384).unwrap();
    let mut t = Table::new(
        "Tab. 3 — resnet50-mini / SynthImageNet with fine-tuning",
        &["method", "top1 acc.", "top5 acc.", "RF", "RP", "paper top1 / RF"],
    );
    let top5 = |g: &spa::ir::Graph| {
        let (x, y) = ds.test_batch(0, 256);
        let logits = spa::engine::predict(g, x).unwrap();
        spa::tensor::ops::topk_accuracy(&logits, &y, 5)
    };
    t.row(&[
        "Base Model".into(),
        common::pct(base_acc),
        common::pct(top5(&base)),
        "1x".into(),
        "1x".into(),
        "76.15% / 1x".into(),
    ]);
    // DFPC + finetune
    {
        let mut g = base.clone();
        spa::baselines::dfpc_prune(&mut g, 2.0, 1).unwrap();
        finetune(&mut g, &ds);
        let acc = train::evaluate(&g, &ds, 384).unwrap();
        let r = spa::analysis::reduction(&base, &g);
        t.row(&[
            "DFPC".into(),
            common::pct(acc),
            common::pct(top5(&g)),
            common::ratio(r.rf),
            common::ratio(r.rp),
            "75.83% / 1.98x".into(),
        ]);
    }
    // ungrouped structured L1 (DepGraph/OTO-v2 proxy)
    {
        let pruned = Session::on(&base)
            .criterion(Criterion::L1)
            .scope(Scope::SourceOnly)
            .target(Target::FlopsRf(2.1))
            .plan()
            .unwrap()
            .apply()
            .unwrap();
        let mut g = pruned.graph;
        finetune(&mut g, &ds);
        let acc = train::evaluate(&g, &ds, 384).unwrap();
        t.row(&[
            "ungrouped-L1 (DepGraph proxy)".into(),
            common::pct(acc),
            common::pct(top5(&g)),
            common::ratio(pruned.report.rf),
            common::ratio(pruned.report.rp),
            "75.83% / 2.07x (DepGraph)".into(),
        ]);
    }
    // SPA-L1 at two compression points
    for (rf, paper) in [(2.8f64, "74.83% / 2.84x"), (2.2, "76.39% / 2.18x")] {
        let pruned = Session::on(&base)
            .criterion(Criterion::L1)
            .target(Target::FlopsRf(rf))
            .plan()
            .unwrap()
            .apply()
            .unwrap();
        let mut g = pruned.graph;
        finetune(&mut g, &ds);
        let acc = train::evaluate(&g, &ds, 384).unwrap();
        t.row(&[
            format!("SPA-L1 (RF {rf:.1})"),
            common::pct(acc),
            common::pct(top5(&g)),
            common::ratio(pruned.report.rf),
            common::ratio(pruned.report.rp),
            paper.to_string(),
        ]);
    }
    // OBSPA + finetune
    {
        let mut g = base.clone();
        let (calib, _) = ds.train_batch_seeded(11, 128);
        obspa::obspa_prune(
            &mut g,
            &calib,
            &ObspaCfg {
                target_rf: 2.2,
                ..Default::default()
            },
        )
        .unwrap();
        finetune(&mut g, &ds);
        let acc = train::evaluate(&g, &ds, 384).unwrap();
        let r = spa::analysis::reduction(&base, &g);
        t.row(&[
            "OBSPA + finetune".into(),
            common::pct(acc),
            common::pct(top5(&g)),
            common::ratio(r.rf),
            common::ratio(r.rp),
            "76.59% / 2.22x".into(),
        ]);
    }
    t.print();
    println!("shape to check: SPA-L1/OBSPA ≥ DFPC & ungrouped proxy at matched RF");
}
