//! Microbench: interpreter (`engine::forward`) vs compiled plan
//! (`exec::Plan`) on eval inference — the ISSUE-5 acceptance case.
//!
//! The plan must be bit-identical (asserted before timing) while winning
//! on wall-clock through batched-GEMM convolution, fused Conv→BN→Act
//! chains, and the zero-allocation buffer arena. Both paths are emitted
//! to `BENCH_SMOKE.json` in the CI smoke lane so the speedup is tracked
//! PR-over-PR.

#[path = "common.rs"]
mod common;

use spa::engine::{self, Mode};
use spa::exec::{Plan, PlanOpts};
use spa::ir::Graph;
use spa::tensor::Tensor;
use spa::util::{bench, Rng, Table};
use spa::zoo::{self, TextCfg};

fn compare(t: &mut Table, label: &str, g: &Graph, x: &Tensor, iters: usize) {
    let plan = Plan::compile(g, PlanOpts::default()).unwrap();
    let mut runner = plan.runner();
    // parity gate before timing: identical bits or the comparison is void
    let want = engine::forward(g, &[(g.inputs[0], x.clone())], Mode::Eval)
        .unwrap()
        .logits(g)
        .clone();
    let got = runner.run(&[(g.inputs[0], x)]).unwrap();
    assert_eq!(want.shape, got.shape, "{label}: shape drift");
    for (a, b) in want.data.iter().zip(&got.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: plan must be bit-identical");
    }
    let fi = bench(
        &format!("exec/{label}/interp"),
        common::warmup(2),
        common::iters(iters),
        || {
            let _ = engine::forward(g, &[(g.inputs[0], x.clone())], Mode::Eval).unwrap();
        },
    );
    let fp = bench(
        &format!("exec/{label}/plan"),
        common::warmup(2),
        common::iters(iters),
        || {
            let _ = runner.run(&[(g.inputs[0], x)]).unwrap();
        },
    );
    let r = plan.report();
    t.row(&[
        label.to_string(),
        format!("{}", x.shape[0]),
        format!("{:.3}", fi.mean_ms()),
        format!("{:.3}", fp.mean_ms()),
        format!("{:.2}x", fi.mean_ns / fp.mean_ns),
        format!("{}/{}", r.peak_arena_bytes, r.interp_intermediate_bytes),
    ]);
}

fn main() {
    let mut t = Table::new(
        "micro — exec: interpreter vs compiled plan (eval, bit-identical)",
        &["model", "batch", "interp (ms)", "plan (ms)", "speedup", "arena/interp bytes"],
    );
    let mut rng = Rng::new(3);

    let g = zoo::by_name("resnet18", common::cifar_cfg(10), 3).unwrap();
    let x = Tensor::new(vec![32, 3, 8, 8], rng.uniform_vec(32 * 3 * 64, -1.0, 1.0));
    compare(&mut t, "resnet18", &g, &x, 10);

    let tcfg = TextCfg::default();
    let gt = zoo::distilbert(tcfg, 4);
    let ids = Tensor::new(
        vec![16, tcfg.seq],
        (0..16 * tcfg.seq)
            .map(|_| rng.below(tcfg.vocab) as f32)
            .collect(),
    );
    compare(&mut t, "distilbert", &gt, &ids, 10);

    t.print();
}
