//! Paper Tab. 1 — "Prune Any Framework": ResNet-18 trained in four
//! frameworks, converted, pruned ~2× with SPA-L1, fine-tuned.
//! Here: resnet18-mini trained per dialect (independent seeds), exported
//! in the dialect's idiom, imported through the SPA-IR funnel, pruned.

#[path = "common.rs"]
mod common;

use spa::criteria::Criterion;
use spa::frontends::{export_model, import_model, Dialect};
use spa::prune::Scope;
use spa::util::Table;
use spa::zoo;

fn main() {
    let ds = common::synth_cifar10(41);
    let mut t = Table::new(
        "Tab. 1 — SPA from 4 frameworks, ResNet-18 (paper: ImageNette; here: SynthCIFAR-10)",
        &["framework", "ori acc.", "pruned acc.", "RF", "RP", "paper ori→pruned / RF"],
    );
    let paper = [
        ("torch", "83.11% → 82.96% / 2.16x"),
        ("tf", "82.62% → 84.30% / 1.94x"),
        ("mxnet", "84.36% → 82.77% / 1.83x"),
        ("jax", "84.46% → 83.33% / 2.26x"),
    ];
    for (i, d) in [Dialect::Torch, Dialect::Tf, Dialect::Mxnet, Dialect::Jax]
        .into_iter()
        .enumerate()
    {
        // "trained in framework X": independent init + training per dialect
        let src = zoo::resnet18(common::cifar_cfg(10), 100 + i as u64);
        let imported = import_model(&export_model(&src, d)).expect("import");
        let rep = common::tpf(imported, &ds, Criterion::L1, Scope::FullCc, 2.0, 1);
        t.row(&[
            d.name().to_string(),
            common::pct(rep.ori_acc),
            common::pct(rep.final_acc),
            common::ratio(rep.rf),
            common::ratio(rep.rp),
            paper[i].1.to_string(),
        ]);
    }
    t.print();
    println!("shape to check: every framework imports + prunes to ~2x RF with small acc delta");
}
